//! End-to-end telemetry consistency: a daemon timeline with arrivals,
//! drift verdicts, and a retirement runs with a [`TelemetryStore`]
//! attached, and every query answer is checked against the ground truth
//! the daemon itself reports — the journal, the drained [`FleetReport`],
//! and the adaptive epoch summaries. Within the retention window the
//! store is lossless, so the agreement is exact, not approximate.

use std::collections::BTreeMap;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{
    journal_json, sim_fleet, AdaptiveConfig, DriftVerdict, FleetConfig, FleetDaemon,
    FleetJobSpec, FleetReport, FleetSession, JournalEntry, Query, TelemetryServer,
    TelemetryStore,
};
use streamprof::simulator::{node, Algo};
use streamprof::stream::ArrivalProcess;
use streamprof::util::json::{self, Json};

fn quick_cfg(workers: usize, rounds: usize) -> FleetConfig {
    FleetConfig {
        workers,
        rounds,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        probe_workers: 0,
        ..FleetConfig::default()
    }
}

/// Sum of the aggregate values across every series the expression matches.
fn agg(store: &TelemetryStore, expr: &str) -> f64 {
    let result = Query::parse(expr).expect("query parses").run(store);
    result.series.iter().filter_map(|s| s.value).sum()
}

/// Every in-window point of every series the expression matches.
fn points(store: &TelemetryStore, expr: &str) -> Vec<(u64, f64)> {
    let result = Query::parse(expr).expect("query parses").run(store);
    result.series.iter().flat_map(|s| s.points.clone()).collect()
}

/// The canonical mixed timeline: four jobs bootstrap at tick 0, a fifth
/// arrives mid-run, two drift verdicts trigger re-profiles, one verdict
/// is stable, and one job retires. Returns the attached store, the
/// journal captured before draining, and the drained report.
fn scenario() -> (Arc<TelemetryStore>, Vec<JournalEntry>, FleetReport) {
    let store = Arc::new(TelemetryStore::new());
    let mut daemon = FleetDaemon::builder()
        .config(quick_cfg(2, 1))
        .jobs(sim_fleet(4, 7))
        .rebalance(true)
        .telemetry(store.clone())
        .build();
    for job in sim_fleet(5, 7).into_iter().skip(4) {
        daemon.submit_at(job, 600);
    }
    daemon.observe_verdict_at("job-01", DriftVerdict::ModelStale { rolling_smape: 0.8 }, 700);
    let shift = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 9.0 };
    daemon.observe_verdict_at("job-02", shift, 800);
    daemon.observe_verdict_at("job-03", DriftVerdict::Stable, 800);
    daemon.retire_at("job-00", 900);
    daemon.run_until(900).expect("timeline runs");
    let journal = daemon.journal().to_vec();
    let report = daemon.drain().expect("daemon drains");
    (store, journal, report)
}

/// Minimal GET over a raw socket; returns the response body.
fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect");
    write!(stream, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")
        .expect("request");
    let mut raw = String::new();
    stream.read_to_string(&mut raw).expect("response");
    raw.split("\r\n\r\n").nth(1).unwrap_or("").to_string()
}

#[test]
fn probes_series_is_exactly_the_journal_probe_timeline() {
    let (store, journal, _report) = scenario();
    let mut expected: BTreeMap<String, Vec<(u64, f64)>> = BTreeMap::new();
    for e in journal.iter().filter(|e| e.kind == "probe-completion") {
        let mut toks = e.detail.split_whitespace();
        let job = toks.next().unwrap().trim_end_matches(':').to_string();
        let count: f64 = toks.next().unwrap().parse().unwrap();
        expected.entry(job).or_default().push((e.at, count));
    }
    assert_eq!(expected.len(), 3, "the arrival and both drift verdicts executed probes");
    for (job, want) in &expected {
        let got = points(&store, &format!("select probes where label={job}"));
        assert_eq!(&got, want, "{job}: probe timeline diverged from the journal");
    }
    let journaled: f64 = expected.values().flatten().map(|(_, v)| v).sum();
    assert_eq!(agg(&store, "select probes | agg sum"), journaled);
    // Jobs that never executed a re-profile have no probes series at all.
    assert!(points(&store, "select probes where label=job-03").is_empty());
}

#[test]
fn verdict_timeline_matches_the_journal() {
    let (store, journal, _report) = scenario();
    let mut want: Vec<(u64, String, i64)> = journal
        .iter()
        .filter(|e| e.kind == "verdict")
        .map(|e| {
            let (job, name) = e.detail.split_once(": ").unwrap();
            let code = match name {
                "stable" => 0,
                "rate-shift" => 1,
                "model-stale" => 2,
                other => panic!("unknown verdict '{other}'"),
            };
            (e.at, job.to_string(), code)
        })
        .collect();
    want.sort();
    assert_eq!(want.len(), 3, "all three injected verdicts journaled");
    let result = Query::parse("select verdicts").unwrap().run(&store);
    let mut got: Vec<(u64, String, i64)> = Vec::new();
    for s in &result.series {
        for (t, v) in &s.points {
            got.push((*t, s.key.label.clone(), *v as i64));
        }
    }
    got.sort();
    assert_eq!(got, want, "stored verdict codes diverge from the journal");
}

#[test]
fn runtime_p99_is_bit_equal_to_the_drained_report() {
    let (store, _journal, report) = scenario();
    let summary = report.summary();
    let outcome = summary.outcomes.iter().find(|o| o.name == "job-03").unwrap();
    let mut obs: Vec<f64> = outcome
        .rounds
        .iter()
        .flat_map(|r| r.steps.iter().map(|s| s.mean_runtime))
        .collect();
    obs.sort_by(f64::total_cmp);
    let want = obs[((obs.len() as f64 * 0.99).ceil() as usize).saturating_sub(1)];
    let q = Query::parse("select runtime where label=job-03 | agg p99").unwrap();
    let got = q.run(&store).single().expect("p99 aggregate");
    assert_eq!(got.to_bits(), want.to_bits(), "telemetry p99 must match the report estimator");
}

#[test]
fn journal_json_document_diffs_cleanly_against_the_store() {
    let (store, journal, _report) = scenario();
    let doc = json::parse(&json::to_string(&journal_json(&journal))).expect("round-trips");
    assert_eq!(doc.get("version").and_then(Json::as_usize), Some(1));
    let entries = doc.get("entries").and_then(Json::as_arr).expect("entries array");
    assert_eq!(entries.len(), journal.len());
    // Rebuild the probe totals from the document alone — the schema the
    // `fleet --daemon --journal-out` flag writes — and diff the store.
    let mut from_json = 0.0;
    for e in entries {
        if e.get("kind").and_then(Json::as_str) == Some("probe-completion") {
            let detail = e.get("detail").and_then(Json::as_str).unwrap();
            let n: f64 = detail.split_whitespace().nth(1).unwrap().parse().unwrap();
            from_json += n;
        }
    }
    assert!(from_json > 0.0, "scenario journaled probe work");
    assert_eq!(agg(&store, "select probes | agg sum"), from_json);
}

#[test]
fn store_is_lossless_within_default_retention() {
    let (store, journal, _report) = scenario();
    assert_eq!(store.total_evicted(), 0, "default retention covers the whole scenario");
    assert!(store.total_points() > 0);
    let arrivals = journal.iter().filter(|e| e.kind == "arrival").count();
    let departures = journal.iter().filter(|e| e.kind == "departure").count();
    assert_eq!(arrivals, 5);
    assert_eq!(departures, 1);
    assert_eq!(agg(&store, "select arrivals | agg count"), arrivals as f64);
    assert_eq!(agg(&store, "select departures | agg count"), departures as f64);
}

#[test]
fn window_queries_count_the_same_entries_as_the_journal() {
    let (store, journal, _report) = scenario();
    let at: Vec<u64> = journal
        .iter()
        .filter(|e| e.kind == "probe-completion")
        .map(|e| e.at)
        .collect();
    let latest = *at.iter().max().expect("probe entries exist");
    let lo = latest - 150;
    let q = Query::parse("select probes | window 150 | agg count").unwrap();
    let result = q.run(&store);
    assert_eq!(result.window, Some((lo, latest)), "window anchors on the newest probe");
    let want = at.iter().filter(|t| **t >= lo).count();
    let got: f64 = result.series.iter().filter_map(|s| s.value).sum();
    assert_eq!(got, want as f64, "windowed count matches the journal slice");
    assert!(want < at.len(), "the window must actually exclude something");
}

#[test]
fn http_endpoint_serves_the_stores_answers() {
    let (store, _journal, report) = scenario();
    let server = TelemetryServer::bind("127.0.0.1:0", store.clone(), &report.to_json()).unwrap();
    let addr = server.local_addr();
    let handle = std::thread::spawn(move || server.serve_requests(3));

    let doc = json::parse(&http_get(addr, "/query?q=select+probes+%7C+agg+sum")).unwrap();
    let series = doc.get("series").and_then(Json::as_arr).expect("series array");
    let got = series[0].get("value").and_then(Json::as_f64);
    assert_eq!(got, Some(agg(&store, "select probes | agg sum")));

    let listing = json::parse(&http_get(addr, "/series")).expect("series listing parses");
    let rows = listing.get("series").and_then(Json::as_arr).expect("rows");
    assert_eq!(rows.len(), store.series_count());

    let snap = json::parse(&http_get(addr, "/snapshot")).expect("snapshot parses");
    assert_eq!(snap.get("version").and_then(Json::as_usize), Some(1));
    handle.join().expect("server thread").expect("all requests served");
}

#[test]
fn cache_series_sum_to_the_reports_cache_delta() {
    let (store, _journal, report) = scenario();
    assert!(report.cache.lookups() > 0, "the scenario exercised the cache");
    assert_eq!(agg(&store, "select cache_hits | agg sum"), report.cache.hits as f64);
    assert_eq!(agg(&store, "select cache_misses | agg sum"), report.cache.misses as f64);
}

#[test]
fn malformed_queries_are_rejected_with_reasons() {
    let bad = [
        "probes",
        "select nope",
        "select probes extra",
        "select probes where color=red",
        "select probes | agg p50",
        "select probes | window soon",
        "select probes | window 5 | window 6",
        "select probes | agg sum | agg mean",
        "select probes |",
    ];
    for expr in bad {
        assert!(Query::parse(expr).is_err(), "'{expr}' must be rejected");
    }
    assert!(Query::parse("select * | window 100 | agg rate").is_ok());
}

#[test]
fn session_replay_fills_an_identical_store() {
    let session_store = Arc::new(TelemetryStore::new());
    FleetSession::builder()
        .config(quick_cfg(2, 1))
        .jobs(sim_fleet(4, 7))
        .telemetry(session_store.clone())
        .run()
        .expect("session run");

    let daemon_store = Arc::new(TelemetryStore::new());
    let mut daemon = FleetDaemon::builder()
        .config(quick_cfg(2, 1))
        .telemetry(daemon_store.clone())
        .build();
    for spec in sim_fleet(4, 7) {
        daemon.submit(spec);
    }
    daemon.drain().expect("daemon drains");

    assert!(session_store.total_points() > 0);
    assert_eq!(session_store.keys(), daemon_store.keys());
    for key in session_store.keys() {
        assert_eq!(
            session_store.points(key.kind, &key.label, &key.node),
            daemon_store.points(key.kind, &key.label, &key.node),
            "series {key:?} diverged between session replay and daemon"
        );
    }
}

#[test]
fn adaptive_epochs_emit_drift_verdicts_and_smape_points() {
    // The drift_e2e recipe: cam-a and cam-c jump from 2 Hz to 8 Hz at
    // tick 1500, the start of epoch 2 — exactly those two re-profile.
    let mut specs = vec![
        FleetJobSpec::simulated("cam-a", node("pi4").unwrap(), Algo::Arima, 101),
        FleetJobSpec::simulated("cam-b", node("wally").unwrap(), Algo::Birch, 102),
        FleetJobSpec::simulated("cam-c", node("e2high").unwrap(), Algo::Lstm, 103),
        FleetJobSpec::simulated("cam-d", node("e216").unwrap(), Algo::Arima, 104),
    ];
    for i in [0usize, 2] {
        specs[i].arrivals = ArrivalProcess::Fixed(2.0)
            .with_shift_at(1500, ArrivalProcess::Fixed(8.0));
    }
    let store = Arc::new(TelemetryStore::new());
    let cfg = FleetConfig {
        workers: 1,
        rounds: 2,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 1000,
        probe_workers: 0,
        ..FleetConfig::default()
    };
    let report = FleetSession::builder()
        .config(cfg)
        .jobs(specs)
        .adaptive(AdaptiveConfig::default())
        .telemetry(store.clone())
        .run()
        .expect("adaptive run");
    let adaptive = report.adaptive.as_ref().expect("adaptive summary");

    let drifted = adaptive
        .epochs
        .iter()
        .flat_map(|e| e.verdicts.iter())
        .filter(|(_, v)| v.is_drift())
        .count();
    assert!(drifted > 0, "the recipe must trigger drift");
    assert_eq!(agg(&store, "select verdicts | agg count"), drifted as f64);

    let reprofiled: Vec<_> = adaptive.epochs.iter().flat_map(|e| e.reprofiled.iter()).collect();
    assert!(!reprofiled.is_empty(), "drifted jobs re-profiled");
    let executed: u64 = reprofiled.iter().map(|r| r.executed_probes).sum();
    assert_eq!(agg(&store, "select probes | agg sum"), executed as f64);
    for r in &reprofiled {
        let got = points(&store, &format!("select smape where label={}", r.name));
        assert!(
            got.iter().any(|(_, v)| v.to_bits() == r.post_smape.to_bits()),
            "{}: post-SMAPE missing from the smape series",
            r.name
        );
    }
}
