//! Integration: AOT artifacts executed via PJRT vs. pure-Rust mirrors.
//!
//! These tests are the end-to-end correctness signal for the three-layer
//! stack: JAX/Pallas kernels (L1) → lowered step functions (L2) → PJRT
//! execution driven from Rust (L3). The mirrors re-implement the exact
//! semantics, so outcome trajectories must agree to f32 tolerance across
//! long streams. Skipped (with a notice) when `make artifacts` hasn't run.

use streamprof::runtime::{artifacts_available, default_artifacts_dir, Engine};
use streamprof::simulator::Algo;
use streamprof::stream::SensorStream;
use streamprof::workloads::{MirrorJob, PjrtJob, StreamJob};

fn engine() -> Option<Engine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&default_artifacts_dir()).expect("engine"))
}

fn assert_close(a: f32, b: f32, tol: f32, what: &str) {
    let denom = b.abs().max(1e-3);
    assert!(
        (a - b).abs() / denom < tol,
        "{what}: pjrt={a} mirror={b}"
    );
}

fn compare_trajectories(algo: Algo, steps: usize, tol: f32) {
    let Some(engine) = engine() else { return };
    let mut pjrt = PjrtJob::load(&engine, algo).expect("load artifact");
    let mut mirror = MirrorJob::from_engine(&engine, algo).expect("mirror");
    let mut stream = SensorStream::new(1234).with_anomalies(0.005);
    let mut flags_pjrt = 0u32;
    let mut flags_mirror = 0u32;
    for i in 0..steps {
        let x = stream.next_sample();
        let a = pjrt.process(&x).expect("pjrt step");
        let b = mirror.process(&x).expect("mirror step");
        assert_close(a.err, b.err, tol, &format!("{algo:?} err @{i}"));
        assert_close(a.thr, b.thr, tol.max(2e-3), &format!("{algo:?} thr @{i}"));
        flags_pjrt += a.flag as u32;
        flags_mirror += b.flag as u32;
    }
    // Flag decisions may differ at most rarely (boundary samples).
    let diff = (flags_pjrt as i64 - flags_mirror as i64).unsigned_abs();
    assert!(diff <= 2, "{algo:?}: flag count diverged {flags_pjrt} vs {flags_mirror}");
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn arima_pjrt_matches_mirror_over_500_samples() {
    compare_trajectories(Algo::Arima, 500, 2e-3);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn birch_pjrt_matches_mirror_over_500_samples() {
    compare_trajectories(Algo::Birch, 500, 2e-3);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn lstm_pjrt_matches_mirror_over_300_samples() {
    compare_trajectories(Algo::Lstm, 300, 5e-3);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn chunked_artifact_matches_per_sample_artifact() {
    let Some(engine) = engine() else { return };
    let chunk = engine.manifest().chunk;
    assert!(chunk > 0);
    let mut per = PjrtJob::load(&engine, Algo::Lstm).unwrap();
    let mut chunked = PjrtJob::load_named(&engine, &format!("lstm_chunk{chunk}")).unwrap();
    let mut stream = SensorStream::new(77);
    let xs = stream.generate(chunk);
    // Per-sample path.
    let mut per_outs = Vec::new();
    for i in 0..chunk {
        let x = &xs[i * 28..(i + 1) * 28];
        per_outs.push(per.process(x).unwrap());
    }
    // Chunked path (one PJRT call).
    let chunk_outs = chunked.process_chunk(&xs).unwrap();
    assert_eq!(chunk_outs.len(), chunk);
    for (i, (a, b)) in chunk_outs.iter().zip(&per_outs).enumerate() {
        assert_close(a.err, b.err, 1e-4, &format!("chunk err @{i}"));
        assert_close(a.thr, b.thr, 1e-3, &format!("chunk thr @{i}"));
        assert_eq!(a.flag, b.flag, "chunk flag @{i}");
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn batched_artifact_runs_independent_streams() {
    let Some(engine) = engine() else { return };
    let mut batched = PjrtJob::load_named(&engine, "lstm_batch8").unwrap();
    let mut singles: Vec<PjrtJob> = (0..8)
        .map(|_| PjrtJob::load(&engine, Algo::Lstm).unwrap())
        .collect();
    let mut streams: Vec<SensorStream> = (0..8).map(|i| SensorStream::new(100 + i)).collect();
    for step in 0..20 {
        let mut xb = Vec::with_capacity(8 * 28);
        let mut singles_out = Vec::new();
        for (j, s) in streams.iter_mut().enumerate() {
            let x = s.next_sample();
            singles_out.push(singles[j].process(&x).unwrap());
            xb.extend(x);
        }
        // The batched artifact returns outcomes for all 8 streams at once.
        let outs = batched.process_chunk(&xb).unwrap();
        assert_eq!(outs.len(), 8);
        for j in 0..8 {
            assert_close(
                outs[j].err,
                singles_out[j].err,
                1e-4,
                &format!("batch err stream {j} @{step}"),
            );
        }
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn anomaly_burst_is_detected_by_real_artifact() {
    let Some(engine) = engine() else { return };
    let mut job = PjrtJob::load(&engine, Algo::Arima).unwrap();
    let mut stream = SensorStream::new(5);
    // Warm up on the calm stream.
    for _ in 0..300 {
        let x = stream.next_sample();
        job.process(&x).unwrap();
    }
    // Inject a hand-made spike.
    let mut x = stream.next_sample();
    for v in x.iter_mut() {
        *v += 10.0;
    }
    let out = job.process(&x).unwrap();
    assert_eq!(out.flag, 1.0, "spike must be flagged (err={}, thr={})", out.err, out.thr);
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn state_reset_restores_initial_trajectory() {
    let Some(engine) = engine() else { return };
    let mut job = PjrtJob::load(&engine, Algo::Birch).unwrap();
    let mut stream = SensorStream::new(9);
    let xs: Vec<Vec<f32>> = (0..50).map(|_| stream.next_sample()).collect();
    let first: Vec<f32> = xs.iter().map(|x| job.process(x).unwrap().err).collect();
    job.reset().unwrap();
    let second: Vec<f32> = xs.iter().map(|x| job.process(x).unwrap().err).collect();
    assert_eq!(first, second, "reset must reproduce the exact trajectory");
}
