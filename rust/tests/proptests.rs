//! Property-based tests (hand-rolled; `proptest` is not in the offline
//! vendor set): randomized sweeps over coordinator invariants — selection,
//! fitting, early stopping, placement, adjustment — with seeds derived from
//! a deterministic PRNG so failures are reproducible.

use std::collections::HashMap;

use streamprof::coordinator::{
    Measurement, Profiler, ProfilerConfig, ResourceAdjuster, SimulatedBackend,
};
use streamprof::earlystop::{EarlyStopConfig, EarlyStopMonitor};
use streamprof::fit::{ModelKind, ProfilePoint, RuntimeModel};
use streamprof::fleet::telemetry::{SeriesBuf, SeriesKind, TelemetryStore};
use streamprof::fleet::{
    journal_json, mesh_rebalance, rebalance, rebalance_across, sim_fleet, DriftVerdict, FleetConfig,
    FleetDaemon, FleetJob, FleetJobSpec, MeasurementCache, MeshConfig, MeshFault, MeshTopology,
    ScaledBackendFactory,
};
use streamprof::simulator::{Algo, SimulatedJob, NODES};
use streamprof::strategies::{self, initial_limits};
use streamprof::util::{json, Rng};

const CASES: u64 = 60;

/// Property: Algorithm 1 placement always satisfies Eq. 2 on random
/// configurations (any node, any p in a broad range, any n).
#[test]
fn prop_initial_limits_feasible() {
    let mut rng = Rng::new(0xA11);
    for _ in 0..CASES {
        let node = &NODES[rng.below(NODES.len())];
        let p = rng.uniform(0.01, 0.2);
        let n = 2 + rng.below(3);
        let limits = initial_limits(p, n, 0.1, node.cores, 0.1);
        assert!(!limits.is_empty());
        let sum: f64 = limits.iter().sum();
        assert!(sum <= node.cores + 1e-9, "{}: {limits:?}", node.name);
        for w in limits.windows(2) {
            assert!(w[1] > w[0] + 0.04, "sorted unique: {limits:?}");
        }
        for &l in &limits {
            assert!(l >= 0.1 - 1e-9 && l <= node.cores + 1e-9);
        }
    }
}

/// Property: the fitted nested model is finite, positive, and monotone
/// non-increasing over the grid for random noisy curves.
#[test]
fn prop_fitted_model_sane() {
    let mut rng = Rng::new(0xF17);
    for case in 0..CASES {
        let a = rng.uniform(0.005, 0.5);
        let b = rng.uniform(0.4, 1.5);
        let c = rng.uniform(0.0, 0.1) * a;
        let n_pts = 2 + rng.below(7);
        let mut pts = Vec::new();
        for _ in 0..n_pts {
            let r = (rng.below(40) + 1) as f64 * 0.1;
            if pts.iter().any(|p: &ProfilePoint| (p.limit - r).abs() < 0.05) {
                continue;
            }
            let clean = a * r.powf(-b) + c;
            pts.push(ProfilePoint::new(r, clean * (1.0 + 0.05 * rng.normal())));
        }
        if pts.is_empty() {
            continue;
        }
        let m = RuntimeModel::fit(&pts);
        let mut prev = f64::INFINITY;
        for i in 1..=40 {
            let r = i as f64 * 0.1;
            let v = m.eval(r);
            assert!(v.is_finite() && v > 0.0, "case {case}: eval({r}) = {v}");
            assert!(v <= prev + 1e-12, "case {case}: not monotone at {r}");
            prev = v;
        }
    }
}

/// Property: model inversion is consistent with evaluation wherever the
/// target is reachable.
#[test]
fn prop_invert_roundtrip() {
    let mut rng = Rng::new(0x1BB);
    for _ in 0..CASES {
        let pts: Vec<ProfilePoint> = (0..6)
            .map(|i| {
                let r = 0.1 + i as f64 * 0.7;
                ProfilePoint::new(r, 0.2 * r.powf(-0.9) + 0.01)
            })
            .collect();
        let m = RuntimeModel::fit(&pts);
        let r = rng.uniform(0.1, 4.0);
        let t = m.eval(r);
        if let Some(back) = m.invert(t) {
            assert!((back - r).abs() / r < 1e-6, "{r} -> {t} -> {back}");
        }
    }
}

/// Property: every strategy, on every node, never re-profiles a limitation
/// and never leaves the grid.
#[test]
fn prop_strategies_respect_grid() {
    let mut rng = Rng::new(0x5E1);
    for case in 0..CASES {
        let node = &NODES[rng.below(NODES.len())];
        let algo = Algo::ALL[rng.below(3)];
        let strat_name = ["nms", "bs", "bo", "random"][rng.below(4)];
        let cfg = ProfilerConfig {
            p: rng.uniform(0.02, 0.15),
            n_initial: 2 + rng.below(2),
            samples: 1000,
            max_steps: 8,
            ..Default::default()
        };
        let mut backend =
            SimulatedBackend::new(SimulatedJob::new(node, algo, case));
        let strat = strategies::by_name(strat_name, case).unwrap();
        let sess = Profiler::new(cfg, strat).run(&mut backend);
        for (i, a) in sess.steps.iter().enumerate() {
            let on_grid = (a.limit / 0.1).round() * 0.1;
            assert!((a.limit - on_grid).abs() < 1e-6, "off grid: {}", a.limit);
            assert!(a.limit >= 0.1 - 1e-9 && a.limit <= node.cores + 1e-9);
            for b in &sess.steps[i + 1..] {
                assert!(
                    (a.limit - b.limit).abs() > 0.05,
                    "case {case} {strat_name}: repeat {}",
                    a.limit
                );
            }
        }
    }
}

/// Property: the early-stopping monitor always terminates and its mean
/// estimate converges to the true mean within a few percent.
#[test]
fn prop_early_stop_terminates_accurately() {
    let mut rng = Rng::new(0xE5);
    for _ in 0..CASES {
        let mean = rng.uniform(0.01, 2.0);
        let cov = rng.uniform(0.02, 0.35);
        let lambda = rng.uniform(0.03, 0.2);
        let mut mon = EarlyStopMonitor::new(EarlyStopConfig::new(0.95, lambda));
        let mut stopped = false;
        for _ in 0..2_000_000 {
            if mon.push(rng.lognormal_mean_cov(mean, cov)) {
                stopped = true;
                break;
            }
        }
        assert!(stopped, "did not terminate (cov={cov}, lambda={lambda})");
        // CI width < lambda*mean implies |est - truth| ~< lambda*mean.
        let rel = (mon.mean() - mean).abs() / mean;
        assert!(rel < lambda.max(0.05) * 1.5, "rel err {rel} vs lambda {lambda}");
    }
}

/// Property: the adjuster's decision is the *tightest* feasible limit —
/// one grid step less always violates the budget.
#[test]
fn prop_adjuster_tightness() {
    let mut rng = Rng::new(0xAD1);
    for _ in 0..CASES {
        let pts: Vec<ProfilePoint> = (0..8)
            .map(|i| {
                let r = 0.1 + i as f64 * 0.5;
                ProfilePoint::new(r, rng.uniform(0.5, 2.0) * 0.1 * r.powf(-1.0) + 0.005)
            })
            .collect();
        let model = RuntimeModel::fit(&pts);
        let adj = ResourceAdjuster::new(model.clone(), 0.1, 4.0, 0.1);
        let gap = rng.uniform(0.01, 5.0);
        let d = adj.decide(gap);
        if d.feasible {
            assert!(d.predicted_runtime <= d.budget + 1e-12);
            if d.limit > 0.15 {
                let below = model.eval(d.limit - 0.1);
                assert!(
                    below > d.budget,
                    "limit {} not tight: one step below still fits",
                    d.limit
                );
            }
        } else {
            assert!(model.eval(4.0) > d.budget);
        }
    }
}

/// Random fleet for the placement properties: jobs scattered over random
/// home nodes with power-law runtime models whose exponent matches the
/// home node's calibration (as a fleet-fitted model would).
fn random_fleet(rng: &mut Rng) -> Vec<FleetJob> {
    let n_jobs = 4 + rng.below(10);
    (0..n_jobs)
        .map(|i| {
            let node = &NODES[rng.below(NODES.len())];
            FleetJob {
                name: format!("job-{i:02}"),
                node,
                model: RuntimeModel {
                    kind: ModelKind::Full,
                    a: rng.uniform(0.005, 0.08),
                    b: node.scaling,
                    c: rng.uniform(0.0005, 0.005),
                    d: node.limit_stretch(),
                    fit_cost: 0.0,
                },
                rate_hz: rng.uniform(0.5, 20.0),
                priority: 1 + rng.below(5) as i32,
            }
        })
        .collect()
}

/// Property: fleet placement invariants hold on random fleets —
///   * no node's guaranteed limits exceed its capacity (`l_max`), and no
///     single granted limit exceeds the node's core count,
///   * migrations only ever move jobs the baseline plan had shed,
///   * no job guaranteed in the baseline regresses (in particular, a
///     higher-priority job is never displaced by a lower-priority one),
///   * the plan is deterministic given the same inputs.
#[test]
fn prop_fleet_placement_invariants() {
    let mut rng = Rng::new(0xF1EE7);
    for case in 0..CASES / 2 {
        let jobs = random_fleet(&mut rng);
        let plan = rebalance(&jobs);

        // Per-node capacity and per-assignment l_max bounds.
        for (name, p) in &plan.plans {
            let spec = NODES.iter().find(|n| n.name == name).unwrap();
            assert!(
                p.total_assigned <= spec.cores + 1e-9,
                "case {case}: {name} assigned {} > l_max {}",
                p.total_assigned,
                spec.cores
            );
            for a in p.assignments.iter().filter(|a| a.guaranteed) {
                assert!(
                    a.adjustment.limit <= spec.cores + 1e-9,
                    "case {case}: {} limit {} > {name} l_max",
                    a.name,
                    a.adjustment.limit
                );
            }
        }

        // Baseline (no-migration) guaranteed set, recomputed independently.
        let mut baseline_guaranteed: Vec<String> = Vec::new();
        let mut baseline_shed: Vec<String> = Vec::new();
        for node in NODES {
            let mut mgr = streamprof::coordinator::JobManager::new(node.cores);
            for j in jobs.iter().filter(|j| j.node.name == node.name) {
                mgr.register(streamprof::coordinator::ManagedJob {
                    name: j.name.clone(),
                    model: j.model.clone(),
                    rate_hz: j.rate_hz,
                    priority: j.priority,
                });
            }
            for a in mgr.plan().assignments {
                if a.guaranteed {
                    baseline_guaranteed.push(a.name);
                } else {
                    baseline_shed.push(a.name);
                }
            }
        }
        assert_eq!(plan.metrics.guaranteed_before, baseline_guaranteed.len());

        // Migrations only move baseline-shed jobs.
        for m in &plan.migrations {
            assert!(
                baseline_shed.iter().any(|s| s == &m.job),
                "case {case}: {} migrated but was guaranteed at home",
                m.job
            );
            assert_ne!(m.from, m.to, "case {case}: self-migration");
        }

        // No previously-guaranteed job regresses; the fleet only wins.
        for name in &baseline_guaranteed {
            let (_, a) = plan.assignment(name).expect("baseline job planned");
            assert!(a.guaranteed, "case {case}: {name} displaced by rebalancing");
        }
        assert!(plan.metrics.guaranteed_after >= plan.metrics.guaranteed_before);

        // Determinism: identical inputs give an identical plan.
        let again = rebalance(&jobs);
        assert_eq!(plan.guaranteed_jobs(), again.guaranteed_jobs());
        assert_eq!(plan.migrations.len(), again.migrations.len());
        for (x, y) in plan.migrations.iter().zip(&again.migrations) {
            assert_eq!((&x.job, x.from, x.to), (&y.job, y.from, y.to));
            assert!((x.limit - y.limit).abs() < 1e-12, "case {case}");
        }
    }
}

/// Random job set for the mesh properties: jobs homed on mesh member
/// nodes (clones of the Table-I machines), with the same power-law model
/// family as [`random_fleet`].
fn random_mesh_fleet(rng: &mut Rng, topo: &MeshTopology, n_jobs: usize) -> Vec<FleetJob> {
    (0..n_jobs)
        .map(|i| {
            let node = topo.nodes()[rng.below(topo.nodes().len())];
            FleetJob {
                name: format!("mjob-{i:03}"),
                node,
                model: RuntimeModel {
                    kind: ModelKind::Full,
                    a: rng.uniform(0.005, 0.08),
                    b: node.scaling,
                    c: rng.uniform(0.0005, 0.005),
                    d: node.limit_stretch(),
                    fit_cost: 0.0,
                },
                rate_hz: rng.uniform(0.5, 20.0),
                priority: 1 + rng.below(5) as i32,
            }
        })
        .collect()
}

/// Property: mesh migrations only ever hop along topology links — the
/// local-optimistic scheduler never consults anything beyond its direct
/// neighbors' gossiped summaries, so a move to a non-adjacent node is
/// impossible by construction — and every plan entry is a mesh member.
#[test]
fn prop_mesh_moves_follow_topology_links() {
    let mut rng = Rng::new(0x3E5B);
    let shapes = ["ring:8", "line:7", "star:9", "grid:3x4", "full:6"];
    for case in 0..CASES / 2 {
        let topo = MeshTopology::parse(shapes[rng.below(shapes.len())]).unwrap();
        let jobs = random_mesh_fleet(&mut rng, &topo, 8 + rng.below(20));
        let cfg = MeshConfig::default();
        let (plan, stats) = mesh_rebalance(&jobs, topo.clone(), &cfg, &[]).unwrap();
        assert_eq!(stats.gossip_rounds as usize, cfg.rounds, "case {case}");
        for m in &plan.migrations {
            assert!(
                topo.are_linked(m.from, m.to),
                "case {case}: {} hopped {} -> {} without a link",
                m.job,
                m.from,
                m.to
            );
            assert_ne!(m.from, m.to, "case {case}: self-migration");
        }
        for (node, _) in &plan.plans {
            assert!(topo.contains(node), "case {case}: plan entry for non-member {node}");
        }
    }
}

/// Property: decentralized scheduling only wins — a job the per-node
/// baseline plan guaranteed at home is never displaced by mesh moves
/// (`try_accept` grants from residual capacity only, and crowded-out
/// migrants roll back), and the plan's baseline counter matches an
/// independent per-node recomputation.
#[test]
fn prop_mesh_never_displaces_guaranteed_jobs() {
    let mut rng = Rng::new(0xD15B);
    for case in 0..CASES / 2 {
        let topo = MeshTopology::parse("grid:3x3").unwrap();
        let jobs = random_mesh_fleet(&mut rng, &topo, 10 + rng.below(16));
        let mut baseline_guaranteed: Vec<String> = Vec::new();
        for &node in topo.nodes() {
            let mut mgr = streamprof::coordinator::JobManager::new(node.cores);
            for j in jobs.iter().filter(|j| j.node.name == node.name) {
                mgr.register(streamprof::coordinator::ManagedJob {
                    name: j.name.clone(),
                    model: j.model.clone(),
                    rate_hz: j.rate_hz,
                    priority: j.priority,
                });
            }
            let planned = mgr.plan();
            baseline_guaranteed
                .extend(planned.assignments.into_iter().filter(|a| a.guaranteed).map(|a| a.name));
        }
        let (plan, _) = mesh_rebalance(&jobs, topo, &MeshConfig::default(), &[]).unwrap();
        assert_eq!(plan.metrics.guaranteed_before, baseline_guaranteed.len(), "case {case}");
        for name in &baseline_guaranteed {
            let (_, a) = plan.assignment(name).expect("baseline job planned");
            assert!(a.guaranteed, "case {case}: {name} displaced by mesh moves");
        }
        assert!(plan.metrics.guaranteed_after >= plan.metrics.guaranteed_before, "case {case}");
    }
}

/// Property: a mesh run is a pure function of the job *set*, topology,
/// cadence, and fault schedule — a second identical run, and a run fed
/// the same jobs in permuted submission order, both produce identical
/// placements, migration sequences, and run counters, even with a link
/// cut landing mid-run and latency-delayed (stale) gossip.
#[test]
fn prop_mesh_schedule_deterministic_under_permutation() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..CASES / 3 {
        let topo = MeshTopology::parse("ring:6@25").unwrap();
        let jobs = random_mesh_fleet(&mut rng, &topo, 8 + rng.below(14));
        let faults = vec![(200u64, MeshFault::Cut("wally.0".into(), "asok.1".into()))];
        let cfg = MeshConfig::default();
        let (plan, stats) = mesh_rebalance(&jobs, topo.clone(), &cfg, &faults).unwrap();

        let mut permuted = jobs.clone();
        for i in (1..permuted.len()).rev() {
            let j = rng.below(i + 1);
            permuted.swap(i, j);
        }
        let runs = [
            mesh_rebalance(&jobs, topo.clone(), &cfg, &faults).unwrap(),
            mesh_rebalance(&permuted, topo, &cfg, &faults).unwrap(),
        ];
        for (again, more) in &runs {
            assert_eq!(plan.guaranteed_jobs(), again.guaranteed_jobs(), "case {case}");
            assert_eq!(plan.migrations.len(), again.migrations.len(), "case {case}");
            for (x, y) in plan.migrations.iter().zip(&again.migrations) {
                assert_eq!((&x.job, x.from, x.to), (&y.job, y.from, y.to), "case {case}");
                assert_eq!(x.limit.to_bits(), y.limit.to_bits(), "case {case}");
            }
            assert_eq!(plan.metrics.guaranteed_after, again.metrics.guaranteed_after);
            assert_eq!(stats.gossip_rounds, more.gossip_rounds, "case {case}");
            assert_eq!(stats.summaries_delivered, more.summaries_delivered, "case {case}");
            assert_eq!(stats.conflict_rollbacks, more.conflict_rollbacks, "case {case}");
            assert_eq!(stats.moves, more.moves, "case {case}");
        }
    }
}

/// Property: on a fully-connected 120-node mesh the local-optimistic
/// scheduler converges to at least 90% of the centralized planner's
/// guaranteed count — with zero-latency gossip (fresh global views), and
/// still under one-round-stale views plus a handful of cut links.
#[test]
fn prop_mesh_converges_toward_centralized_plan() {
    let mut rng = Rng::new(0xC04E);
    for (case, spec) in ["full:120", "full:120@30"].into_iter().enumerate() {
        let topo = MeshTopology::parse(spec).unwrap();
        let jobs = random_mesh_fleet(&mut rng, &topo, 300);
        let centralized = rebalance_across(&jobs, topo.nodes());
        let mut faults: Vec<(u64, MeshFault)> = Vec::new();
        if case == 1 {
            // Stale-gossip variant: also cut six links before round one.
            for pair in topo.nodes().windows(2).take(6) {
                let fault = MeshFault::Cut(pair[0].name.into(), pair[1].name.into());
                faults.push((0, fault));
            }
        }
        let cfg = MeshConfig { every: 200, rounds: 8 };
        let (plan, stats) = mesh_rebalance(&jobs, topo, &cfg, &faults).unwrap();
        assert_eq!(plan.metrics.jobs, jobs.len(), "{spec}: every job planned");
        assert!(stats.summaries_delivered > 0, "{spec}: gossip flowed");
        let target = centralized.metrics.guaranteed_after;
        let floor = (target as f64 * 0.9).ceil() as usize;
        assert!(
            plan.metrics.guaranteed_after >= floor,
            "{spec}: mesh guaranteed {} < 90% of centralized {target}",
            plan.metrics.guaranteed_after
        );
    }
}

/// Property: measurement-cache generation aging, checked against an exact
/// reference model under randomized interleavings of insert / lookup /
/// bump / evict (with adversarially varying caller-supplied bucket
/// widths, which the canonical per-label width must neutralize):
///   * a generation bump never lets `lookup` serve a pre-bump measurement,
///   * `evict_stale` reclaims exactly the stale entries and never a
///     current-generation one,
///   * `stats()` totals stay consistent: `hits + misses == lookups`,
///     `hits`/`stale_hits_refused` match the reference exactly, and
///     `evictions <= inserts`.
#[test]
fn prop_cache_aging_matches_reference_model() {
    let mut rng = Rng::new(0xCAC4E);
    const LABELS: [&str; 3] = ["cam", "lidar", "mic"];
    for case in 0..CASES {
        let cache = MeasurementCache::new();
        let mut gens = [0u64; 3];
        // Reference store: (label, bucket) -> (generation, tag).
        let mut reference: HashMap<(usize, i64), (u64, f64)> = HashMap::new();
        let mut lookups = 0u64;
        let mut hits = 0u64;
        let mut stale = 0u64;
        // Register every label's canonical width (0.1) up front, so the
        // later adversarial widths exercise canonicalization.
        for (li, label) in LABELS.iter().enumerate() {
            let tag = (case * 1_000_000 + li as u64) as f64;
            cache.insert(label, 0.1, tagged(0.1, tag));
            reference.insert((li, 1), (0, tag));
        }
        for step in 0..240u64 {
            let li = rng.below(3);
            let label = LABELS[li];
            let bucket = 1 + rng.below(8) as i64;
            let limit = bucket as f64 * 0.1;
            // The caller "reconfigures" its width at random; the cache
            // must keep keying by the canonical 0.1.
            let width = [0.1, 0.2, 0.05][rng.below(3)];
            match rng.below(10) {
                0..=3 => {
                    let tag = (case * 1_000_000 + 1000 + step) as f64;
                    cache.insert(label, width, tagged(limit, tag));
                    reference.insert((li, bucket), (gens[li], tag));
                }
                4..=7 => {
                    lookups += 1;
                    let got = cache.lookup(label, limit, width).map(|m| m.mean_runtime);
                    let entry = reference.get(&(li, bucket));
                    let want = entry.and_then(|&(g, tag)| (g == gens[li]).then_some(tag));
                    assert_eq!(
                        got, want,
                        "case {case} step {step}: {label} bucket {bucket} served wrong entry"
                    );
                    match entry {
                        Some(_) if want.is_some() => hits += 1,
                        Some(_) => stale += 1,
                        None => {}
                    }
                }
                8 => {
                    gens[li] += 1;
                    assert_eq!(cache.bump_generation(label), gens[li]);
                }
                _ => {
                    let removed = cache.evict_stale();
                    let before = reference.len();
                    reference.retain(|&(l, _), &mut (g, _)| g == gens[l]);
                    assert_eq!(
                        removed,
                        before - reference.len(),
                        "case {case} step {step}: evict count diverged from reference"
                    );
                }
            }
        }
        let s = cache.stats();
        assert_eq!(s.lookups(), lookups, "case {case}: every lookup counted exactly once");
        assert_eq!(s.hits, hits, "case {case}");
        assert_eq!(s.stale_hits_refused, stale, "case {case}");
        assert!(s.stale_hits_refused <= s.misses, "case {case}: refusals are misses");
        assert!(s.evictions <= s.inserts, "case {case}: evictions bounded by inserts");
        // Final sweep: evict, then every current-generation reference
        // entry must still be served — evict_stale never over-reclaims.
        cache.evict_stale();
        reference.retain(|&(l, _), &mut (g, _)| g == gens[l]);
        assert_eq!(cache.len(), reference.len());
        for (&(li, bucket), &(g, tag)) in &reference {
            assert_eq!(g, gens[li], "reference retains only current entries");
            let got = cache.lookup(LABELS[li], bucket as f64 * 0.1, 0.1);
            assert_eq!(got.map(|m| m.mean_runtime), Some(tag), "case {case}");
        }
    }
}

fn tagged(limit: f64, tag: f64) -> Measurement {
    Measurement { limit, mean_runtime: tag, samples: 1, wallclock: 1.0 }
}

/// Property: cache stats stay consistent under genuinely concurrent
/// insert / lookup / bump / evict interleavings, and `evict_stale` leaves
/// no stale entry behind regardless of interleaving.
#[test]
fn prop_cache_stats_consistent_under_concurrent_aging() {
    for case in 0..8u64 {
        let cache = MeasurementCache::new();
        let total_lookups: u64 = std::thread::scope(|s| {
            let handles: Vec<_> = (0..6u64)
                .map(|w| {
                    let cache = &cache;
                    s.spawn(move || {
                        let mut rng = Rng::new(case * 1000 + w + 1);
                        let mut lookups = 0u64;
                        for _ in 0..200 {
                            let label = ["a", "b"][rng.below(2)];
                            let limit = (1 + rng.below(6)) as f64 * 0.1;
                            match rng.below(8) {
                                0..=4 => {
                                    lookups += 1;
                                    if cache.lookup(label, limit, 0.1).is_none() {
                                        cache.insert(label, 0.1, tagged(limit, 1.0));
                                    }
                                }
                                5 => cache.insert(label, 0.1, tagged(limit, 2.0)),
                                6 => {
                                    cache.bump_generation(label);
                                }
                                _ => {
                                    cache.evict_stale();
                                }
                            }
                            std::thread::yield_now();
                        }
                        lookups
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        let s = cache.stats();
        assert_eq!(s.lookups(), total_lookups, "case {case}: lookups counted exactly once");
        assert!(s.stale_hits_refused <= s.misses, "case {case}");
        assert!(s.evictions <= s.inserts, "case {case}");
        assert!(s.hits <= s.lookups(), "case {case}");
        // After a quiescent evict, a full sweep over every bucket must not
        // encounter a single stale entry.
        cache.evict_stale();
        let refused_before = cache.stats().stale_hits_refused;
        for label in ["a", "b"] {
            for b in 1..=6i64 {
                cache.lookup(label, b as f64 * 0.1, 0.1);
            }
        }
        assert_eq!(
            cache.stats().stale_hits_refused,
            refused_before,
            "case {case}: evict_stale left a stale entry behind"
        );
    }
}

/// Property: the lock-striped shards aggregate into the same global stats
/// invariants a single-lock cache guaranteed — exact lookup and insert
/// accounting, refusals bounded by misses, evictions bounded by inserts —
/// even when the labels span every shard and every operation interleaves
/// across threads; and a quiescent `delta_since` over the aggregated
/// counters is exact.
#[test]
fn prop_sharded_stats_aggregate_like_a_single_lock() {
    let labels: Vec<String> = (0..16).map(|i| format!("node{}/algo{}", i % 8, i % 3)).collect();
    for case in 0..8u64 {
        let cache = MeasurementCache::new();
        let labels = &labels;
        let (total_lookups, total_inserts) = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8u64)
                .map(|w| {
                    let cache = &cache;
                    s.spawn(move || {
                        let mut rng = Rng::new(case * 7919 + w + 1);
                        let (mut lookups, mut inserts) = (0u64, 0u64);
                        for _ in 0..200 {
                            let label = &labels[rng.below(16)];
                            let limit = (1 + rng.below(6)) as f64 * 0.1;
                            match rng.below(10) {
                                0..=4 => {
                                    lookups += 1;
                                    cache.lookup(label, limit, 0.1);
                                }
                                5..=7 => {
                                    inserts += 1;
                                    cache.insert(label, 0.1, tagged(limit, 1.0));
                                }
                                8 => {
                                    cache.bump_generation(label);
                                }
                                _ => {
                                    cache.evict_stale();
                                }
                            }
                            std::thread::yield_now();
                        }
                        (lookups, inserts)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .fold((0, 0), |(l, i), (dl, di)| (l + dl, i + di))
        });
        let s = cache.stats();
        assert_eq!(s.lookups(), total_lookups, "case {case}: lookup lost between shards");
        assert_eq!(s.inserts, total_inserts, "case {case}: insert lost between shards");
        assert!(s.stale_hits_refused <= s.misses, "case {case}: refusals are misses");
        assert!(s.evictions <= s.inserts, "case {case}: evictions bounded by inserts");
        assert!(s.hits <= s.lookups(), "case {case}");
        assert!(cache.len() as u64 <= s.inserts - s.evictions, "case {case}");
        assert!(s.saved_wallclock >= 0.0 && s.saved_wallclock.is_finite(), "case {case}");

        // Quiescent delta accounting: the aggregated counters advance by
        // exactly the single-threaded tail of operations.
        let before = cache.stats();
        for (i, label) in labels.iter().enumerate() {
            cache.insert(label, 0.1, tagged(0.3, i as f64));
            cache.lookup(label, 0.3, 0.1);
            cache.lookup(label, 5.0, 0.1);
        }
        let delta = cache.stats().delta_since(&before);
        assert_eq!(delta.inserts, 16, "case {case}");
        assert_eq!(delta.hits, 16, "case {case}: post-insert lookups all hit");
        assert_eq!(delta.misses, 16, "case {case}: off-bucket lookups all miss");
        assert_eq!(delta.evictions, 0, "case {case}");
    }
}

/// Property: profiling wallclock equals the sum of iterative steps plus the
/// max of the initial parallel phase (time accounting never drifts).
#[test]
fn prop_time_accounting_consistent() {
    let mut rng = Rng::new(0x71E);
    for case in 0..CASES / 2 {
        let node = &NODES[rng.below(NODES.len())];
        let cfg = ProfilerConfig { samples: 1000, max_steps: 7, ..Default::default() };
        let mut backend =
            SimulatedBackend::new(SimulatedJob::new(node, Algo::Arima, case + 999));
        let strat = strategies::by_name("nms", case).unwrap();
        let sess = Profiler::new(cfg, strat).run(&mut backend);
        // Placement may return fewer initial runs than requested (small
        // machines); use the actual count.
        let n_initial = sess.initial_limits.len();
        let init_max = sess.steps[..n_initial.min(sess.steps.len())]
            .iter()
            .map(|s| s.wallclock)
            .fold(0.0f64, f64::max);
        let tail: f64 = sess.steps.iter().skip(n_initial).map(|s| s.wallclock).sum();
        assert!(
            (sess.total_time - (init_max + tail)).abs() < 1e-9,
            "time drift: {} vs {}",
            sess.total_time,
            init_max + tail
        );
    }
}

/// Property: the delta-of-delta + RLE codec round-trips arbitrary
/// timelines bit-for-bit — zero-delta bursts, out-of-order appends from
/// interleaved writers, long value runs, block-boundary crossings — and
/// `points_in` equals a filter over the full decode.
#[test]
fn prop_telemetry_codec_roundtrip() {
    let mut rng = Rng::new(0x7E1E);
    for case in 0..CASES {
        let n = 1 + rng.below(300);
        let mut buf = SeriesBuf::new(10_000);
        let mut want: Vec<(u64, f64)> = Vec::with_capacity(n);
        let mut t = rng.below(1000) as u64;
        for _ in 0..n {
            // Zero and negative deltas stress the dod encoder; repeated
            // values stress the RLE side.
            t = match rng.below(6) {
                0 => t,
                1 => t + 1,
                2 => t + rng.below(10) as u64,
                3 => t + rng.below(500) as u64,
                4 => t.saturating_sub(rng.below(100) as u64),
                _ => t + rng.below(100_000) as u64,
            };
            let v = match rng.below(4) {
                0 => want.last().map_or(1.0, |(_, v)| *v),
                1 => rng.below(50) as f64,
                2 => rng.uniform(-1e6, 1e6),
                _ => rng.normal() * 1e-9,
            };
            buf.append(t, v);
            want.push((t, v));
        }
        let got = buf.points();
        assert_eq!(got.len(), want.len(), "case {case}: point count");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.0, w.0, "case {case}: timestamp {i}");
            assert_eq!(g.1.to_bits(), w.1.to_bits(), "case {case}: value {i}");
        }
        assert_eq!(buf.evicted(), 0, "case {case}: capacity never reached");
        // Windowed decode == filter over the full decode, random bounds.
        let hi = want.iter().map(|&(pt, _)| pt).max().unwrap();
        let lo = hi.saturating_sub(rng.below(1 + hi as usize) as u64);
        let filtered: Vec<(u64, f64)> =
            want.iter().copied().filter(|&(pt, _)| pt >= lo && pt <= hi).collect();
        assert_eq!(buf.points_in(lo, hi), filtered, "case {case}: windowed decode");
    }
}

/// Property: the ring retains at most `capacity` points, never loses
/// accounting (`len + evicted == appended`), and what survives is the
/// exact newest suffix of the appended sequence.
#[test]
fn prop_telemetry_retention_invariants() {
    let mut rng = Rng::new(0x4E7A1);
    for case in 0..CASES {
        let capacity = 1 + rng.below(200);
        let appends = rng.below(1000);
        let mut buf = SeriesBuf::new(capacity);
        let mut appended: Vec<(u64, f64)> = Vec::with_capacity(appends);
        let mut t = 0u64;
        for i in 0..appends {
            t += rng.below(5) as u64;
            let v = i as f64;
            buf.append(t, v);
            appended.push((t, v));
            assert!(buf.len() <= buf.capacity(), "case {case}: over-retained after {i}");
        }
        assert_eq!(buf.capacity(), capacity);
        assert_eq!(buf.len() as u64 + buf.evicted(), appends as u64, "case {case}: accounting");
        let got = buf.points();
        assert_eq!(&got, &appended[appends - buf.len()..], "case {case}: newest suffix");
        if let (Some(earliest), Some(&(t0, _))) = (buf.earliest(), got.first()) {
            assert_eq!(earliest, t0, "case {case}: earliest");
        }
        if let (Some(latest), Some(&(tn, _))) = (buf.latest(), got.last()) {
            assert_eq!(latest, tn, "case {case}: latest");
        }
    }
}

/// The 16 interleaved series identities used by the concurrency property.
fn key_for(idx: usize) -> (SeriesKind, String, String) {
    let kind = SeriesKind::ALL[idx % SeriesKind::ALL.len()];
    (kind, format!("job-{idx:02}"), format!("node{}", idx % 4))
}

/// Property: 8 threads hammering `TelemetryStore::append` across 16
/// interleaved keys lose nothing — per-key point counts and value sums
/// match a single-threaded replay of the same deterministic operation
/// streams, and the global accounting adds up.
#[test]
fn prop_telemetry_concurrent_appends_aggregate_exactly() {
    const THREADS: u64 = 8;
    const OPS: usize = 200;
    for case in 0..8u64 {
        let store = TelemetryStore::new();
        std::thread::scope(|s| {
            for w in 0..THREADS {
                let store = &store;
                s.spawn(move || {
                    let mut rng = Rng::new(case * 6151 + w + 1);
                    for op in 0..OPS {
                        let (kind, label, node) = key_for(rng.below(16));
                        let t = (w as usize * OPS + op) as u64;
                        store.append(kind, &label, &node, t, rng.below(100) as f64);
                        std::thread::yield_now();
                    }
                });
            }
        });

        // Single-lock reference: replay the identical streams serially.
        let mut expect: HashMap<usize, (usize, f64)> = HashMap::new();
        for w in 0..THREADS {
            let mut rng = Rng::new(case * 6151 + w + 1);
            for _ in 0..OPS {
                let idx = rng.below(16);
                let slot = expect.entry(idx).or_default();
                slot.0 += 1;
                slot.1 += rng.below(100) as f64;
            }
        }
        let mut total = 0;
        for (idx, &(count, sum)) in &expect {
            let (kind, label, node) = key_for(*idx);
            let pts = store.points(kind, &label, &node);
            assert_eq!(pts.len(), count, "case {case}: key {idx} lost appends");
            let got: f64 = pts.iter().map(|(_, v)| v).sum();
            assert_eq!(got, sum, "case {case}: key {idx} sum drifted");
            total += count;
        }
        assert_eq!(store.total_points(), total, "case {case}: global accounting");
        assert_eq!(store.series_count(), expect.len(), "case {case}: series count");
        assert_eq!(store.total_evicted(), 0, "case {case}: retention untouched");
    }
}

/// Property: however the probe pool's worker threads interleave, the
/// overlapped daemon drains a bit-identical report and journal — and the
/// report matches the synchronous daemon byte for byte. Seq-ordered
/// settling erases the completion-order permutation; the jobs carry
/// distinct cache labels, so no two in-flight probes share cold entries.
#[test]
fn prop_overlapped_drain_is_invariant_under_completion_order() {
    fn scenario(probe_workers: usize) -> FleetDaemon {
        let cfg = FleetConfig {
            workers: 4,
            rounds: 1,
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 500,
            probe_workers,
            ..Default::default()
        };
        let mut d = FleetDaemon::builder().config(cfg).jobs(sim_fleet(4, 7)).build();
        let shift = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 8.0 };
        d.observe_verdict_at("job-00", shift, 600);
        d.observe_verdict_at("job-01", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 650);
        let mut extras = sim_fleet(6, 7).split_off(4);
        d.submit_at(extras.remove(0), 700);
        d.submit_at(extras.remove(0), 700);
        d.retire_at("job-02", 900);
        d
    }
    let sync_bytes = json::to_string(&scenario(0).drain().expect("sync drain").to_json());
    let mut journals: Vec<String> = Vec::new();
    for run in 0..4 {
        let mut d = scenario(4);
        d.run_until(2_000).expect("overlapped run");
        journals.push(json::to_string(&journal_json(d.journal())));
        let bytes = json::to_string(&d.drain().expect("overlapped drain").to_json());
        assert_eq!(bytes, sync_bytes, "run {run}: overlapped report diverged from sync");
    }
    for (run, j) in journals.iter().enumerate().skip(1) {
        assert_eq!(j, &journals[0], "run {run}: journal depends on thread interleaving");
    }
}

/// Property: a rejected transfer prior costs nothing. For every fleet
/// seed, a primed daemon whose arrivals are regime-shifted siblings (3x
/// slower, so every donor consult fails its check probe) drains a report
/// byte-identical to the same schedule with transfer off — with
/// overlapped dispatch (`probe_workers: 1`), so the fallback holds on the
/// async path too. The journal differs (it records the rejections); the
/// report must not.
#[test]
fn prop_rejected_prior_report_is_byte_identical_to_cold() {
    fn scenario(transfer: bool, fleet_seed: u64) -> FleetDaemon {
        let cfg = FleetConfig {
            workers: 2,
            rounds: 1,
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 500,
            probe_workers: 1,
            transfer,
            ..Default::default()
        };
        let donors = sim_fleet(3, fleet_seed);
        let mut d = FleetDaemon::builder().config(cfg).jobs(donors.clone()).build();
        for (i, base) in donors.into_iter().enumerate() {
            let spec = FleetJobSpec {
                name: format!("shift-{i:02}"),
                backend: ScaledBackendFactory::shared(base.backend.clone(), 3.0),
                ..base
            };
            d.submit_at(spec, 700);
        }
        d
    }
    for case in 0..3u64 {
        let fleet_seed = 7 + case * 13;
        let mut cold = scenario(false, fleet_seed);
        cold.run_until(2_000).expect("cold run");
        let cold_bytes = json::to_string(&cold.drain().expect("cold drain").to_json());

        let mut primed = scenario(true, fleet_seed);
        primed.run_until(2_000).expect("primed run");
        let rejected = primed.journal().iter().filter(|e| e.kind == "prior-rejected").count();
        assert_eq!(rejected, 3, "case {case}: every shifted arrival rejects its donor");
        let primed_bytes = json::to_string(&primed.drain().expect("primed drain").to_json());
        assert_eq!(primed_bytes, cold_bytes, "case {case}: a rejected prior must cost nothing");
    }
}
