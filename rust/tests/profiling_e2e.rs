//! End-to-end integration over the full L3 pipeline on the simulated
//! testbed: acquisition sweep → profiling session (all strategies) →
//! runtime model → adaptive resource adjustment, plus the PJRT-backed
//! profiling path when artifacts are present.

use streamprof::coordinator::{
    smape_vs_dataset, PjrtBackend, Profiler, ProfilerConfig, ProfilingBackend,
    ResourceAdjuster, SimulatedBackend,
};
use streamprof::earlystop::EarlyStopConfig;
use streamprof::repro::{AcquiredDataset, DatasetBackend};
use streamprof::runtime::{artifacts_available, default_artifacts_dir, Engine};
use streamprof::simulator::{node, Algo, SimulatedJob, NODES};
use streamprof::strategies;
use streamprof::stream::{ArrivalProcess, SensorStream};
use streamprof::workloads::PjrtJob;

#[test]
fn full_pipeline_profile_then_adjust() {
    // 1. Profile the job on a simulated pi4.
    let cfg = ProfilerConfig { samples: 10_000, max_steps: 6, ..Default::default() };
    let mut backend =
        SimulatedBackend::new(SimulatedJob::new(node("pi4").unwrap(), Algo::Lstm, 42));
    let sess = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap()).run(&mut backend);
    let model = sess.final_model().clone();

    // 2. The model predicts the measured points well.
    for step in &sess.steps {
        let rel = (model.eval(step.limit) - step.mean_runtime).abs() / step.mean_runtime;
        assert!(rel < 0.5, "model off at {}: {rel}", step.limit);
    }

    // 3. Adjust resources for a varying-rate stream.
    let adj = ResourceAdjuster::new(model, 0.1, 4.0, 0.1);
    let arrivals = ArrivalProcess::Varying { lo: 1.0, hi: 4.0, period: 300.0 };
    let plan = adj.plan(&arrivals, 900, 100);
    assert_eq!(plan.len(), 9);
    assert!(plan.iter().all(|a| a.feasible), "pi4 should sustain 4 Hz LSTM");
    // Faster windows get more CPU.
    let max_limit = plan.iter().map(|a| a.limit).fold(0.0f64, f64::max);
    let min_limit = plan.iter().map(|a| a.limit).fold(f64::MAX, f64::min);
    assert!(max_limit > min_limit);
}

#[test]
fn all_strategies_on_all_nodes_produce_usable_models() {
    for node_spec in NODES {
        for strat in ["nms", "bs", "bo", "random"] {
            let ds = AcquiredDataset::acquire(node_spec, Algo::Birch, 7);
            let mut backend = DatasetBackend::new(&ds, 10_000);
            let cfg = ProfilerConfig { samples: 10_000, max_steps: 8, ..Default::default() };
            let strategy = strategies::by_name(strat, 3).unwrap();
            let sess = Profiler::new(cfg, strategy).run(&mut backend);
            let smape = smape_vs_dataset(sess.final_model(), &ds.truth_points());
            assert!(
                smape < 0.35,
                "{}/{strat}: final SMAPE {smape}",
                node_spec.name
            );
        }
    }
}

#[test]
fn early_stopping_pipeline_reduces_time_at_similar_accuracy() {
    let ds = AcquiredDataset::acquire(node("pi4").unwrap(), Algo::Arima, 11);
    let truth = ds.truth_points();
    let run = |early: bool| {
        let cfg = ProfilerConfig {
            samples: 10_000,
            max_steps: 6,
            early_stop: early.then(|| EarlyStopConfig::new(0.95, 0.10)),
            ..Default::default()
        };
        let mut backend = DatasetBackend::new(&ds, 10_000);
        Profiler::new(cfg, strategies::by_name("nms", 5).unwrap()).run(&mut backend)
    };
    let full = run(false);
    let es = run(true);
    assert!(es.total_time < full.total_time * 0.5);
    let s_full = smape_vs_dataset(full.final_model(), &truth);
    let s_es = smape_vs_dataset(es.final_model(), &truth);
    assert!(s_es < s_full + 0.15, "ES {s_es} vs full {s_full}");
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn pjrt_backed_profiling_session() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let engine = Engine::new(&default_artifacts_dir()).unwrap();
    let job = PjrtJob::load(&engine, Algo::Arima).unwrap();
    let mut backend = PjrtBackend::new(job, SensorStream::new(3), 4.0);
    // Small sample counts: this hits the real executable per sample.
    let m1 = backend.measure(0.5, 40);
    let m2 = backend.measure(1.0, 40);
    assert_eq!(m1.samples, 40);
    assert!(m1.mean_runtime > 0.0 && m1.mean_runtime.is_finite());
    // Duty-cycle accounting: 0.5 CPU should look ~2x slower than 1.0 CPU.
    let ratio = m1.mean_runtime / m2.mean_runtime;
    assert!(
        ratio > 1.3 && ratio < 3.5,
        "throttle accounting off: ratio {ratio}"
    );

    // A full (short) profiling session against the real artifact.
    let cfg = ProfilerConfig {
        samples: 30,
        max_steps: 5,
        n_initial: 2,
        ..Default::default()
    };
    let sess = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap()).run(&mut backend);
    assert_eq!(sess.steps.len(), 5);
    assert!(sess.final_model().eval(1.0) > 0.0);
    // Runtime model should predict the throttle's 1/R shape for R < 1:
    // eval(0.2) substantially above eval(1.0).
    let m = sess.final_model();
    assert!(m.eval(0.2) > m.eval(1.0) * 2.0);
}
