//! Transfer-prior end-to-end: cross-job runtime priors kill cold-start
//! profiling for fresh arrivals, a mismatched donor falls back to the
//! cold sweep with no accuracy regression, and the daemon journals /
//! telemeters the whole lifecycle.
//!
//! The scenarios mirror the `fleet` CLI: a workload-zoo roster
//! ([`sim_fleet`]) bootstraps the corpus, later arrivals of the same job
//! classes profile primed, and a regime-shifted sibling (3× slower via
//! [`ScaledBackendFactory`]) exercises the rejection path.

use std::sync::Arc;

use streamprof::coordinator::backend::ProfilingBackend;
use streamprof::coordinator::{smape_vs_dataset, PriorVerdict, ProfilerConfig};
use streamprof::fit::ProfilePoint;
use streamprof::fleet::worker::profile_job_with;
use streamprof::fleet::{
    model_fingerprint, sim_fleet, FleetConfig, FleetDaemon, FleetJobSpec, FleetSession,
    MeasurementCache, PriorCorpus, ProfilePass, Query, ScaledBackendFactory, TelemetryStore,
};

/// Accuracy bar a primed profile must still clear against ground truth.
const TARGET_SMAPE: f64 = 0.15;

fn quick_cfg() -> FleetConfig {
    FleetConfig {
        workers: 2,
        rounds: 1,
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        ..FleetConfig::default()
    }
}

/// Ground truth for a spec: its own backend measured over an even grid.
fn truth(spec: &FleetJobSpec) -> Vec<ProfilePoint> {
    let mut backend = spec.backend.build().expect("backend builds");
    let l_max = backend.l_max();
    (1..=6)
        .map(|i| {
            let limit = l_max * i as f64 / 6.0;
            let m = backend.measure(limit, 4000);
            ProfilePoint::new(limit, m.mean_runtime)
        })
        .collect()
}

fn cold_outcome(spec: &FleetJobSpec, cfg: &FleetConfig) -> streamprof::fleet::JobOutcome {
    let fresh = MeasurementCache::new();
    profile_job_with(spec, cfg, &fresh, 0, &ProfilePass::default()).expect("cold profile")
}

/// A fleet of returning job classes profiles in measurably fewer probes
/// when primed from the corpus, and still reaches the target SMAPE —
/// the headline acceptance bar of the transfer subsystem.
#[test]
fn primed_arrivals_reach_target_smape_in_fewer_probes() {
    let cfg = quick_cfg();
    // Bootstrap: the full workload zoo (7 nodes x 3 algorithms) profiled
    // cold builds the corpus — exactly what a daemon's first replan does.
    let donor_cache = MeasurementCache::new();
    let mut corpus = PriorCorpus::new();
    for spec in sim_fleet(21, 7) {
        let outcome = profile_job_with(&spec, &cfg, &donor_cache, 0, &ProfilePass::default())
            .expect("donor profile");
        corpus.absorb(&outcome);
    }
    // Recipients: the next 7 arrivals repeat the zoo's classes, so each
    // has an exact-label donor. Every profile runs on a FRESH cache: only
    // the transfer seed carries cross-job knowledge.
    let recipients = sim_fleet(28, 7).split_off(21);
    let (mut cold_probes, mut primed_probes) = (0u64, 0u64);
    let (mut cold_err, mut primed_err) = (0.0f64, 0.0f64);
    for spec in &recipients {
        let cold = cold_outcome(spec, &cfg);
        let seed = corpus.donor_for(spec).expect("the corpus covers every zoo class");
        let pass = ProfilePass { transfer: Some(seed), ..ProfilePass::default() };
        let fresh = MeasurementCache::new();
        let primed = profile_job_with(spec, &cfg, &fresh, 0, &pass).expect("primed profile");
        let tr = primed.transfer.as_ref().expect("primed outcome records its donor");
        assert!(
            matches!(tr.verdict, PriorVerdict::Adopted | PriorVerdict::Tempered),
            "{}: same-class donor must not be rejected, got {:?}",
            spec.name,
            tr.verdict
        );
        cold_probes += cold.cache_delta.misses;
        primed_probes += primed.cache_delta.misses;
        let dataset = truth(spec);
        cold_err += smape_vs_dataset(&cold.model, &dataset);
        primed_err += smape_vs_dataset(&primed.model, &dataset);
    }
    assert!(
        primed_probes < cold_probes,
        "priming must save probes: primed {primed_probes} vs cold {cold_probes}"
    );
    let n = recipients.len() as f64;
    let (cold_avg, primed_avg) = (cold_err / n, primed_err / n);
    assert!(
        primed_avg <= TARGET_SMAPE,
        "primed fleet SMAPE {primed_avg:.4} misses the {TARGET_SMAPE} target"
    );
    assert!(
        primed_avg <= cold_avg + 0.05,
        "priming must not trade away accuracy: primed {primed_avg:.4} vs cold {cold_avg:.4}"
    );
}

/// A regime-shifted sibling (same class, uniformly 3x slower) is rejected
/// by the check probe, costs at most one probe more than the cold sweep,
/// and ends with the cold sweep's exact model — prior mismatch is never
/// worse than cold.
#[test]
fn mismatched_donor_rejects_within_one_probe_of_cold() {
    let cfg = quick_cfg();
    let base = sim_fleet(1, 7).remove(0);
    let mut corpus = PriorCorpus::new();
    corpus.absorb(&cold_outcome(&base, &cfg));

    let shifted = FleetJobSpec {
        name: "shifted".to_string(),
        backend: ScaledBackendFactory::shared(base.backend.clone(), 3.0),
        ..base
    };
    let cold = cold_outcome(&shifted, &cfg);
    let seed = corpus.donor_for(&shifted).expect("the base class donates to its @x3 sibling");
    let pass = ProfilePass { transfer: Some(seed), ..ProfilePass::default() };
    let fresh = MeasurementCache::new();
    let primed = profile_job_with(&shifted, &cfg, &fresh, 0, &pass).expect("primed profile");

    let tr = primed.transfer.as_ref().expect("the donor attempt is recorded");
    assert_eq!(tr.verdict, PriorVerdict::Rejected, "a 3x regime shift must reject the prior");
    assert!(
        primed.cache_delta.misses <= cold.cache_delta.misses + 1,
        "rejection cost {} probes vs {} cold",
        primed.cache_delta.misses,
        cold.cache_delta.misses
    );
    assert_eq!(
        model_fingerprint(&primed.model),
        model_fingerprint(&cold.model),
        "the rejected-prior fallback must end on the cold sweep's exact model"
    );
}

/// The daemon wires the whole lifecycle: bootstrap builds the corpus,
/// fresh arrivals consult it (journaled as `prior-adopted`), arrivals
/// with no transferable donor profile cold (the `cold_start_probes`
/// telemetry series), and adoptions land in `prior_adoptions`.
#[test]
fn daemon_journals_and_telemeters_the_corpus_lifecycle() {
    let store = Arc::new(TelemetryStore::new());
    let cfg = FleetConfig { transfer: true, ..quick_cfg() };
    // Bootstrap with only the first two zoo classes: the third class has
    // no donor, so its later arrival is a measurable cold start.
    let mut daemon = FleetDaemon::builder()
        .config(cfg)
        .jobs(sim_fleet(2, 7))
        .telemetry(store.clone())
        .build();
    let mut extras = sim_fleet(24, 7).split_off(21);
    daemon.submit_at(extras.remove(0), 600); // job-21: exact donor (class 0)
    daemon.submit_at(extras.remove(0), 650); // job-22: exact donor (class 1)
    daemon.submit_at(extras.remove(0), 700); // job-23: class 2 — no donor
    daemon.run_until(2_000).expect("daemon run");

    let journal = daemon.journal();
    let primed = journal
        .iter()
        .filter(|e| e.kind == "prior-adopted" || e.kind == "prior-tempered")
        .count();
    assert_eq!(primed, 2, "both exact-donor arrivals consult the corpus");
    assert!(
        !journal.iter().any(|e| e.kind == "prior-rejected"),
        "nothing in this timeline should reject its donor"
    );

    let agg = |expr: &str| {
        let result = Query::parse(expr).expect("query parses").run(&store);
        result.series.iter().filter_map(|s| s.value).sum::<f64>()
    };
    assert_eq!(agg("select prior_adoptions | agg sum"), 2.0, "one point per adoption");
    assert!(
        agg("select cold_start_probes | agg sum") > 0.0,
        "the donor-less arrival pays (and records) cold-start probes"
    );
    assert_eq!(
        agg("select cold_start_probes | agg count"),
        1.0,
        "only the donor-less arrival is a cold start"
    );
}

/// `FleetConfig::plan_quantile` flows through the sweep: provisioning for
/// the p95 runtime reserves strictly more capacity than mean planning.
#[test]
fn quantile_planning_reserves_more_capacity_end_to_end() {
    let mean = FleetSession::builder()
        .config(quick_cfg())
        .jobs(sim_fleet(6, 7))
        .run()
        .expect("mean-planned run");
    let tail = FleetSession::builder()
        .config(FleetConfig { plan_quantile: Some(0.95), ..quick_cfg() })
        .jobs(sim_fleet(6, 7))
        .run()
        .expect("quantile-planned run");
    let assigned = |r: &streamprof::fleet::FleetReport| {
        let plans = &r.summary().plans;
        plans.iter().map(|(_, p)| p.total_assigned).sum::<f64>()
    };
    let (m, t) = (assigned(&mean), assigned(&tail));
    assert!(t > m, "p95 planning must reserve more capacity: {t:.4} vs mean {m:.4}");
}
