//! Deterministic drift-scenario harness for the adaptive fleet loop.
//!
//! Every scenario runs a single-worker engine (fully deterministic: the
//! cold sweep, the live probes, and the strategy replays all draw from
//! seeded PRNGs) with drift injected at a known virtual tick, and asserts
//! the adaptive loop's contract: exactly the drifted jobs re-profile,
//! rolling SMAPE returns under the threshold, stable jobs' models stay
//! bit-identical (checked by fit fingerprint), and the whole adaptation
//! costs less than naively re-profiling the fleet.

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{
    model_fingerprint, sim_fleet, AdaptiveConfig, AdaptiveSummary, DriftVerdict, FleetConfig,
    FleetJobSpec, FleetSession, RuntimeShift,
};
use streamprof::simulator::{node, Algo};
use streamprof::stream::ArrivalProcess;

/// Deterministic single-worker engine config shared by the scenarios.
fn quiet_cfg() -> FleetConfig {
    FleetConfig {
        workers: 1,
        rounds: 2,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 1000,
        probe_workers: 0,
        ..FleetConfig::default()
    }
}

/// Run the adaptive session pipeline and unwrap its summary.
fn run_adaptive(
    specs: Vec<FleetJobSpec>,
    acfg: &AdaptiveConfig,
) -> anyhow::Result<AdaptiveSummary> {
    let report = FleetSession::builder()
        .config(quiet_cfg())
        .jobs(specs)
        .adaptive(acfg.clone())
        .run()?;
    Ok(report.adaptive.expect("adaptive stage ran"))
}

/// Four jobs with distinct cache labels, all on fixed 2 Hz streams.
fn quad_fleet() -> Vec<FleetJobSpec> {
    vec![
        FleetJobSpec::simulated("cam-a", node("pi4").unwrap(), Algo::Arima, 101),
        FleetJobSpec::simulated("cam-b", node("wally").unwrap(), Algo::Birch, 102),
        FleetJobSpec::simulated("cam-c", node("e2high").unwrap(), Algo::Lstm, 103),
        FleetJobSpec::simulated("cam-d", node("e216").unwrap(), Algo::Arima, 104),
    ]
}

#[test]
fn rate_shift_reprofiles_exactly_the_shifted_jobs() {
    // cam-a and cam-c jump from 2 Hz to 8 Hz at tick 1500 — the start of
    // epoch 2 (horizon 1000 + one 500-tick epoch). The loop must
    // re-profile exactly those two, re-provision them at the new rate,
    // and leave cam-b/cam-d byte-untouched.
    let mut specs = quad_fleet();
    for i in [0usize, 2] {
        specs[i].arrivals = ArrivalProcess::Fixed(2.0)
            .with_shift_at(1500, ArrivalProcess::Fixed(8.0));
    }
    let acfg = AdaptiveConfig::default();
    let summary = run_adaptive(specs, &acfg).expect("adaptive run");

    assert_eq!(summary.epochs.len(), 3);
    // Epoch 1 ends at tick 1500: still the old regime, nothing fires.
    assert!(summary.epochs[0].reprofiled.is_empty(), "no drift before the shift");
    assert!(summary.epochs[0].verdicts.iter().all(|(_, v)| !v.is_drift()));
    assert!(summary.epochs[0].plan.is_none(), "stable epochs do not re-plan");

    // Epoch 2 observes the shifted window: exactly cam-a and cam-c fire.
    let e2 = &summary.epochs[1];
    let mut fired: Vec<&str> = e2.reprofiled.iter().map(|r| r.name.as_str()).collect();
    fired.sort_unstable();
    assert_eq!(fired, vec!["cam-a", "cam-c"], "exactly the shifted jobs re-profile");
    for r in &e2.reprofiled {
        assert!(
            matches!(
                r.verdict,
                DriftVerdict::RateShift { provisioned_hz, observed_hz }
                    if (provisioned_hz - 2.0).abs() < 1e-9 && (observed_hz - 8.0).abs() < 1e-9
            ),
            "{}: verdict {:?}",
            r.name,
            r.verdict
        );
        // The runtime behaviour never changed: the still-valid cache
        // replays the whole re-profile session for free.
        assert_eq!(r.executed_probes, 0, "{}: rate shift must replay the cache", r.name);
        // Rolling SMAPE ends under the threshold (the model was and
        // remains accurate; the shift was provisioning, not behaviour).
        assert!(
            r.post_smape < acfg.drift.smape_threshold,
            "{}: post SMAPE {:.3}",
            r.name,
            r.post_smape
        );
    }
    let plan = e2.plan.as_ref().expect("a drift epoch re-plans the fleet");
    assert_eq!(plan.metrics.jobs, 4);

    // Re-provisioned at the observed rate, with a larger granted limit.
    for name in ["cam-a", "cam-c"] {
        let job = summary.job(name).unwrap();
        assert!((job.rate_hz - 8.0).abs() < 1e-9, "{name} re-provisioned at 8 Hz");
        assert_eq!(job.reprofiles, 1);
        let cold_limit = summary.initial.assignment(name).unwrap().adjustment.limit;
        assert!(
            job.limit > cold_limit,
            "{name}: a 4x faster stream needs more CPU ({} -> {})",
            cold_limit,
            job.limit
        );
    }

    // Epoch 3: the adapted fleet is stable again.
    assert!(summary.epochs[2].reprofiled.is_empty());
    assert!(summary.epochs[2].verdicts.iter().all(|(_, v)| !v.is_drift()));

    // Stable jobs' fits are untouched — assert by fingerprint.
    for name in ["cam-b", "cam-d"] {
        let job = summary.job(name).unwrap();
        assert_eq!(job.reprofiles, 0);
        let initial = summary
            .initial
            .outcomes
            .iter()
            .find(|o| o.name == name)
            .unwrap();
        assert_eq!(
            job.fingerprint,
            model_fingerprint(&initial.model),
            "{name}: stable model must stay bit-identical"
        );
        assert!((job.rate_hz - 2.0).abs() < 1e-9);
    }
}

#[test]
fn model_stale_reprofiles_ages_the_cache_and_recovers_smape() {
    // cam-c's runtime behaviour turns 3x slower at tick 1500 (a model
    // upgrade). The monitor must flag it ModelStale, the cache must age
    // out its generation, and the warm re-profile must pull the rolling
    // SMAPE back under the threshold — touching nobody else.
    let mut specs = quad_fleet();
    specs[2].runtime_shift = Some(RuntimeShift { at_tick: 1500, scale: 3.0 });
    let acfg = AdaptiveConfig::default();
    let summary = run_adaptive(specs, &acfg).expect("adaptive run");

    assert!(summary.epochs[0].reprofiled.is_empty());
    let e2 = &summary.epochs[1];
    assert_eq!(e2.reprofiled.len(), 1, "only the shifted job re-profiles");
    let r = &e2.reprofiled[0];
    assert_eq!(r.name, "cam-c");
    assert!(matches!(
        r.verdict,
        DriftVerdict::ModelStale { rolling_smape } if rolling_smape > acfg.drift.smape_threshold
    ));
    assert!(r.pre_smape > acfg.drift.smape_threshold, "pre SMAPE {:.3}", r.pre_smape);
    assert!(
        r.post_smape < acfg.drift.smape_threshold,
        "post SMAPE {:.3} must recover under the threshold",
        r.post_smape
    );
    assert!(r.post_smape < r.pre_smape);
    assert!(r.executed_probes > 0, "a bumped generation cannot replay");

    // The stale generation was reclaimed, and the loop executed far fewer
    // probes than naive full re-profiling of all four jobs.
    assert!(summary.cache.evictions > 0, "stale entries must be evicted");
    assert!(summary.cache.evictions <= summary.cache.inserts);
    assert!(
        summary.adaptive_probe_executions < summary.naive_probe_executions(),
        "adaptive {} vs naive {}",
        summary.adaptive_probe_executions,
        summary.naive_probe_executions()
    );

    // The refit tracks the 3x shift; the untouched jobs do not move.
    let cold = summary
        .initial
        .outcomes
        .iter()
        .find(|o| o.name == "cam-c")
        .unwrap();
    let hot = summary.job("cam-c").unwrap();
    assert_ne!(hot.fingerprint, model_fingerprint(&cold.model), "stale fit was replaced");
    for &r_eval in &[0.5, 1.0, 2.0] {
        let ratio = hot.model.eval(r_eval) / cold.model.eval(r_eval);
        assert!((2.0..4.5).contains(&ratio), "3x shift tracked at {r_eval}: ratio {ratio}");
    }
    for name in ["cam-a", "cam-b", "cam-d"] {
        let job = summary.job(name).unwrap();
        let initial = summary
            .initial
            .outcomes
            .iter()
            .find(|o| o.name == name)
            .unwrap();
        assert_eq!(job.reprofiles, 0);
        assert_eq!(job.fingerprint, model_fingerprint(&initial.model), "{name} untouched");
    }
    // Epoch 3 is quiet: the adapted model describes the new regime.
    assert!(summary.epochs[2].reprofiled.is_empty());
    assert!(summary.epochs[2].verdicts.iter().all(|(_, v)| !v.is_drift()));
}

#[test]
fn zero_drift_is_a_byte_identical_noop() {
    // Adversarial guard against threshold jitter: with default thresholds
    // and zero injected drift, `run_adaptive` must perform zero
    // re-profiles, execute zero adaptation probes, and report a cold
    // sweep byte-identical to a plain `run` of the same specs.
    let specs = sim_fleet(6, 5);
    let plain_report = FleetSession::builder()
        .config(quiet_cfg())
        .jobs(specs.clone())
        .run()
        .expect("plain run");
    let plain = plain_report.summary();
    let summary = run_adaptive(specs, &AdaptiveConfig::default()).expect("adaptive run");

    assert!(summary.reprofiled_names().is_empty(), "zero re-profiles");
    assert_eq!(summary.adaptive_probe_executions, 0, "zero probes executed");
    assert_eq!(summary.naive_probe_executions(), 0, "no drift epoch at all");
    for e in &summary.epochs {
        assert!(e.verdicts.iter().all(|(_, v)| matches!(v, DriftVerdict::Stable)));
        assert!(e.plan.is_none());
    }
    for job in &summary.jobs {
        assert_eq!(job.reprofiles, 0);
    }

    // Byte-identical cold sweep: models, rates, sessions, plans, stats.
    let adaptive = &summary.initial;
    assert_eq!(plain.outcomes.len(), adaptive.outcomes.len());
    for (a, b) in plain.outcomes.iter().zip(&adaptive.outcomes) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.model.kind, b.model.kind);
        for (x, y) in [
            (a.model.a, b.model.a),
            (a.model.b, b.model.b),
            (a.model.c, b.model.c),
            (a.model.d, b.model.d),
            (a.rate_hz, b.rate_hz),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{}: parameter drift", a.name);
        }
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.steps.len(), rb.steps.len());
            for (sa, sb) in ra.steps.iter().zip(&rb.steps) {
                assert_eq!(sa.limit.to_bits(), sb.limit.to_bits());
                assert_eq!(sa.mean_runtime.to_bits(), sb.mean_runtime.to_bits());
            }
            assert_eq!(ra.total_time.to_bits(), rb.total_time.to_bits());
        }
    }
    assert_eq!(plain.plans.len(), adaptive.plans.len());
    for ((na, pa), (nb, pb)) in plain.plans.iter().zip(&adaptive.plans) {
        assert_eq!(na, nb);
        assert_eq!(pa.total_assigned.to_bits(), pb.total_assigned.to_bits());
        assert_eq!(pa.assignments.len(), pb.assignments.len());
        for (aa, ab) in pa.assignments.iter().zip(&pb.assignments) {
            assert_eq!(aa.name, ab.name);
            assert_eq!(aa.guaranteed, ab.guaranteed);
            assert_eq!(aa.adjustment.limit.to_bits(), ab.adjustment.limit.to_bits());
        }
    }
    assert_eq!(plain.cache.hits, adaptive.cache.hits);
    assert_eq!(plain.cache.misses, adaptive.cache.misses);
    assert_eq!(plain.cache.inserts, adaptive.cache.inserts);
    assert_eq!(plain.cache.stale_hits_refused, 0);
    assert_eq!(adaptive.cache.stale_hits_refused, 0);
    assert_eq!(adaptive.cache.evictions, 0);
    assert_eq!(
        plain.cache.saved_wallclock.to_bits(),
        adaptive.cache.saved_wallclock.to_bits()
    );
}

#[test]
fn sub_period_epochs_do_not_alias_varying_troughs_into_rate_shifts() {
    // Epochs much shorter than the arrival period: the rate tracker's
    // horizon-length lookback must keep windowed peaks comparable to the
    // provisioned peak — otherwise every trough epoch would fire a false
    // RateShift and re-provision jobs at trough rates.
    let mut specs = quad_fleet();
    for s in specs.iter_mut() {
        s.arrivals = ArrivalProcess::Varying { lo: 1.0, hi: 6.0, period: 400.0 };
    }
    let acfg = AdaptiveConfig { epochs: 5, epoch_ticks: 100, ..AdaptiveConfig::default() };
    let summary = run_adaptive(specs, &acfg).expect("adaptive run");
    assert!(summary.reprofiled_names().is_empty(), "no drift injected, none may fire");
    for e in &summary.epochs {
        assert!(
            e.verdicts.iter().all(|(_, v)| !v.is_drift()),
            "epoch {}: trough aliased into a verdict",
            e.epoch
        );
    }
}

#[test]
fn mismatched_runtime_shift_within_a_shared_label_is_rejected() {
    // Two replicas of one class share a cache label; letting only one of
    // them drift would poison the other's replays, so the adaptive loop
    // refuses the scenario outright.
    let pi4 = node("pi4").unwrap();
    let mut specs = vec![
        FleetJobSpec::simulated("twin-a", pi4, Algo::Arima, 7),
        FleetJobSpec::simulated("twin-b", pi4, Algo::Arima, 7),
    ];
    specs[0].runtime_shift = Some(RuntimeShift { at_tick: 1500, scale: 3.0 });
    let err = run_adaptive(specs, &AdaptiveConfig::default())
        .expect_err("mismatched class drift must be rejected");
    assert!(err.to_string().contains("share cache label"), "{err:#}");
}

#[test]
fn rate_shift_can_downgrade_and_migrate_via_rebalance() {
    // A drift epoch re-enters migrate::rebalance: when the shifted job's
    // home node can no longer guarantee everyone, the epoch plan may move
    // shed jobs to idle capacity. Here four 2 Hz pi4 streams jump to
    // 18 Hz (each then needs ≥ 1.1 CPU on the 4-core Pi — or is outright
    // infeasible there — while costing ~0.3 CPU on wally) while wally
    // idles: the epoch's fleet plan must migrate the overflow out.
    let pi4 = node("pi4").unwrap();
    let wally = node("wally").unwrap();
    let mut specs: Vec<FleetJobSpec> = (0..4)
        .map(|i| {
            // One seed for all four: same class on the same device type
            // shares runtime behaviour (and cache label), per the fleet
            // engine's labeling convention.
            let mut s = FleetJobSpec::simulated(&format!("edge-{i}"), pi4, Algo::Arima, 300);
            s.arrivals = ArrivalProcess::Fixed(2.0)
                .with_shift_at(1500, ArrivalProcess::Fixed(18.0));
            s
        })
        .collect();
    specs.push(FleetJobSpec::simulated("anchor", wally, Algo::Birch, 305));

    let summary = run_adaptive(specs, &AdaptiveConfig::default()).expect("adaptive run");
    let e2 = &summary.epochs[1];
    assert_eq!(e2.reprofiled.len(), 4, "all four shifted streams fire");
    let plan = e2.plan.as_ref().expect("drift epoch re-plans");
    assert_eq!(plan.metrics.jobs, 5);
    assert!(
        plan.metrics.guaranteed_after >= plan.metrics.guaranteed_before,
        "rebalance never loses guarantees: {:?}",
        plan.metrics
    );
    // The re-provisioned demand exceeds pi4's 4 cores, so the baseline
    // must shed and the rebalance must migrate at least one job out.
    assert!(
        !plan.migrations.is_empty(),
        "over-subscribed home node must shed into idle capacity: {:?}",
        plan.metrics
    );
    for m in &plan.migrations {
        assert_eq!(m.from, "pi4");
        assert_eq!(m.to, "wally");
    }
    // The anchor stays guaranteed at home throughout.
    let (home, anchor) = plan.assignment("anchor").unwrap();
    assert_eq!(home, "wally");
    assert!(anchor.guaranteed);
}
