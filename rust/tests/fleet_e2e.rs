//! End-to-end fleet engine run: N simulated jobs sharded over a worker
//! pool, probing through the shared measurement cache, with incremental
//! refits feeding per-node capacity plans. Mirrors the acceptance bar for
//! the fleet subsystem: ≥ 8 jobs on a 4-worker pool must finish with a
//! ≥ 30% measurement-cache hit rate.

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{sim_fleet, FleetConfig, FleetEngine, FleetJobSpec};
use streamprof::simulator::{node, Algo};
use streamprof::stream::ArrivalProcess;

fn quick_cfg(workers: usize, rounds: usize) -> FleetConfig {
    FleetConfig {
        workers,
        rounds,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
    }
}

#[test]
fn eight_jobs_on_four_workers_hit_the_cache() {
    let engine = FleetEngine::new(quick_cfg(4, 2));
    let summary = engine.run(sim_fleet(8, 7)).expect("fleet run");
    assert_eq!(summary.outcomes.len(), 8);
    // Submission order restored after the pool finishes out of order.
    for (i, o) in summary.outcomes.iter().enumerate() {
        assert_eq!(o.index, i);
        assert_eq!(o.name, format!("job-{i:02}"));
        assert_eq!(o.rounds.len(), 2);
        assert!(o.model.eval(1.0).is_finite() && o.model.eval(1.0) > 0.0);
        assert!(o.refits >= o.points);
        assert!(o.rate_hz > 0.0);
    }
    // The acceptance bar: re-profiling rounds replay through the cache.
    let rate = summary.hit_rate();
    assert!(rate >= 0.30, "cache hit rate {rate:.2} below 30%");
    assert!(summary.cache.saved_wallclock > 0.0);
    assert!(summary.executed_wallclock() > 0.0);
}

#[test]
fn work_queue_drains_with_more_jobs_than_workers() {
    // 12 jobs on 3 workers: every job must be profiled exactly once and
    // the worker ids span the pool.
    let engine = FleetEngine::new(quick_cfg(3, 1));
    let summary = engine.run(sim_fleet(12, 3)).expect("fleet run");
    assert_eq!(summary.outcomes.len(), 12);
    assert!(summary.outcomes.iter().all(|o| o.worker < 3));
    let mut names: Vec<&str> = summary.outcomes.iter().map(|o| o.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), 12, "each job profiled exactly once");
}

#[test]
fn replicas_of_one_job_class_share_cache_entries() {
    // Two replicas of the same (device, algo) class: the second replica's
    // probes reuse the first one's measurements even within a single
    // round, because they share the cache label.
    let engine = FleetEngine::new(FleetConfig { workers: 1, rounds: 1, ..quick_cfg(1, 1) });
    let pi4 = node("pi4").unwrap();
    let specs = vec![
        FleetJobSpec::simulated("cam-a", pi4, Algo::Lstm, 5),
        FleetJobSpec::simulated("cam-b", pi4, Algo::Lstm, 5),
    ];
    let summary = engine.run(specs).expect("fleet run");
    let stats = summary.cache;
    assert!(stats.hits > 0, "replica probes must hit the shared cache");
    // Both replicas end with usable models and assignments on the node.
    assert_eq!(summary.plans.len(), 1);
    assert_eq!(summary.plans[0].0, "pi4");
    assert!(summary.assignment("cam-a").is_some());
    assert!(summary.assignment("cam-b").is_some());
}

#[test]
fn capacity_plans_cover_every_job_and_respect_capacity() {
    let engine = FleetEngine::new(quick_cfg(4, 2));
    let summary = engine.run(sim_fleet(10, 11)).expect("fleet run");
    let planned: usize = summary.plans.iter().map(|(_, p)| p.assignments.len()).sum();
    assert_eq!(planned, 10, "every job appears in exactly one node plan");
    for (node_name, plan) in &summary.plans {
        assert!(
            plan.total_assigned <= plan.capacity + 1e-9,
            "{node_name}: guaranteed set exceeds capacity"
        );
    }
    for o in &summary.outcomes {
        let a = summary.assignment(&o.name).expect("assignment exists");
        assert!(a.adjustment.limit > 0.0);
    }
}

#[test]
fn rebalance_migrates_shed_jobs_to_under_subscribed_nodes() {
    // An over-subscribed Pi 4 carries twelve 12 Hz streams (each needs
    // ~0.7 CPU just-in-time — far beyond 4 cores), while wally and e216
    // idle with one light job each. The scheduler must migrate the shed
    // jobs out, strictly increase the number of guaranteed jobs over the
    // no-migration baseline, and regress zero previously-guaranteed jobs.
    let pi4 = node("pi4").unwrap();
    let wally = node("wally").unwrap();
    let e216 = node("e216").unwrap();
    let mut specs: Vec<FleetJobSpec> = (0..12usize)
        .map(|i| {
            let mut s = FleetJobSpec::simulated(&format!("cam-{i:02}"), pi4, Algo::Arima, 7);
            s.priority = 1 + (i % 3) as i32;
            s.arrivals = ArrivalProcess::Fixed(12.0);
            s
        })
        .collect();
    specs.push(FleetJobSpec::simulated("light-wally", wally, Algo::Arima, 3));
    specs.push(FleetJobSpec::simulated("light-e216", e216, Algo::Birch, 4));

    let engine = FleetEngine::new(quick_cfg(2, 1));
    let (summary, plan) = engine.run_rebalanced(specs).expect("fleet run");

    // The no-migration baseline really is over-subscribed: pi4 shed jobs.
    let baseline_guaranteed: Vec<String> = summary
        .plans
        .iter()
        .flat_map(|(_, p)| p.assignments.iter())
        .filter(|a| a.guaranteed)
        .map(|a| a.name.clone())
        .collect();
    let (_, pi4_plan) = summary.plans.iter().find(|(n, _)| n == "pi4").unwrap();
    let pi4_shed = pi4_plan.assignments.iter().filter(|a| !a.guaranteed).count();
    assert!(pi4_shed > 0, "scenario must over-subscribe pi4");
    assert_eq!(plan.metrics.guaranteed_before, baseline_guaranteed.len());

    // Shed jobs migrated off the Pi into idle capacity.
    assert!(!plan.migrations.is_empty(), "shed jobs must migrate");
    for m in &plan.migrations {
        assert_eq!(m.from, "pi4");
        assert!(m.to == "wally" || m.to == "e216");
        let (node_name, a) = plan.assignment(&m.job).expect("migrated job planned");
        assert_eq!(node_name, m.to);
        assert!(a.guaranteed, "{} migrated but still best-effort", m.job);
    }

    // Strictly more guaranteed jobs than the baseline…
    assert!(
        plan.metrics.guaranteed_after > plan.metrics.guaranteed_before,
        "rebalance must win: {:?}",
        plan.metrics
    );
    // …with zero previously-guaranteed jobs regressed…
    for name in &baseline_guaranteed {
        let (_, a) = plan.assignment(name).expect("baseline job still planned");
        assert!(a.guaranteed, "{name} was guaranteed before rebalancing");
    }
    // …and every node still within capacity.
    for (name, p) in &plan.plans {
        assert!(p.total_assigned <= p.capacity + 1e-9, "{name} over capacity");
    }
}

#[test]
fn varying_arrivals_drive_rate_demand() {
    // A job with a faster stream must register a higher rate demand.
    let engine = FleetEngine::new(quick_cfg(2, 1));
    let wally = node("wally").unwrap();
    let mut slow = FleetJobSpec::simulated("slow", wally, Algo::Arima, 1);
    slow.arrivals = ArrivalProcess::Fixed(1.0);
    let mut fast = FleetJobSpec::simulated("fast", wally, Algo::Arima, 1);
    fast.arrivals = ArrivalProcess::Varying { lo: 2.0, hi: 8.0, period: 100.0 };
    let summary = engine.run(vec![slow, fast]).expect("fleet run");
    let rate = |n: &str| summary.outcomes.iter().find(|o| o.name == n).unwrap().rate_hz;
    assert!((rate("slow") - 1.0).abs() < 1e-9);
    assert!(rate("fast") > 7.0);
    // The faster job needs at least as much CPU.
    let limit = |n: &str| summary.assignment(n).unwrap().adjustment.limit;
    assert!(limit("fast") >= limit("slow"));
}
