//! End-to-end fleet session run: N jobs sharded over a worker pool,
//! probing through the shared measurement cache, with incremental refits
//! feeding per-node capacity plans. Mirrors the acceptance bar for the
//! fleet subsystem: ≥ 8 jobs on a 4-worker pool must finish with a ≥ 30%
//! measurement-cache hit rate — plus the api-redesign guards: the batch
//! session's `run()` is provably an event replay of the long-lived
//! `FleetDaemon` (every arrival at tick 0, then drain), and a
//! non-simulator `BackendFactory` plugs into the same builder.

use std::sync::Arc;

use streamprof::coordinator::ProfilerConfig;
use streamprof::fleet::{
    model_fingerprint, sim_fleet, DriftVerdict, EngineBackendFactory, FleetConfig, FleetDaemon,
    FleetJobSpec, FleetSession, MeasurementCache,
};
use streamprof::runtime::{artifacts_available, default_artifacts_dir, pjrt_enabled};
use streamprof::simulator::{node, Algo};
use streamprof::stream::ArrivalProcess;
use streamprof::util::json;

fn quick_cfg(workers: usize, rounds: usize) -> FleetConfig {
    FleetConfig {
        workers,
        rounds,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
        horizon: 500,
        probe_workers: 0,
        ..FleetConfig::default()
    }
}

#[test]
fn eight_jobs_on_four_workers_hit_the_cache() {
    let report = FleetSession::builder()
        .config(quick_cfg(4, 2))
        .jobs(sim_fleet(8, 7))
        .run()
        .expect("fleet run");
    let summary = report.summary();
    assert_eq!(summary.outcomes.len(), 8);
    // Submission order restored after the pool finishes out of order.
    for (i, o) in summary.outcomes.iter().enumerate() {
        assert_eq!(o.index, i);
        assert_eq!(o.name, format!("job-{i:02}"));
        assert_eq!(o.rounds.len(), 2);
        assert!(o.model.eval(1.0).is_finite() && o.model.eval(1.0) > 0.0);
        assert!(o.refits >= o.points);
        assert!(o.rate_hz > 0.0);
    }
    // The acceptance bar: re-profiling rounds replay through the cache.
    let rate = summary.hit_rate();
    assert!(rate >= 0.30, "cache hit rate {rate:.2} below 30%");
    assert!(summary.cache.saved_wallclock > 0.0);
    assert!(summary.executed_wallclock() > 0.0);
}

#[test]
fn session_run_is_byte_identical_to_daemon_event_replay() {
    // The api-redesign acceptance guard: the batch session is a thin
    // wrapper over the event-driven daemon ("replay every arrival at
    // tick 0, drain"), so driving the daemon by hand through its event
    // queue must not move any numbers.
    let report = FleetSession::builder()
        .config(quick_cfg(4, 2))
        .jobs(sim_fleet(8, 7))
        .run()
        .expect("session run");
    let batch = report.summary();

    let mut daemon = FleetDaemon::builder().config(quick_cfg(4, 2)).build();
    for spec in sim_fleet(8, 7) {
        daemon.submit(spec);
    }
    let replay = daemon.drain().expect("daemon drain");
    let event = replay.summary();

    assert_eq!(batch.outcomes.len(), event.outcomes.len());
    for (a, b) in batch.outcomes.iter().zip(&event.outcomes) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.label, b.label);
        assert_eq!(
            model_fingerprint(&a.model),
            model_fingerprint(&b.model),
            "{}: fit fingerprint moved",
            a.name
        );
        assert_eq!(a.rate_hz.to_bits(), b.rate_hz.to_bits());
        assert_eq!(a.points, b.points);
        assert_eq!(a.refits, b.refits);
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.steps.len(), rb.steps.len());
            assert_eq!(ra.total_time.to_bits(), rb.total_time.to_bits());
        }
    }
    assert_eq!(batch.plans.len(), event.plans.len());
    for ((na, pa), (nb, pb)) in batch.plans.iter().zip(&event.plans) {
        assert_eq!(na, nb);
        assert_eq!(pa.assignments.len(), pb.assignments.len());
        for (x, y) in pa.assignments.iter().zip(&pb.assignments) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.guaranteed, y.guaranteed);
            assert_eq!(x.adjustment.limit.to_bits(), y.adjustment.limit.to_bits());
        }
    }
    assert_eq!(report.cache.hits, replay.cache.hits);
    assert_eq!(report.cache.misses, replay.cache.misses);
    assert_eq!(report.cache.inserts, replay.cache.inserts);
    assert_eq!(report.cache.stale_hits_refused, replay.cache.stale_hits_refused);
    assert_eq!(
        report.cache.saved_wallclock.to_bits(),
        replay.cache.saved_wallclock.to_bits()
    );
}

#[test]
fn single_worker_reports_serialize_byte_identically() {
    // With one worker even scheduling jitter has nothing to reorder, so
    // the emitted JSON documents must match byte for byte.
    let session = FleetSession::builder()
        .config(quick_cfg(1, 2))
        .jobs(sim_fleet(6, 7))
        .run()
        .expect("session run");
    let mut daemon = FleetDaemon::builder().config(quick_cfg(1, 2)).build();
    for spec in sim_fleet(6, 7) {
        daemon.submit(spec);
    }
    let replay = daemon.drain().expect("daemon drain");
    assert_eq!(
        json::to_string(&session.to_json()),
        json::to_string(&replay.to_json()),
        "batch and event-replay reports diverge"
    );
}

/// A busy mid-run schedule — verdicts, arrivals, and a departure across
/// four replans — driven once synchronously and once through the
/// overlapped probe pool.
fn busy_daemon(probe_workers: usize) -> FleetDaemon {
    let cfg = FleetConfig { probe_workers, ..quick_cfg(1, 2) };
    let mut d = FleetDaemon::builder().config(cfg).jobs(sim_fleet(3, 7)).build();
    let mut extras = sim_fleet(5, 7).split_off(3);
    let shift = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 8.0 };
    d.observe_verdict_at("job-00", shift, 600);
    d.submit_at(extras.remove(0), 700);
    d.observe_verdict_at("job-01", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 800);
    d.submit_at(extras.remove(0), 900);
    d.retire_at("job-02", 900);
    d
}

#[test]
fn overlapped_profiling_drains_byte_identically_to_the_synchronous_daemon() {
    // The perf-opt acceptance guard: with the pool overlapping probe
    // execution across replans, completions still merge in dispatch
    // order, so the drained report must not move a single byte.
    let sync = busy_daemon(0).drain().expect("sync drain");
    let overlapped = busy_daemon(1).drain().expect("overlapped drain");
    assert_eq!(
        json::to_string(&sync.to_json()),
        json::to_string(&overlapped.to_json()),
        "overlapped drain diverged from the synchronous daemon"
    );
}

#[test]
fn stub_engine_backend_plugs_into_the_session() {
    // The builder accepts a PJRT backend factory with no simulator types
    // at the call site (the placement home is a *name*). Without the
    // `pjrt` feature the stub engine refuses to build, and that error
    // must surface through the session — proving the pipeline reached
    // the backend without assuming the simulator.
    let factory = EngineBackendFactory::shared(default_artifacts_dir(), "arima", 1, 4.0);
    let spec = FleetJobSpec::with_backend("pjrt-arima", "wally", factory, 1).expect("home node");
    assert_eq!(spec.label(), "pjrt/arima");
    let result = FleetSession::builder().config(quick_cfg(1, 1)).job(spec).run();
    if pjrt_enabled() && artifacts_available() {
        let report = result.expect("real PJRT fleet run");
        assert_eq!(report.summary().outcomes[0].label, "pjrt/arima");
    } else {
        let err = result.expect_err("stub engine (or missing artifacts) cannot execute");
        let text = format!("{err:#}");
        assert!(text.contains("pjrt-arima"), "failure names the job: {text}");
    }
}

#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "requires PJRT artifacts")]
fn pjrt_fleet_session_profiles_real_artifacts() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let factory = EngineBackendFactory::shared(default_artifacts_dir(), "arima", 1, 2.0);
    let spec = FleetJobSpec::with_backend("pjrt-arima", "wally", factory, 1).expect("home node");
    let cfg = FleetConfig {
        workers: 1,
        rounds: 2,
        profiler: ProfilerConfig { samples: 40, n_initial: 2, max_steps: 4, ..Default::default() },
        horizon: 100,
        ..Default::default()
    };
    let report = FleetSession::builder().config(cfg).job(spec).run().expect("pjrt fleet run");
    let summary = report.summary();
    assert_eq!(summary.outcomes.len(), 1);
    assert_eq!(summary.outcomes[0].label, "pjrt/arima");
    assert!(summary.outcomes[0].model.eval(1.0).is_finite());
    assert!(report.cache.inserts > 0, "real probes populate the shared cache");
}

#[test]
fn work_queue_drains_with_more_jobs_than_workers() {
    // 12 jobs on 3 workers: every job must be profiled exactly once and
    // the worker ids span the pool.
    let report = FleetSession::builder()
        .config(quick_cfg(3, 1))
        .jobs(sim_fleet(12, 3))
        .run()
        .expect("fleet run");
    let summary = report.summary();
    assert_eq!(summary.outcomes.len(), 12);
    assert!(summary.outcomes.iter().all(|o| o.worker < 3));
    let mut names: Vec<&str> = summary.outcomes.iter().map(|o| o.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), 12, "each job profiled exactly once");
}

#[test]
fn replicas_of_one_job_class_share_cache_entries() {
    // Two replicas of the same (device, algo) class: the second replica's
    // probes reuse the first one's measurements even within a single
    // round, because they share the cache label.
    let pi4 = node("pi4").unwrap();
    let report = FleetSession::builder()
        .config(quick_cfg(1, 1))
        .job(FleetJobSpec::simulated("cam-a", pi4, Algo::Lstm, 5))
        .job(FleetJobSpec::simulated("cam-b", pi4, Algo::Lstm, 5))
        .run()
        .expect("fleet run");
    let summary = report.summary();
    let stats = summary.cache;
    assert!(stats.hits > 0, "replica probes must hit the shared cache");
    // Both replicas end with usable models and assignments on the node.
    assert_eq!(summary.plans.len(), 1);
    assert_eq!(summary.plans[0].0, "pi4");
    assert!(summary.assignment("cam-a").is_some());
    assert!(summary.assignment("cam-b").is_some());
}

#[test]
fn capacity_plans_cover_every_job_and_respect_capacity() {
    let report = FleetSession::builder()
        .config(quick_cfg(4, 2))
        .jobs(sim_fleet(10, 11))
        .run()
        .expect("fleet run");
    let summary = report.summary();
    let planned: usize = summary.plans.iter().map(|(_, p)| p.assignments.len()).sum();
    assert_eq!(planned, 10, "every job appears in exactly one node plan");
    for (node_name, plan) in &summary.plans {
        assert!(
            plan.total_assigned <= plan.capacity + 1e-9,
            "{node_name}: guaranteed set exceeds capacity"
        );
    }
    for o in &summary.outcomes {
        let a = summary.assignment(&o.name).expect("assignment exists");
        assert!(a.adjustment.limit > 0.0);
    }
}

#[test]
fn rebalance_migrates_shed_jobs_to_under_subscribed_nodes() {
    // An over-subscribed Pi 4 carries twelve 12 Hz streams (each needs
    // ~0.7 CPU just-in-time — far beyond 4 cores), while wally and e216
    // idle with one light job each. The scheduler must migrate the shed
    // jobs out, strictly increase the number of guaranteed jobs over the
    // no-migration baseline, and regress zero previously-guaranteed jobs.
    let pi4 = node("pi4").unwrap();
    let wally = node("wally").unwrap();
    let e216 = node("e216").unwrap();
    let mut specs: Vec<FleetJobSpec> = (0..12usize)
        .map(|i| {
            let mut s = FleetJobSpec::simulated(&format!("cam-{i:02}"), pi4, Algo::Arima, 7);
            s.priority = 1 + (i % 3) as i32;
            s.arrivals = ArrivalProcess::Fixed(12.0);
            s
        })
        .collect();
    specs.push(FleetJobSpec::simulated("light-wally", wally, Algo::Arima, 3));
    specs.push(FleetJobSpec::simulated("light-e216", e216, Algo::Birch, 4));

    let report = FleetSession::builder()
        .config(quick_cfg(2, 1))
        .jobs(specs)
        .rebalance(true)
        .run()
        .expect("fleet run");
    let summary = report.summary();
    let plan = report.plan.as_ref().expect("rebalance stage ran");

    // The no-migration baseline really is over-subscribed: pi4 shed jobs.
    let baseline_guaranteed: Vec<String> = summary
        .plans
        .iter()
        .flat_map(|(_, p)| p.assignments.iter())
        .filter(|a| a.guaranteed)
        .map(|a| a.name.clone())
        .collect();
    let (_, pi4_plan) = summary.plans.iter().find(|(n, _)| n == "pi4").unwrap();
    let pi4_shed = pi4_plan.assignments.iter().filter(|a| !a.guaranteed).count();
    assert!(pi4_shed > 0, "scenario must over-subscribe pi4");
    assert_eq!(plan.metrics.guaranteed_before, baseline_guaranteed.len());

    // Shed jobs migrated off the Pi into idle capacity.
    assert!(!plan.migrations.is_empty(), "shed jobs must migrate");
    for m in &plan.migrations {
        assert_eq!(m.from, "pi4");
        assert!(m.to == "wally" || m.to == "e216");
        let (node_name, a) = plan.assignment(&m.job).expect("migrated job planned");
        assert_eq!(node_name, m.to);
        assert!(a.guaranteed, "{} migrated but still best-effort", m.job);
    }

    // Strictly more guaranteed jobs than the baseline…
    assert!(
        plan.metrics.guaranteed_after > plan.metrics.guaranteed_before,
        "rebalance must win: {:?}",
        plan.metrics
    );
    // …with zero previously-guaranteed jobs regressed…
    for name in &baseline_guaranteed {
        let (_, a) = plan.assignment(name).expect("baseline job still planned");
        assert!(a.guaranteed, "{name} was guaranteed before rebalancing");
    }
    // …and every node still within capacity.
    for (name, p) in &plan.plans {
        assert!(p.total_assigned <= p.capacity + 1e-9, "{name} over capacity");
    }
}

#[test]
fn varying_arrivals_drive_rate_demand() {
    // A job with a faster stream must register a higher rate demand.
    let wally = node("wally").unwrap();
    let mut slow = FleetJobSpec::simulated("slow", wally, Algo::Arima, 1);
    slow.arrivals = ArrivalProcess::Fixed(1.0);
    let mut fast = FleetJobSpec::simulated("fast", wally, Algo::Arima, 1);
    fast.arrivals = ArrivalProcess::Varying { lo: 2.0, hi: 8.0, period: 100.0 };
    let report = FleetSession::builder()
        .config(quick_cfg(2, 1))
        .jobs([slow, fast])
        .run()
        .expect("fleet run");
    let summary = report.summary();
    let rate = |n: &str| summary.outcomes.iter().find(|o| o.name == n).unwrap().rate_hz;
    assert!((rate("slow") - 1.0).abs() < 1e-9);
    assert!(rate("fast") > 7.0);
    // The faster job needs at least as much CPU.
    let limit = |n: &str| summary.assignment(n).unwrap().adjustment.limit;
    assert!(limit("fast") >= limit("slow"));
}

#[test]
fn report_out_and_cache_file_round_trip() {
    // The CLI contract behind `--out report.json --cache-file cache.json`:
    // the emitted report parses back, and a cache snapshot restored into a
    // fresh session replays the whole roster (≥ 50% hit rate immediately).
    let cache = Arc::new(MeasurementCache::new());
    let report = FleetSession::builder()
        .config(quick_cfg(2, 1))
        .jobs(sim_fleet(4, 13))
        .cache(cache.clone())
        .run()
        .expect("cold run");
    let report_text = json::to_string(&report.to_json());
    let parsed = json::parse(&report_text).expect("report parses back");
    assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));

    let snapshot_text = json::to_string(&cache.snapshot());
    let restored = Arc::new(MeasurementCache::new());
    let n = restored
        .restore(&json::parse(&snapshot_text).expect("snapshot parses"))
        .expect("snapshot restores");
    assert!(n.restored > 0);
    assert_eq!(n.refused(), 0, "a live snapshot restores without refusals");
    let rerun = FleetSession::builder()
        .config(quick_cfg(2, 1))
        .jobs(sim_fleet(4, 13))
        .cache(restored)
        .run()
        .expect("warm run");
    assert!(
        rerun.hit_rate() >= 0.5,
        "restored cache must replay the re-run: hit rate {:.2}",
        rerun.hit_rate()
    );
    assert_eq!(rerun.summary().executed_wallclock(), 0.0, "full replay");
}
