//! Bench/regenerator for table1 — runs the experiment end-to-end, reports
//! wallclock, and prints the paper-comparison rendering.
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = streamprof::repro::table1::run();
    println!("{}", report.rendered);
    println!("[bench] table1_nodes: regenerated in {:.2?}", t0.elapsed());
    for p in &report.csv_paths {
        println!("[bench] wrote {}", p.display());
    }
}
