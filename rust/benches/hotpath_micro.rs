//! Micro-benchmarks over every L3 hot path (hand-rolled harness — criterion
//! is not in the offline vendor set). These feed EXPERIMENTS.md §Perf.
//!
//! Paths measured:
//!   * nested-model LM fit (the per-step cost of NMS),
//!   * GP fit + EI argmax (the per-step cost of BO),
//!   * early-stop monitor push (per profiled sample),
//!   * simulated observation + full profiling session (experiment harness),
//!   * SMAPE evaluation over a grid,
//!   * PJRT per-sample step and chunked step (the serving request path,
//!     when artifacts are built).

use streamprof::coordinator::{smape_vs_dataset, Profiler, ProfilerConfig, SimulatedBackend};
use streamprof::earlystop::{EarlyStopConfig, EarlyStopMonitor};
use streamprof::fit::{ProfilePoint, RuntimeModel};
use streamprof::gp::{Gp, Matern52};
use streamprof::runtime::{artifacts_available, default_artifacts_dir, Engine};
use streamprof::simulator::{node, Algo, SimulatedJob};
use streamprof::strategies;
use streamprof::stream::SensorStream;
use streamprof::util::bench::{black_box, Bench};
use streamprof::util::Rng;
use streamprof::workloads::PjrtJob;

fn main() {
    let mut csv: Vec<String> = vec!["name,mean_ns,p50_ns,p95_ns".into()];
    let mut run = |b: Bench| {
        println!("{}", b.report());
        csv.push(b.csv_row());
    };

    // --- fit: nested LM on 6 noisy points (NMS per-step cost) ---
    let mut rng = Rng::new(1);
    let pts: Vec<ProfilePoint> = [0.1f64, 0.2, 0.5, 1.0, 2.0, 4.0]
        .iter()
        .map(|&r| {
            let y = 0.05 * r.powf(-0.9) + 0.01;
            ProfilePoint::new(r, y * (1.0 + 0.05 * rng.normal()))
        })
        .collect();
    let mut b = Bench::new("fit/lm_6pt_full_model");
    b.iter(|| RuntimeModel::fit(black_box(&pts)));
    run(b);

    let warm = RuntimeModel::fit(&pts);
    let mut b = Bench::new("fit/lm_6pt_warm_start");
    b.iter(|| RuntimeModel::fit_warm(black_box(&pts), Some(&warm)));
    run(b);

    let m = warm.clone();
    let mut b = Bench::new("fit/model_eval");
    b.iter(|| black_box(m.eval(black_box(0.7))));
    run(b);

    let mut b = Bench::new("fit/model_invert");
    b.iter(|| black_box(m.invert(black_box(0.2))));
    run(b);

    // --- gp: fit + EI argmax over a 40-point grid (BO per-step cost) ---
    let obs: Vec<(f64, f64)> = (0..8).map(|i| (0.1 + i as f64 * 0.5, (i as f64).sin())).collect();
    let cands: Vec<f64> = (1..=40).map(|i| i as f64 * 0.1).collect();
    let mut b = Bench::new("gp/fit8_plus_ei_40cand");
    b.iter(|| {
        let mut gp = Gp::new(Matern52::default(), 1e-2, 0.1, 4.0);
        gp.fit(black_box(&obs));
        black_box(gp.argmax_ei(&cands, 0.9))
    });
    run(b);

    // --- early stopping: per-sample push (profiling inner loop) ---
    let mut mon = EarlyStopMonitor::new(EarlyStopConfig::new(0.95, 0.0001));
    let mut x = 0.7f64;
    let mut b = Bench::new("earlystop/push");
    b.iter(|| {
        x = 0.2 + (x * 1.3).fract() * 0.01;
        black_box(mon.push(black_box(x)))
    });
    run(b);

    // --- simulator: single observation + full session ---
    let mut job = SimulatedJob::new(node("pi4").unwrap(), Algo::Lstm, 3);
    let mut b = Bench::new("sim/observe_mean_10k");
    b.iter(|| black_box(job.observe_mean(black_box(0.5), 10_000)));
    run(b);

    let mut seed = 0u64;
    let mut b = Bench::new("session/nms_6steps_sim");
    b.iter(|| {
        seed += 1;
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut backend =
            SimulatedBackend::new(SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, seed));
        Profiler::new(cfg, strategies::by_name("nms", seed).unwrap()).run(&mut backend)
    });
    run(b);

    // --- SMAPE over a 40-point dataset ---
    let truth: Vec<ProfilePoint> =
        (1..=40).map(|i| ProfilePoint::new(i as f64 * 0.1, 0.05 / (i as f64 * 0.1))).collect();
    let mut b = Bench::new("eval/smape_40pt");
    b.iter(|| black_box(smape_vs_dataset(&m, black_box(&truth))));
    run(b);

    // --- PJRT request path (needs artifacts) ---
    if artifacts_available() {
        let engine = Engine::new(&default_artifacts_dir()).expect("engine");
        let mut stream = SensorStream::new(7);
        for algo in Algo::ALL {
            let mut pj = PjrtJob::load(&engine, algo).expect("load");
            let x = stream.next_sample();
            let mut b = Bench::new(&format!("pjrt/{}_step", algo.name()));
            b.iter(|| pj.process_chunk(black_box(&x)).expect("step"));
            run(b);
        }
        let chunk = engine.manifest().chunk;
        let mut pj = PjrtJob::load_named(&engine, &format!("lstm_chunk{chunk}")).unwrap();
        let xs = stream.generate(chunk);
        let mut b = Bench::new(&format!("pjrt/lstm_chunk{chunk}_per_call"));
        b.iter(|| pj.process_chunk(black_box(&xs)).expect("chunk"));
        run(b);
    } else {
        println!("(skipping pjrt benches: artifacts not built)");
    }

    // Persist CSV for EXPERIMENTS.md §Perf.
    let dir = std::path::PathBuf::from("results");
    std::fs::create_dir_all(&dir).ok();
    std::fs::write(dir.join("hotpath_micro.csv"), csv.join("\n") + "\n").ok();
    println!("[bench] wrote results/hotpath_micro.csv");
}
