//! Bench/regenerator for fig3 — runs the experiment end-to-end, reports
//! wallclock, and prints the paper-comparison rendering.
//! Pass --full for the paper-scale repetition counts (default: quick).
use std::time::Instant;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = Instant::now();
    let report = streamprof::repro::fig3::run(!full);
    println!("{}", report.rendered);
    println!(
        "[bench] fig3_synthetic_targets ({}): regenerated in {:.2?}",
        if full { "full" } else { "quick" },
        t0.elapsed()
    );
    for p in &report.csv_paths {
        println!("[bench] wrote {}", p.display());
    }
}
