//! Fleet daemon throughput — the first fleet-scale baseline (hand-rolled
//! harness; criterion is not in the offline vendor set).
//!
//! Spins the event-driven fleet daemon over simulated rosters of 1k, 10k,
//! and 100k jobs and measures, per tier:
//!   * jobs profiled per second of real wallclock (the bootstrap sweep),
//!   * virtual profiling wallclock saved by the sharded measurement cache,
//!     plus its hit rate (rosters cycle 21 node/algo labels, so almost the
//!     whole fleet replays cached probes),
//!   * p99 verdict-to-replan latency — real time from an external drift
//!     verdict landing in the event queue to the localized replan that
//!     re-profiles the job against its observed rate,
//!   * the same verdict phase in overlapped mode (`probe_workers` > 0):
//!     p99 real time from a verdict landing to its probe being dispatched
//!     on the persistent pool, and the phase's wallclock speedup over the
//!     synchronous daemon,
//!   * the same bootstrap sweep with a telemetry store attached — the
//!     jobs/sec cost of recording every processed event as a compressed
//!     time-series point (target: ≤ 5% at the 10k tier),
//!   * the decentralized mesh stage: a full mesh sized to the tier
//!     (jobs/8 nodes, clamped to 16..=128) schedules a capped job slice
//!     local-optimistically and reports the ratio of its guaranteed count
//!     to the centralized planner's on the identical input, plus the
//!     gossip rounds spent getting there.
//!
//! Results land in BENCH_fleet.json, committed at the repository root as
//! the standing baseline; regenerate on quiet hardware with:
//!
//! ```bash
//! cargo bench --bench fleet_throughput -- --tier all --out ../BENCH_fleet.json
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use streamprof::coordinator::ProfilerConfig;
use streamprof::fit::{ModelKind, RuntimeModel};
use streamprof::fleet::worker::profile_job_with;
use streamprof::fleet::{
    mesh_rebalance, rebalance_across, sim_fleet, DriftVerdict, FleetConfig, FleetDaemon, FleetJob,
    MeasurementCache, MeshConfig, MeshTopology, PriorCorpus, ProfilePass, TelemetryStore,
};
use streamprof::util::{json, Args, Json, Rng, Table};

/// Verdict cycles timed per tier (each is one verdict -> replan round trip).
const VERDICT_CYCLES: usize = 32;

struct TierResult {
    tier: &'static str,
    jobs: usize,
    jobs_per_sec: f64,
    sweep_s: f64,
    saved_s: f64,
    hit_rate: f64,
    p99_ms: f64,
    p99_first_probe_ms: f64,
    overlap_speedup: f64,
    jobs_per_sec_telemetry: f64,
    overhead_pct: f64,
    telemetry_points: usize,
    mesh_nodes: usize,
    mesh_guaranteed_ratio: f64,
    gossip_rounds: u64,
    transfer_probe_savings_pct: f64,
}

impl TierResult {
    fn to_json(&self) -> Json {
        Json::obj([
            ("tier", Json::str(self.tier)),
            ("jobs", Json::num(self.jobs as f64)),
            ("jobs_per_sec", Json::num(self.jobs_per_sec)),
            ("sweep_wallclock_s", Json::num(self.sweep_s)),
            ("cache_saved_wallclock_s", Json::num(self.saved_s)),
            ("hit_rate", Json::num(self.hit_rate)),
            ("verdicts", Json::num(VERDICT_CYCLES as f64)),
            ("p99_verdict_to_replan_ms", Json::num(self.p99_ms)),
            ("p99_verdict_to_first_probe_ms", Json::num(self.p99_first_probe_ms)),
            ("overlap_speedup", Json::num(self.overlap_speedup)),
            ("jobs_per_sec_telemetry", Json::num(self.jobs_per_sec_telemetry)),
            ("telemetry_overhead_pct", Json::num(self.overhead_pct)),
            ("telemetry_points", Json::num(self.telemetry_points as f64)),
            ("mesh_nodes", Json::num(self.mesh_nodes as f64)),
            ("mesh_guaranteed_ratio", Json::num(self.mesh_guaranteed_ratio)),
            ("gossip_rounds", Json::num(self.gossip_rounds as f64)),
            ("transfer_probe_savings_pct", Json::num(self.transfer_probe_savings_pct)),
        ])
    }
}

fn tier_cfg() -> FleetConfig {
    FleetConfig {
        workers: 8,
        rounds: 1,
        strategy: "nms".to_string(),
        profiler: ProfilerConfig { samples: 64, max_steps: 4, ..Default::default() },
        horizon: 1000,
        probe_workers: 0,
        transfer: false,
        plan_quantile: None,
    }
}

/// The verdict phase re-run in overlapped mode: every verdict is
/// pre-scheduled, so each completion defers behind the next verdict and
/// profiling overlaps across replans on the persistent probe pool.
/// Returns p99 real time from a verdict landing to its probe being
/// dispatched, plus the whole phase's speedup over the synchronous
/// daemon's identical phase.
fn run_tier_overlapped(jobs: usize, sync_phase_s: f64) -> Result<(f64, f64)> {
    let cfg = FleetConfig { probe_workers: 8, ..tier_cfg() };
    let mut daemon = FleetDaemon::builder()
        .config(cfg)
        .jobs(sim_fleet(jobs, 7))
        .rebalance(false)
        .cache(Arc::new(MeasurementCache::new()))
        .build();
    daemon.run_until(0)?; // untimed bootstrap: the phase under test starts warm
    for k in 0..VERDICT_CYCLES {
        let job = format!("job-{:02}", k % jobs);
        let verdict = DriftVerdict::RateShift {
            provisioned_hz: 2.0,
            observed_hz: 4.0 + (k % 5) as f64,
        };
        daemon.observe_verdict_at(&job, verdict, 1000 + k as u64);
    }
    let t0 = Instant::now();
    let mut lat_ms = Vec::with_capacity(VERDICT_CYCLES);
    for k in 0..VERDICT_CYCLES {
        let t = Instant::now();
        daemon.run_until(1000 + k as u64)?;
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let phase_s = t0.elapsed().as_secs_f64().max(1e-9);
    // The last cycle has no later event to defer behind, so it settles
    // the whole backlog — a drain cost, not a dispatch latency.
    lat_ms.pop();
    lat_ms.sort_by(f64::total_cmp);
    let p99 = lat_ms[((lat_ms.len() as f64 * 0.99).ceil() as usize).saturating_sub(1)];
    Ok((p99, sync_phase_s / phase_s))
}

/// The bootstrap sweep re-run with a telemetry store attached: same
/// roster, fresh cache, measuring the jobs/sec cost of recording every
/// processed event as a compressed point.
fn run_tier_telemetry(jobs: usize) -> Result<(f64, usize)> {
    let store = Arc::new(TelemetryStore::new());
    let mut daemon = FleetDaemon::builder()
        .config(tier_cfg())
        .jobs(sim_fleet(jobs, 7))
        .rebalance(false)
        .cache(Arc::new(MeasurementCache::new()))
        .telemetry(store.clone())
        .build();
    let t0 = Instant::now();
    daemon.run_until(0)?;
    let sweep_s = t0.elapsed().as_secs_f64().max(1e-9);
    Ok((jobs as f64 / sweep_s, store.total_points()))
}

/// Deterministic job set homed on the mesh's member nodes. The daemon
/// tiers use `sim_fleet`, whose homes are the 7 base machines — the mesh
/// stage needs jobs the topology can place directly on its own roster.
fn mesh_fleet(topo: &MeshTopology, n_jobs: usize) -> Vec<FleetJob> {
    let mut rng = Rng::new(0xBE5C);
    (0..n_jobs)
        .map(|i| {
            let node = topo.nodes()[rng.below(topo.nodes().len())];
            FleetJob {
                name: format!("mjob-{i:05}"),
                node,
                model: RuntimeModel {
                    kind: ModelKind::Full,
                    a: rng.uniform(0.005, 0.08),
                    b: node.scaling,
                    c: rng.uniform(0.0005, 0.005),
                    d: node.limit_stretch(),
                    fit_cost: 0.0,
                },
                rate_hz: rng.uniform(0.5, 20.0),
                priority: 1 + rng.below(5) as i32,
            }
        })
        .collect()
}

/// Decentralized mesh stage: a full mesh sized to the tier schedules a
/// capped job slice local-optimistically; `mesh_guaranteed_ratio` is the
/// quality figure (mesh guaranteed count over the centralized planner's
/// on the identical input) that the CI schema check guards.
fn run_tier_mesh(jobs: usize) -> Result<(usize, f64, u64)> {
    let nodes = (jobs / 8).clamp(16, 128);
    let topo = MeshTopology::parse(&format!("full:{nodes}"))?;
    let mesh_jobs = mesh_fleet(&topo, jobs.min(4000));
    let centralized = rebalance_across(&mesh_jobs, topo.nodes());
    let cfg = MeshConfig { every: 200, rounds: 8 };
    let (plan, stats) = mesh_rebalance(&mesh_jobs, topo, &cfg, &[])?;
    let ratio =
        plan.metrics.guaranteed_after as f64 / centralized.metrics.guaranteed_after.max(1) as f64;
    Ok((nodes, ratio, stats.gossip_rounds))
}

/// Transfer-priming stage (fixed size, tier-independent): profile the
/// 21-label workload zoo cold to build a corpus, then profile one
/// recipient per label twice on FRESH caches — once cold, once primed by
/// its corpus donor. Probes = executed cache misses; the fresh caches
/// keep the shared-label replay path from masking what the prior saves.
fn run_tier_transfer() -> Result<f64> {
    let cfg = tier_cfg();
    let donor_cache = MeasurementCache::new();
    let mut corpus = PriorCorpus::new();
    for spec in sim_fleet(21, 7) {
        let outcome = profile_job_with(&spec, &cfg, &donor_cache, 0, &ProfilePass::default())?;
        corpus.absorb(&outcome);
    }
    let recipients = sim_fleet(42, 7).split_off(21);
    let (mut cold, mut primed) = (0u64, 0u64);
    for spec in &recipients {
        let c = profile_job_with(spec, &cfg, &MeasurementCache::new(), 0, &ProfilePass::default())?;
        cold += c.cache_delta.misses;
        let pass = ProfilePass { transfer: corpus.donor_for(spec), ..ProfilePass::default() };
        let p = profile_job_with(spec, &cfg, &MeasurementCache::new(), 0, &pass)?;
        primed += p.cache_delta.misses;
    }
    Ok(100.0 * (cold as f64 - primed as f64) / (cold as f64).max(1.0))
}

fn run_tier(tier: &'static str, jobs: usize) -> Result<TierResult> {
    let cfg = tier_cfg();
    let cache = Arc::new(MeasurementCache::new());
    let mut daemon = FleetDaemon::builder()
        .config(cfg)
        .jobs(sim_fleet(jobs, 7))
        .rebalance(false)
        .cache(cache.clone())
        .build();

    // Bootstrap sweep: the whole roster arrives at tick 0 and one
    // coalesced replan profiles it (cold labels execute, the rest replay).
    let t0 = Instant::now();
    daemon.run_until(0)?;
    let sweep_s = t0.elapsed().as_secs_f64().max(1e-9);

    // Verdict-to-replan latency: an external rate-shift verdict lands and
    // the daemon re-profiles just that job against the observed rate.
    let phase_t0 = Instant::now();
    let mut lat_ms = Vec::with_capacity(VERDICT_CYCLES);
    for k in 0..VERDICT_CYCLES {
        let job = format!("job-{:02}", k % jobs);
        let verdict = DriftVerdict::RateShift {
            provisioned_hz: 2.0,
            observed_hz: 4.0 + (k % 5) as f64,
        };
        let tick = 1000 + k as u64;
        let t = Instant::now();
        daemon.observe_verdict_at(&job, verdict, tick);
        daemon.run_until(tick)?;
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let sync_phase_s = phase_t0.elapsed().as_secs_f64().max(1e-9);
    lat_ms.sort_by(f64::total_cmp);
    let p99 = lat_ms[((lat_ms.len() as f64 * 0.99).ceil() as usize).saturating_sub(1)];

    let stats = cache.stats();
    let jobs_per_sec = jobs as f64 / sweep_s;
    let (p99_first_probe_ms, overlap_speedup) = run_tier_overlapped(jobs, sync_phase_s)?;
    let (jobs_per_sec_telemetry, telemetry_points) = run_tier_telemetry(jobs)?;
    let (mesh_nodes, mesh_guaranteed_ratio, gossip_rounds) = run_tier_mesh(jobs)?;
    let transfer_probe_savings_pct = run_tier_transfer()?;
    Ok(TierResult {
        tier,
        jobs,
        jobs_per_sec,
        sweep_s,
        saved_s: stats.saved_wallclock,
        hit_rate: stats.hit_rate(),
        p99_ms: p99,
        p99_first_probe_ms,
        overlap_speedup,
        jobs_per_sec_telemetry,
        overhead_pct: (1.0 - jobs_per_sec_telemetry / jobs_per_sec) * 100.0,
        telemetry_points,
        mesh_nodes,
        mesh_guaranteed_ratio,
        gossip_rounds,
        transfer_probe_savings_pct,
    })
}

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let tier = args.opt_or("tier", "1k");
    let out = args.opt_or("out", "../BENCH_fleet.json");
    let tiers: &[(&'static str, usize)] = match tier.as_str() {
        "1k" => &[("1k", 1000)],
        "10k" => &[("10k", 10_000)],
        "100k" => &[("100k", 100_000)],
        "all" => &[("1k", 1000), ("10k", 10_000), ("100k", 100_000)],
        other => bail!("unknown --tier '{other}' (1k|10k|100k|all)"),
    };

    let mut results = Vec::new();
    for &(name, jobs) in tiers {
        results.push(run_tier(name, jobs)?);
    }

    let headers = [
        "tier", "jobs", "jobs/s", "jobs/s tel", "ovh %", "saved (s)", "hit rate", "p99 (ms)",
        "p99 disp (ms)", "overlap x", "mesh ratio", "xfer save %",
    ];
    let mut table = Table::new(&headers).with_title("Fleet daemon throughput");
    for r in &results {
        table.rowd(&[
            &r.tier,
            &r.jobs,
            &format!("{:.0}", r.jobs_per_sec),
            &format!("{:.0}", r.jobs_per_sec_telemetry),
            &format!("{:.1}", r.overhead_pct),
            &format!("{:.1}", r.saved_s),
            &format!("{:.2}", r.hit_rate),
            &format!("{:.3}", r.p99_ms),
            &format!("{:.3}", r.p99_first_probe_ms),
            &format!("{:.2}", r.overlap_speedup),
            &format!("{:.2}", r.mesh_guaranteed_ratio),
            &format!("{:.1}", r.transfer_probe_savings_pct),
        ]);
    }
    println!("{}", table.render());

    let doc = Json::obj([
        ("version", Json::num(1.0)),
        ("bench", Json::str("fleet_throughput")),
        ("measured", Json::Bool(true)),
        ("tiers", Json::Arr(results.iter().map(TierResult::to_json).collect())),
    ]);
    std::fs::write(&out, json::to_string(&doc)).with_context(|| format!("writing {out}"))?;
    println!("[bench] wrote {out}");
    Ok(())
}
