//! Bench/regenerator for fig2 — runs the experiment end-to-end, reports
//! wallclock, and prints the paper-comparison rendering.
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = streamprof::repro::fig2::run();
    println!("{}", report.rendered);
    println!("[bench] fig2_early_stopping: regenerated in {:.2?}", t0.elapsed());
    for p in &report.csv_paths {
        println!("[bench] wrote {}", p.display());
    }
}
