//! Ablation bench: regenerate the design-choice comparison (DESIGN.md).
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = streamprof::repro::ablation::run();
    println!("{}", report.rendered);
    println!("[bench] ablations: regenerated in {:.2?}", t0.elapsed());
}
