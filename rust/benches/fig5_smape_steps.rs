//! Bench/regenerator for fig5 — runs the experiment end-to-end, reports
//! wallclock, and prints the paper-comparison rendering.
//! Pass --full for the paper-scale repetition counts (default: quick).
use std::time::Instant;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let t0 = Instant::now();
    let report = streamprof::repro::fig5::run(!full);
    println!("{}", report.rendered);
    println!(
        "[bench] fig5_smape_steps ({}): regenerated in {:.2?}",
        if full { "full" } else { "quick" },
        t0.elapsed()
    );
    for p in &report.csv_paths {
        println!("[bench] wrote {}", p.display());
    }
}
