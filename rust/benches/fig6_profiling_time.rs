//! Bench/regenerator for fig6 — profiling time vs. steps + early stopping.
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let report = streamprof::repro::fig6::run();
    println!("{}", report.rendered);
    println!("[bench] fig6_profiling_time: regenerated in {:.2?}", t0.elapsed());
    for p in &report.csv_paths {
        println!("[bench] wrote {}", p.display());
    }
}
