//! Student-t and normal distribution functions.
//!
//! The t CDF is computed through the regularized incomplete beta function
//! (Lentz continued fraction), and `t_quantile` inverts it with a bracketed
//! Newton iteration. Accuracy is ~1e-10 across the df/levels used by the
//! early-stopping monitor (95% / 99.5%); values are validated against scipy
//! in the unit tests.

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
fn ln_gamma(x: f64) -> f64 {
    const COEFFS: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI.ln() - (std::f64::consts::PI * x).sin().ln() - ln_gamma(1.0 - x)
    } else {
        let x = x - 1.0;
        let mut a = COEFFS[0];
        let t = x + 7.5;
        for (i, &c) in COEFFS.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
    }
}

/// Continued fraction for the incomplete beta function (Numerical Recipes
/// `betacf`, modified Lentz).
fn betacf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-16;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// Regularized incomplete beta function I_x(a, b).
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front =
        ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b) + a * x.ln() + b * (1.0 - x).ln();
    let front = ln_front.exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        front * betacf(a, b, x) / a
    } else {
        1.0 - front * betacf(b, a, 1.0 - x) / b
    }
}

/// Student-t CDF with `df` degrees of freedom.
pub fn t_cdf(t: f64, df: f64) -> f64 {
    debug_assert!(df > 0.0);
    let x = df / (df + t * t);
    let p = 0.5 * betainc(0.5 * df, 0.5, x);
    if t >= 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Student-t quantile: smallest `t` with `t_cdf(t, df) >= p`.
///
/// Bracketed Newton iteration seeded by the normal quantile; falls back to
/// bisection steps when Newton leaves the bracket.
pub fn t_quantile(p: f64, df: f64) -> f64 {
    assert!((0.0..1.0).contains(&p) && p > 0.0, "p in (0,1)");
    assert!(df > 0.0);
    if (p - 0.5).abs() < 1e-15 {
        return 0.0;
    }
    // Initial guess from the normal quantile with a Cornish-Fisher-ish df
    // correction; then expand a bracket around it.
    let z = normal_quantile(p);
    let g1 = (z.powi(3) + z) / 4.0;
    let mut t = z + g1 / df;
    let (mut lo, mut hi): (f64, f64) = (-1e10, 1e10);
    for _ in 0..200 {
        let f = t_cdf(t, df) - p;
        if f.abs() < 1e-13 {
            break;
        }
        if f > 0.0 {
            hi = hi.min(t);
        } else {
            lo = lo.max(t);
        }
        let pdf = t_pdf(t, df);
        let mut next = if pdf > 1e-300 { t - f / pdf } else { f64::NAN };
        if !next.is_finite() || next <= lo || next >= hi {
            // Bisection fallback (with sane outer bounds).
            let l = if lo.is_finite() && lo > -1e9 { lo } else { t - 1.0 - t.abs() };
            let h = if hi.is_finite() && hi < 1e9 { hi } else { t + 1.0 + t.abs() };
            next = 0.5 * (l + h);
        }
        if (next - t).abs() < 1e-14 * (1.0 + t.abs()) {
            t = next;
            break;
        }
        t = next;
    }
    t
}

/// Student-t pdf.
pub fn t_pdf(t: f64, df: f64) -> f64 {
    let ln_c = ln_gamma(0.5 * (df + 1.0))
        - ln_gamma(0.5 * df)
        - 0.5 * (df * std::f64::consts::PI).ln();
    (ln_c - 0.5 * (df + 1.0) * (1.0 + t * t / df).ln()).exp()
}

/// Standard normal pdf.
pub fn normal_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via erfc (Numerical Recipes Chebyshev fit, |err| < 1.2e-7).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal quantile (Acklam's rational approximation, refined by one
/// Halley step; |err| < 1e-9).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p in (0,1)");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    let x = if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley refinement.
    let e = normal_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from scipy.stats.
    #[test]
    fn t_quantile_matches_scipy() {
        // scipy.stats.t.ppf(0.975, df)
        let cases = [
            (0.975, 1.0, 12.706204736),
            (0.975, 4.0, 2.7764451052),
            (0.975, 9.0, 2.2621571628),
            (0.975, 29.0, 2.0452296421),
            (0.975, 99.0, 1.9842169516),
            (0.9975, 9.0, 3.6896623923), // 99.5% two-sided
            (0.9975, 99.0, 2.8713076612),
            (0.95, 4.0, 2.1318467863),
            (0.05, 4.0, -2.1318467863),
        ];
        for (p, df, want) in cases {
            let got = t_quantile(p, df);
            assert!(
                (got - want).abs() < 1e-6,
                "t_quantile({p},{df}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn t_cdf_matches_scipy() {
        // scipy.stats.t.cdf(x, df)
        let cases = [
            (0.0, 5.0, 0.5),
            (1.0, 5.0, 0.8183912662),
            (2.0, 10.0, 0.9633059826),
            (-1.5, 3.0, 0.1152919326),
        ];
        for (x, df, want) in cases {
            assert!((t_cdf(x, df) - want).abs() < 1e-8, "t_cdf({x},{df})");
        }
    }

    #[test]
    fn t_cdf_quantile_roundtrip() {
        for df in [1.0, 2.0, 5.0, 30.0, 200.0] {
            for p in [0.01, 0.1, 0.5, 0.9, 0.975, 0.995, 0.9975] {
                let t = t_quantile(p, df);
                assert!(
                    (t_cdf(t, df) - p).abs() < 1e-9,
                    "roundtrip p={p} df={df}: cdf={}",
                    t_cdf(t, df)
                );
            }
        }
    }

    #[test]
    fn t_approaches_normal_for_large_df() {
        let t = t_quantile(0.975, 1e6);
        assert!((t - 1.959964).abs() < 1e-3);
    }

    #[test]
    fn normal_cdf_values() {
        // The erfc Chebyshev fit is accurate to ~1.2e-7.
        assert!((normal_cdf(0.0) - 0.5).abs() < 2e-7);
        assert!((normal_cdf(1.959963985) - 0.975).abs() < 2e-7);
        assert!((normal_cdf(-1.0) - 0.1586552539).abs() < 2e-7);
    }

    #[test]
    fn normal_quantile_roundtrip() {
        for p in [0.001, 0.01, 0.3, 0.5, 0.7, 0.99, 0.999] {
            let x = normal_quantile(p);
            assert!((normal_cdf(x) - p).abs() < 1e-8, "p={p}");
        }
    }

    #[test]
    fn normal_pdf_peak() {
        assert!((normal_pdf(0.0) - 0.3989422804).abs() < 1e-9);
    }

    #[test]
    fn betainc_symmetry() {
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for (a, b, x) in [(2.0, 3.0, 0.4), (0.5, 0.5, 0.7), (5.0, 1.0, 0.2)] {
            let lhs = betainc(a, b, x);
            let rhs = 1.0 - betainc(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-10);
        }
    }

    #[test]
    fn ln_gamma_known() {
        // Γ(5)=24, Γ(0.5)=√π
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-10);
    }
}
