//! Statistics substrate: running moments, Student-t quantiles, normal
//! pdf/cdf, SMAPE (paper Eq. 3), confidence intervals.

mod running;
mod smape;
mod tdist;

pub use running::RunningStats;
pub use smape::{smape, smape_guarded};
pub use tdist::{normal_cdf, normal_pdf, normal_quantile, t_cdf, t_quantile};

/// Two-sided Student-t confidence interval for the mean of `stats` at
/// confidence level `conf` (e.g. 0.95). Returns `(lo, hi)`; `None` when
/// fewer than 2 samples are present.
pub fn t_confidence_interval(stats: &RunningStats, conf: f64) -> Option<(f64, f64)> {
    let n = stats.count();
    if n < 2 {
        return None;
    }
    let df = (n - 1) as f64;
    let alpha = 1.0 - conf;
    let t = t_quantile(1.0 - alpha / 2.0, df);
    let half = t * stats.std_dev() / (n as f64).sqrt();
    let mean = stats.mean();
    Some((mean - half, mean + half))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ci_shrinks_with_samples() {
        let mut s = RunningStats::new();
        // identical spread at n=10 and n=100 (same std), so CI must shrink.
        for i in 0..10 {
            s.push(if i % 2 == 0 { 1.0 } else { 2.0 });
        }
        let (lo1, hi1) = t_confidence_interval(&s, 0.95).unwrap();
        for i in 0..90 {
            s.push(if i % 2 == 0 { 1.0 } else { 2.0 });
        }
        let (lo2, hi2) = t_confidence_interval(&s, 0.95).unwrap();
        assert!(hi2 - lo2 < hi1 - lo1);
    }

    #[test]
    fn ci_matches_textbook_example() {
        // n=16, mean=10, s=2  =>  CI_95 = 10 ± 2.1314 * 2/4 = 10 ± 1.0657
        let mut s = RunningStats::new();
        // Construct a sample with exactly mean 10, sd 2: 8,12 repeated (sd=2.066..)
        // Instead verify against scipy-computed values with a concrete set:
        let xs = [9.0, 11.0, 10.5, 8.5, 12.0, 9.5, 10.0, 11.5];
        for x in xs {
            s.push(x);
        }
        // scipy.stats.t.interval(0.95, 7, loc=mean, scale=sem) ->
        // mean=10.25, sd=1.2247..., sem=0.43301, t=2.364624 -> half=1.02393
        let (lo, hi) = t_confidence_interval(&s, 0.95).unwrap();
        assert!((s.mean() - 10.25).abs() < 1e-12);
        assert!(((hi - lo) / 2.0 - 1.023938).abs() < 1e-4, "half={}", (hi - lo) / 2.0);
    }

    #[test]
    fn ci_none_with_single_sample() {
        let mut s = RunningStats::new();
        s.push(1.0);
        assert!(t_confidence_interval(&s, 0.95).is_none());
    }
}
