//! SMAPE — the paper's primary accuracy metric (Eq. 3):
//!
//! ```text
//! SMAPE = Σ |ŷᵢ − yᵢ| / Σ (yᵢ + ŷᵢ)   ∈ [0, 1]
//! ```
//!
//! assuming non-negative predictions; `smape_guarded` applies the paper's
//! `ŷᵢ = max(ŷᵢ, ε)` guard first.

/// Plain SMAPE per Eq. 3. Panics in debug builds on negative values.
pub fn smape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len(), "smape arity");
    let mut num = 0.0;
    let mut den = 0.0;
    for (&y, &yh) in truth.iter().zip(pred) {
        debug_assert!(y >= 0.0, "smape expects non-negative truth");
        num += (yh - y).abs();
        den += y + yh;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

/// SMAPE with the paper's ε-guard on predictions (`ŷ = max(ŷ, ε)`), which
/// also makes negative model extrapolations safe to score.
pub fn smape_guarded(truth: &[f64], pred: &[f64], eps: f64) -> f64 {
    let guarded: Vec<f64> = pred.iter().map(|&p| p.max(eps)).collect();
    smape(truth, &guarded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_is_zero() {
        let y = [1.0, 2.0, 3.0];
        assert_eq!(smape(&y, &y), 0.0);
    }

    #[test]
    fn worst_case_is_one() {
        // truth 0 vs pred >0 everywhere -> num == den -> 1.0
        let y = [0.0, 0.0];
        let p = [5.0, 1.0];
        assert_eq!(smape(&y, &p), 1.0);
    }

    #[test]
    fn bounded_in_unit_interval() {
        let y = [0.1, 4.0, 2.0, 7.5];
        let p = [0.4, 1.0, 9.0, 7.0];
        let s = smape(&y, &p);
        assert!((0.0..=1.0).contains(&s));
    }

    #[test]
    fn known_value() {
        // |2-1|/(1+2) aggregated: num=1+1=2, den=3+7=10 -> 0.2
        let y = [1.0, 4.0];
        let p = [2.0, 3.0];
        assert!((smape(&y, &p) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn guard_clips_negative_predictions() {
        let y = [1.0];
        let p = [-5.0];
        let s = smape_guarded(&y, &p, 1e-6);
        assert!(s <= 1.0 && s > 0.99);
    }

    #[test]
    fn symmetric_in_scale() {
        // SMAPE is scale-free: scaling truth+pred by k leaves it unchanged.
        let y = [1.0, 2.0, 3.0];
        let p = [1.5, 1.5, 3.5];
        let y10: Vec<f64> = y.iter().map(|v| v * 10.0).collect();
        let p10: Vec<f64> = p.iter().map(|v| v * 10.0).collect();
        assert!((smape(&y, &p) - smape(&y10, &p10)).abs() < 1e-12);
    }
}
