//! Welford's online mean/variance — used by the early-stopping monitor on
//! per-sample runtime streams.

#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl RunningStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        let delta2 = x - self.mean;
        self.m2 += delta * delta2;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Coefficient of variation (std/mean); 0 for degenerate inputs.
    pub fn cov(&self) -> f64 {
        if self.mean.abs() < f64::MIN_POSITIVE {
            0.0
        } else {
            self.std_dev() / self.mean
        }
    }

    /// Merge two accumulators (parallel profiling runs).
    pub fn merge(&self, other: &RunningStats) -> RunningStats {
        if self.n == 0 {
            return other.clone();
        }
        if other.n == 0 {
            return self.clone();
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        RunningStats {
            n,
            mean,
            m2,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
        (mean, var)
    }

    #[test]
    fn matches_naive_two_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.37).sin() * 5.0 + 10.0).collect();
        let mut s = RunningStats::new();
        for &x in &xs {
            s.push(x);
        }
        let (mean, var) = naive(&xs);
        assert!((s.mean() - mean).abs() < 1e-10);
        assert!((s.variance() - var).abs() < 1e-10);
    }

    #[test]
    fn merge_equals_concat() {
        let xs: Vec<f64> = (0..57).map(|i| (i as f64).sqrt()).collect();
        let (a_xs, b_xs) = xs.split_at(23);
        let mut a = RunningStats::new();
        let mut b = RunningStats::new();
        let mut whole = RunningStats::new();
        for &x in a_xs {
            a.push(x);
        }
        for &x in b_xs {
            b.push(x);
        }
        for &x in &xs {
            whole.push(x);
        }
        let merged = a.merge(&b);
        assert_eq!(merged.count(), whole.count());
        assert!((merged.mean() - whole.mean()).abs() < 1e-10);
        assert!((merged.variance() - whole.variance()).abs() < 1e-10);
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
    }

    #[test]
    fn degenerate_cases() {
        let s = RunningStats::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.variance(), 0.0);
        let mut s1 = RunningStats::new();
        s1.push(4.2);
        assert_eq!(s1.mean(), 4.2);
        assert_eq!(s1.variance(), 0.0);
        assert_eq!(s1.min(), 4.2);
        assert_eq!(s1.max(), 4.2);
    }

    #[test]
    fn merge_with_empty() {
        let mut a = RunningStats::new();
        a.push(1.0);
        a.push(3.0);
        let empty = RunningStats::new();
        let m = a.merge(&empty);
        assert_eq!(m.count(), 2);
        assert_eq!(m.mean(), 2.0);
        let m2 = empty.merge(&a);
        assert_eq!(m2.count(), 2);
    }
}
