//! Duty-cycle CPU throttle emulating Docker's `--cpus` CFS quota.
//!
//! Docker's `--cpus=R` (R < 1) gives a container `R * period` of CPU time
//! per scheduling period — i.e. a duty cycle: run, then stall until the
//! next period. We reproduce the observable effect for a single-threaded
//! job step: after a step that consumed `t_busy` of CPU, stall for
//! `t_busy * (1/R − 1)`, making the *effective* per-sample runtime
//! `t_busy / R`. For R ≥ 1 a single-threaded step cannot run faster than
//! unthrottled, so the effective runtime equals `t_busy` (multi-core
//! scaling of the paper's multi-threaded jobs is covered by the node
//! simulator — see DESIGN.md §5).

use std::time::{Duration, Instant};

/// Throttle wrapper measuring + stalling around closures.
#[derive(Clone, Copy, Debug)]
pub struct Throttle {
    limit: f64,
    /// When true (default in tests/benches), the stall is accounted but not
    /// actually slept, keeping experiments fast while reporting identical
    /// effective runtimes.
    virtual_time: bool,
}

/// Result of one throttled execution.
#[derive(Clone, Copy, Debug)]
pub struct ThrottledRun {
    /// CPU time actually consumed by the closure.
    pub busy: Duration,
    /// Stall injected by the quota (zero when limit >= 1).
    pub stall: Duration,
}

impl ThrottledRun {
    /// The runtime an observer (and the profiler) sees.
    pub fn effective(&self) -> Duration {
        self.busy + self.stall
    }
}

impl Throttle {
    /// A real sleeping throttle (e2e serving example).
    pub fn sleeping(limit: f64) -> Self {
        assert!(limit > 0.0, "limit must be positive");
        Self { limit, virtual_time: false }
    }

    /// An accounting-only throttle (fast experiments; identical numbers).
    pub fn virtual_time(limit: f64) -> Self {
        assert!(limit > 0.0, "limit must be positive");
        Self { limit, virtual_time: true }
    }

    pub fn limit(&self) -> f64 {
        self.limit
    }

    /// Run `f` under the quota.
    pub fn run<T>(&self, f: impl FnOnce() -> T) -> (T, ThrottledRun) {
        let t0 = Instant::now();
        let out = f();
        let busy = t0.elapsed();
        let stall = if self.limit < 1.0 {
            busy.mul_f64(1.0 / self.limit - 1.0)
        } else {
            Duration::ZERO
        };
        if !self.virtual_time && !stall.is_zero() {
            std::thread::sleep(stall);
        }
        (out, ThrottledRun { busy, stall })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_work_us(us: u64) {
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_micros(us) {
            std::hint::black_box(0u64);
        }
    }

    #[test]
    fn effective_runtime_scales_inverse_to_limit() {
        let t = Throttle::virtual_time(0.25);
        let (_, run) = t.run(|| busy_work_us(200));
        let ratio = run.effective().as_secs_f64() / run.busy.as_secs_f64();
        assert!((ratio - 4.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn no_stall_at_full_allocation() {
        let t = Throttle::virtual_time(1.0);
        let (_, run) = t.run(|| busy_work_us(100));
        assert!(run.stall.is_zero());
        let t2 = Throttle::virtual_time(2.5);
        let (_, run2) = t2.run(|| busy_work_us(100));
        assert!(run2.stall.is_zero());
    }

    #[test]
    fn sleeping_throttle_actually_stalls() {
        let t = Throttle::sleeping(0.5);
        let t0 = Instant::now();
        let (_, run) = t.run(|| busy_work_us(2000));
        let wall = t0.elapsed();
        // Wall time should be ~2x busy time (±scheduler noise).
        assert!(wall >= run.busy + run.stall / 2, "wall {wall:?} run {run:?}");
    }

    #[test]
    fn returns_closure_output() {
        let t = Throttle::virtual_time(0.5);
        let (val, _) = t.run(|| 41 + 1);
        assert_eq!(val, 42);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn rejects_zero_limit() {
        Throttle::virtual_time(0.0);
    }
}
