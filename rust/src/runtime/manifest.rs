//! `artifacts/manifest.json` schema: what the AOT pipeline produced and how
//! to drive it (input order, roles, the output→input state loop).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Role of a tensor in the step-function contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Constant across the stream (weights); loaded once from init.bin.
    Param,
    /// Threaded state; replaced by the matching output after every call.
    State,
    /// The stream input (`x` or `xs`), provided per call.
    Stream,
    /// Plain output (err/thr/flag).
    Out,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "state" => Role::State,
            "stream" => Role::Stream,
            "out" => Role::Out,
            other => bail!("unknown role '{other}'"),
        })
    }
}

/// One tensor in the artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: Role,
    /// For state outputs: index of the input this output feeds.
    pub feeds: Option<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One lowered job variant.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub init_path: PathBuf,
    /// 0 for per-sample artifacts; T for scan'd chunk artifacts.
    pub chunk: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Index of the stream input (always last by AOT convention; verified).
    pub fn stream_input(&self) -> Result<usize> {
        let idx = self
            .inputs
            .iter()
            .position(|t| t.role == Role::Stream)
            .context("artifact has no stream input")?;
        if idx != self.inputs.len() - 1 {
            bail!("stream input must be last (artifact {})", self.name);
        }
        Ok(idx)
    }

    /// Load `init.bin`: per non-stream input, its f32 values (input order).
    pub fn load_init(&self) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(&self.init_path)
            .with_context(|| format!("reading {}", self.init_path.display()))?;
        let expect: usize = self
            .inputs
            .iter()
            .filter(|t| t.role != Role::Stream)
            .map(|t| t.elements() * 4)
            .sum();
        if bytes.len() != expect {
            bail!(
                "init blob size mismatch for {}: {} bytes, expected {expect}",
                self.name,
                bytes.len()
            );
        }
        let mut out = Vec::new();
        let mut off = 0;
        for t in self.inputs.iter().filter(|t| t.role != Role::Stream) {
            let n = t.elements();
            let mut vals = Vec::with_capacity(n);
            for i in 0..n {
                let b = &bytes[off + i * 4..off + i * 4 + 4];
                vals.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += n * 4;
            out.push(vals);
        }
        Ok(out)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub metrics: usize,
    pub chunk: usize,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let metrics = root
            .req("metrics")
            .map_err(anyhow::Error::msg)?
            .as_usize()
            .context("metrics not a number")?;
        let chunk = root.get("chunk").and_then(Json::as_usize).unwrap_or(0);
        let mut artifacts = Vec::new();
        for art in root
            .req("artifacts")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("artifacts not an array")?
        {
            artifacts.push(Self::parse_artifact(art, dir)?);
        }
        Ok(Manifest { metrics, chunk, artifacts })
    }

    fn parse_artifact(art: &Json, dir: &Path) -> Result<ArtifactSpec> {
        let name = art
            .req("name")
            .map_err(anyhow::Error::msg)?
            .as_str()
            .context("name")?
            .to_string();
        let file = art.req("file").map_err(anyhow::Error::msg)?.as_str().context("file")?;
        let init = art
            .req("init_file")
            .map_err(anyhow::Error::msg)?
            .as_str()
            .context("init_file")?;
        let chunk = art.get("chunk").and_then(Json::as_usize).unwrap_or(0);
        let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>> {
            let mut out = Vec::new();
            for t in art
                .req(key)
                .map_err(anyhow::Error::msg)?
                .as_arr()
                .with_context(|| format!("{key} not an array"))?
            {
                let shape = t
                    .req("shape")
                    .map_err(anyhow::Error::msg)?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<Vec<_>>>()?;
                out.push(TensorSpec {
                    name: t
                        .req("name")
                        .map_err(anyhow::Error::msg)?
                        .as_str()
                        .context("tensor name")?
                        .to_string(),
                    shape,
                    role: Role::parse(
                        t.req("role").map_err(anyhow::Error::msg)?.as_str().context("role")?,
                    )?,
                    feeds: t.get("feeds").and_then(Json::as_usize),
                });
            }
            Ok(out)
        };
        let spec = ArtifactSpec {
            name,
            hlo_path: dir.join(file),
            init_path: dir.join(init),
            chunk,
            inputs: parse_tensors("inputs")?,
            outputs: parse_tensors("outputs")?,
        };
        // Validate the state loop.
        for o in &spec.outputs {
            if o.role == Role::State {
                let feeds = o
                    .feeds
                    .with_context(|| format!("state output {} missing feeds", o.name))?;
                let inp = spec
                    .inputs
                    .get(feeds)
                    .with_context(|| format!("feeds index {feeds} out of range"))?;
                if inp.shape != o.shape {
                    bail!(
                        "state loop shape mismatch {}: {:?} -> {:?}",
                        o.name,
                        o.shape,
                        inp.shape
                    );
                }
            }
        }
        spec.stream_input()?;
        Ok(spec)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_built_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        assert_eq!(m.metrics, 28);
        for name in ["arima", "birch", "lstm"] {
            let a = m.artifact(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(a.chunk, 0);
            assert!(a.hlo_path.exists());
            // err/thr/flag lead the outputs.
            assert_eq!(a.outputs[0].name, "err");
            assert_eq!(a.outputs[1].name, "thr");
            assert_eq!(a.outputs[2].name, "flag");
        }
        let chunked = m.artifact("lstm_chunk32").expect("chunk artifact");
        assert_eq!(chunked.chunk, 32);
    }

    #[test]
    fn init_blob_loads_with_correct_sizes() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        let lstm = m.artifact("lstm").unwrap();
        let init = lstm.load_init().unwrap();
        // 8 params + 5 state tensors.
        assert_eq!(init.len(), 13);
        let wx1 = &init[0];
        assert_eq!(wx1.len(), 28 * 128);
        assert!(wx1.iter().any(|v| *v != 0.0), "weights should be non-zero");
        let h1 = &init[8];
        assert!(h1.iter().all(|v| *v == 0.0), "initial state should be zero");
    }

    #[test]
    fn state_loop_contract_holds() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        for a in &m.artifacts {
            for o in a.outputs.iter().filter(|o| o.role == Role::State) {
                let inp = &a.inputs[o.feeds.unwrap()];
                assert_eq!(inp.name, o.name);
                assert_eq!(inp.shape, o.shape);
            }
            assert_eq!(a.stream_input().unwrap(), a.inputs.len() - 1);
        }
    }
}
