//! API-identical stub for [`Engine`]/[`LoadedJob`] used when the crate is
//! built without the `pjrt` feature (the default: the `xla` PJRT bindings
//! are a vendored dependency, not a crates.io one).
//!
//! `Engine::new` always fails, so a `LoadedJob` can never be constructed
//! through this stub — the remaining methods exist only to keep the
//! downstream code (workloads, backends, CLI, examples) compiling and are
//! unreachable at runtime.

use std::path::Path;

use anyhow::{bail, Result};

use super::manifest::Manifest;

/// One job step's observable outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepOutcome {
    /// Identity-function error.
    pub err: f32,
    /// Threshold-model boundary in effect for this sample.
    pub thr: f32,
    /// 1.0 when the sample was flagged anomalous.
    pub flag: f32,
}

/// Stub PJRT client: construction always fails.
pub struct Engine {
    _private: (),
}

impl Engine {
    pub fn new(_artifacts_dir: &Path) -> Result<Engine> {
        bail!(
            "built without the `pjrt` feature — rebuild with \
             `--features pjrt` and a vendored xla-rs to execute AOT artifacts"
        )
    }

    pub fn manifest(&self) -> &Manifest {
        unreachable!("stub Engine cannot be constructed")
    }

    pub fn platform(&self) -> String {
        unreachable!("stub Engine cannot be constructed")
    }

    pub fn load_job(&self, _name: &str) -> Result<LoadedJob> {
        unreachable!("stub Engine cannot be constructed")
    }
}

/// Stub compiled artifact — never constructed.
pub struct LoadedJob {
    _private: (),
}

impl LoadedJob {
    pub fn name(&self) -> &str {
        unreachable!("stub LoadedJob cannot be constructed")
    }

    pub fn stream_elements(&self) -> usize {
        unreachable!("stub LoadedJob cannot be constructed")
    }

    pub fn samples_per_call(&self) -> usize {
        unreachable!("stub LoadedJob cannot be constructed")
    }

    pub fn reset(&mut self) -> Result<()> {
        unreachable!("stub LoadedJob cannot be constructed")
    }

    pub fn step(&mut self, _x: &[f32]) -> Result<Vec<StepOutcome>> {
        unreachable!("stub LoadedJob cannot be constructed")
    }

    pub fn state(&self, _name: &str) -> Result<Vec<f32>> {
        unreachable!("stub LoadedJob cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_fails_with_actionable_message() {
        let err = Engine::new(Path::new("/nonexistent")).err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
