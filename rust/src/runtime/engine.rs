//! PJRT engine: compile HLO-text artifacts once, execute them per sample or
//! per chunk with the state loop threaded on the Rust side.
//!
//! Python never runs here — the artifacts were lowered AOT by
//! `python/compile/aot.py` and this module is the entire request path.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest, Role};

/// Shared PJRT CPU client (compile + execute).
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
}

/// One job step's observable outcome.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepOutcome {
    /// Identity-function error.
    pub err: f32,
    /// Threshold-model boundary in effect for this sample.
    pub thr: f32,
    /// 1.0 when the sample was flagged anomalous.
    pub flag: f32,
}

impl Engine {
    /// Create a CPU PJRT client and parse the manifest in `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact into a ready-to-step job instance.
    pub fn load_job(&self, name: &str) -> Result<LoadedJob> {
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.hlo_path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", spec.hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let init = spec.load_init()?;
        let mut carried = Vec::with_capacity(init.len());
        for (vals, t) in init.iter().zip(spec.inputs.iter().filter(|t| t.role != Role::Stream)) {
            carried.push(literal_from_f32(vals, &t.shape)?);
        }
        Ok(LoadedJob { spec, exe, carried })
    }
}

/// A compiled artifact plus its carried (param + state) literals.
pub struct LoadedJob {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Literals for every non-stream input, in input order. Params stay
    /// fixed; state entries are replaced after each call.
    carried: Vec<xla::Literal>,
}

impl LoadedJob {
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Stream tensor length expected per call (`metrics` for per-sample
    /// artifacts, `chunk * metrics` for chunked ones).
    pub fn stream_elements(&self) -> usize {
        let idx = self.spec.inputs.len() - 1;
        self.spec.inputs[idx].elements()
    }

    /// Samples processed per call (1 unless chunked).
    pub fn samples_per_call(&self) -> usize {
        self.spec.chunk.max(1)
    }

    /// Reset all state tensors to their init.bin values.
    pub fn reset(&mut self) -> Result<()> {
        let init = self.spec.load_init()?;
        for (slot, (vals, t)) in self
            .carried
            .iter_mut()
            .zip(init.iter().zip(self.spec.inputs.iter().filter(|t| t.role != Role::Stream)))
        {
            *slot = literal_from_f32(vals, &t.shape)?;
        }
        Ok(())
    }

    /// Execute one call with the given stream values; threads state.
    ///
    /// For per-sample artifacts `x` is one `[metrics]` sample and one
    /// [`StepOutcome`] is returned; for chunked artifacts `x` is
    /// `[chunk * metrics]` and `chunk` outcomes are returned.
    pub fn step(&mut self, x: &[f32]) -> Result<Vec<StepOutcome>> {
        let stream_idx = self.spec.inputs.len() - 1;
        let want = self.spec.inputs[stream_idx].elements();
        if x.len() != want {
            bail!(
                "stream input length {} != expected {want} for {}",
                x.len(),
                self.spec.name
            );
        }
        let x_lit = literal_from_f32(x, &self.spec.inputs[stream_idx].shape)?;
        let mut args: Vec<&xla::Literal> = self.carried.iter().collect();
        args.push(&x_lit);
        let result = self
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling result: {e:?}"))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "output arity mismatch for {}: {} vs {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut outcomes = Vec::new();
        let mut errs: Vec<f32> = Vec::new();
        let mut thrs: Vec<f32> = Vec::new();
        let mut flags: Vec<f32> = Vec::new();
        for (part, ospec) in parts.into_iter().zip(&self.spec.outputs) {
            match (ospec.role, ospec.name.as_str()) {
                (Role::Out, "err") | (Role::Out, "errs") => {
                    errs = part.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                }
                (Role::Out, "thr") | (Role::Out, "thrs") => {
                    thrs = part.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                }
                (Role::Out, "flag") | (Role::Out, "flags") => {
                    flags = part.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
                }
                (Role::State, _) => {
                    let feeds = ospec.feeds.context("state output missing feeds")?;
                    self.carried[feeds] = part;
                }
                (role, name) => bail!("unexpected output {name} with role {role:?}"),
            }
        }
        for i in 0..errs.len() {
            outcomes.push(StepOutcome { err: errs[i], thr: thrs[i], flag: flags[i] });
        }
        Ok(outcomes)
    }

    /// Fetch a carried state tensor by input name (diagnostics/tests).
    pub fn state(&self, name: &str) -> Result<Vec<f32>> {
        let pos = self
            .spec
            .inputs
            .iter()
            .filter(|t| t.role != Role::Stream)
            .position(|t| t.name == name)
            .with_context(|| format!("no carried input '{name}'"))?;
        self.carried[pos].to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
fn literal_from_f32(vals: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(vals);
    if shape.len() <= 1 {
        return Ok(lit);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape {shape:?}: {e:?}"))
}
