//! PJRT runtime: load AOT artifacts (HLO text), compile once, execute on
//! the request path; plus the Docker-like CPU throttle.
//!
//! Adapted from the verified `/opt/xla-example/load_hlo` wiring: HLO *text*
//! is the interchange (xla_extension 0.5.1 rejects jax≥0.5 protos), the
//! lowered module returns a 1-tuple which is decomposed per call, and state
//! tensors are threaded back into the next call's inputs.

// The real engine needs the `xla` PJRT bindings (a vendored xla-rs
// checkout — not on crates.io), so it is gated behind the `pjrt` feature.
// The default build uses an API-identical stub whose `Engine::new` fails
// with a clear message; everything downstream (workloads, backends, CLI)
// compiles unchanged and the artifact-dependent paths self-skip.
#[cfg(feature = "pjrt")]
#[path = "engine.rs"]
mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;
mod manifest;
mod throttle;

pub use engine::{Engine, LoadedJob, StepOutcome};
pub use manifest::{ArtifactSpec, Manifest, Role, TensorSpec};
pub use throttle::{Throttle, ThrottledRun};

use std::path::PathBuf;

/// Default artifacts directory: `$STREAMPROF_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("STREAMPROF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// True when the AOT artifacts have been built.
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

/// True when the crate was built with the real PJRT engine
/// (`--features pjrt`); false means [`Engine::new`] is the stub that
/// fails with an actionable message. Recorded in fleet reports so a
/// serialized run states which execution substrate produced it.
pub const fn pjrt_enabled() -> bool {
    cfg!(feature = "pjrt")
}
