//! Utility substrates: PRNG, JSON, CSV, tables, CLI args, logging, bench.
//!
//! Everything here replaces crates (`rand`, `serde`, `clap`, `criterion`,
//! `env_logger`) that are unavailable in the offline vendor set — see
//! DESIGN.md §5 (substitutions).

pub mod args;
pub mod bench;
pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;
pub mod table;

pub use args::Args;
pub use csv::CsvWriter;
pub use json::Json;
pub use rng::Rng;
pub use table::Table;

/// FNV-1a over a byte stream — the crate's one stable, dependency-free
/// hash (seed derivation, model fingerprints).
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::fnv1a;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(std::iter::empty::<u8>()), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a".iter().copied()), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar".iter().copied()), 0x85944171f73967e8);
    }
}
