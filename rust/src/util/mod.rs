//! Utility substrates: PRNG, JSON, CSV, tables, CLI args, logging, bench.
//!
//! Everything here replaces crates (`rand`, `serde`, `clap`, `criterion`,
//! `env_logger`) that are unavailable in the offline vendor set — see
//! DESIGN.md §5 (substitutions).

pub mod args;
pub mod bench;
pub mod csv;
pub mod json;
pub mod logging;
pub mod rng;
pub mod table;

pub use args::Args;
pub use csv::CsvWriter;
pub use json::Json;
pub use rng::Rng;
pub use table::Table;
