//! ASCII table renderer for the repro harness (paper tables/figures as text).

/// Accumulates rows and renders an aligned, boxed ASCII table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(fields.len(), self.header.len(), "table row arity");
        self.rows.push(fields.to_vec());
    }

    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) {
        self.row(&fields.iter().map(|f| f.to_string()).collect::<Vec<_>>());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..cols {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "v"]).with_title("T");
        t.rowd(&[&"pi4", &1.25f64]);
        t.rowd(&[&"e2high", &33]);
        let s = t.render();
        assert!(s.contains("| pi4    | 1.25 |"));
        assert!(s.contains("| e2high | 33   |"));
        assert!(s.starts_with("T\n+"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
