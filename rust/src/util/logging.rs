//! Leveled stderr logger with monotonic timestamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static START: OnceLock<Instant> = OnceLock::new();
static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level_from_str(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        _ => Level::Info,
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $mod, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_levels() {
        assert_eq!(level_from_str("debug"), Level::Debug);
        assert_eq!(level_from_str("ERROR"), Level::Error);
        assert_eq!(level_from_str("bogus"), Level::Info);
    }
}
