//! CSV emitter for experiment outputs (`results/*.csv`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create the file (and parent directories) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, columns: header.len() })
    }

    /// Write one row; panics in debug builds when the column count differs.
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        debug_assert_eq!(fields.len(), self.columns, "csv row arity mismatch");
        let escaped: Vec<String> = fields.iter().map(|f| escape(f)).collect();
        writeln!(self.out, "{}", escaped.join(","))
    }

    /// Convenience: mixed display row.
    pub fn rowd(&mut self, fields: &[&dyn std::fmt::Display]) -> std::io::Result<()> {
        let strings: Vec<String> = fields.iter().map(|f| f.to_string()).collect();
        self.row(&strings)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("streamprof_csv_test");
        let path = dir.join("out.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.rowd(&[&1.5f64, &"x"]).unwrap();
            w.row(&["2".into(), "with,comma".into()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1.5,x\n2,\"with,comma\"\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn escapes_quotes() {
        assert_eq!(escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(escape("plain"), "plain");
    }
}
