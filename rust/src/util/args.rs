//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — excludes argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut args = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(item) = it.next() {
            if let Some(rest) = item.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    let bound = it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    if bound {
                        let v = it.next().unwrap();
                        args.options.insert(rest.to_string(), v);
                    } else {
                        args.flags.push(rest.to_string());
                    }
                }
            } else {
                args.positional.push(item);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    /// True when `--name` was present — either as a bare flag or (because a
    /// schema-less parser binds `--name value` greedily) as an option.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> u64 {
        self.opt(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Comma-separated list option (empty items dropped).
    pub fn opt_list(&self, name: &str) -> Option<Vec<String>> {
        self.opt(name).map(|s| {
            s.split(',')
                .map(|p| p.trim().to_string())
                .filter(|p| !p.is_empty())
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("profile out.csv --node pi4 --algo=arima --verbose");
        assert_eq!(a.positional, vec!["profile", "out.csv"]);
        assert_eq!(a.opt("node"), Some("pi4"));
        assert_eq!(a.opt("algo"), Some("arima"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn greedy_value_binding_still_counts_as_flag() {
        // Schema-less ambiguity: `--verbose out.csv` binds greedily; flag()
        // still reports presence.
        let a = parse("profile --verbose out.csv");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["profile"]);
    }

    #[test]
    fn typed_accessors() {
        let a = parse("--p 0.05 --steps 6 --seed 42");
        assert_eq!(a.opt_f64("p", 0.1), 0.05);
        assert_eq!(a.opt_usize("steps", 1), 6);
        assert_eq!(a.opt_u64("seed", 0), 42);
        assert_eq!(a.opt_f64("missing", 9.0), 9.0);
    }

    #[test]
    fn list_option() {
        let a = parse("--nodes pi4,wally, asok");
        assert_eq!(a.opt_list("nodes").unwrap(), vec!["pi4", "wally"]);
        let b = parse("--nodes=pi4,wally,asok");
        assert_eq!(b.opt_list("nodes").unwrap(), vec!["pi4", "wally", "asok"]);
    }

    #[test]
    fn negative_number_values() {
        // "--key value" where value starts with '-' but not '--'.
        let a = parse("--offset -3.5");
        assert_eq!(a.opt_f64("offset", 0.0), -3.5);
    }
}
