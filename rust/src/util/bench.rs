//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Usage inside a `harness = false` bench target:
//!
//! ```ignore
//! let mut b = Bench::new("fit/lm_5pt");
//! b.iter(|| fit_once(&pts));
//! println!("{}", b.report());
//! ```
//!
//! Runs a calibration phase to pick an iteration count targeting
//! ~`target_time`, then measures batches and reports mean/p50/p95 with a
//! simple MAD-based outlier note. Results can also be dumped as CSV rows so
//! EXPERIMENTS.md §Perf tables are regenerable.

use std::time::{Duration, Instant};

pub struct Bench {
    name: String,
    target_time: Duration,
    samples: Vec<f64>, // seconds per iteration
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            target_time: Duration::from_millis(300),
            samples: Vec::new(),
        }
    }

    pub fn with_target_time(mut self, d: Duration) -> Self {
        self.target_time = d;
        self
    }

    /// Measure `f` repeatedly; stores per-iteration seconds.
    pub fn iter<T, F: FnMut() -> T>(&mut self, mut f: F) {
        // Calibrate: how many iterations fit into ~10ms?
        let mut n = 1u64;
        loop {
            let t0 = Instant::now();
            for _ in 0..n {
                black_box(f());
            }
            let dt = t0.elapsed();
            if dt > Duration::from_millis(10) || n > 1 << 24 {
                break;
            }
            n *= 4;
        }
        // Measure batches until target_time is spent.
        let t_all = Instant::now();
        while t_all.elapsed() < self.target_time {
            let t0 = Instant::now();
            for _ in 0..n {
                black_box(f());
            }
            let per_iter = t0.elapsed().as_secs_f64() / n as f64;
            self.samples.push(per_iter);
        }
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * p).round() as usize;
        s[idx]
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {}  p50 {}  p95 {}  ({} batches)",
            self.name,
            fmt_time(self.mean()),
            fmt_time(self.percentile(0.50)),
            fmt_time(self.percentile(0.95)),
            self.samples.len()
        )
    }

    /// `name,mean_ns,p50_ns,p95_ns` CSV row.
    pub fn csv_row(&self) -> String {
        format!(
            "{},{:.1},{:.1},{:.1}",
            self.name,
            self.mean() * 1e9,
            self.percentile(0.50) * 1e9,
            self.percentile(0.95) * 1e9
        )
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

pub fn fmt_time(secs: f64) -> String {
    if secs.is_nan() {
        "n/a".to_string()
    } else if secs < 1e-6 {
        format!("{:7.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:7.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:7.2}ms", secs * 1e3)
    } else {
        format!("{:7.3}s ", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new("noop").with_target_time(Duration::from_millis(30));
        let mut acc = 0u64;
        b.iter(|| {
            acc = acc.wrapping_add(1);
            acc
        });
        assert!(b.mean() > 0.0);
        assert!(!b.samples.is_empty());
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn percentiles_ordered() {
        let mut b = Bench::new("x").with_target_time(Duration::from_millis(30));
        b.iter(|| std::hint::black_box(3.0f64).sqrt());
        assert!(b.percentile(0.5) <= b.percentile(0.95) * 1.0001);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains("s "));
    }
}
