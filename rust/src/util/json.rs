//! Minimal JSON substrate (no `serde` in the offline vendor set).
//!
//! A recursive-descent parser producing a [`Json`] tree plus a small writer.
//! Covers exactly what `artifacts/manifest.json` and our own emitted result
//! files need: objects, arrays, strings, numbers, bools, null, and
//! `\uXXXX`-free string escapes (the manifest never contains them).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Number constructor for emitters: non-finite values (which JSON
    /// cannot represent) become `null` instead of producing an unparsable
    /// document.
    pub fn num(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    /// String constructor (owning).
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Object constructor from `(key, value)` pairs (keys sort
    /// lexicographically in the map; duplicate keys keep the last value).
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array constructor from any sequence of values.
    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field helpers (errors instead of panics).
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        other => return Err(format!("unsupported escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let s = &self.bytes[self.pos..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|e| e.to_string())?;
                    out.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize a [`Json`] value (compact).
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) if !n.is_finite() => out.push_str("null"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            _ => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let text = r#"{
          "metrics": 28,
          "artifacts": [
            {"name": "arima", "chunk": 0,
             "inputs": [{"name": "coeffs", "shape": [8, 28], "role": "state"}],
             "outputs": [{"name": "err", "shape": [1], "role": "out"}]}
          ]
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("metrics").unwrap().as_usize(), Some(28));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("arima"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(28));
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null}"#;
        let v = parse(text).unwrap();
        let emitted = to_string(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn rejects_unterminated_string() {
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn parses_scientific_numbers() {
        let v = parse("[1e-3, 2.5E2]").unwrap();
        let arr = v.as_arr().unwrap();
        assert!((arr[0].as_f64().unwrap() - 1e-3).abs() < 1e-12);
        assert!((arr[1].as_f64().unwrap() - 250.0).abs() < 1e-12);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn constructors_build_parseable_trees() {
        let v = Json::obj([
            ("name", Json::str("cam-01")),
            ("rate", Json::num(2.5)),
            ("bad", Json::num(f64::NAN)),
        ]);
        assert_eq!(v.get("bad"), Some(&Json::Null), "non-finite maps to null");
        let text = to_string(&v);
        let back = parse(&text).unwrap();
        assert_eq!(back.get("name").unwrap().as_str(), Some("cam-01"));
        assert!((back.get("rate").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn arr_and_as_bool_helpers() {
        let v = Json::arr((0..3).map(|i| Json::num(i as f64)));
        assert_eq!(to_string(&v), "[0,1,2]");
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("false").unwrap().as_bool(), Some(false));
        assert_eq!(parse("1").unwrap().as_bool(), None);
    }

    #[test]
    fn writer_never_emits_non_finite_numbers() {
        // A raw Json::Num(NaN/inf) (bypassing Json::num) must still write
        // valid JSON.
        let v = Json::Arr(vec![Json::Num(f64::NAN), Json::Num(f64::INFINITY), Json::Num(1.0)]);
        let text = to_string(&v);
        assert_eq!(text, "[null,null,1]");
        assert!(parse(&text).is_ok());
    }

    #[test]
    fn float_roundtrip_is_bit_exact() {
        // Cache persistence relies on measurements surviving the snapshot:
        // Display for f64 prints a shortest-roundtrip representation.
        for &x in &[0.1 + 0.2, 1.0 / 3.0, 6.02214076e23, 5e-324, 0.062_537_128_4] {
            let text = to_string(&Json::Num(x));
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} -> {text}");
        }
    }
}
