//! Deterministic PRNG substrate (no `rand` crate in the offline vendor set).
//!
//! `SplitMix64` seeds `Xoshiro256**`; normal variates via Box–Muller.
//! Everything is reproducible from a single `u64` seed, which the experiment
//! harness relies on (50-repetition runs in Fig. 7 are seeded per repeat).

/// SplitMix64 — used for seeding and cheap one-off streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for n << 2^64 in our experiment harness.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean/std.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterized by the *target* mean and coefficient of
    /// variation of the resulting distribution (not of the underlying
    /// normal) — convenient for runtime-noise modeling.
    pub fn lognormal_mean_cov(&mut self, mean: f64, cov: f64) -> f64 {
        debug_assert!(mean > 0.0 && cov >= 0.0);
        if cov == 0.0 {
            return mean;
        }
        let sigma2 = (1.0 + cov * cov).ln();
        let mu = mean.ln() - 0.5 * sigma2;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_mean_and_cov_match() {
        let mut rng = Rng::new(13);
        let n = 100_000;
        let (target_mean, target_cov) = (0.25, 0.15);
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            let x = rng.lognormal_mean_cov(target_mean, target_cov);
            assert!(x > 0.0);
            xs.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - target_mean).abs() / target_mean < 0.02);
        assert!((var.sqrt() / mean - target_cov).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(5);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
