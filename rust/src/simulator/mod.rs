//! Testbed substitute: node models (Table I) and simulated black-box jobs.
//!
//! See DESIGN.md §4/§5 — the profiling methods only ever observe
//! `(CPU limitation → noisy per-sample runtimes)`, which is exactly the
//! interface this module reproduces. The `localhost` path in
//! [`crate::workloads`] provides the same interface backed by *real* PJRT
//! executions under a duty-cycle throttle.

pub mod job;
pub mod nodes;

pub use job::{Algo, GroundTruth, SimulatedJob};
pub use nodes::{node, NodeSpec, NODES};
