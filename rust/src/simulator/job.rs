//! Simulated black-box job: ground-truth runtime curves + per-sample noise.
//!
//! The profiler observes exactly what it would observe on the real testbed:
//! per-sample processing times of a containerized job under a CPU
//! limitation. The ground truth follows the paper's own model family
//! `t(R) = a·(R·d)^(−b) + c` with parameters derived from the node spec and
//! the algorithm's base cost, plus lognormal per-sample noise.
//!
//! Fig. 6 anchoring (Arima on pi4): four NMS profiling steps with 1000
//! samples ≈ 268 s, i.e. mean per-sample times of ~60–70 ms around
//! limitations of 0.2–1.0 CPU. The base costs below put Arima/pi4 at
//! t(1.0) ≈ 54 ms and t(0.2) ≈ 210 ms, matching those magnitudes.

use super::nodes::NodeSpec;
use crate::fit::ProfilePoint;
use crate::util::Rng;

/// The three IFTM workloads from the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Arima,
    Birch,
    Lstm,
}

impl Algo {
    pub const ALL: [Algo; 3] = [Algo::Arima, Algo::Birch, Algo::Lstm];

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Arima => "arima",
            Algo::Birch => "birch",
            Algo::Lstm => "lstm",
        }
    }

    pub fn from_name(s: &str) -> Option<Algo> {
        match s.to_ascii_lowercase().as_str() {
            "arima" => Some(Algo::Arima),
            "birch" => Some(Algo::Birch),
            "lstm" => Some(Algo::Lstm),
            _ => None,
        }
    }

    /// Per-sample compute cost (seconds) at one full reference core
    /// (wally-speed). Ratios mirror the relative FLOP counts of the three
    /// AOT artifacts (LSTM ≫ Birch > Arima).
    pub fn base_cost(&self) -> f64 {
        match self {
            Algo::Arima => 0.013,
            Algo::Birch => 0.021,
            Algo::Lstm => 0.055,
        }
    }

    /// Fraction of the base cost that remains at unbounded parallelism
    /// (runtime floor `c`): framework overhead + sequential part.
    pub fn floor_fraction(&self) -> f64 {
        match self {
            Algo::Arima => 0.18,
            Algo::Birch => 0.15,
            Algo::Lstm => 0.12,
        }
    }
}

/// Ground-truth curve parameters for one (node, algorithm) pair.
///
/// Deliberately **not** a member of the fitted family: real measured
/// runtime curves deviate systematically from `a·(R·d)^(−b)+c` — streaming
/// jobs saturate at their intrinsic parallelism, and CFS scheduling leaves
/// limit-dependent artifacts. Without this mismatch every strategy would
/// fit the curve perfectly from any 5 points and the paper's SMAPE floors
/// (0.1–0.3 on pi4) and strategy rankings could not emerge.
#[derive(Clone, Debug)]
pub struct GroundTruth {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
    pub noise_cov: f64,
    /// Intrinsic parallelism of the job (cores it can actually use);
    /// runtime stops improving (smoothly) beyond this.
    pub saturation: f64,
    /// Systematic limit-dependent deviation (scheduler/interference
    /// artifacts), deterministic per (node, algo): two sine components.
    pub wiggle: [(f64, f64, f64); 2], // (amplitude, frequency, phase)
    /// Per-sample runtimes are far noisier than the aggregate CoV —
    /// interference, scheduling, and GC make individual samples vary
    /// wildly (visible in the paper's Fig. 2). The per-sample CoV is
    /// `noise_cov * sqrt(autocorr)`, so the mean over n samples still has
    /// standard error `noise_cov * sqrt(autocorr / n)` — equivalently, n
    /// samples carry `n / autocorr` independent observations' worth of
    /// information. This is what makes early stopping meaningful: the
    /// paper's 95%/10% criterion consumed roughly half of the 10k samples.
    pub autocorr: f64,
    /// Low-limit scheduling penalty: CFS quota overhead is proportionally
    /// worse at very small limits (fixed per-period costs), adding
    /// `a · knee_amp · exp(−r / knee_scale)` that the fitted family cannot
    /// express — capturing it requires actually profiling the knee.
    pub knee_amp: f64,
    pub knee_scale: f64,
}

/// Deterministic per-(node, algo) parameter stream.
fn param_rng(node: &NodeSpec, algo: Algo) -> Rng {
    Rng::new(crate::util::fnv1a(node.name.bytes().chain(algo.name().bytes())))
}

impl GroundTruth {
    pub fn derive(node: &NodeSpec, algo: Algo) -> Self {
        let mut rng = param_rng(node, algo);
        let base = algo.base_cost() / node.speed;
        let sat_base = match algo {
            Algo::Arima => 1.3,
            Algo::Birch => 2.0,
            Algo::Lstm => 3.0,
        };
        GroundTruth {
            a: base * (1.0 - algo.floor_fraction()),
            b: node.scaling,
            c: base * algo.floor_fraction(),
            // Mild per-node stretch of the limitation axis; keeps d
            // non-trivial so the full Eq. 1 is exercised.
            d: node.limit_stretch(),
            noise_cov: node.noise_cov,
            saturation: (sat_base * rng.uniform(0.8, 1.2)).min(node.cores),
            wiggle: [
                (rng.uniform(0.01, 0.035), rng.uniform(4.0, 8.0), rng.uniform(0.0, 6.28)),
                (rng.uniform(0.008, 0.02), rng.uniform(12.0, 20.0), rng.uniform(0.0, 6.28)),
            ],
            autocorr: 100.0,
            knee_amp: rng.uniform(2.5, 6.0),
            knee_scale: rng.uniform(0.05, 0.12),
        }
    }

    /// Noise-free mean per-sample runtime at limitation `r`.
    pub fn mean_runtime(&self, r: f64) -> f64 {
        debug_assert!(r > 0.0);
        // Parallelism saturation with a crisp elbow (k=4 smooth-min):
        // r_eff ~= r below the saturation point, -> saturation above it.
        let s = self.saturation;
        let r_eff = r * s / (r.powi(4) + s.powi(4)).powf(0.25);
        let smooth = self.a * (r_eff * self.d).powf(-self.b) + self.c;
        // CFS per-period overhead: a sharp, localized blow-up below ~0.2
        // CPU (the paper's "exponential increase ... at lower CPU
        // limitations"). Capturing it requires profiling the deep knee.
        let knee = self.a * self.knee_amp * (-r / self.knee_scale).exp();
        // Systematic limit-dependent artifact (same for every sample).
        let mut w = 1.0;
        for &(amp, freq, phase) in &self.wiggle {
            w += amp * (freq * r + phase).sin();
        }
        (smooth + knee) * w
    }

    /// Standard error of the mean over `n` samples.
    pub fn mean_se(&self, mean: f64, n: usize) -> f64 {
        let n_eff = (n as f64 / self.autocorr).max(1.0);
        mean * self.noise_cov / n_eff.sqrt()
    }

    /// Coefficient of variation of a SINGLE per-sample runtime (consistent
    /// with `mean_se`: iid draws at this CoV give the same aggregate SE).
    pub fn sample_cov(&self) -> f64 {
        self.noise_cov * self.autocorr.sqrt()
    }
}

/// A simulated containerized ML job on a specific node.
pub struct SimulatedJob {
    pub node: &'static NodeSpec,
    pub algo: Algo,
    truth: GroundTruth,
    rng: Rng,
}

impl SimulatedJob {
    pub fn new(node: &'static NodeSpec, algo: Algo, seed: u64) -> Self {
        let truth = GroundTruth::derive(node, algo);
        Self { node, algo, truth, rng: Rng::new(seed) }
    }

    pub fn truth(&self) -> &GroundTruth {
        &self.truth
    }

    /// Observe ONE per-sample processing time under limitation `r`
    /// (lognormal noise at the per-sample CoV around the ground-truth
    /// mean — individual samples are much noisier than aggregate means).
    pub fn observe_sample(&mut self, r: f64) -> f64 {
        let mean = self.truth.mean_runtime(r);
        self.rng.lognormal_mean_cov(mean, self.truth.sample_cov())
    }

    /// Observe the empirical mean over `n` samples under limitation `r`.
    ///
    /// For large `n` the sample mean is drawn from its CLT distribution
    /// (normal with the autocorrelation-adjusted standard error) instead of
    /// summing `n` lognormals — statistically equivalent for n ≥ 256 and
    /// ~1000x faster, which matters for the 50-repetition Fig. 7 sweep.
    pub fn observe_mean(&mut self, r: f64, n: usize) -> f64 {
        debug_assert!(n > 0);
        let mean = self.truth.mean_runtime(r);
        if n < 256 {
            let mut acc = 0.0;
            for _ in 0..n {
                acc += self.rng.lognormal_mean_cov(mean, self.truth.sample_cov());
            }
            acc / n as f64
        } else {
            let se = self.truth.mean_se(mean, n);
            (mean + se * self.rng.normal()).max(mean * 0.01)
        }
    }

    /// The wallclock cost of profiling `n` samples at limitation `r` —
    /// the job processes samples back-to-back, so profiling time is the sum
    /// of per-sample runtimes ≈ n · observed mean.
    pub fn profiling_time(&mut self, r: f64, n: usize) -> (f64, f64) {
        let mean = self.observe_mean(r, n);
        (mean, mean * n as f64)
    }

    /// The paper's data-acquisition sweep (§III-A.a): start from all cores,
    /// decrease by 0.1, measure the mean over `n` samples at each limit.
    /// Returns points sorted by ascending limit.
    pub fn acquire_dataset(&mut self, n: usize) -> Vec<ProfilePoint> {
        let mut pts: Vec<ProfilePoint> = self
            .node
            .limit_grid()
            .iter()
            .map(|&r| ProfilePoint::new(r, self.observe_mean(r, n)))
            .collect();
        pts.sort_by(|x, y| x.limit.partial_cmp(&y.limit).unwrap());
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::nodes::node;

    #[test]
    fn runtime_decreases_with_more_cpu() {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 1);
        let slow = job.truth().mean_runtime(0.1);
        let mid = job.truth().mean_runtime(1.0);
        let fast = job.truth().mean_runtime(4.0);
        assert!(slow > mid && mid > fast);
        // Exponential blow-up at small limits: 0.1 is ~7x 1.0 (b≈0.85).
        assert!(slow / mid > 5.0, "ratio {}", slow / mid);
    }

    #[test]
    fn lstm_slower_than_birch_slower_than_arima() {
        let n = node("wally").unwrap();
        let a = GroundTruth::derive(n, Algo::Arima).mean_runtime(1.0);
        let b = GroundTruth::derive(n, Algo::Birch).mean_runtime(1.0);
        let l = GroundTruth::derive(n, Algo::Lstm).mean_runtime(1.0);
        assert!(a < b && b < l);
    }

    #[test]
    fn pi4_slowest_per_core() {
        for algo in Algo::ALL {
            let pi4 = GroundTruth::derive(node("pi4").unwrap(), algo).mean_runtime(1.0);
            for other in ["wally", "asok", "e2high", "e2small", "e216", "n1"] {
                let t = GroundTruth::derive(node(other).unwrap(), algo).mean_runtime(1.0);
                assert!(pi4 > t, "pi4 vs {other} for {algo:?}");
            }
        }
    }

    #[test]
    fn e2high_faster_than_e2small_at_same_limit() {
        // The paper's Fig. 3 discussion: same core count, different runtime.
        let h = GroundTruth::derive(node("e2high").unwrap(), Algo::Lstm);
        let s = GroundTruth::derive(node("e2small").unwrap(), Algo::Lstm);
        for r in [0.2, 0.5, 1.0, 2.0] {
            assert!(h.mean_runtime(r) < s.mean_runtime(r));
        }
    }

    #[test]
    fn observed_mean_converges_to_truth() {
        let mut job = SimulatedJob::new(node("pi4").unwrap(), Algo::Lstm, 7);
        let truth = job.truth().mean_runtime(0.5);
        let m = job.observe_mean(0.5, 100_000);
        assert!((m - truth).abs() / truth < 0.01, "{m} vs {truth}");
    }

    #[test]
    fn small_n_path_unbiased() {
        let mut job = SimulatedJob::new(node("wally").unwrap(), Algo::Arima, 9);
        let truth = job.truth().mean_runtime(1.0);
        let mut acc = 0.0;
        let reps = 2000;
        for _ in 0..reps {
            acc += job.observe_mean(1.0, 100);
        }
        let grand = acc / reps as f64;
        assert!((grand - truth).abs() / truth < 0.01);
    }

    #[test]
    fn acquisition_sweep_covers_grid() {
        let mut job = SimulatedJob::new(node("e2high").unwrap(), Algo::Birch, 3);
        let ds = job.acquire_dataset(1000);
        assert_eq!(ds.len(), 20); // 2.0 / 0.1
        assert!(ds.windows(2).all(|w| w[0].limit < w[1].limit));
        assert!(ds.iter().all(|p| p.runtime > 0.0));
        // Monotone-ish: first point (0.1 CPU) much slower than last (2.0).
        assert!(ds[0].runtime > ds.last().unwrap().runtime * 3.0);
    }

    #[test]
    fn profiling_time_scales_with_samples() {
        let mut job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 5);
        let (_, t1k) = job.profiling_time(0.2, 1000);
        let (_, t10k) = job.profiling_time(0.2, 10_000);
        let ratio = t10k / t1k;
        // Linear in n (modulo noise on the observed means).
        assert!((9.0..11.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fig6_magnitude_anchor() {
        // Paper: ~268s for 4 profiling steps, Arima/pi4, 1000 samples.
        // Our 4-step cost at plausible NMS-selected limits (0.2, 0.55, 2.0,
        // 0.3) should land within a factor ~2 of that.
        let mut job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 11);
        let limits = [0.2, 0.55, 2.0, 0.3];
        let total: f64 = limits.iter().map(|&r| job.profiling_time(r, 1000).1).sum();
        assert!(
            (130.0..500.0).contains(&total),
            "4-step profiling time {total}s should be near the paper's 268s"
        );
    }

    #[test]
    fn algo_name_roundtrip() {
        for a in Algo::ALL {
            assert_eq!(Algo::from_name(a.name()), Some(a));
        }
        assert_eq!(Algo::from_name("bogus"), None);
    }
}
