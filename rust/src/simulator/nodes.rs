//! The seven evaluation machines (paper Table I) as calibrated node models.
//!
//! We do not have the authors' physical testbed (two Xeon servers, a
//! Raspberry Pi 4, four GCP VM types), so each machine is modeled by the
//! parameters that determine what the profiler can observe: core count
//! (`l_max`), a single-core speed factor (relative to the Xeon E3-1230),
//! a parallel-scaling exponent, a runtime floor, and per-sample noise.
//! See DESIGN.md §4 for the calibration rationale and §5 for why this
//! substitution preserves the paper's findings.

/// Static description of one machine type (Table I row).
#[derive(Clone, Debug)]
pub struct NodeSpec {
    /// Hostname used throughout the paper's figures.
    pub name: &'static str,
    /// Human-readable machine type.
    pub kind: &'static str,
    /// CPU model string.
    pub cpu_model: &'static str,
    /// Number of cores == largest assignable CPU limitation `l_max`.
    pub cores: f64,
    /// Memory in GB (Table I column; informational).
    pub memory_gb: f64,
    /// Single-core speed relative to the fastest machine (wally).
    /// Smaller = slower CPU = larger per-sample runtimes.
    pub speed: f64,
    /// Parallel-scaling exponent `b` of the ground-truth curve; < 1 means
    /// sublinear gains from additional cores (Amdahl-ish).
    pub scaling: f64,
    /// Coefficient of variation of per-sample runtime noise (lognormal).
    pub noise_cov: f64,
}

impl NodeSpec {
    /// Smallest assignable CPU limitation (Docker `--cpus` granularity used
    /// in the paper's acquisition sweep).
    pub const L_MIN: f64 = 0.1;
    /// Logical step size δ of the limitation grid.
    pub const DELTA: f64 = 0.1;

    /// The limitation grid `L = {l_min, l_min+δ, ..., l_max}` (paper §II-B).
    pub fn limit_grid(&self) -> Vec<f64> {
        let n = (self.cores / Self::DELTA).round() as usize;
        (1..=n).map(|i| i as f64 * Self::DELTA).collect()
    }

    pub fn l_max(&self) -> f64 {
        self.cores
    }

    /// Limitation-axis stretch `d` of the calibrated ground-truth curve.
    /// Exposed (rather than buried in `GroundTruth::derive`) so cross-node
    /// model translation can renormalize a fitted `d` between machines.
    pub fn limit_stretch(&self) -> f64 {
        1.0 + 0.05 * (self.cores / 8.0)
    }

    /// Factor by which per-sample runtimes grow when the same job moves
    /// from `self` to `to` at an equal CPU limitation (pre-saturation):
    /// the inverse single-core speed ratio. > 1 means `to` is slower.
    pub fn runtime_factor_to(&self, to: &NodeSpec) -> f64 {
        self.speed / to.speed
    }

    /// Rescaling applied to a fitted parallel-scaling exponent when a model
    /// calibrated on `self` is read on `to` (the exponent tracks the
    /// machine's Amdahl behaviour, not the job).
    pub fn scaling_factor_to(&self, to: &NodeSpec) -> f64 {
        to.scaling / self.scaling
    }
}

/// Table I registry. Speed factors follow the CPU generations: wally's
/// E3-1230 (Sandy Bridge, 2011) ≈ 1.0; asok's X5355 (Clovertown, 2007) is
/// roughly half as fast per core; the Pi 4's Cortex-A72 is ~4x slower; GCP
/// e2 machines run on recent Xeon/EPYC hosts near wally's per-core speed,
/// with e2-small being a shared-core (throttled) variant; n1's Skylake
/// vCPU sits in between.
pub const NODES: &[NodeSpec] = &[
    NodeSpec {
        name: "wally",
        kind: "Commodity server",
        cpu_model: "Intel Xeon E3-1230",
        cores: 8.0,
        memory_gb: 16.0,
        speed: 1.0,
        scaling: 0.92,
        noise_cov: 0.10,
    },
    NodeSpec {
        name: "asok",
        kind: "Commodity server",
        cpu_model: "Intel Xeon X5355",
        cores: 8.0,
        memory_gb: 32.0,
        speed: 0.52,
        scaling: 0.88,
        noise_cov: 0.12,
    },
    NodeSpec {
        name: "pi4",
        kind: "Single-board computer",
        cpu_model: "Raspberry Pi 4B (Cortex-A72)",
        cores: 4.0,
        memory_gb: 2.0,
        speed: 0.24,
        scaling: 0.85,
        noise_cov: 0.18,
    },
    NodeSpec {
        name: "e2high",
        kind: "GCP VM",
        cpu_model: "e2-highcpu (2 vCPU)",
        cores: 2.0,
        memory_gb: 2.0,
        speed: 0.90,
        scaling: 0.90,
        noise_cov: 0.14,
    },
    NodeSpec {
        name: "e2small",
        kind: "GCP VM",
        cpu_model: "e2-small (2 shared vCPU)",
        cores: 2.0,
        memory_gb: 2.0,
        speed: 0.55,
        scaling: 0.90,
        noise_cov: 0.16,
    },
    NodeSpec {
        name: "e216",
        kind: "GCP VM",
        cpu_model: "e2-highcpu (16 vCPU)",
        cores: 16.0,
        memory_gb: 16.0,
        speed: 0.90,
        scaling: 0.95,
        noise_cov: 0.12,
    },
    NodeSpec {
        name: "n1",
        kind: "GCP VM",
        cpu_model: "n1-standard (1 vCPU)",
        cores: 1.0,
        memory_gb: 3.75,
        speed: 0.70,
        scaling: 0.90,
        noise_cov: 0.14,
    },
];

/// Look up a node by hostname.
pub fn node(name: &str) -> Option<&'static NodeSpec> {
    NODES.iter().find(|n| n.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_seven_machines() {
        assert_eq!(NODES.len(), 7);
        let names: Vec<_> = NODES.iter().map(|n| n.name).collect();
        assert_eq!(names, vec!["wally", "asok", "pi4", "e2high", "e2small", "e216", "n1"]);
    }

    #[test]
    fn core_counts_match_table1() {
        assert_eq!(node("wally").unwrap().cores, 8.0);
        assert_eq!(node("asok").unwrap().cores, 8.0);
        assert_eq!(node("pi4").unwrap().cores, 4.0);
        assert_eq!(node("e2high").unwrap().cores, 2.0);
        assert_eq!(node("e2small").unwrap().cores, 2.0);
        assert_eq!(node("e216").unwrap().cores, 16.0);
        assert_eq!(node("n1").unwrap().cores, 1.0);
    }

    #[test]
    fn e2high_faster_than_e2small_same_cores() {
        // Paper §III-B.1: identical core count, different CPUs -> different
        // runtime behaviour, motivating per-device profiling.
        let high = node("e2high").unwrap();
        let small = node("e2small").unwrap();
        assert_eq!(high.cores, small.cores);
        assert!(high.speed > small.speed);
    }

    #[test]
    fn limit_grid_spans_l_min_to_l_max() {
        let g = node("pi4").unwrap().limit_grid();
        assert_eq!(g.len(), 40);
        assert!((g[0] - 0.1).abs() < 1e-12);
        assert!((g[39] - 4.0).abs() < 1e-12);
        let n1 = node("n1").unwrap().limit_grid();
        assert_eq!(n1.len(), 10);
    }

    #[test]
    fn unknown_node_is_none() {
        assert!(node("gcp-tpu").is_none());
    }

    #[test]
    fn runtime_factor_is_reciprocal_and_transitive() {
        let wally = node("wally").unwrap();
        let pi4 = node("pi4").unwrap();
        let asok = node("asok").unwrap();
        // wally -> pi4 slows runtimes down by the speed ratio.
        assert!((wally.runtime_factor_to(pi4) - 1.0 / 0.24).abs() < 1e-9);
        // Reciprocal pairs cancel.
        let round = wally.runtime_factor_to(pi4) * pi4.runtime_factor_to(wally);
        assert!((round - 1.0).abs() < 1e-12);
        // Transitive through an intermediate node.
        let direct = wally.runtime_factor_to(pi4);
        let hop = wally.runtime_factor_to(asok) * asok.runtime_factor_to(pi4);
        assert!((direct - hop).abs() < 1e-9);
        // Self-translation is a no-op for every node.
        for n in NODES {
            assert!((n.runtime_factor_to(n) - 1.0).abs() < 1e-12);
            assert!((n.scaling_factor_to(n) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn limit_stretch_matches_calibration() {
        // d = 1 + 0.05 * cores/8: wally (8 cores) -> 1.05, n1 (1) -> 1.00625.
        assert!((node("wally").unwrap().limit_stretch() - 1.05).abs() < 1e-12);
        assert!((node("n1").unwrap().limit_stretch() - 1.00625).abs() < 1e-12);
        for n in NODES {
            assert!(n.limit_stretch() > 1.0 && n.limit_stretch() < 1.2);
        }
    }
}
