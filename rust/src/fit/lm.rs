//! Levenberg–Marquardt nonlinear least squares.
//!
//! Generic over a residual function; the Jacobian is computed by central
//! differences (problem sizes here are ≤5 params × ≤40 points, so numeric
//! differentiation costs nothing and avoids per-model analytic code).

use crate::linalg::{Cholesky, Mat};

pub struct LmOptions {
    pub max_iters: usize,
    /// Initial damping factor.
    pub lambda0: f64,
    /// Stop when the relative cost improvement falls below this.
    pub cost_tol: f64,
    /// Stop when the max step magnitude falls below this.
    pub step_tol: f64,
}

impl Default for LmOptions {
    fn default() -> Self {
        Self { max_iters: 80, lambda0: 1e-3, cost_tol: 1e-10, step_tol: 1e-10 }
    }
}

#[derive(Clone, Debug)]
pub struct LmResult {
    pub params: Vec<f64>,
    pub cost: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Minimize `0.5 * ||residuals(θ)||²` starting from `theta0`.
///
/// `residuals(θ, out)` must fill `out` with the residual vector.
pub fn levenberg_marquardt<F>(
    theta0: &[f64],
    n_residuals: usize,
    mut residuals: F,
    opts: &LmOptions,
) -> LmResult
where
    F: FnMut(&[f64], &mut [f64]),
{
    let np = theta0.len();
    let mut theta = theta0.to_vec();
    let mut r = vec![0.0; n_residuals];
    let mut r_try = vec![0.0; n_residuals];
    residuals(&theta, &mut r);
    let mut cost = 0.5 * dot(&r, &r);
    let mut lambda = opts.lambda0;
    let mut converged = false;
    let mut iters = 0;

    // Scratch for the Jacobian.
    let mut jac = Mat::zeros(n_residuals, np);
    let mut rp = vec![0.0; n_residuals];
    let mut rm = vec![0.0; n_residuals];

    for iter in 0..opts.max_iters {
        iters = iter + 1;
        // Central-difference Jacobian.
        for j in 0..np {
            let h = 1e-6 * (1.0 + theta[j].abs());
            let saved = theta[j];
            theta[j] = saved + h;
            residuals(&theta, &mut rp);
            theta[j] = saved - h;
            residuals(&theta, &mut rm);
            theta[j] = saved;
            for i in 0..n_residuals {
                jac[(i, j)] = (rp[i] - rm[i]) / (2.0 * h);
            }
        }
        // Normal equations: (JᵀJ + λ diag(JᵀJ)) δ = -Jᵀ r
        let jt = jac.transpose();
        let mut jtj = jt.matmul(&jac);
        let jtr = jt.matvec(&r);
        let grad_inf = jtr.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        if grad_inf < 1e-14 {
            converged = true;
            break;
        }
        let mut improved = false;
        for _ in 0..12 {
            let mut a = jtj.clone();
            for k in 0..np {
                // Marquardt scaling with a floor to keep A SPD.
                let d = jtj[(k, k)].max(1e-12);
                a[(k, k)] += lambda * d;
            }
            let delta = match Cholesky::new(&a) {
                Ok(ch) => ch.solve(&jtr.iter().map(|v| -v).collect::<Vec<_>>()),
                Err(_) => {
                    lambda *= 10.0;
                    continue;
                }
            };
            let theta_try: Vec<f64> =
                theta.iter().zip(&delta).map(|(t, d)| t + d).collect();
            residuals(&theta_try, &mut r_try);
            let cost_try = 0.5 * dot(&r_try, &r_try);
            if cost_try.is_finite() && cost_try < cost {
                let rel_impr = (cost - cost_try) / cost.max(1e-300);
                let step_inf = delta.iter().fold(0.0f64, |m, v| m.max(v.abs()));
                theta = theta_try;
                r.copy_from_slice(&r_try);
                cost = cost_try;
                lambda = (lambda * 0.3).max(1e-12);
                improved = true;
                if rel_impr < opts.cost_tol || step_inf < opts.step_tol {
                    converged = true;
                }
                break;
            }
            lambda *= 10.0;
            if lambda > 1e12 {
                break;
            }
        }
        if !improved {
            converged = true; // stuck at a (local) minimum
            break;
        }
        if converged {
            break;
        }
        // Keep borrow checker happy about jtj reuse.
        let _ = &mut jtj;
    }
    LmResult { params: theta, cost, iterations: iters, converged }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_linear_model_exactly() {
        // y = 2x + 1, residuals r_i = θ0 x_i + θ1 − y_i
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let res = levenberg_marquardt(
            &[0.0, 0.0],
            xs.len(),
            |theta, out| {
                for i in 0..xs.len() {
                    out[i] = theta[0] * xs[i] + theta[1] - ys[i];
                }
            },
            &LmOptions::default(),
        );
        assert!(res.converged);
        assert!((res.params[0] - 2.0).abs() < 1e-8);
        assert!((res.params[1] - 1.0).abs() < 1e-8);
        assert!(res.cost < 1e-16);
    }

    #[test]
    fn fits_rosenbrock_style_nonlinear() {
        // Classic Rosenbrock as residuals: r1 = 10(y − x²), r2 = 1 − x.
        let res = levenberg_marquardt(
            &[-1.2, 1.0],
            2,
            |t, out| {
                out[0] = 10.0 * (t[1] - t[0] * t[0]);
                out[1] = 1.0 - t[0];
            },
            &LmOptions { max_iters: 500, ..Default::default() },
        );
        assert!((res.params[0] - 1.0).abs() < 1e-6, "{:?}", res.params);
        assert!((res.params[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fits_exponential_decay() {
        // y = 3 exp(-1.5 x); θ in log-space for positivity.
        let xs: Vec<f64> = (0..20).map(|i| i as f64 * 0.2).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * (-1.5 * x).exp()).collect();
        let res = levenberg_marquardt(
            &[0.0, 0.0],
            xs.len(),
            |t, out| {
                let (a, k) = (t[0].exp(), t[1].exp());
                for i in 0..xs.len() {
                    out[i] = a * (-k * xs[i]).exp() - ys[i];
                }
            },
            &LmOptions::default(),
        );
        assert!((res.params[0].exp() - 3.0).abs() < 1e-6);
        assert!((res.params[1].exp() - 1.5).abs() < 1e-6);
    }

    #[test]
    fn survives_flat_residuals() {
        let res = levenberg_marquardt(
            &[5.0],
            3,
            |_t, out| out.iter_mut().for_each(|r| *r = 0.0),
            &LmOptions::default(),
        );
        assert!(res.converged);
        assert_eq!(res.params[0], 5.0);
    }
}
