//! The paper's runtime model (§II-A): `compute(R) = a·(R·d)^(−b) + c`
//! with the nested fallback family for few profiling points:
//!
//! ```text
//! |R| = 1:  f(R) = R^(−1)
//! |R| = 2:  f(R) = a·R^(−1)
//! |R| = 3:  f(R) = a·R^(−b)
//! |R| = 4:  f(R) = a·R^(−b) + c
//! |R| ≥ 5:  f(R) = a·(R·d)^(−b) + c
//! ```
//!
//! Fitting uses Levenberg–Marquardt on *relative* residuals
//! `(f(Rᵢ) − yᵢ)/yᵢ` so the exponential low-CPU region and the flat
//! high-CPU region contribute comparably (the paper scores with SMAPE,
//! which is likewise scale-free). Parameters are optimized in log-space to
//! enforce positivity. The NMS warm start (§III-B.3: "reuses the previously
//! fitted parameters from preceding runtime models") maps directly onto
//! [`RuntimeModel::fit_warm`].

mod lm;

pub use lm::{levenberg_marquardt, LmOptions, LmResult};

/// One profiled point: CPU limitation → mean per-sample runtime (seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProfilePoint {
    pub limit: f64,
    pub runtime: f64,
}

impl ProfilePoint {
    pub fn new(limit: f64, runtime: f64) -> Self {
        Self { limit, runtime }
    }
}

/// Which member of the nested family is active.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ModelKind {
    /// `R^-1` — no data-dependent parameters.
    Inverse,
    /// `a·R^-1`.
    ScaledInverse,
    /// `a·R^-b`.
    PowerLaw,
    /// `a·R^-b + c`.
    PowerLawOffset,
    /// `a·(R·d)^-b + c` — Eq. 1.
    Full,
}

impl ModelKind {
    /// Paper §II-A: the member is chosen by the number of profiled points.
    pub fn for_points(n: usize) -> ModelKind {
        match n {
            0 | 1 => ModelKind::Inverse,
            2 => ModelKind::ScaledInverse,
            3 => ModelKind::PowerLaw,
            4 => ModelKind::PowerLawOffset,
            _ => ModelKind::Full,
        }
    }

    pub fn n_params(&self) -> usize {
        match self {
            ModelKind::Inverse => 0,
            ModelKind::ScaledInverse => 1,
            ModelKind::PowerLaw => 2,
            ModelKind::PowerLawOffset => 3,
            ModelKind::Full => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Inverse => "R^-1",
            ModelKind::ScaledInverse => "a*R^-1",
            ModelKind::PowerLaw => "a*R^-b",
            ModelKind::PowerLawOffset => "a*R^-b+c",
            ModelKind::Full => "a*(R*d)^-b+c",
        }
    }

    /// Inverse of [`ModelKind::name`]: how snapshot restores map the
    /// persisted member string back onto the enum. `None` for strings no
    /// member ever produced.
    pub fn from_name(name: &str) -> Option<ModelKind> {
        match name {
            "R^-1" => Some(ModelKind::Inverse),
            "a*R^-1" => Some(ModelKind::ScaledInverse),
            "a*R^-b" => Some(ModelKind::PowerLaw),
            "a*R^-b+c" => Some(ModelKind::PowerLawOffset),
            "a*(R*d)^-b+c" => Some(ModelKind::Full),
            _ => None,
        }
    }
}

/// Fitted runtime model. `params = [a, b, c, d]` with inactive members held
/// at their neutral values (a=1, b=1, c=0, d=1) so every kind evaluates
/// through the same closed form.
#[derive(Clone, Debug)]
pub struct RuntimeModel {
    pub kind: ModelKind,
    pub a: f64,
    pub b: f64,
    pub c: f64,
    pub d: f64,
    /// Final 0.5·Σr² of the fit (relative residuals).
    pub fit_cost: f64,
}

impl RuntimeModel {
    /// Neutral model (used before any point is profiled).
    pub fn identity() -> Self {
        Self { kind: ModelKind::Inverse, a: 1.0, b: 1.0, c: 0.0, d: 1.0, fit_cost: 0.0 }
    }

    /// Predicted per-sample runtime at CPU limitation `r`.
    pub fn eval(&self, r: f64) -> f64 {
        debug_assert!(r > 0.0);
        self.a * (r * self.d).powf(-self.b) + self.c
    }

    /// Invert the model: the CPU limitation whose predicted runtime equals
    /// `target`. Returns `None` when the target is unreachable (below the
    /// asymptote `c`).
    pub fn invert(&self, target: f64) -> Option<f64> {
        if target <= self.c || target <= 0.0 {
            return None;
        }
        let base = self.a / (target - self.c);
        if base <= 0.0 {
            return None;
        }
        let r = base.powf(1.0 / self.b) / self.d;
        r.is_finite().then_some(r)
    }

    /// Uniformly rescale the predicted runtime curve by `factor`: both the
    /// power-law scale `a` and the asymptote `c` grow together, so
    /// `rescaled(k).eval(r) == k * eval(r)` for every `r`. This is the
    /// calibration primitive the transfer-prior path uses — one or two
    /// fresh probes can recalibrate a donor curve's magnitude without
    /// refitting (a refit at 1–2 points would degrade the model kind).
    pub fn rescaled(&self, factor: f64) -> Self {
        let mut m = self.clone();
        m.a *= factor;
        m.c *= factor;
        m
    }

    /// Fit the nested family to `points` with no warm start.
    pub fn fit(points: &[ProfilePoint]) -> Self {
        Self::fit_warm(points, None)
    }

    /// Fit with an optional warm start from the previous step's model (the
    /// NMS reuse). The member is chosen from `points.len()` per §II-A.
    pub fn fit_warm(points: &[ProfilePoint], warm: Option<&RuntimeModel>) -> Self {
        Self::fit_opts(points, warm, true)
    }

    /// Fit with explicit control over the multi-start basin search
    /// (`multistart = false` uses only the primary seed) — exposed for the
    /// ablation experiments.
    pub fn fit_opts(
        points: &[ProfilePoint],
        warm: Option<&RuntimeModel>,
        multistart: bool,
    ) -> Self {
        let kind = ModelKind::for_points(points.len());
        match kind {
            ModelKind::Inverse => {
                let mut m = Self::identity();
                if let Some(p) = points.first() {
                    // The curve still passes f(R) = R^-1; keep cost bookkeeping.
                    let r = (1.0 / p.limit - p.runtime) / p.runtime;
                    m.fit_cost = 0.5 * r * r;
                }
                m
            }
            ModelKind::ScaledInverse => {
                // Closed-form LSQ on relative residuals:
                // min_a Σ ((a/Rᵢ − yᵢ)/yᵢ)²
                //   =>  a = Σ 1/(Rᵢ yᵢ)  /  Σ 1/(Rᵢ² yᵢ²).
                let num: f64 = points.iter().map(|p| 1.0 / (p.limit * p.runtime)).sum();
                let den: f64 = points
                    .iter()
                    .map(|p| {
                        let t = 1.0 / (p.limit * p.runtime);
                        t * t
                    })
                    .sum();
                let a = if den > 0.0 { num / den } else { 1.0 };
                let mut m = Self { kind, a, b: 1.0, c: 0.0, d: 1.0, fit_cost: 0.0 };
                m.fit_cost = Self::relative_cost(&m, points);
                m
            }
            _ => Self::fit_lm(kind, points, warm, multistart),
        }
    }

    fn relative_cost(model: &RuntimeModel, points: &[ProfilePoint]) -> f64 {
        0.5 * points
            .iter()
            .map(|p| {
                let r = (model.eval(p.limit) - p.runtime) / p.runtime;
                r * r
            })
            .sum::<f64>()
    }

    fn fit_lm(
        kind: ModelKind,
        points: &[ProfilePoint],
        warm: Option<&RuntimeModel>,
        multistart: bool,
    ) -> Self {
        let np = kind.n_params();
        // θ layout (log-space): [ln a, ln b, ln c, ln d][..np]
        let theta0 = Self::initial_theta(kind, points, warm);
        let limits: Vec<f64> = points.iter().map(|p| p.limit).collect();
        let runtimes: Vec<f64> = points.iter().map(|p| p.runtime).collect();
        let kind_copy = kind;
        let eval_theta = move |t: &[f64], r: f64| -> f64 {
            let a = t[0].exp();
            let b = if kind_copy.n_params() >= 2 { t[1].exp() } else { 1.0 };
            let c = if kind_copy.n_params() >= 3 { t[2].exp() } else { 0.0 };
            let d = if kind_copy.n_params() >= 4 { t[3].exp() } else { 1.0 };
            a * (r * d).powf(-b) + c
        };
        // Multi-start LM: the loss surface has (at least) two basins — a
        // "plateau" basin where the offset c carries the saturated
        // high-CPU region, and a zero-offset basin with a stretched
        // exponent. Which one LM lands in depends on the seed, so we try
        // the primary seed (warm-started for NMS) plus a plateau seed and
        // keep the better fit.
        // Residual scale: SMAPE (the paper's target metric, Eq. 3) sums
        // *absolute* errors, so the fit weighs points by magnitude — the
        // exponential knee dominates, matching how the profiler is scored.
        // A geometric blend with the per-point scale keeps the plateau from
        // being ignored entirely (the adjuster needs it).
        let y_bar = runtimes.iter().sum::<f64>() / runtimes.len() as f64;
        let scales: Vec<f64> = runtimes.iter().map(|&y| (y * y_bar).sqrt()).collect();
        let mut seeds: Vec<Vec<f64>> = vec![theta0.clone()];
        if multistart && np >= 3 {
            // Plateau basin seed: assume the saturated floor carries 80% of
            // the smallest observed runtime, then seed (a, b) from a
            // log-log regression of the *residual* y − c0 so the whole
            // seed is self-consistent and LM descends inside that basin.
            let c0 = (min_runtime(points) * 0.8).max(1e-9);
            let shifted: Vec<ProfilePoint> = points
                .iter()
                .map(|p| ProfilePoint::new(p.limit, (p.runtime - c0).max(c0 * 0.01)))
                .collect();
            let (a0, b0) = loglog_seed(&shifted);
            let mut plateau = theta0.clone();
            plateau[0] = a0.max(1e-12).ln();
            plateau[1] = b0.clamp(0.1, 4.0).ln();
            plateau[2] = c0.ln();
            seeds.push(plateau);
        }
        // Priors keep degenerate point sets (e.g. plateau-heavy sets on
        // many-core machines) from extrapolating catastrophically into the
        // unprofiled knee:
        //   * scale params a, c: weak pull toward the seed (λ=0.03),
        //   * shape params b, d: moderate pull toward their physical
        //     neutral value 1 (λ=0.1) — CFS scaling exponents far from 1
        //     need actual knee evidence to be believed.
        let n_res = points.len() + np;
        let mut best: Option<(f64, Vec<f64>)> = None;
        for seed in seeds {
            let res = levenberg_marquardt(
                &seed,
                n_res,
                |t, out| {
                    for i in 0..limits.len() {
                        out[i] = (eval_theta(t, limits[i]) - runtimes[i]) / scales[i];
                    }
                    for j in 0..np {
                        out[limits.len() + j] = match j {
                            0 | 2 => 0.03 * (t[j] - seed[j]),
                            _ => 0.1 * t[j], // toward ln 1 = 0
                        };
                    }
                },
                &LmOptions::default(),
            );
            // Basin selection: data residuals plus an additive shape
            // penalty, so an overfit basin with wild exponents loses to a
            // sane basin that fits the points marginally worse.
            let data_cost: f64 = 0.5
                * limits
                    .iter()
                    .zip(runtimes.iter().zip(&scales))
                    .map(|(&l, (&y, &s))| {
                        let r = (eval_theta(&res.params, l) - y) / s;
                        r * r
                    })
                    .sum::<f64>();
            let ln_b = if np >= 2 { res.params[1] } else { 0.0 };
            let ln_d = if np >= 4 { res.params[3] } else { 0.0 };
            let score = data_cost + 0.005 * (ln_b * ln_b + ln_d * ln_d);
            if best.as_ref().map(|(c, _)| score < *c).unwrap_or(true) {
                best = Some((score, res.params));
            }
        }
        let theta = best.expect("at least one seed").1;
        let a = theta[0].exp();
        let b = if np >= 2 { theta[1].exp().clamp(0.02, 8.0) } else { 1.0 };
        let c = if np >= 3 { theta[2].exp() } else { 0.0 };
        let d = if np >= 4 { theta[3].exp().clamp(1e-3, 1e3) } else { 1.0 };
        let mut model = Self { kind, a, b, c, d, fit_cost: 0.0 };
        model.fit_cost = Self::relative_cost(&model, points);
        // Guard against degenerate LM outcomes: fall back to the previous
        // (simpler or warm) model when it explains the data clearly better.
        if let Some(w) = warm {
            let warm_cost = Self::relative_cost(w, points);
            if !model.fit_cost.is_finite() || model.fit_cost > warm_cost * 4.0 {
                let mut fallback = w.clone();
                fallback.kind = kind;
                fallback.fit_cost = warm_cost;
                return fallback;
            }
        }
        model
    }

    /// Initial θ: warm-started from the previous model when available
    /// (newly activated parameters start neutral), otherwise from a log-log
    /// regression heuristic.
    fn initial_theta(
        kind: ModelKind,
        points: &[ProfilePoint],
        warm: Option<&RuntimeModel>,
    ) -> Vec<f64> {
        let np = kind.n_params();
        let mut theta = vec![0.0; np];
        if let Some(w) = warm {
            theta[0] = w.a.max(1e-12).ln();
            if np >= 2 {
                theta[1] = w.b.max(1e-6).ln();
            }
            if np >= 3 {
                theta[2] = if w.c > 0.0 {
                    w.c.ln()
                } else {
                    // Newly activated offset: start well below the smallest
                    // observed runtime.
                    (min_runtime(points) * 0.05).max(1e-9).ln()
                };
            }
            if np >= 4 {
                theta[3] = if (w.d - 1.0).abs() > 1e-9 { w.d.max(1e-6).ln() } else { 0.0 };
            }
            return theta;
        }
        // Cold start: log-log slope for b, intercept for a.
        let (a0, b0) = loglog_seed(points);
        theta[0] = a0.max(1e-12).ln();
        if np >= 2 {
            theta[1] = b0.clamp(0.05, 5.0).ln();
        }
        if np >= 3 {
            theta[2] = (min_runtime(points) * 0.05).max(1e-9).ln();
        }
        if np >= 4 {
            theta[3] = 0.0; // d = 1
        }
        theta
    }
}

fn min_runtime(points: &[ProfilePoint]) -> f64 {
    points.iter().map(|p| p.runtime).fold(f64::INFINITY, f64::min)
}

/// Least-squares line through (ln R, ln y): y ≈ a R^-b.
fn loglog_seed(points: &[ProfilePoint]) -> (f64, f64) {
    let n = points.len() as f64;
    if points.len() < 2 {
        let p = points.first().copied().unwrap_or(ProfilePoint::new(1.0, 1.0));
        return (p.runtime * p.limit, 1.0);
    }
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0);
    for p in points {
        let x = p.limit.ln();
        let y = p.runtime.max(1e-12).ln();
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        let p = points[0];
        return (p.runtime * p.limit, 1.0);
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    (intercept.exp(), -slope)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(a: f64, b: f64, c: f64, d: f64, limits: &[f64]) -> Vec<ProfilePoint> {
        limits
            .iter()
            .map(|&r| ProfilePoint::new(r, a * (r * d).powf(-b) + c))
            .collect()
    }

    #[test]
    fn kind_selection_follows_paper() {
        assert_eq!(ModelKind::for_points(1), ModelKind::Inverse);
        assert_eq!(ModelKind::for_points(2), ModelKind::ScaledInverse);
        assert_eq!(ModelKind::for_points(3), ModelKind::PowerLaw);
        assert_eq!(ModelKind::for_points(4), ModelKind::PowerLawOffset);
        assert_eq!(ModelKind::for_points(5), ModelKind::Full);
        assert_eq!(ModelKind::for_points(9), ModelKind::Full);
    }

    #[test]
    fn scaled_inverse_recovers_a() {
        let pts = synth(3.0, 1.0, 0.0, 1.0, &[0.5, 2.0]);
        let m = RuntimeModel::fit(&pts);
        assert_eq!(m.kind, ModelKind::ScaledInverse);
        assert!((m.a - 3.0).abs() < 1e-9, "a={}", m.a);
    }

    #[test]
    fn power_law_recovers_a_b() {
        let pts = synth(2.0, 0.7, 0.0, 1.0, &[0.2, 1.0, 4.0]);
        let m = RuntimeModel::fit(&pts);
        assert_eq!(m.kind, ModelKind::PowerLaw);
        // Shape priors (see fit_lm) trade exact recovery for robust
        // extrapolation: allow ~2% bias on noiseless data.
        assert!((m.a - 2.0).abs() / 2.0 < 0.02, "a={}", m.a);
        assert!((m.b - 0.7).abs() / 0.7 < 0.05, "b={}", m.b);
    }

    #[test]
    fn offset_model_recovers_asymptote() {
        let pts = synth(1.5, 0.9, 0.08, 1.0, &[0.2, 0.6, 2.0, 6.0]);
        let m = RuntimeModel::fit(&pts);
        assert_eq!(m.kind, ModelKind::PowerLawOffset);
        for &r in &[0.3f64, 1.0, 3.0] {
            let want = 1.5 * r.powf(-0.9) + 0.08;
            assert!((m.eval(r) - want).abs() / want < 0.02, "r={r}");
        }
    }

    #[test]
    fn full_model_fits_noiseless_curve() {
        let pts = synth(0.8, 1.1, 0.02, 2.0, &[0.1, 0.3, 0.8, 2.0, 4.0, 8.0]);
        let m = RuntimeModel::fit(&pts);
        assert_eq!(m.kind, ModelKind::Full);
        // d is redundant with a (a·(Rd)^-b = (a d^-b)·R^-b), so compare
        // predictions rather than raw params.
        for &r in &[0.15f64, 0.5, 1.5, 6.0] {
            let want = 0.8 * (r * 2.0).powf(-1.1) + 0.02;
            assert!((m.eval(r) - want).abs() / want < 0.03, "r={r}: {} vs {want}", m.eval(r));
        }
    }

    #[test]
    fn warm_start_not_worse_than_cold() {
        let pts5 = synth(1.2, 0.8, 0.05, 1.5, &[0.1, 0.4, 1.0, 2.5, 6.0]);
        let warm_src = RuntimeModel::fit(&pts5[..4]);
        let cold = RuntimeModel::fit(&pts5);
        let warm = RuntimeModel::fit_warm(&pts5, Some(&warm_src));
        assert!(warm.fit_cost <= cold.fit_cost * 1.5 + 1e-6);
        // Both should describe the curve well (priors allow a small bias).
        assert!(warm.fit_cost < 1e-3, "warm cost {}", warm.fit_cost);
    }

    #[test]
    fn invert_is_inverse_of_eval() {
        let pts = synth(1.0, 1.2, 0.03, 1.0, &[0.1, 0.5, 1.0, 3.0, 8.0]);
        let m = RuntimeModel::fit(&pts);
        for &r in &[0.2f64, 0.7, 2.0, 5.0] {
            let t = m.eval(r);
            let r_back = m.invert(t).expect("invertible");
            assert!((r_back - r).abs() / r < 1e-6, "r={r}, back={r_back}");
        }
    }

    #[test]
    fn invert_rejects_unreachable_targets() {
        let m =
            RuntimeModel { kind: ModelKind::Full, a: 1.0, b: 1.0, c: 0.5, d: 1.0, fit_cost: 0.0 };
        assert!(m.invert(0.4).is_none()); // below asymptote
        assert!(m.invert(-1.0).is_none());
        assert!(m.invert(0.6).is_some());
    }

    #[test]
    fn noisy_fit_stays_close() {
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        let limits = [0.1f64, 0.2, 0.4, 0.8, 1.6, 3.2];
        let pts: Vec<ProfilePoint> = limits
            .iter()
            .map(|&r| {
                let clean = 2.0 * r.powf(-1.0) + 0.05;
                ProfilePoint::new(r, clean * (1.0 + 0.03 * rng.normal()))
            })
            .collect();
        let m = RuntimeModel::fit(&pts);
        for &r in &limits {
            let want = 2.0 * r.powf(-1.0) + 0.05;
            assert!((m.eval(r) - want).abs() / want < 0.15, "r={r}");
        }
    }

    #[test]
    fn rescaled_scales_every_prediction_uniformly() {
        let pts = synth(1.5, 0.9, 0.08, 1.0, &[0.2, 0.6, 2.0, 6.0]);
        let m = RuntimeModel::fit(&pts);
        let k = 2.75;
        let scaled = m.rescaled(k);
        assert_eq!(scaled.kind, m.kind);
        for &r in &[0.15f64, 0.5, 1.5, 6.0] {
            assert!((scaled.eval(r) - k * m.eval(r)).abs() < 1e-12, "r={r}");
        }
    }

    #[test]
    fn model_kind_names_roundtrip() {
        for kind in [
            ModelKind::Inverse,
            ModelKind::ScaledInverse,
            ModelKind::PowerLaw,
            ModelKind::PowerLawOffset,
            ModelKind::Full,
        ] {
            assert_eq!(ModelKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::from_name("not-a-kind"), None);
    }

    #[test]
    fn single_point_model_is_pure_inverse() {
        let m = RuntimeModel::fit(&[ProfilePoint::new(0.5, 10.0)]);
        assert_eq!(m.kind, ModelKind::Inverse);
        assert!((m.eval(0.5) - 2.0).abs() < 1e-12); // 1/0.5, ignores data per paper
    }
}
