//! Adaptive resource adjustment — the downstream consumer of the runtime
//! model (paper Fig. 1): "set the highest restriction of resources, while
//! still meeting runtime targets of the incoming data".

use crate::fit::RuntimeModel;
use crate::stream::ArrivalProcess;

/// One adjustment decision.
#[derive(Clone, Copy, Debug)]
pub struct Adjustment {
    /// Chosen CPU limitation (grid value).
    pub limit: f64,
    /// Model-predicted per-sample runtime at that limitation.
    pub predicted_runtime: f64,
    /// The per-sample budget that had to be met (1/arrival-rate · margin).
    pub budget: f64,
    /// False when even `l_max` cannot meet the budget (stream too fast).
    pub feasible: bool,
}

/// Picks the tightest CPU limitation that still meets just-in-time
/// processing for a given arrival rate.
#[derive(Clone, Debug)]
pub struct ResourceAdjuster {
    model: RuntimeModel,
    l_min: f64,
    l_max: f64,
    delta: f64,
    /// Safety margin: predicted runtime must be ≤ `margin · gap`.
    pub margin: f64,
}

impl ResourceAdjuster {
    pub fn new(model: RuntimeModel, l_min: f64, l_max: f64, delta: f64) -> Self {
        Self { model, l_min, l_max, delta, margin: 0.9 }
    }

    pub fn model(&self) -> &RuntimeModel {
        &self.model
    }

    /// Replace the model (e.g. after re-profiling).
    pub fn update_model(&mut self, model: RuntimeModel) {
        self.model = model;
    }

    /// Decide for a stream's arrival rate (Hz): the per-sample gap is
    /// `1/rate`. The convenience entry the job manager and the adaptive
    /// fleet loop use after a rate observation.
    pub fn decide_rate(&self, rate_hz: f64) -> Adjustment {
        self.decide(1.0 / rate_hz.max(1e-9))
    }

    /// Decide for a fixed per-sample gap (seconds between samples).
    pub fn decide(&self, gap: f64) -> Adjustment {
        let budget = gap * self.margin;
        let n = ((self.l_max - self.l_min) / self.delta).round() as usize;
        for i in 0..=n {
            let limit = self.l_min + i as f64 * self.delta;
            let predicted = self.model.eval(limit);
            if predicted <= budget {
                return Adjustment { limit, predicted_runtime: predicted, budget, feasible: true };
            }
        }
        Adjustment {
            limit: self.l_max,
            predicted_runtime: self.model.eval(self.l_max),
            budget,
            feasible: false,
        }
    }

    /// Decide for an arrival process over a horizon, re-deciding every
    /// `window` samples — the adaptive loop of Fig. 1.
    pub fn plan(
        &self,
        arrivals: &ArrivalProcess,
        horizon: usize,
        window: usize,
    ) -> Vec<Adjustment> {
        assert!(window > 0);
        let mut out = Vec::new();
        let mut i = 0;
        while i < horizon {
            let end = (i + window).min(horizon);
            // Tightest gap inside the window governs.
            let gap = (i..end).map(|k| arrivals.gap_at(k)).fold(f64::INFINITY, f64::min);
            out.push(self.decide(gap));
            i = end;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{ModelKind, RuntimeModel};

    fn model() -> RuntimeModel {
        // t(R) = 0.05/R + 0.005
        RuntimeModel { kind: ModelKind::Full, a: 0.05, b: 1.0, c: 0.005, d: 1.0, fit_cost: 0.0 }
    }

    #[test]
    fn picks_tightest_feasible_limit() {
        let adj = ResourceAdjuster::new(model(), 0.1, 4.0, 0.1);
        // 10 Hz stream -> gap 0.1s, budget 0.09 -> need 0.05/R+0.005 <= 0.09
        // -> R >= 0.588 -> grid 0.6.
        let d = adj.decide(0.1);
        assert!(d.feasible);
        assert!((d.limit - 0.6).abs() < 1e-9, "got {}", d.limit);
        assert!(d.predicted_runtime <= d.budget);
    }

    #[test]
    fn decide_rate_matches_gap_form() {
        let adj = ResourceAdjuster::new(model(), 0.1, 4.0, 0.1);
        let by_rate = adj.decide_rate(10.0);
        let by_gap = adj.decide(0.1);
        assert_eq!(by_rate.limit.to_bits(), by_gap.limit.to_bits());
        assert_eq!(by_rate.feasible, by_gap.feasible);
        // Degenerate rate is clamped, not a division blow-up: a dead
        // stream is trivially feasible at the smallest limit.
        let dead = adj.decide_rate(0.0);
        assert!(dead.feasible);
        assert!((dead.limit - 0.1).abs() < 1e-9);
    }

    #[test]
    fn slow_stream_gets_tiny_limit() {
        let adj = ResourceAdjuster::new(model(), 0.1, 4.0, 0.1);
        let d = adj.decide(10.0); // one sample every 10s
        assert!(d.feasible);
        assert!((d.limit - 0.1).abs() < 1e-9);
    }

    #[test]
    fn infeasible_stream_detected() {
        let adj = ResourceAdjuster::new(model(), 0.1, 4.0, 0.1);
        // gap 1ms: even at 4 cores t = 0.0175 > 0.0009.
        let d = adj.decide(0.001);
        assert!(!d.feasible);
        assert_eq!(d.limit, 4.0);
    }

    #[test]
    fn plan_adapts_to_varying_rate() {
        let adj = ResourceAdjuster::new(model(), 0.1, 4.0, 0.1);
        let arrivals = ArrivalProcess::Varying { lo: 2.0, hi: 15.0, period: 200.0 };
        let plan = adj.plan(&arrivals, 400, 50);
        assert_eq!(plan.len(), 8);
        let limits: Vec<f64> = plan.iter().map(|a| a.limit).collect();
        let max = limits.iter().cloned().fold(f64::MIN, f64::max);
        let min = limits.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max > min, "limits should vary with the rate: {limits:?}");
        assert!(plan.iter().all(|a| a.feasible));
    }

    #[test]
    fn margin_tightens_choice() {
        let mut adj = ResourceAdjuster::new(model(), 0.1, 4.0, 0.1);
        adj.margin = 0.5;
        let strict = adj.decide(0.1).limit;
        adj.margin = 1.0;
        let loose = adj.decide(0.1).limit;
        assert!(strict > loose);
    }
}
