//! Multi-job coordinator: the paper's "adaptive adjustment of resources per
//! job and component" (abstract) on one node.
//!
//! Each registered stream job carries its fitted runtime model; the manager
//! assigns every job the tightest CPU limit meeting its arrival rate and
//! resolves over-subscription by shedding the *lowest-priority* jobs to
//! best-effort (the node cannot run everything just-in-time — someone must
//! lose, and it should be a deliberate choice).

use std::collections::BTreeMap;

use crate::fit::RuntimeModel;

use super::adjuster::{Adjustment, ResourceAdjuster};

/// One managed stream-analysis job.
#[derive(Clone, Debug)]
pub struct ManagedJob {
    pub name: String,
    pub model: RuntimeModel,
    /// Current sample arrival rate (Hz).
    pub rate_hz: f64,
    /// Larger = more important (kept just-in-time longer).
    pub priority: i32,
}

impl ManagedJob {
    /// Provision this job at runtime quantile `q` instead of the mean
    /// prediction: the model is inflated by `1 + z(q) · spread`, where
    /// `spread` is the model's relative residual spread — a Gaussian
    /// tail assumption on the relative prediction error. `q = 0.5` (or a
    /// zero spread) leaves the job unchanged; quantiles below the median
    /// deflate, floored so the model never goes non-positive.
    pub fn at_quantile(mut self, q: f64, spread: f64) -> Self {
        self.model = quantile_model(&self.model, q, spread);
        self
    }
}

/// The capacity-planning view of a fitted runtime curve at quantile `q`:
/// the mean model inflated by `1 + z(q) · spread` (Gaussian tail on the
/// relative prediction error), floored so it never goes non-positive.
/// This is [`ManagedJob::at_quantile`] as a free function, for call
/// sites that re-plan from a bare [`RuntimeModel`].
pub fn quantile_model(model: &RuntimeModel, q: f64, spread: f64) -> RuntimeModel {
    let z = crate::stats::normal_quantile(q.clamp(1e-9, 1.0 - 1e-9));
    let factor = (1.0 + z * spread.max(0.0)).max(0.1);
    model.rescaled(factor)
}

/// Assignment outcome for one job.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub name: String,
    pub adjustment: Adjustment,
    /// False when the job was shed to best-effort (capacity or
    /// infeasibility).
    pub guaranteed: bool,
}

/// Node-level capacity plan.
#[derive(Clone, Debug)]
pub struct CapacityPlan {
    pub assignments: Vec<Assignment>,
    pub total_assigned: f64,
    pub capacity: f64,
}

/// The adjustment a node with `capacity` assignable cores would grant a
/// job with the given model and arrival rate — [`JobManager::quote`]
/// without a manager. The mesh scheduler scores remote placements with
/// this from gossiped capacity summaries alone.
pub fn quote_for(capacity: f64, model: &RuntimeModel, rate_hz: f64) -> Adjustment {
    let adj =
        ResourceAdjuster::new(model.clone(), JobManager::L_MIN, capacity, JobManager::DELTA);
    adj.decide_rate(rate_hz)
}

/// The job registry + allocator.
pub struct JobManager {
    capacity: f64,
    l_min: f64,
    delta: f64,
    jobs: BTreeMap<String, ManagedJob>,
}

impl JobManager {
    /// Smallest assignable CPU limit (fraction of a core).
    pub const L_MIN: f64 = 0.1;
    /// Limit-grid step the adjuster searches on.
    pub const DELTA: f64 = 0.1;

    pub fn new(capacity: f64) -> Self {
        Self { capacity, l_min: Self::L_MIN, delta: Self::DELTA, jobs: BTreeMap::new() }
    }

    /// Register (or replace) a job with its profiled runtime model.
    pub fn register(&mut self, job: ManagedJob) {
        self.jobs.insert(job.name.clone(), job);
    }

    pub fn deregister(&mut self, name: &str) -> Option<ManagedJob> {
        self.jobs.remove(name)
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    /// Registered jobs in name order (introspection/diagnostics).
    pub fn jobs(&self) -> impl Iterator<Item = &ManagedJob> {
        self.jobs.values()
    }

    /// Assignable capacity of the node this manager governs.
    pub fn capacity(&self) -> f64 {
        self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// The adjustment this node would grant a job with the given model and
    /// arrival rate, without registering it — the quote the fleet scheduler
    /// scores candidate placements with.
    pub fn quote(&self, model: &RuntimeModel, rate_hz: f64) -> Adjustment {
        let adj = ResourceAdjuster::new(model.clone(), self.l_min, self.capacity, self.delta);
        adj.decide_rate(rate_hz)
    }

    /// Capacity left after the current plan's guaranteed assignments — what
    /// this node advertises to the fleet scheduler.
    pub fn residual_capacity(&self) -> f64 {
        (self.capacity - self.plan().total_assigned).max(0.0)
    }

    /// Accept an externally placed job iff its tightest feasible limit fits
    /// the *residual* capacity, so admission can never displace a job that
    /// is already guaranteed here. A job whose name is already registered
    /// is refused outright — silently replacing a resident (and deleting
    /// it on a later rollback) must never happen. Returns the granted
    /// limit.
    pub fn try_accept(&mut self, job: ManagedJob) -> Option<f64> {
        if self.jobs.contains_key(&job.name) {
            return None;
        }
        let a = self.quote(&job.model, job.rate_hz);
        if !a.feasible || a.limit > self.residual_capacity() + 1e-9 {
            return None;
        }
        self.register(job);
        Some(a.limit)
    }

    /// Update a job's arrival rate (the Fig. 1 adaptive loop input).
    pub fn update_rate(&mut self, name: &str, rate_hz: f64) -> bool {
        if let Some(j) = self.jobs.get_mut(name) {
            j.rate_hz = rate_hz;
            true
        } else {
            false
        }
    }

    /// Replace a job's fitted runtime model in place — how a
    /// drift-triggered re-profile re-enters the manager without losing the
    /// job's rate and priority.
    pub fn update_model(&mut self, name: &str, model: RuntimeModel) -> bool {
        if let Some(j) = self.jobs.get_mut(name) {
            j.model = model;
            true
        } else {
            false
        }
    }

    /// Compute the capacity plan: per-job tightest limits, then shed
    /// lowest-priority jobs while the node is over-subscribed.
    pub fn plan(&self) -> CapacityPlan {
        let mut assignments: Vec<Assignment> = self
            .jobs
            .values()
            .map(|j| {
                let adj = ResourceAdjuster::new(
                    j.model.clone(),
                    self.l_min,
                    self.capacity,
                    self.delta,
                );
                let a = adj.decide(1.0 / j.rate_hz);
                Assignment {
                    name: j.name.clone(),
                    guaranteed: a.feasible,
                    adjustment: a,
                }
            })
            .collect();

        // Shed until the guaranteed set fits: lowest priority first,
        // largest demand as tie-break.
        loop {
            let total: f64 = assignments
                .iter()
                .filter(|a| a.guaranteed)
                .map(|a| a.adjustment.limit)
                .sum();
            if total <= self.capacity + 1e-9 {
                break;
            }
            let victim = assignments
                .iter_mut()
                .filter(|a| a.guaranteed)
                .min_by(|x, y| {
                    let px = self.jobs[&x.name].priority;
                    let py = self.jobs[&y.name].priority;
                    let by_demand = x.adjustment.limit.partial_cmp(&y.adjustment.limit).unwrap();
                    px.cmp(&py).then(by_demand.reverse())
                });
            match victim {
                Some(v) => v.guaranteed = false,
                None => break,
            }
        }
        let total_assigned = assignments
            .iter()
            .filter(|a| a.guaranteed)
            .map(|a| a.adjustment.limit)
            .sum();
        CapacityPlan { assignments, total_assigned, capacity: self.capacity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::ModelKind;

    fn model(a: f64) -> RuntimeModel {
        RuntimeModel { kind: ModelKind::Full, a, b: 1.0, c: 0.001, d: 1.0, fit_cost: 0.0 }
    }

    fn job(name: &str, a: f64, rate: f64, prio: i32) -> ManagedJob {
        ManagedJob { name: name.into(), model: model(a), rate_hz: rate, priority: prio }
    }

    #[test]
    fn at_quantile_inflates_the_upper_tail_only() {
        let j = job("q", 0.05, 5.0, 1);
        let p95 = j.clone().at_quantile(0.95, 0.2);
        let p50 = j.clone().at_quantile(0.5, 0.2);
        let tight = j.clone().at_quantile(0.95, 0.0);
        for &r in &[0.3f64, 1.0, 2.0] {
            assert!(p95.model.eval(r) > j.model.eval(r), "p95 inflates at {r}");
            assert!((p50.model.eval(r) - j.model.eval(r)).abs() < 1e-12, "median = mean");
            assert!((tight.model.eval(r) - j.model.eval(r)).abs() < 1e-12, "zero spread");
        }
        // z(0.95) * 0.2 ≈ 0.329: the inflation is the Gaussian tail factor.
        let ratio = p95.model.eval(1.0) / j.model.eval(1.0);
        assert!((ratio - 1.329).abs() < 1e-3, "{ratio}");
    }

    #[test]
    fn quantile_planning_reserves_more_capacity() {
        let plan_at = |q: Option<f64>| {
            let mut mgr = JobManager::new(4.0);
            let mut j = job("a", 0.05, 5.0, 1);
            if let Some(q) = q {
                j = j.at_quantile(q, 0.3);
            }
            mgr.register(j);
            mgr.plan()
        };
        let mean = plan_at(None);
        let p95 = plan_at(Some(0.95));
        assert!(
            p95.total_assigned > mean.total_assigned,
            "p95 {} vs mean {}",
            p95.total_assigned,
            mean.total_assigned
        );
    }

    #[test]
    fn assigns_tight_limits_when_capacity_suffices() {
        let mut mgr = JobManager::new(4.0);
        mgr.register(job("a", 0.05, 5.0, 1)); // needs 0.05/R+0.001 <= 0.18 -> R>=0.28 -> 0.3
        mgr.register(job("b", 0.02, 5.0, 1));
        let plan = mgr.plan();
        assert!(plan.assignments.iter().all(|a| a.guaranteed));
        assert!(plan.total_assigned <= 4.0);
        let a = plan.assignments.iter().find(|x| x.name == "a").unwrap();
        assert!((a.adjustment.limit - 0.3).abs() < 1e-9, "{}", a.adjustment.limit);
    }

    #[test]
    fn sheds_lowest_priority_on_oversubscription() {
        let mut mgr = JobManager::new(1.0);
        // Each needs ~0.6 CPU at 10 Hz -> two can't both be guaranteed.
        mgr.register(job("important", 0.05, 10.0, 10));
        mgr.register(job("batch", 0.05, 10.0, 1));
        let plan = mgr.plan();
        let imp = plan.assignments.iter().find(|a| a.name == "important").unwrap();
        let batch = plan.assignments.iter().find(|a| a.name == "batch").unwrap();
        assert!(imp.guaranteed);
        assert!(!batch.guaranteed);
        assert!(plan.total_assigned <= 1.0);
    }

    #[test]
    fn shedding_order_is_priority_then_largest_demand() {
        // Three jobs on a 1-core node. Demands (margin 0.9):
        //   "high"      prio 5, a=0.10 -> tightest limit 0.6
        //   "low-big"   prio 1, a=0.12 -> tightest limit 0.7
        //   "low-small" prio 1, a=0.05 -> tightest limit 0.3
        // Total 1.6 > 1.0. The first victim must be the *lowest priority*
        // with the *largest demand* ("low-big"); after shedding it the
        // remaining 0.9 fits, so "low-small" survives despite equal
        // priority.
        let mut mgr = JobManager::new(1.0);
        mgr.register(job("high", 0.10, 5.0, 5));
        mgr.register(job("low-big", 0.12, 5.0, 1));
        mgr.register(job("low-small", 0.05, 5.0, 1));
        let plan = mgr.plan();
        let by = |n: &str| plan.assignments.iter().find(|a| a.name == n).unwrap();
        assert!(by("high").guaranteed);
        assert!(!by("low-big").guaranteed, "largest low-priority demand sheds first");
        assert!(by("low-small").guaranteed, "small same-priority job must survive");
        assert!((plan.total_assigned - 0.9).abs() < 1e-9, "{}", plan.total_assigned);
    }

    #[test]
    fn jobs_accessor_iterates_in_name_order() {
        let mut mgr = JobManager::new(4.0);
        mgr.register(job("zeta", 0.05, 2.0, 1));
        mgr.register(job("alpha", 0.05, 2.0, 1));
        let names: Vec<&str> = mgr.jobs().map(|j| j.name.as_str()).collect();
        assert_eq!(names, vec!["alpha", "zeta"]);
        assert_eq!(mgr.capacity(), 4.0);
    }

    #[test]
    fn rate_update_changes_plan() {
        let mut mgr = JobManager::new(4.0);
        mgr.register(job("a", 0.05, 2.0, 1));
        let before = mgr.plan().assignments[0].adjustment.limit;
        assert!(mgr.update_rate("a", 20.0));
        let after = mgr.plan().assignments[0].adjustment.limit;
        assert!(after > before, "{before} -> {after}");
        assert!(!mgr.update_rate("ghost", 1.0));
    }

    #[test]
    fn model_update_changes_plan_in_place() {
        let mut mgr = JobManager::new(4.0);
        mgr.register(job("a", 0.05, 5.0, 3));
        let before = mgr.plan().assignments[0].adjustment.limit;
        // A re-profile found the job 3x slower: the granted limit grows,
        // while rate and priority survive the swap.
        assert!(mgr.update_model("a", model(0.15)));
        let plan = mgr.plan();
        assert!(plan.assignments[0].adjustment.limit > before);
        let j = mgr.jobs().next().unwrap();
        assert_eq!((j.rate_hz, j.priority), (5.0, 3));
        assert!(!mgr.update_model("ghost", model(0.1)));
    }

    #[test]
    fn infeasible_job_not_guaranteed() {
        let mut mgr = JobManager::new(2.0);
        mgr.register(job("fast", 0.05, 1000.0, 5)); // 1 kHz: impossible
        let plan = mgr.plan();
        assert!(!plan.assignments[0].guaranteed);
    }

    #[test]
    fn residual_capacity_tracks_guaranteed_assignments() {
        let mut mgr = JobManager::new(4.0);
        assert!((mgr.residual_capacity() - 4.0).abs() < 1e-9, "idle node");
        mgr.register(job("a", 0.05, 5.0, 1)); // tightest limit 0.3
        assert!((mgr.residual_capacity() - 3.7).abs() < 1e-9);
        // A shed job consumes no residual capacity.
        let mut tight = JobManager::new(1.0);
        tight.register(job("big", 0.05, 10.0, 2)); // needs 0.6
        tight.register(job("lost", 0.05, 10.0, 1)); // shed
        assert!((tight.residual_capacity() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn quote_matches_plan_decision() {
        let mut mgr = JobManager::new(4.0);
        let j = job("a", 0.05, 5.0, 1);
        let quoted = mgr.quote(&j.model, j.rate_hz);
        let free = quote_for(4.0, &j.model, j.rate_hz);
        mgr.register(j);
        let planned = &mgr.plan().assignments[0].adjustment;
        assert!((quoted.limit - planned.limit).abs() < 1e-12);
        assert_eq!(quoted.feasible, planned.feasible);
        // The manager-free quote is the same decision.
        assert!((free.limit - quoted.limit).abs() < 1e-12);
        assert_eq!(free.feasible, quoted.feasible);
    }

    #[test]
    fn try_accept_grants_only_from_residual() {
        let mut mgr = JobManager::new(1.0);
        mgr.register(job("resident", 0.05, 10.0, 5)); // guaranteed at 0.6
        // Fits: needs 0.3 <= residual 0.4.
        let granted = mgr.try_accept(job("guest", 0.05, 5.0, 1));
        assert!((granted.unwrap() - 0.3).abs() < 1e-9);
        // Does not fit: needs 0.6 > residual 0.1 — refused, not registered.
        assert!(mgr.try_accept(job("crowd", 0.05, 10.0, 9)).is_none());
        assert_eq!(mgr.len(), 2);
        // The resident stayed guaranteed throughout.
        let plan = mgr.plan();
        let resident = plan.assignments.iter().find(|a| a.name == "resident").unwrap();
        assert!(resident.guaranteed);
        // Infeasible stream: refused regardless of residual.
        let mut idle = JobManager::new(2.0);
        assert!(idle.try_accept(job("fast", 0.05, 1000.0, 5)).is_none());
        assert!(idle.is_empty());
        // A name collision with a resident is refused, never replaced.
        assert!(mgr.try_accept(job("resident", 0.01, 1.0, 1)).is_none());
        let resident = mgr.jobs().find(|j| j.name == "resident").unwrap();
        assert!((resident.model.a - 0.05).abs() < 1e-12, "resident model untouched");
    }

    #[test]
    fn register_replaces() {
        let mut mgr = JobManager::new(4.0);
        mgr.register(job("a", 0.05, 2.0, 1));
        mgr.register(job("a", 0.10, 2.0, 1));
        assert_eq!(mgr.len(), 1);
        assert!(mgr.deregister("a").is_some());
        assert!(mgr.is_empty());
    }
}
