//! The profiling orchestrator — the paper's end-to-end procedure:
//!
//! 1. place `n` initial runs via Algorithm 1 and profile them in parallel
//!    (wallclock = the slowest run, Eq. 2 guarantees they fit on the node),
//! 2. adopt the runtime observed at the smallest limitation as the
//!    **synthetic target**,
//! 3. iterate: fit the nested runtime model (warm-started for NMS), ask the
//!    selection strategy for the next limitation, profile it (optionally
//!    with early stopping), and
//! 4. stop after `max_steps` profiled limitations (or grid exhaustion).

use crate::earlystop::EarlyStopConfig;
use crate::fit::{ProfilePoint, RuntimeModel};
use crate::stats::smape_guarded;
use crate::strategies::{initial_limits, ProfilingContext, SelectionStrategy};

use super::backend::{Measurement, ProfilingBackend};

/// Session configuration (§III-A.c names).
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Synthetic-target fraction `p` of `l_max`.
    pub p: f64,
    /// Initial parallel profiling runs `n ∈ {2,3,4}`.
    pub n_initial: usize,
    /// Samples per profiling run (1000/3000/5000/10000 in the paper).
    pub samples: usize,
    /// When set, runs stop early per §II-C instead of consuming `samples`.
    pub early_stop: Option<EarlyStopConfig>,
    /// Cap on per-run samples when early stopping is active.
    pub early_stop_cap: usize,
    /// Total profiled limitations, including the initial runs.
    pub max_steps: usize,
    /// Limitation grid parameters.
    pub l_min: f64,
    pub delta: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        Self {
            p: 0.05,
            n_initial: 3,
            samples: 10_000,
            early_stop: None,
            early_stop_cap: 10_000,
            max_steps: 6,
            l_min: 0.1,
            delta: 0.1,
        }
    }
}

/// One profiled limitation with the model state after refitting.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// 1-based step index (initial parallel runs share step 1..n).
    pub index: usize,
    pub limit: f64,
    pub mean_runtime: f64,
    pub samples: usize,
    /// Wallclock of this run.
    pub wallclock: f64,
    /// Cumulative session wallclock after this step (parallel initial runs
    /// contribute their max).
    pub cumulative_time: f64,
    /// Model fitted to all points up to and including this step.
    pub model: RuntimeModel,
}

/// Completed profiling session.
#[derive(Clone, Debug)]
pub struct SessionResult {
    pub backend: String,
    pub strategy: String,
    pub initial_limits: Vec<f64>,
    /// Synthetic target runtime adopted after the initial phase.
    pub target: f64,
    pub steps: Vec<StepRecord>,
    pub total_time: f64,
}

impl SessionResult {
    /// The model after the final step.
    pub fn final_model(&self) -> &RuntimeModel {
        &self.steps.last().expect("non-empty session").model
    }

    /// Model state after `k` profiled limitations (k >= n_initial).
    pub fn model_after(&self, k: usize) -> Option<&RuntimeModel> {
        self.steps.get(k.checked_sub(1)?).map(|s| &s.model)
    }

    /// Cumulative wallclock after `k` profiled limitations.
    pub fn time_after(&self, k: usize) -> Option<f64> {
        self.steps.get(k.checked_sub(1)?).map(|s| s.cumulative_time)
    }
}

/// Score a fitted model against a ground-truth dataset (the acquisition
/// sweep): SMAPE over all grid limitations (paper Eq. 3, ε-guarded).
pub fn smape_vs_dataset(model: &RuntimeModel, dataset: &[ProfilePoint]) -> f64 {
    let truth: Vec<f64> = dataset.iter().map(|p| p.runtime).collect();
    let pred: Vec<f64> = dataset.iter().map(|p| model.eval(p.limit)).collect();
    smape_guarded(&truth, &pred, 1e-9)
}

/// The orchestrator.
pub struct Profiler {
    cfg: ProfilerConfig,
    strategy: Box<dyn SelectionStrategy>,
}

impl Profiler {
    pub fn new(cfg: ProfilerConfig, strategy: Box<dyn SelectionStrategy>) -> Self {
        assert!(cfg.max_steps >= cfg.n_initial, "max_steps < n_initial");
        Self { cfg, strategy }
    }

    pub fn config(&self) -> &ProfilerConfig {
        &self.cfg
    }

    fn run_one(&self, backend: &mut dyn ProfilingBackend, limit: f64) -> Measurement {
        match &self.cfg.early_stop {
            Some(es) => backend.measure_early_stop(limit, es, self.cfg.early_stop_cap),
            None => backend.measure(limit, self.cfg.samples),
        }
    }

    /// Run a full profiling session against `backend`.
    pub fn run(&mut self, backend: &mut dyn ProfilingBackend) -> SessionResult {
        self.run_observed(backend, &mut |_| {})
    }

    /// Run a full profiling session, invoking `observer` after every
    /// measurement (initial parallel runs included, in placement order).
    ///
    /// This is the seam the fleet engine hooks into: the observer feeds each
    /// measurement into the job's incremental model refit while the session
    /// is still in flight, instead of waiting for the final [`SessionResult`].
    pub fn run_observed(
        &mut self,
        backend: &mut dyn ProfilingBackend,
        observer: &mut dyn FnMut(&Measurement),
    ) -> SessionResult {
        self.run_observed_from(backend, observer, None)
    }

    /// [`Profiler::run_observed`] warm-started from a `prior` model — the
    /// drift re-profiling path: the stale fit seeds every refit of the
    /// session (regardless of the strategy's own warm-start policy), so
    /// the new session converges from what the old model already knew
    /// instead of from scratch. `prior = None` is byte-identical to
    /// [`Profiler::run_observed`].
    pub fn run_observed_from(
        &mut self,
        backend: &mut dyn ProfilingBackend,
        observer: &mut dyn FnMut(&Measurement),
        prior: Option<&RuntimeModel>,
    ) -> SessionResult {
        let l_max = backend.l_max();
        let mut ctx = ProfilingContext::new(self.cfg.l_min, l_max, self.cfg.delta);
        if let Some(p) = prior {
            ctx.model = p.clone();
        }
        let init =
            initial_limits(self.cfg.p, self.cfg.n_initial, self.cfg.l_min, l_max, self.cfg.delta);

        let mut steps: Vec<StepRecord> = Vec::new();
        let mut cumulative = 0.0;

        // ---- Phase 1: initial parallel runs (wallclock = slowest). ----
        let measurements: Vec<Measurement> = init
            .iter()
            .map(|&l| {
                let m = self.run_one(backend, l);
                observer(&m);
                m
            })
            .collect();
        let parallel_wall = measurements.iter().map(|m| m.wallclock).fold(0.0f64, f64::max);
        cumulative += parallel_wall;
        // Synthetic target: runtime at the smallest initial limitation.
        let target_meas = measurements
            .iter()
            .min_by(|a, b| a.limit.partial_cmp(&b.limit).unwrap())
            .expect("non-empty initial placement");
        ctx.target = target_meas.mean_runtime;

        for m in &measurements {
            ctx.points.push(ProfilePoint::new(m.limit, m.mean_runtime));
        }
        ctx.model = RuntimeModel::fit_warm(&ctx.points, prior);
        for (i, m) in measurements.iter().enumerate() {
            steps.push(StepRecord {
                index: i + 1,
                limit: m.limit,
                mean_runtime: m.mean_runtime,
                samples: m.samples,
                wallclock: m.wallclock,
                cumulative_time: cumulative,
                model: RuntimeModel::fit(&ctx.points[..=i]),
            });
        }
        // The record for the last initial step holds the joint fit.
        if let Some(last) = steps.last_mut() {
            last.model = ctx.model.clone();
        }

        // ---- Phase 2: iterative strategy-driven profiling. ----
        while steps.len() < self.cfg.max_steps {
            let Some(next) = self.strategy.next_limit(&ctx) else {
                break;
            };
            let m = self.run_one(backend, next);
            observer(&m);
            cumulative += m.wallclock;
            ctx.points.push(ProfilePoint::new(m.limit, m.mean_runtime));
            let warm = (self.strategy.warm_start() || prior.is_some()).then_some(&ctx.model);
            ctx.model = RuntimeModel::fit_warm(&ctx.points, warm);
            steps.push(StepRecord {
                index: steps.len() + 1,
                limit: m.limit,
                mean_runtime: m.mean_runtime,
                samples: m.samples,
                wallclock: m.wallclock,
                cumulative_time: cumulative,
                model: ctx.model.clone(),
            });
        }

        SessionResult {
            backend: backend.label(),
            strategy: self.strategy.name().to_string(),
            initial_limits: init,
            target: ctx.target,
            steps,
            total_time: cumulative,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimulatedBackend;
    use crate::simulator::{node, Algo, SimulatedJob};
    use crate::strategies;

    fn backend(node_name: &str, algo: Algo, seed: u64) -> SimulatedBackend {
        SimulatedBackend::new(SimulatedJob::new(node(node_name).unwrap(), algo, seed))
    }

    fn run_session(strategy: &str, node_name: &str, steps: usize, seed: u64) -> SessionResult {
        let cfg = ProfilerConfig {
            samples: 1000,
            max_steps: steps,
            ..Default::default()
        };
        let mut b = backend(node_name, Algo::Arima, seed);
        let mut prof = Profiler::new(cfg, strategies::by_name(strategy, seed).unwrap());
        prof.run(&mut b)
    }

    #[test]
    fn session_has_expected_shape() {
        let s = run_session("nms", "pi4", 6, 1);
        assert_eq!(s.steps.len(), 6);
        assert_eq!(s.initial_limits.len(), 3);
        assert!(s.target > 0.0);
        assert!(s.total_time > 0.0);
        // Cumulative time is monotone.
        for w in s.steps.windows(2) {
            assert!(w[1].cumulative_time >= w[0].cumulative_time);
        }
        // No duplicate profiled limits.
        for (i, a) in s.steps.iter().enumerate() {
            for b in &s.steps[i + 1..] {
                assert!((a.limit - b.limit).abs() > 0.05, "dup {}", a.limit);
            }
        }
    }

    #[test]
    fn initial_runs_accounted_in_parallel() {
        let s = run_session("nms", "pi4", 3, 2);
        // All three initial steps share the same cumulative time == max.
        let c0 = s.steps[0].cumulative_time;
        assert!(s.steps.iter().all(|st| (st.cumulative_time - c0).abs() < 1e-9));
        let max_wall = s.steps.iter().map(|st| st.wallclock).fold(0.0f64, f64::max);
        assert!((c0 - max_wall).abs() < 1e-9);
    }

    #[test]
    fn smape_improves_with_steps_for_nms() {
        let mut truth_job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 999);
        let dataset = truth_job.acquire_dataset(10_000);
        let s = run_session("nms", "pi4", 8, 3);
        let early = smape_vs_dataset(s.model_after(3).unwrap(), &dataset);
        let late = smape_vs_dataset(s.model_after(8).unwrap(), &dataset);
        assert!(late < early, "SMAPE should improve: {early} -> {late}");
        assert!(late < 0.2, "final SMAPE should be decent: {late}");
    }

    #[test]
    fn all_strategies_complete_sessions() {
        for strat in ["nms", "bs", "bo", "random"] {
            let s = run_session(strat, "e2high", 6, 7);
            assert_eq!(s.steps.len(), 6, "{strat}");
            assert!(s.final_model().eval(1.0).is_finite());
        }
    }

    #[test]
    fn early_stopping_reduces_profiling_time() {
        let cfg_full = ProfilerConfig { samples: 10_000, max_steps: 6, ..Default::default() };
        let cfg_es = ProfilerConfig {
            samples: 10_000,
            max_steps: 6,
            early_stop: Some(crate::earlystop::EarlyStopConfig::new(0.95, 0.10)),
            early_stop_cap: 10_000,
            ..Default::default()
        };
        let mut b1 = backend("pi4", Algo::Arima, 11);
        let mut b2 = backend("pi4", Algo::Arima, 11);
        let t_full = Profiler::new(cfg_full, strategies::by_name("nms", 1).unwrap())
            .run(&mut b1)
            .total_time;
        let t_es = Profiler::new(cfg_es, strategies::by_name("nms", 1).unwrap())
            .run(&mut b2)
            .total_time;
        assert!(
            t_es < t_full * 0.5,
            "early stopping should at least halve profiling time: {t_es} vs {t_full}"
        );
    }

    #[test]
    fn observer_sees_every_measurement_in_order() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b = backend("pi4", Algo::Arima, 21);
        let mut seen: Vec<Measurement> = Vec::new();
        let s = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_observed(&mut b, &mut |m| seen.push(*m));
        assert_eq!(seen.len(), s.steps.len());
        for (m, step) in seen.iter().zip(&s.steps) {
            assert_eq!(m.limit, step.limit);
            assert_eq!(m.mean_runtime, step.mean_runtime);
        }
    }

    #[test]
    fn prior_none_is_byte_identical_to_plain_run() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b1 = backend("pi4", Algo::Arima, 31);
        let mut b2 = backend("pi4", Algo::Arima, 31);
        let s1 = Profiler::new(cfg.clone(), strategies::by_name("nms", 1).unwrap()).run(&mut b1);
        let s2 = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_observed_from(&mut b2, &mut |_| {}, None);
        assert_eq!(s1.steps.len(), s2.steps.len());
        for (a, b) in s1.steps.iter().zip(&s2.steps) {
            assert_eq!(a.limit.to_bits(), b.limit.to_bits());
            assert_eq!(a.mean_runtime.to_bits(), b.mean_runtime.to_bits());
            assert_eq!(a.model.a.to_bits(), b.model.a.to_bits());
            assert_eq!(a.model.b.to_bits(), b.model.b.to_bits());
        }
        assert_eq!(s1.total_time.to_bits(), s2.total_time.to_bits());
    }

    #[test]
    fn prior_seeds_every_refit_of_the_session() {
        // A warm session from a decent prior must finish with a usable fit
        // and the same step shape as a cold one (the prior changes where
        // fits start, never how the session is driven).
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut cold_backend = backend("pi4", Algo::Arima, 33);
        let cold = Profiler::new(cfg.clone(), strategies::by_name("bs", 1).unwrap())
            .run(&mut cold_backend);
        let mut warm_backend = backend("pi4", Algo::Arima, 33);
        let warm = Profiler::new(cfg, strategies::by_name("bs", 1).unwrap())
            .run_observed_from(&mut warm_backend, &mut |_| {}, Some(cold.final_model()));
        assert_eq!(warm.steps.len(), cold.steps.len());
        let m = warm.final_model();
        assert!(m.eval(0.5).is_finite() && m.eval(0.5) > 0.0);
        // Both describe the same backend: predictions agree within noise.
        for &r in &[0.3, 1.0, 3.0] {
            let rel = (m.eval(r) - cold.final_model().eval(r)).abs() / cold.final_model().eval(r);
            assert!(rel < 0.5, "warm vs cold diverged at {r}: {rel}");
        }
    }

    #[test]
    fn single_core_node_works_with_two_initial() {
        let cfg =
            ProfilerConfig { n_initial: 2, samples: 1000, max_steps: 5, ..Default::default() };
        let mut b = backend("n1", Algo::Lstm, 13);
        let s = Profiler::new(cfg, strategies::by_name("bs", 1).unwrap()).run(&mut b);
        assert!(s.steps.len() <= 5);
        assert!(s.initial_limits.iter().sum::<f64>() <= 1.0 + 1e-9);
    }
}
