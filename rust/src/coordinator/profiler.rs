//! The profiling orchestrator — the paper's end-to-end procedure:
//!
//! 1. place `n` initial runs via Algorithm 1 and profile them in parallel
//!    (wallclock = the slowest run, Eq. 2 guarantees they fit on the node),
//! 2. adopt the runtime observed at the smallest limitation as the
//!    **synthetic target**,
//! 3. iterate: fit the nested runtime model (warm-started for NMS), ask the
//!    selection strategy for the next limitation, profile it (optionally
//!    with early stopping), and
//! 4. stop after `max_steps` profiled limitations (or grid exhaustion).

use crate::earlystop::EarlyStopConfig;
use crate::fit::{ProfilePoint, RuntimeModel};
use crate::stats::smape_guarded;
use crate::strategies::{initial_limits, ProfilingContext, SelectionStrategy};

use super::backend::{Measurement, ProfilingBackend};

/// Session configuration (§III-A.c names).
#[derive(Clone, Debug)]
pub struct ProfilerConfig {
    /// Synthetic-target fraction `p` of `l_max`.
    pub p: f64,
    /// Initial parallel profiling runs `n ∈ {2,3,4}`.
    pub n_initial: usize,
    /// Samples per profiling run (1000/3000/5000/10000 in the paper).
    pub samples: usize,
    /// When set, runs stop early per §II-C instead of consuming `samples`.
    pub early_stop: Option<EarlyStopConfig>,
    /// Cap on per-run samples when early stopping is active.
    pub early_stop_cap: usize,
    /// Total profiled limitations, including the initial runs.
    pub max_steps: usize,
    /// Limitation grid parameters.
    pub l_min: f64,
    pub delta: f64,
}

impl Default for ProfilerConfig {
    fn default() -> Self {
        Self {
            p: 0.05,
            n_initial: 3,
            samples: 10_000,
            early_stop: None,
            early_stop_cap: 10_000,
            max_steps: 6,
            l_min: 0.1,
            delta: 0.1,
        }
    }
}

/// One profiled limitation with the model state after refitting.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// 1-based step index (initial parallel runs share step 1..n).
    pub index: usize,
    pub limit: f64,
    pub mean_runtime: f64,
    pub samples: usize,
    /// Wallclock of this run.
    pub wallclock: f64,
    /// Cumulative session wallclock after this step (parallel initial runs
    /// contribute their max).
    pub cumulative_time: f64,
    /// Model fitted to all points up to and including this step.
    pub model: RuntimeModel,
}

/// Completed profiling session.
#[derive(Clone, Debug)]
pub struct SessionResult {
    pub backend: String,
    pub strategy: String,
    pub initial_limits: Vec<f64>,
    /// Synthetic target runtime adopted after the initial phase.
    pub target: f64,
    pub steps: Vec<StepRecord>,
    pub total_time: f64,
}

impl SessionResult {
    /// The model after the final step.
    pub fn final_model(&self) -> &RuntimeModel {
        &self.steps.last().expect("non-empty session").model
    }

    /// Model state after `k` profiled limitations (k >= n_initial).
    pub fn model_after(&self, k: usize) -> Option<&RuntimeModel> {
        self.steps.get(k.checked_sub(1)?).map(|s| &s.model)
    }

    /// Cumulative wallclock after `k` profiled limitations.
    pub fn time_after(&self, k: usize) -> Option<f64> {
        self.steps.get(k.checked_sub(1)?).map(|s| s.cumulative_time)
    }
}

/// Score a fitted model against a ground-truth dataset (the acquisition
/// sweep): SMAPE over all grid limitations (paper Eq. 3, ε-guarded).
pub fn smape_vs_dataset(model: &RuntimeModel, dataset: &[ProfilePoint]) -> f64 {
    let truth: Vec<f64> = dataset.iter().map(|p| p.runtime).collect();
    let pred: Vec<f64> = dataset.iter().map(|p| model.eval(p.limit)).collect();
    smape_guarded(&truth, &pred, 1e-9)
}

/// A distributional runtime prior a profiling session can be primed from.
///
/// The profiler stays decoupled from where the prior comes from (the fleet
/// layer's transfer corpus implements this over a GP seeded with donor
/// pseudo-observations); all it needs is a predicted mean and spread at
/// any limitation — **both on the original runtime scale** — plus a way to
/// condition on fresh measurements mid-session.
pub trait SessionPrior {
    /// Predicted mean per-sample runtime (seconds) at limitation `x`.
    fn mean(&self, x: f64) -> f64;
    /// Posterior standard deviation of the runtime prediction at `x`, on
    /// the same scale as [`SessionPrior::mean`].
    fn sd(&self, x: f64) -> f64;
    /// Condition the prior on a fresh measurement (recalibration).
    fn observe(&mut self, m: &Measurement);
    /// The prior's current best [`RuntimeModel`] summary, used as the
    /// fitted model of primed step records.
    fn model(&self) -> RuntimeModel;
}

/// How a primed session judged its transfer prior after the check probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PriorVerdict {
    /// The check probe agreed with the prior; probes were dispatched only
    /// where the posterior stayed uncertain.
    Adopted,
    /// The check probe disagreed mildly; the prior was kept but the
    /// confidence gate tightened, so more verification probes ran.
    Tempered,
    /// The check probe disagreed beyond the reject threshold; the session
    /// fell back to a cold sweep (reusing the check probe as its first
    /// initial run, so no probe is wasted).
    Rejected,
}

impl PriorVerdict {
    /// Stable wire name used by daemon journals and reports.
    pub fn name(self) -> &'static str {
        match self {
            PriorVerdict::Adopted => "adopted",
            PriorVerdict::Tempered => "tempered",
            PriorVerdict::Rejected => "rejected",
        }
    }
}

/// Thresholds steering [`Profiler::run_with_prior`]. All three are
/// relative (SMAPE-style) quantities, so they are scale-free.
#[derive(Clone, Debug)]
pub struct PriorGate {
    /// Check-probe gap above which the prior is kept but tempered.
    pub temper: f64,
    /// Check-probe gap above which the prior is rejected outright.
    pub reject: f64,
    /// Posterior `sd / |mean|` below which a grid point needs no probe.
    /// Tempered priors verify against half this gate.
    pub confidence: f64,
}

impl Default for PriorGate {
    fn default() -> Self {
        Self { temper: 0.12, reject: 0.4, confidence: 0.2 }
    }
}

/// The orchestrator.
pub struct Profiler {
    cfg: ProfilerConfig,
    strategy: Box<dyn SelectionStrategy>,
}

impl Profiler {
    pub fn new(cfg: ProfilerConfig, strategy: Box<dyn SelectionStrategy>) -> Self {
        assert!(cfg.max_steps >= cfg.n_initial, "max_steps < n_initial");
        Self { cfg, strategy }
    }

    pub fn config(&self) -> &ProfilerConfig {
        &self.cfg
    }

    fn run_one(&self, backend: &mut dyn ProfilingBackend, limit: f64) -> Measurement {
        match &self.cfg.early_stop {
            Some(es) => backend.measure_early_stop(limit, es, self.cfg.early_stop_cap),
            None => backend.measure(limit, self.cfg.samples),
        }
    }

    /// Run a full profiling session against `backend`.
    pub fn run(&mut self, backend: &mut dyn ProfilingBackend) -> SessionResult {
        self.run_observed(backend, &mut |_| {})
    }

    /// Run a full profiling session, invoking `observer` after every
    /// measurement (initial parallel runs included, in placement order).
    ///
    /// This is the seam the fleet engine hooks into: the observer feeds each
    /// measurement into the job's incremental model refit while the session
    /// is still in flight, instead of waiting for the final [`SessionResult`].
    pub fn run_observed(
        &mut self,
        backend: &mut dyn ProfilingBackend,
        observer: &mut dyn FnMut(&Measurement),
    ) -> SessionResult {
        self.run_observed_from(backend, observer, None)
    }

    /// [`Profiler::run_observed`] warm-started from a `prior` model — the
    /// drift re-profiling path: the stale fit seeds every refit of the
    /// session (regardless of the strategy's own warm-start policy), so
    /// the new session converges from what the old model already knew
    /// instead of from scratch. `prior = None` is byte-identical to
    /// [`Profiler::run_observed`].
    pub fn run_observed_from(
        &mut self,
        backend: &mut dyn ProfilingBackend,
        observer: &mut dyn FnMut(&Measurement),
        prior: Option<&RuntimeModel>,
    ) -> SessionResult {
        self.session_body(backend, observer, prior, None)
    }

    /// The cold/warm session body shared by [`Profiler::run_observed_from`]
    /// and the rejected-prior fallback of [`Profiler::run_with_prior`].
    ///
    /// `first`, when set, is a measurement **already executed** at the
    /// smallest initial limitation (the primed path's check probe): it is
    /// used verbatim in place of re-probing that limit, and the observer is
    /// NOT re-invoked for it — so a rejected prior costs exactly the cold
    /// sweep, with the check probe reused as the first initial run.
    fn session_body(
        &mut self,
        backend: &mut dyn ProfilingBackend,
        observer: &mut dyn FnMut(&Measurement),
        prior: Option<&RuntimeModel>,
        first: Option<Measurement>,
    ) -> SessionResult {
        let l_max = backend.l_max();
        let mut ctx = ProfilingContext::new(self.cfg.l_min, l_max, self.cfg.delta);
        if let Some(p) = prior {
            ctx.model = p.clone();
        }
        let init =
            initial_limits(self.cfg.p, self.cfg.n_initial, self.cfg.l_min, l_max, self.cfg.delta);

        let mut steps: Vec<StepRecord> = Vec::new();
        let mut cumulative = 0.0;

        // ---- Phase 1: initial parallel runs (wallclock = slowest). ----
        let measurements: Vec<Measurement> = init
            .iter()
            .enumerate()
            .map(|(i, &l)| match (i, first) {
                (0, Some(m)) => m,
                _ => {
                    let m = self.run_one(backend, l);
                    observer(&m);
                    m
                }
            })
            .collect();
        let parallel_wall = measurements.iter().map(|m| m.wallclock).fold(0.0f64, f64::max);
        cumulative += parallel_wall;
        // Synthetic target: runtime at the smallest initial limitation.
        let target_meas = measurements
            .iter()
            .min_by(|a, b| a.limit.partial_cmp(&b.limit).unwrap())
            .expect("non-empty initial placement");
        ctx.target = target_meas.mean_runtime;

        for m in &measurements {
            ctx.points.push(ProfilePoint::new(m.limit, m.mean_runtime));
        }
        ctx.model = RuntimeModel::fit_warm(&ctx.points, prior);
        for (i, m) in measurements.iter().enumerate() {
            steps.push(StepRecord {
                index: i + 1,
                limit: m.limit,
                mean_runtime: m.mean_runtime,
                samples: m.samples,
                wallclock: m.wallclock,
                cumulative_time: cumulative,
                model: RuntimeModel::fit(&ctx.points[..=i]),
            });
        }
        // The record for the last initial step holds the joint fit.
        if let Some(last) = steps.last_mut() {
            last.model = ctx.model.clone();
        }

        // ---- Phase 2: iterative strategy-driven profiling. ----
        while steps.len() < self.cfg.max_steps {
            let Some(next) = self.strategy.next_limit(&ctx) else {
                break;
            };
            let m = self.run_one(backend, next);
            observer(&m);
            cumulative += m.wallclock;
            ctx.points.push(ProfilePoint::new(m.limit, m.mean_runtime));
            let warm = (self.strategy.warm_start() || prior.is_some()).then_some(&ctx.model);
            ctx.model = RuntimeModel::fit_warm(&ctx.points, warm);
            steps.push(StepRecord {
                index: steps.len() + 1,
                limit: m.limit,
                mean_runtime: m.mean_runtime,
                samples: m.samples,
                wallclock: m.wallclock,
                cumulative_time: cumulative,
                model: ctx.model.clone(),
            });
        }

        SessionResult {
            backend: backend.label(),
            strategy: self.strategy.name().to_string(),
            initial_limits: init,
            target: ctx.target,
            steps,
            total_time: cumulative,
        }
    }

    /// Prior-primed profiling: probe only where the prior stays uncertain.
    ///
    /// One **check probe** runs first, at the smallest Algorithm-1 initial
    /// limitation (the synthetic-target anchor). Its SMAPE-style gap to the
    /// prior's prediction decides the verdict:
    ///
    /// * gap > `gate.reject` → [`PriorVerdict::Rejected`]: the session
    ///   falls back to the cold sweep, reusing the check probe as its first
    ///   initial run — a mismatched prior costs exactly the cold session.
    /// * gap > `gate.temper` → [`PriorVerdict::Tempered`]: the prior is
    ///   kept but verified against half the confidence gate.
    /// * otherwise → [`PriorVerdict::Adopted`].
    ///
    /// In the adopted/tempered path the session conditions the prior on the
    /// check probe, then repeatedly probes the unprofiled grid point with
    /// the largest posterior `sd / |mean|` until every candidate clears the
    /// confidence gate (or `max_steps` is hit) — a well-matched prior
    /// reaches its target accuracy in measurably fewer probes than cold.
    pub fn run_with_prior(
        &mut self,
        backend: &mut dyn ProfilingBackend,
        observer: &mut dyn FnMut(&Measurement),
        prior: &mut dyn SessionPrior,
        gate: &PriorGate,
    ) -> (SessionResult, PriorVerdict) {
        let l_max = backend.l_max();
        let init =
            initial_limits(self.cfg.p, self.cfg.n_initial, self.cfg.l_min, l_max, self.cfg.delta);
        let check = init.first().copied().unwrap_or(self.cfg.l_min);
        let m0 = self.run_one(backend, check);
        observer(&m0);

        let predicted = prior.mean(check);
        let denom = (m0.mean_runtime.abs() + predicted.abs()).max(1e-12) / 2.0;
        let gap = (m0.mean_runtime - predicted).abs() / denom;
        // NaN-safe: a non-finite gap (degenerate prior) rejects.
        if !(gap <= gate.reject) {
            let fallback = self.session_body(backend, observer, None, Some(m0));
            return (fallback, PriorVerdict::Rejected);
        }
        let verdict =
            if gap > gate.temper { PriorVerdict::Tempered } else { PriorVerdict::Adopted };
        let confidence = match verdict {
            PriorVerdict::Tempered => gate.confidence * 0.5,
            _ => gate.confidence,
        };
        prior.observe(&m0);

        let mut ctx = ProfilingContext::new(self.cfg.l_min, l_max, self.cfg.delta);
        ctx.target = m0.mean_runtime;
        ctx.points.push(ProfilePoint::new(m0.limit, m0.mean_runtime));
        ctx.model = prior.model();
        let mut cumulative = m0.wallclock;
        let mut steps = vec![StepRecord {
            index: 1,
            limit: m0.limit,
            mean_runtime: m0.mean_runtime,
            samples: m0.samples,
            wallclock: m0.wallclock,
            cumulative_time: cumulative,
            model: ctx.model.clone(),
        }];

        while steps.len() < self.cfg.max_steps {
            // Most-uncertain unprofiled grid point, relative to the
            // predicted magnitude. Candidates ascend, so strict `>` keeps
            // the smallest limit on ties.
            let mut best: Option<(f64, f64)> = None;
            for cand in ctx.candidates() {
                let ratio = prior.sd(cand) / prior.mean(cand).abs().max(1e-9);
                if best.map(|(r, _)| ratio > r).unwrap_or(true) {
                    best = Some((ratio, cand));
                }
            }
            let Some((ratio, next)) = best else { break };
            if !(ratio > confidence) {
                break;
            }
            let m = self.run_one(backend, next);
            observer(&m);
            cumulative += m.wallclock;
            ctx.points.push(ProfilePoint::new(m.limit, m.mean_runtime));
            prior.observe(&m);
            ctx.model = prior.model();
            steps.push(StepRecord {
                index: steps.len() + 1,
                limit: m.limit,
                mean_runtime: m.mean_runtime,
                samples: m.samples,
                wallclock: m.wallclock,
                cumulative_time: cumulative,
                model: ctx.model.clone(),
            });
        }

        let session = SessionResult {
            backend: backend.label(),
            strategy: self.strategy.name().to_string(),
            initial_limits: vec![check],
            target: ctx.target,
            steps,
            total_time: cumulative,
        };
        (session, verdict)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimulatedBackend;
    use crate::simulator::{node, Algo, SimulatedJob};
    use crate::strategies;

    fn backend(node_name: &str, algo: Algo, seed: u64) -> SimulatedBackend {
        SimulatedBackend::new(SimulatedJob::new(node(node_name).unwrap(), algo, seed))
    }

    fn run_session(strategy: &str, node_name: &str, steps: usize, seed: u64) -> SessionResult {
        let cfg = ProfilerConfig {
            samples: 1000,
            max_steps: steps,
            ..Default::default()
        };
        let mut b = backend(node_name, Algo::Arima, seed);
        let mut prof = Profiler::new(cfg, strategies::by_name(strategy, seed).unwrap());
        prof.run(&mut b)
    }

    #[test]
    fn session_has_expected_shape() {
        let s = run_session("nms", "pi4", 6, 1);
        assert_eq!(s.steps.len(), 6);
        assert_eq!(s.initial_limits.len(), 3);
        assert!(s.target > 0.0);
        assert!(s.total_time > 0.0);
        // Cumulative time is monotone.
        for w in s.steps.windows(2) {
            assert!(w[1].cumulative_time >= w[0].cumulative_time);
        }
        // No duplicate profiled limits.
        for (i, a) in s.steps.iter().enumerate() {
            for b in &s.steps[i + 1..] {
                assert!((a.limit - b.limit).abs() > 0.05, "dup {}", a.limit);
            }
        }
    }

    #[test]
    fn initial_runs_accounted_in_parallel() {
        let s = run_session("nms", "pi4", 3, 2);
        // All three initial steps share the same cumulative time == max.
        let c0 = s.steps[0].cumulative_time;
        assert!(s.steps.iter().all(|st| (st.cumulative_time - c0).abs() < 1e-9));
        let max_wall = s.steps.iter().map(|st| st.wallclock).fold(0.0f64, f64::max);
        assert!((c0 - max_wall).abs() < 1e-9);
    }

    #[test]
    fn smape_improves_with_steps_for_nms() {
        let mut truth_job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 999);
        let dataset = truth_job.acquire_dataset(10_000);
        let s = run_session("nms", "pi4", 8, 3);
        let early = smape_vs_dataset(s.model_after(3).unwrap(), &dataset);
        let late = smape_vs_dataset(s.model_after(8).unwrap(), &dataset);
        assert!(late < early, "SMAPE should improve: {early} -> {late}");
        assert!(late < 0.2, "final SMAPE should be decent: {late}");
    }

    #[test]
    fn all_strategies_complete_sessions() {
        for strat in ["nms", "bs", "bo", "random"] {
            let s = run_session(strat, "e2high", 6, 7);
            assert_eq!(s.steps.len(), 6, "{strat}");
            assert!(s.final_model().eval(1.0).is_finite());
        }
    }

    #[test]
    fn early_stopping_reduces_profiling_time() {
        let cfg_full = ProfilerConfig { samples: 10_000, max_steps: 6, ..Default::default() };
        let cfg_es = ProfilerConfig {
            samples: 10_000,
            max_steps: 6,
            early_stop: Some(crate::earlystop::EarlyStopConfig::new(0.95, 0.10)),
            early_stop_cap: 10_000,
            ..Default::default()
        };
        let mut b1 = backend("pi4", Algo::Arima, 11);
        let mut b2 = backend("pi4", Algo::Arima, 11);
        let t_full = Profiler::new(cfg_full, strategies::by_name("nms", 1).unwrap())
            .run(&mut b1)
            .total_time;
        let t_es = Profiler::new(cfg_es, strategies::by_name("nms", 1).unwrap())
            .run(&mut b2)
            .total_time;
        assert!(
            t_es < t_full * 0.5,
            "early stopping should at least halve profiling time: {t_es} vs {t_full}"
        );
    }

    #[test]
    fn observer_sees_every_measurement_in_order() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b = backend("pi4", Algo::Arima, 21);
        let mut seen: Vec<Measurement> = Vec::new();
        let s = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_observed(&mut b, &mut |m| seen.push(*m));
        assert_eq!(seen.len(), s.steps.len());
        for (m, step) in seen.iter().zip(&s.steps) {
            assert_eq!(m.limit, step.limit);
            assert_eq!(m.mean_runtime, step.mean_runtime);
        }
    }

    #[test]
    fn prior_none_is_byte_identical_to_plain_run() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b1 = backend("pi4", Algo::Arima, 31);
        let mut b2 = backend("pi4", Algo::Arima, 31);
        let s1 = Profiler::new(cfg.clone(), strategies::by_name("nms", 1).unwrap()).run(&mut b1);
        let s2 = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_observed_from(&mut b2, &mut |_| {}, None);
        assert_eq!(s1.steps.len(), s2.steps.len());
        for (a, b) in s1.steps.iter().zip(&s2.steps) {
            assert_eq!(a.limit.to_bits(), b.limit.to_bits());
            assert_eq!(a.mean_runtime.to_bits(), b.mean_runtime.to_bits());
            assert_eq!(a.model.a.to_bits(), b.model.a.to_bits());
            assert_eq!(a.model.b.to_bits(), b.model.b.to_bits());
        }
        assert_eq!(s1.total_time.to_bits(), s2.total_time.to_bits());
    }

    #[test]
    fn prior_seeds_every_refit_of_the_session() {
        // A warm session from a decent prior must finish with a usable fit
        // and the same step shape as a cold one (the prior changes where
        // fits start, never how the session is driven).
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut cold_backend = backend("pi4", Algo::Arima, 33);
        let cold = Profiler::new(cfg.clone(), strategies::by_name("bs", 1).unwrap())
            .run(&mut cold_backend);
        let mut warm_backend = backend("pi4", Algo::Arima, 33);
        let warm = Profiler::new(cfg, strategies::by_name("bs", 1).unwrap())
            .run_observed_from(&mut warm_backend, &mut |_| {}, Some(cold.final_model()));
        assert_eq!(warm.steps.len(), cold.steps.len());
        let m = warm.final_model();
        assert!(m.eval(0.5).is_finite() && m.eval(0.5) > 0.0);
        // Both describe the same backend: predictions agree within noise.
        for &r in &[0.3, 1.0, 3.0] {
            let rel = (m.eval(r) - cold.final_model().eval(r)).abs() / cold.final_model().eval(r);
            assert!(rel < 0.5, "warm vs cold diverged at {r}: {rel}");
        }
    }

    /// Minimal test prior: a fixed model curve scaled by `scale`, with a
    /// constant relative spread. `observe` is a no-op — these tests drive
    /// the gate logic, not the calibration (the fleet transfer prior owns
    /// that).
    struct FlatPrior {
        model: RuntimeModel,
        sd_rel: f64,
        scale: f64,
    }

    impl SessionPrior for FlatPrior {
        fn mean(&self, x: f64) -> f64 {
            self.scale * self.model.eval(x)
        }
        fn sd(&self, x: f64) -> f64 {
            self.sd_rel * self.mean(x).abs()
        }
        fn observe(&mut self, _m: &Measurement) {}
        fn model(&self) -> RuntimeModel {
            self.model.rescaled(self.scale)
        }
    }

    #[test]
    fn confident_matching_prior_is_adopted_with_fewer_probes() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b1 = backend("pi4", Algo::Arima, 41);
        let cold = Profiler::new(cfg.clone(), strategies::by_name("nms", 1).unwrap()).run(&mut b1);
        let mut prior =
            FlatPrior { model: cold.final_model().clone(), sd_rel: 0.01, scale: 1.0 };
        let mut b2 = backend("pi4", Algo::Arima, 41);
        let (primed, verdict) = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_with_prior(&mut b2, &mut |_| {}, &mut prior, &PriorGate::default());
        assert_eq!(verdict, PriorVerdict::Adopted);
        assert!(
            primed.steps.len() < cold.steps.len(),
            "primed {} probes vs cold {}",
            primed.steps.len(),
            cold.steps.len()
        );
        assert_eq!(primed.initial_limits.len(), 1, "one check probe");
    }

    #[test]
    fn mild_disagreement_tempers_the_prior() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b1 = backend("pi4", Algo::Arima, 43);
        let cold = Profiler::new(cfg.clone(), strategies::by_name("nms", 1).unwrap()).run(&mut b1);
        // ~30% uniform miscalibration: gap ≈ 0.26, between temper and reject.
        let mut prior =
            FlatPrior { model: cold.final_model().clone(), sd_rel: 0.01, scale: 1.3 };
        let mut b2 = backend("pi4", Algo::Arima, 43);
        let (_, verdict) = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_with_prior(&mut b2, &mut |_| {}, &mut prior, &PriorGate::default());
        assert_eq!(verdict, PriorVerdict::Tempered);
    }

    #[test]
    fn rejected_prior_falls_back_byte_identical_to_cold() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b1 = backend("pi4", Algo::Arima, 47);
        let cold = Profiler::new(cfg.clone(), strategies::by_name("nms", 1).unwrap()).run(&mut b1);
        // 5x regime shift: the check probe must reject the prior.
        let mut prior =
            FlatPrior { model: cold.final_model().clone(), sd_rel: 0.01, scale: 5.0 };
        let mut b2 = backend("pi4", Algo::Arima, 47);
        let (fallback, verdict) = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_with_prior(&mut b2, &mut |_| {}, &mut prior, &PriorGate::default());
        assert_eq!(verdict, PriorVerdict::Rejected);
        assert_eq!(fallback.steps.len(), cold.steps.len(), "no extra probe spent");
        for (a, b) in cold.steps.iter().zip(&fallback.steps) {
            assert_eq!(a.limit.to_bits(), b.limit.to_bits());
            assert_eq!(a.mean_runtime.to_bits(), b.mean_runtime.to_bits());
            assert_eq!(a.wallclock.to_bits(), b.wallclock.to_bits());
            assert_eq!(a.model.a.to_bits(), b.model.a.to_bits());
            assert_eq!(a.model.b.to_bits(), b.model.b.to_bits());
        }
        assert_eq!(cold.total_time.to_bits(), fallback.total_time.to_bits());
        assert_eq!(cold.initial_limits, fallback.initial_limits);
    }

    #[test]
    fn observer_not_reinvoked_for_the_reused_check_probe() {
        let cfg = ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() };
        let mut b1 = backend("pi4", Algo::Arima, 53);
        let cold = Profiler::new(cfg.clone(), strategies::by_name("nms", 1).unwrap()).run(&mut b1);
        let mut prior =
            FlatPrior { model: cold.final_model().clone(), sd_rel: 0.01, scale: 5.0 };
        let mut b2 = backend("pi4", Algo::Arima, 53);
        let mut seen: Vec<Measurement> = Vec::new();
        let (fallback, verdict) = Profiler::new(cfg, strategies::by_name("nms", 1).unwrap())
            .run_with_prior(&mut b2, &mut |m| seen.push(*m), &mut prior, &PriorGate::default());
        assert_eq!(verdict, PriorVerdict::Rejected);
        assert_eq!(seen.len(), fallback.steps.len(), "check probe observed exactly once");
    }

    #[test]
    fn single_core_node_works_with_two_initial() {
        let cfg =
            ProfilerConfig { n_initial: 2, samples: 1000, max_steps: 5, ..Default::default() };
        let mut b = backend("n1", Algo::Lstm, 13);
        let s = Profiler::new(cfg, strategies::by_name("bs", 1).unwrap()).run(&mut b);
        assert!(s.steps.len() <= 5);
        assert!(s.initial_limits.iter().sum::<f64>() <= 1.0 + 1e-9);
    }
}
