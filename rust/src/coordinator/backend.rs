//! Profiling backends: what the profiler measures against.
//!
//! The profiler is backend-agnostic — it only needs "profile `n` samples
//! (or until early stopping) under limitation `R` and report the mean
//! per-sample runtime plus the wallclock spent". Two backends:
//!
//!   * [`SimulatedBackend`] — Table-I node models (fast, deterministic;
//!     used by the experiment harness).
//!   * [`PjrtBackend`] — the real AOT-compiled IFTM jobs under the
//!     duty-cycle throttle on the local machine.
//!
//! ## Backend factories
//!
//! The fleet layer never holds a backend directly: a profiling session is
//! replayed (re-profiling rounds, drift-triggered re-profiles), and each
//! replay needs a *fresh* backend whose observation stream is
//! deterministic per build. [`BackendFactory`] is that seam — an
//! object-safe, `Send + Sync` recipe a
//! [`crate::fleet::FleetJobSpec`] carries instead of baked-in simulator
//! fields, so the simulated nodes ([`SimBackendFactory`]) and the real
//! PJRT runtime ([`EngineBackendFactory`], stub or `--features pjrt`)
//! plug into the same pipeline.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::earlystop::{EarlyStopConfig, EarlyStopMonitor};
use crate::simulator::{Algo, NodeSpec, SimulatedJob};
use crate::stream::SensorStream;
use crate::workloads::{PjrtJob, StreamJob};

/// One profiling run's outcome.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub limit: f64,
    /// Mean per-sample runtime observed (seconds).
    pub mean_runtime: f64,
    /// Samples actually consumed (early stopping may use fewer).
    pub samples: usize,
    /// Wallclock spent on this run (seconds).
    pub wallclock: f64,
}

/// Backend abstraction for the profiler.
pub trait ProfilingBackend {
    /// Profile `samples` samples under `limit`.
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement;

    /// Profile under `limit` until the early-stop criterion fires (capped).
    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement;

    /// Largest assignable limitation (`l_max`, the core count).
    fn l_max(&self) -> f64;

    /// Label for logs.
    fn label(&self) -> String;
}

/// Forward the trait through boxes so factory-built backends
/// (`Box<dyn ProfilingBackend>`) compose with the generic decorators
/// (`ScaledBackend`, `CachedBackend`) exactly like concrete ones.
impl<B: ProfilingBackend + ?Sized> ProfilingBackend for Box<B> {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        (**self).measure(limit, samples)
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        (**self).measure_early_stop(limit, cfg, cap)
    }

    fn l_max(&self) -> f64 {
        (**self).l_max()
    }

    fn label(&self) -> String {
        (**self).label()
    }
}

/// Object-safe recipe for profiling backends: how the fleet layer measures
/// a job without knowing what executes it.
///
/// Contract:
///
/// * **Determinism per build** — repeated [`BackendFactory::build`] calls
///   must replay the same observation stream (same seed, same state), so a
///   re-profiling round makes the same probes and the measurement cache
///   can absorb it. Backends whose observations are inherently live (the
///   real PJRT runtime) satisfy this vacuously — their "replay" is a fresh
///   measurement of the same black box.
/// * **Independent probes** — [`BackendFactory::probe`] returns an
///   observation source for *live* drift monitoring, drawing fresh
///   samples rather than replaying the profiling stream. The default
///   implementation reuses [`BackendFactory::build`].
/// * **Stable label** — [`BackendFactory::label`] names the job class for
///   the measurement cache: factories with equal labels must describe
///   interchangeable runtime behaviour.
pub trait BackendFactory: Send + Sync {
    /// Build a fresh backend for one profiling session.
    fn build(&self) -> Result<Box<dyn ProfilingBackend>>;

    /// Build an independent observation source for live drift probes.
    fn probe(&self) -> Result<Box<dyn ProfilingBackend>> {
        self.build()
    }

    /// Measurement-cache label of the job class this factory measures.
    fn label(&self) -> String;
}

/// Seed salt separating the live-probe observation stream from the
/// profiling replays (the drift monitor must see fresh draws, not the
/// cached session's).
pub const PROBE_SEED_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// [`BackendFactory`] over the Table-I node models: each build replays the
/// same seeded [`SimulatedJob`], so profiling rounds are deterministic and
/// cache-absorbable.
pub struct SimBackendFactory {
    node: &'static NodeSpec,
    algo: Algo,
    seed: u64,
}

impl SimBackendFactory {
    pub fn new(node: &'static NodeSpec, algo: Algo, seed: u64) -> Self {
        Self { node, algo, seed }
    }

    /// The factory behind every shared reference (`Arc<dyn BackendFactory>`)
    /// a [`crate::fleet::FleetJobSpec`] carries.
    pub fn shared(node: &'static NodeSpec, algo: Algo, seed: u64) -> Arc<dyn BackendFactory> {
        Arc::new(Self::new(node, algo, seed))
    }
}

impl BackendFactory for SimBackendFactory {
    fn build(&self) -> Result<Box<dyn ProfilingBackend>> {
        Ok(Box::new(SimulatedBackend::new(SimulatedJob::new(self.node, self.algo, self.seed))))
    }

    fn probe(&self) -> Result<Box<dyn ProfilingBackend>> {
        Ok(Box::new(SimulatedBackend::new(SimulatedJob::new(
            self.node,
            self.algo,
            self.seed ^ PROBE_SEED_SALT,
        ))))
    }

    fn label(&self) -> String {
        format!("{}/{}", self.node.name, self.algo.name())
    }
}

/// [`BackendFactory`] over the PJRT runtime: each build loads the named
/// AOT artifact through [`crate::runtime::Engine`] and feeds it a seeded
/// [`SensorStream`]. Compiles against the stub engine too (the default
/// build), where [`BackendFactory::build`] surfaces the stub's actionable
/// "rebuild with `--features pjrt`" error — the fleet pipeline itself
/// makes no simulator assumption.
pub struct EngineBackendFactory {
    artifacts_dir: PathBuf,
    /// Artifact name from the manifest (e.g. `"arima"`, `"lstm_batch8"`).
    artifact: String,
    stream_seed: u64,
    /// Assignable core budget of the machine executing the artifacts.
    cores: f64,
}

impl EngineBackendFactory {
    pub fn new(artifacts_dir: PathBuf, artifact: &str, stream_seed: u64, cores: f64) -> Self {
        Self { artifacts_dir, artifact: artifact.to_string(), stream_seed, cores }
    }

    pub fn shared(
        artifacts_dir: PathBuf,
        artifact: &str,
        stream_seed: u64,
        cores: f64,
    ) -> Arc<dyn BackendFactory> {
        Arc::new(Self::new(artifacts_dir, artifact, stream_seed, cores))
    }

    fn load(&self, stream_seed: u64) -> Result<Box<dyn ProfilingBackend>> {
        let engine = crate::runtime::Engine::new(&self.artifacts_dir)
            .with_context(|| format!("loading PJRT engine for artifact '{}'", self.artifact))?;
        let job = PjrtJob::load_named(&engine, &self.artifact)?;
        Ok(Box::new(PjrtBackend::new(job, SensorStream::new(stream_seed), self.cores)))
    }
}

impl BackendFactory for EngineBackendFactory {
    fn build(&self) -> Result<Box<dyn ProfilingBackend>> {
        self.load(self.stream_seed)
    }

    fn probe(&self) -> Result<Box<dyn ProfilingBackend>> {
        self.load(self.stream_seed ^ PROBE_SEED_SALT)
    }

    fn label(&self) -> String {
        format!("pjrt/{}", self.artifact)
    }
}

/// Simulated node backend.
pub struct SimulatedBackend {
    job: SimulatedJob,
}

impl SimulatedBackend {
    pub fn new(job: SimulatedJob) -> Self {
        Self { job }
    }

    pub fn job(&self) -> &SimulatedJob {
        &self.job
    }

    pub fn job_mut(&mut self) -> &mut SimulatedJob {
        &mut self.job
    }
}

impl ProfilingBackend for SimulatedBackend {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        let (mean, wall) = self.job.profiling_time(limit, samples);
        Measurement { limit, mean_runtime: mean, samples, wallclock: wall }
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        let mut mon = EarlyStopMonitor::new(*cfg);
        let mut wall = 0.0;
        for _ in 0..cap {
            let rt = self.job.observe_sample(limit);
            wall += rt;
            if mon.push(rt) {
                break;
            }
        }
        Measurement {
            limit,
            mean_runtime: mon.mean(),
            samples: mon.samples() as usize,
            wallclock: wall,
        }
    }

    fn l_max(&self) -> f64 {
        self.job.node.cores
    }

    fn label(&self) -> String {
        format!("sim:{}/{}", self.job.node.name, self.job.algo.name())
    }
}

/// Real PJRT backend: executes the per-sample artifact under a virtual-time
/// duty-cycle throttle and feeds it synthetic sensor samples.
pub struct PjrtBackend {
    job: PjrtJob,
    stream: SensorStream,
    /// Assignable core budget of the local machine.
    cores: f64,
    /// When true, the throttle actually sleeps (e2e serving); otherwise the
    /// stall is accounted only (fast profiling experiments).
    pub real_sleep: bool,
}

impl PjrtBackend {
    pub fn new(job: PjrtJob, stream: SensorStream, cores: f64) -> Self {
        Self { job, stream, cores, real_sleep: false }
    }

    pub fn job_mut(&mut self) -> &mut PjrtJob {
        &mut self.job
    }

    fn throttle(&self, limit: f64) -> crate::runtime::Throttle {
        if self.real_sleep {
            crate::runtime::Throttle::sleeping(limit)
        } else {
            crate::runtime::Throttle::virtual_time(limit)
        }
    }
}

impl ProfilingBackend for PjrtBackend {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        let throttle = self.throttle(limit);
        self.job.set_throttle(Some(throttle));
        let mut total = 0.0;
        let mut n = 0usize;
        for _ in 0..samples {
            let x = self.stream.next_sample();
            let before = self.job.latencies.len();
            if self.job.process_chunk(&x).is_err() {
                break;
            }
            for lat in &self.job.latencies[before..] {
                total += lat.as_secs_f64();
                n += 1;
            }
        }
        self.job.set_throttle(None);
        Measurement {
            limit,
            mean_runtime: if n > 0 { total / n as f64 } else { f64::NAN },
            samples: n,
            wallclock: total,
        }
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        let throttle = self.throttle(limit);
        self.job.set_throttle(Some(throttle));
        let mut mon = EarlyStopMonitor::new(*cfg);
        let mut wall = 0.0;
        for _ in 0..cap {
            let x = self.stream.next_sample();
            let before = self.job.latencies.len();
            if self.job.process_chunk(&x).is_err() {
                break;
            }
            let mut stop = false;
            for lat in &self.job.latencies[before..] {
                wall += lat.as_secs_f64();
                stop = mon.push(lat.as_secs_f64());
            }
            if stop {
                break;
            }
        }
        self.job.set_throttle(None);
        Measurement {
            limit,
            mean_runtime: mon.mean(),
            samples: mon.samples() as usize,
            wallclock: wall,
        }
    }

    fn l_max(&self) -> f64 {
        self.cores
    }

    fn label(&self) -> String {
        format!("pjrt:{}", self.job.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{node, Algo};

    #[test]
    fn simulated_measure_matches_truth() {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let truth = job.truth().mean_runtime(0.5);
        let mut b = SimulatedBackend::new(job);
        let m = b.measure(0.5, 10_000);
        assert_eq!(m.samples, 10_000);
        assert!((m.mean_runtime - truth).abs() / truth < 0.05);
        assert!((m.wallclock - m.mean_runtime * 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn early_stop_uses_fewer_samples() {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Lstm, 5);
        let mut b = SimulatedBackend::new(job);
        let cfg = EarlyStopConfig::new(0.95, 0.10);
        let m = b.measure_early_stop(0.3, &cfg, 10_000);
        assert!(m.samples < 10_000, "should stop early, used {}", m.samples);
        assert!(m.samples >= cfg.min_samples as usize);
        let truth = b.job().truth().mean_runtime(0.3);
        assert!((m.mean_runtime - truth).abs() / truth < 0.15);
    }

    #[test]
    fn backend_l_max_is_core_count() {
        let b = SimulatedBackend::new(SimulatedJob::new(node("e216").unwrap(), Algo::Birch, 1));
        assert_eq!(b.l_max(), 16.0);
        assert!(b.label().contains("e216"));
    }

    #[test]
    fn sim_factory_builds_are_deterministic_replays() {
        let f = SimBackendFactory::new(node("pi4").unwrap(), Algo::Arima, 42);
        assert_eq!(f.label(), "pi4/arima");
        let m1 = f.build().unwrap().measure(0.5, 1000);
        let m2 = f.build().unwrap().measure(0.5, 1000);
        assert_eq!(m1.mean_runtime.to_bits(), m2.mean_runtime.to_bits(), "fresh build replays");
        assert_eq!(f.build().unwrap().l_max(), 4.0);
    }

    #[test]
    fn sim_factory_probe_stream_is_independent_of_builds() {
        let f = SimBackendFactory::new(node("pi4").unwrap(), Algo::Arima, 42);
        let built = f.build().unwrap().measure(0.5, 1000);
        let probed = f.probe().unwrap().measure(0.5, 1000);
        // Distinct seeded streams: same distribution, different draws.
        assert_ne!(built.mean_runtime.to_bits(), probed.mean_runtime.to_bits());
        // The probe source matches the drift loop's historical derivation.
        let mut legacy =
            SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 42 ^ PROBE_SEED_SALT);
        assert_eq!(probed.mean_runtime.to_bits(), legacy.observe_mean(0.5, 1000).to_bits());
    }

    #[test]
    fn factories_are_object_safe_and_shareable() {
        let wally = node("wally").unwrap();
        let f: Arc<dyn BackendFactory> = SimBackendFactory::shared(wally, Algo::Lstm, 7);
        assert_eq!(f.label(), "wally/lstm");
        // Boxed backends forward the trait (the decorator seam).
        let mut b: Box<dyn ProfilingBackend> = f.build().unwrap();
        let m = b.measure(1.0, 500);
        assert!(m.mean_runtime > 0.0 && m.wallclock > 0.0);
    }

    #[test]
    #[cfg(not(feature = "pjrt"))]
    fn engine_factory_surfaces_the_stub_error() {
        let f = EngineBackendFactory::new(PathBuf::from("/nonexistent"), "arima", 1, 4.0);
        assert_eq!(f.label(), "pjrt/arima");
        let err = f.build().err().expect("stub engine cannot build");
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
