//! Profiling backends: what the profiler measures against.
//!
//! The profiler is backend-agnostic — it only needs "profile `n` samples
//! (or until early stopping) under limitation `R` and report the mean
//! per-sample runtime plus the wallclock spent". Two backends:
//!
//!   * [`SimulatedBackend`] — Table-I node models (fast, deterministic;
//!     used by the experiment harness).
//!   * [`PjrtBackend`] — the real AOT-compiled IFTM jobs under the
//!     duty-cycle throttle on the local machine.

use crate::earlystop::{EarlyStopConfig, EarlyStopMonitor};
use crate::simulator::SimulatedJob;
use crate::stream::SensorStream;
use crate::workloads::{PjrtJob, StreamJob};

/// One profiling run's outcome.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub limit: f64,
    /// Mean per-sample runtime observed (seconds).
    pub mean_runtime: f64,
    /// Samples actually consumed (early stopping may use fewer).
    pub samples: usize,
    /// Wallclock spent on this run (seconds).
    pub wallclock: f64,
}

/// Backend abstraction for the profiler.
pub trait ProfilingBackend {
    /// Profile `samples` samples under `limit`.
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement;

    /// Profile under `limit` until the early-stop criterion fires (capped).
    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement;

    /// Largest assignable limitation (`l_max`, the core count).
    fn l_max(&self) -> f64;

    /// Label for logs.
    fn label(&self) -> String;
}

/// Simulated node backend.
pub struct SimulatedBackend {
    job: SimulatedJob,
}

impl SimulatedBackend {
    pub fn new(job: SimulatedJob) -> Self {
        Self { job }
    }

    pub fn job(&self) -> &SimulatedJob {
        &self.job
    }

    pub fn job_mut(&mut self) -> &mut SimulatedJob {
        &mut self.job
    }
}

impl ProfilingBackend for SimulatedBackend {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        let (mean, wall) = self.job.profiling_time(limit, samples);
        Measurement { limit, mean_runtime: mean, samples, wallclock: wall }
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        let mut mon = EarlyStopMonitor::new(*cfg);
        let mut wall = 0.0;
        for _ in 0..cap {
            let rt = self.job.observe_sample(limit);
            wall += rt;
            if mon.push(rt) {
                break;
            }
        }
        Measurement {
            limit,
            mean_runtime: mon.mean(),
            samples: mon.samples() as usize,
            wallclock: wall,
        }
    }

    fn l_max(&self) -> f64 {
        self.job.node.cores
    }

    fn label(&self) -> String {
        format!("sim:{}/{}", self.job.node.name, self.job.algo.name())
    }
}

/// Real PJRT backend: executes the per-sample artifact under a virtual-time
/// duty-cycle throttle and feeds it synthetic sensor samples.
pub struct PjrtBackend {
    job: PjrtJob,
    stream: SensorStream,
    /// Assignable core budget of the local machine.
    cores: f64,
    /// When true, the throttle actually sleeps (e2e serving); otherwise the
    /// stall is accounted only (fast profiling experiments).
    pub real_sleep: bool,
}

impl PjrtBackend {
    pub fn new(job: PjrtJob, stream: SensorStream, cores: f64) -> Self {
        Self { job, stream, cores, real_sleep: false }
    }

    pub fn job_mut(&mut self) -> &mut PjrtJob {
        &mut self.job
    }

    fn throttle(&self, limit: f64) -> crate::runtime::Throttle {
        if self.real_sleep {
            crate::runtime::Throttle::sleeping(limit)
        } else {
            crate::runtime::Throttle::virtual_time(limit)
        }
    }
}

impl ProfilingBackend for PjrtBackend {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        let throttle = self.throttle(limit);
        self.job.set_throttle(Some(throttle));
        let mut total = 0.0;
        let mut n = 0usize;
        for _ in 0..samples {
            let x = self.stream.next_sample();
            let before = self.job.latencies.len();
            if self.job.process_chunk(&x).is_err() {
                break;
            }
            for lat in &self.job.latencies[before..] {
                total += lat.as_secs_f64();
                n += 1;
            }
        }
        self.job.set_throttle(None);
        Measurement {
            limit,
            mean_runtime: if n > 0 { total / n as f64 } else { f64::NAN },
            samples: n,
            wallclock: total,
        }
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        let throttle = self.throttle(limit);
        self.job.set_throttle(Some(throttle));
        let mut mon = EarlyStopMonitor::new(*cfg);
        let mut wall = 0.0;
        for _ in 0..cap {
            let x = self.stream.next_sample();
            let before = self.job.latencies.len();
            if self.job.process_chunk(&x).is_err() {
                break;
            }
            let mut stop = false;
            for lat in &self.job.latencies[before..] {
                wall += lat.as_secs_f64();
                stop = mon.push(lat.as_secs_f64());
            }
            if stop {
                break;
            }
        }
        self.job.set_throttle(None);
        Measurement {
            limit,
            mean_runtime: mon.mean(),
            samples: mon.samples() as usize,
            wallclock: wall,
        }
    }

    fn l_max(&self) -> f64 {
        self.cores
    }

    fn label(&self) -> String {
        format!("pjrt:{}", self.job.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{node, Algo};

    #[test]
    fn simulated_measure_matches_truth() {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let truth = job.truth().mean_runtime(0.5);
        let mut b = SimulatedBackend::new(job);
        let m = b.measure(0.5, 10_000);
        assert_eq!(m.samples, 10_000);
        assert!((m.mean_runtime - truth).abs() / truth < 0.05);
        assert!((m.wallclock - m.mean_runtime * 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn early_stop_uses_fewer_samples() {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Lstm, 5);
        let mut b = SimulatedBackend::new(job);
        let cfg = EarlyStopConfig::new(0.95, 0.10);
        let m = b.measure_early_stop(0.3, &cfg, 10_000);
        assert!(m.samples < 10_000, "should stop early, used {}", m.samples);
        assert!(m.samples >= cfg.min_samples as usize);
        let truth = b.job().truth().mean_runtime(0.3);
        assert!((m.mean_runtime - truth).abs() / truth < 0.15);
    }

    #[test]
    fn backend_l_max_is_core_count() {
        let b = SimulatedBackend::new(SimulatedJob::new(node("e216").unwrap(), Algo::Birch, 1));
        assert_eq!(b.l_max(), 16.0);
        assert!(b.label().contains("e216"));
    }
}
