//! L3 coordinator: the paper's system contribution.
//!
//! * [`backend`] — what gets profiled (simulated nodes / real PJRT jobs),
//! * [`profiler`] — Algorithm-1 initial placement + strategy loop + early
//!   stopping orchestration,
//! * [`adjuster`] — the adaptive resource adjustment the model enables.

pub mod adjuster;
pub mod backend;
pub mod manager;
pub mod profiler;

pub use adjuster::{Adjustment, ResourceAdjuster};
pub use backend::{
    BackendFactory, EngineBackendFactory, Measurement, PjrtBackend, ProfilingBackend,
    SimBackendFactory, SimulatedBackend,
};
pub use manager::{quantile_model, quote_for, Assignment, CapacityPlan, JobManager, ManagedJob};
pub use profiler::{
    smape_vs_dataset, PriorGate, PriorVerdict, Profiler, ProfilerConfig, SessionPrior,
    SessionResult, StepRecord,
};
