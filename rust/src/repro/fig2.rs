//! Fig. 2 — early-stopping behaviour for the LSTM algorithm on the
//! Raspberry Pi 4 with a 95% confidence interval.
//!
//! Emits the CI trajectory (running mean ± t-interval vs. samples seen) for
//! a set of CPU limitations, plus the per-limit samples-to-stop summary
//! that quantifies the §III-B.4 claim: early stopping ≈ halves profiling
//! time at 10k-sample accuracy.

use crate::earlystop::{EarlyStopConfig, EarlyStopMonitor};
use crate::simulator::{node, Algo, SimulatedJob};
use crate::util::{CsvWriter, Table};

use super::{results_dir, ReproReport};

pub fn run() -> ReproReport {
    let pi4 = node("pi4").expect("pi4");
    let cfg = EarlyStopConfig::new(0.95, 0.10);
    let trace_path = results_dir().join("fig2_ci_trace.csv");
    let summary_path = results_dir().join("fig2_summary.csv");
    let mut trace_csv = CsvWriter::create(
        &trace_path,
        &["limit", "n", "mean", "ci_lo", "ci_hi", "stopped"],
    )
    .expect("csv");
    let mut summary_csv = CsvWriter::create(
        &summary_path,
        &[
            "limit",
            "samples_to_stop",
            "mean_estimate",
            "truth_mean",
            "rel_err",
            "time_saved_vs_10k",
        ],
    )
    .expect("csv");

    let mut table = Table::new(&[
        "limit",
        "samples",
        "mean est (s)",
        "truth (s)",
        "rel err",
        "time saved",
    ])
    .with_title("Fig. 2 — early stopping, LSTM on pi4, 95% CI, lambda=10%");

    let limits = [0.2, 0.5, 1.0, 2.0, 4.0];
    let mut total_saved = 0.0;
    let mut worst_rel_err: f64 = 0.0;
    for (i, &limit) in limits.iter().enumerate() {
        let mut job = SimulatedJob::new(pi4, Algo::Lstm, 42 + i as u64);
        let truth = job.truth().mean_runtime(limit);
        let mut mon = EarlyStopMonitor::new(cfg).with_trace();
        let mut used = 0usize;
        for _ in 0..10_000 {
            used += 1;
            if mon.push(job.observe_sample(limit)) {
                break;
            }
        }
        for &(n, mean, width) in mon.trace() {
            let stopped = n as usize == used;
            trace_csv
                .rowd(&[
                    &limit,
                    &n,
                    &mean,
                    &(mean - width / 2.0),
                    &(mean + width / 2.0),
                    &(stopped as u8),
                ])
                .unwrap();
        }
        let rel_err = (mon.mean() - truth).abs() / truth;
        worst_rel_err = worst_rel_err.max(rel_err);
        let saved = 1.0 - used as f64 / 10_000.0;
        total_saved += saved;
        summary_csv
            .rowd(&[&limit, &used, &mon.mean(), &truth, &rel_err, &saved])
            .unwrap();
        table.rowd(&[
            &limit,
            &used,
            &format!("{:.4}", mon.mean()),
            &format!("{:.4}", truth),
            &format!("{:.2}%", rel_err * 100.0),
            &format!("{:.1}%", saved * 100.0),
        ]);
    }
    trace_csv.flush().unwrap();
    summary_csv.flush().unwrap();

    let avg_saved = total_saved / limits.len() as f64;
    let mut rendered = table.render();
    rendered.push_str(&format!(
        "\nAverage profiling-time reduction vs. 10k samples: {:.1}% \
         (paper SIII-B.4: early stopping halves profiling time)\n",
        avg_saved * 100.0
    ));
    ReproReport {
        id: "fig2",
        rendered,
        findings: vec![
            ("avg_time_saved".into(), avg_saved),
            ("worst_rel_err".into(), worst_rel_err),
        ],
        csv_paths: vec![trace_path, summary_path],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn early_stopping_saves_most_of_the_samples_accurately() {
        let r = super::run();
        // The paper reports ~50% profiling-time reduction; with lambda=10%
        // and pi4's noise the monitor stops after a few hundred samples,
        // i.e. >50% saved.
        assert!(r.finding("avg_time_saved").unwrap() > 0.5);
        // And the mean estimate stays close to the truth.
        assert!(r.finding("worst_rel_err").unwrap() < 0.15);
    }
}
