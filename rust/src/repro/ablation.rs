//! Ablations over the design choices DESIGN.md calls out:
//!
//!   A1 — **warm start** (NMS's parameter reuse) on vs. off,
//!   A2 — **multi-start LM** (plateau-basin seed) on vs. off,
//!   A3 — **synthetic target + Algorithm-1 placement** vs. naive
//!        equidistant initial points with the same budget.
//!
//! Each ablation reports the mean SMAPE after 4/6/8 profiled limitations
//! across nodes × algorithms × repetitions.

use crate::coordinator::smape_vs_dataset;
use crate::fit::{ProfilePoint, RuntimeModel};
use crate::simulator::{node, Algo};
use crate::stats::RunningStats;
use crate::strategies::{NestedModeling, ProfilingContext, SelectionStrategy};
use crate::util::{CsvWriter, Table};

use super::{results_dir, AcquiredDataset, ReproReport};

const NODES_UNDER_TEST: [&str; 3] = ["pi4", "e2high", "wally"];
const REPS: u64 = 8;
const MAX_STEPS: usize = 8;

/// A hand-rolled NMS session driver with ablation knobs (the production
/// profiler hard-wires the full design; this driver varies it).
fn run_nms_session(
    ds: &AcquiredDataset,
    warm_start: bool,
    multistart: bool,
    algorithm1_placement: bool,
) -> Vec<(usize, RuntimeModel)> {
    let l_max = ds.node.cores;
    let mut ctx = ProfilingContext::new(0.1, l_max, 0.1);
    let initial: Vec<f64> = if algorithm1_placement {
        crate::strategies::initial_limits(0.05, 3, 0.1, l_max, 0.1)
    } else {
        // Naive equidistant placement with the same number of runs
        // (violates the parallel-capacity idea and skips the synthetic
        // target's knee anchor).
        (1..=3)
            .map(|i| ctx.snap(l_max * i as f64 / 4.0))
            .collect::<Vec<_>>()
    };
    let mut dedup: Vec<f64> = Vec::new();
    for l in initial {
        if !dedup.iter().any(|&x: &f64| (x - l).abs() < 0.05) {
            dedup.push(l);
        }
    }
    for &l in &dedup {
        ctx.points.push(ProfilePoint::new(l, ds.mean_at(l, 10_000)));
    }
    // Synthetic target = runtime at the smallest initial point.
    ctx.target = ctx
        .points
        .iter()
        .min_by(|a, b| a.limit.partial_cmp(&b.limit).unwrap())
        .unwrap()
        .runtime;
    ctx.model = RuntimeModel::fit_opts(&ctx.points, None, multistart);

    let mut nms = NestedModeling::new();
    let mut snapshots = vec![(ctx.points.len(), ctx.model.clone())];
    while ctx.points.len() < MAX_STEPS {
        let Some(next) = nms.next_limit(&ctx) else { break };
        ctx.points.push(ProfilePoint::new(next, ds.mean_at(next, 10_000)));
        let warm = warm_start.then_some(&ctx.model);
        ctx.model = RuntimeModel::fit_opts(&ctx.points, warm, multistart);
        snapshots.push((ctx.points.len(), ctx.model.clone()));
    }
    snapshots
}

pub fn run() -> ReproReport {
    let variants: [(&str, bool, bool, bool); 4] = [
        ("full-design", true, true, true),
        ("no-warm-start", false, true, true),
        ("no-multistart", true, false, true),
        ("naive-placement", true, true, false),
    ];
    let csv_path = results_dir().join("ablations.csv");
    let mut csv = CsvWriter::create(&csv_path, &["variant", "steps", "mean_smape"]).expect("csv");
    let mut table = Table::new(&["variant", "SMAPE@4", "SMAPE@6", "SMAPE@8"])
        .with_title("Ablations — NMS design choices (avg over nodes x algos x reps)");
    let mut findings = Vec::new();

    for (name, warm, multi, alg1) in variants {
        let mut stats: Vec<RunningStats> = (0..=MAX_STEPS).map(|_| RunningStats::new()).collect();
        for node_name in NODES_UNDER_TEST {
            let spec = node(node_name).unwrap();
            for algo in Algo::ALL {
                for rep in 0..REPS {
                    let ds = AcquiredDataset::acquire(spec, algo, 3000 + rep);
                    let truth = ds.truth_points();
                    for (k, model) in run_nms_session(&ds, warm, multi, alg1) {
                        if k <= MAX_STEPS {
                            stats[k].push(smape_vs_dataset(&model, &truth));
                        }
                    }
                }
            }
        }
        for (k, s) in stats.iter().enumerate() {
            if s.count() > 0 {
                csv.rowd(&[&name, &k, &s.mean()]).unwrap();
            }
        }
        table.rowd(&[
            &name,
            &format!("{:.3}", stats[4].mean()),
            &format!("{:.3}", stats[6].mean()),
            &format!("{:.3}", stats[8].mean()),
        ]);
        findings.push((format!("{name}_at4"), stats[4].mean()));
        findings.push((format!("{name}_at6"), stats[6].mean()));
        findings.push((format!("{name}_at8"), stats[8].mean()));
    }
    csv.flush().unwrap();
    ReproReport { id: "ablation", rendered: table.render(), findings, csv_paths: vec![csv_path] }
}

#[cfg(test)]
mod tests {
    #[test]
    fn design_choices_do_not_hurt() {
        let r = super::run();
        let full6 = r.finding("full-design_at6").unwrap();
        // Multi-start protects against basin flapping: removing it must not
        // help (allow noise).
        let nomulti6 = r.finding("no-multistart_at6").unwrap();
        assert!(full6 <= nomulti6 + 0.02, "full {full6} vs no-multistart {nomulti6}");
        // Algorithm-1 placement (synthetic target anchored at the knee)
        // should beat naive equidistant placement at small step counts.
        let full4 = r.finding("full-design_at4").unwrap();
        let naive4 = r.finding("naive-placement_at4").unwrap();
        assert!(full4 <= naive4 + 0.02, "full {full4} vs naive {naive4}");
    }
}
