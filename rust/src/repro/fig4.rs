//! Fig. 4 — NMS profiling-point selection after six profiled limitations,
//! Arima on pi4, for each sample-size scenario (3 initial parallel runs,
//! synthetic target 5% ⇒ 0.2 CPU).
//!
//! Emits the profiled points (initial vs. NMS-selected) and the fitted
//! curve per sample size; the paper's visual claim — the NMS-selected
//! points cluster near the synthetic target at ~0.2 CPU, and larger sample
//! sizes fit the curve better — is exported as findings.

use crate::coordinator::smape_vs_dataset;
use crate::util::{CsvWriter, Table};

use super::{results_dir, AcquiredDataset, ExemplaryConfig, ReproReport, SAMPLE_SIZES};

pub fn run() -> ReproReport {
    let cfg = ExemplaryConfig::default();
    let points_path = results_dir().join("fig4_points.csv");
    let curve_path = results_dir().join("fig4_curves.csv");
    let mut points_csv = CsvWriter::create(
        &points_path,
        &["sample_size", "step", "limit", "runtime", "phase"],
    )
    .expect("csv");
    let mut curve_csv =
        CsvWriter::create(&curve_path, &["sample_size", "limit", "predicted", "truth_10k"])
            .expect("csv");

    let mut table = Table::new(&["samples", "selected limits (step 4..6)", "SMAPE@6"])
        .with_title("Fig. 4 — NMS-chosen profiling points, Arima on pi4 (target 5% => 0.2 CPU)");

    let mut findings = Vec::new();
    for &size in &SAMPLE_SIZES {
        let ds = AcquiredDataset::acquire(cfg.node, cfg.algo, 404);
        let sess = super::run_session(&ds, "NMS", size, cfg.p, cfg.n_initial, 6, 11);
        let truth = ds.truth_points();
        for s in &sess.steps {
            let phase = if s.index <= cfg.n_initial { "initial" } else { "selected" };
            points_csv
                .rowd(&[&size, &s.index, &s.limit, &s.mean_runtime, &phase])
                .unwrap();
        }
        let model = sess.final_model();
        for p in &truth {
            curve_csv
                .rowd(&[&size, &p.limit, &model.eval(p.limit), &p.runtime])
                .unwrap();
        }
        let smape = smape_vs_dataset(model, &truth);
        let selected: Vec<f64> =
            sess.steps.iter().skip(cfg.n_initial).map(|s| s.limit).collect();
        // Distance of selected points from the synthetic-target limit 0.2.
        let mean_dist = selected.iter().map(|l| (l - 0.2).abs()).sum::<f64>()
            / selected.len().max(1) as f64;
        findings.push((format!("smape_{size}"), smape));
        findings.push((format!("mean_dist_to_target_{size}"), mean_dist));
        table.rowd(&[
            &size,
            &format!("{selected:.2?}"),
            &format!("{smape:.3}"),
        ]);
    }
    points_csv.flush().unwrap();
    curve_csv.flush().unwrap();

    let rendered = table.render();
    ReproReport { id: "fig4", rendered, findings, csv_paths: vec![points_path, curve_path] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selected_points_cluster_near_synthetic_target() {
        let r = run();
        for size in SAMPLE_SIZES {
            let d = r.finding(&format!("mean_dist_to_target_{size}")).unwrap();
            // Fig. 4: "selected next profiling points ... located close to
            // the chosen synthetic target at a CPU limitation of 0.2".
            assert!(d < 1.0, "size {size}: mean distance {d}");
        }
    }

    #[test]
    fn more_samples_fit_better() {
        let r = run();
        let s1k = r.finding("smape_1000").unwrap();
        let s10k = r.finding("smape_10000").unwrap();
        assert!(
            s10k <= s1k + 0.02,
            "10k should fit at least as well: {s10k} vs {s1k}"
        );
        assert!(s10k < 0.15, "10k-sample fit should be good: {s10k}");
    }
}
