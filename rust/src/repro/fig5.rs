//! Fig. 5 — SMAPE after consecutive profiling steps, all strategies and
//! algorithms on pi4, for every sample-size scenario, with 95% CIs over
//! repetitions (3 initial parallel runs, synthetic target 5%).

use crate::coordinator::smape_vs_dataset;
use crate::simulator::Algo;
use crate::stats::{t_confidence_interval, RunningStats};
use crate::util::{CsvWriter, Table};

use super::{results_dir, AcquiredDataset, ExemplaryConfig, ReproReport, SAMPLE_SIZES};

const STRATEGIES: [&str; 4] = ["NMS", "BS", "BO", "Random"];
const MAX_STEPS: usize = 8;

pub fn run(quick: bool) -> ReproReport {
    let cfg = ExemplaryConfig::default();
    let reps: u64 = if quick { 5 } else { 20 };
    let csv_path = results_dir().join("fig5_smape_steps.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["algo", "strategy", "sample_size", "step", "smape_mean", "ci_lo", "ci_hi"],
    )
    .expect("csv");

    let mut findings = Vec::new();
    let mut table = Table::new(&["samples", "strategy", "SMAPE@4", "SMAPE@6", "SMAPE@8"])
        .with_title("Fig. 5 — SMAPE vs. profiling steps on pi4 (avg over algorithms)");

    for &size in &SAMPLE_SIZES {
        for strat in STRATEGIES {
            // stats[step] across algos x reps.
            let mut stats: Vec<RunningStats> =
                (0..=MAX_STEPS).map(|_| RunningStats::new()).collect();
            for algo in Algo::ALL {
                for rep in 0..reps {
                    let ds = AcquiredDataset::acquire(cfg.node, algo, 2000 + rep);
                    let sess = super::run_session(
                        &ds,
                        strat,
                        size,
                        cfg.p,
                        cfg.n_initial,
                        MAX_STEPS,
                        500 + rep,
                    );
                    let truth = ds.truth_points();
                    for k in cfg.n_initial..=sess.steps.len() {
                        let model = sess.model_after(k).unwrap();
                        stats[k].push(smape_vs_dataset(model, &truth));
                    }
                }
            }
            for (step, s) in stats.iter().enumerate().skip(cfg.n_initial) {
                if s.count() == 0 {
                    continue;
                }
                let (lo, hi) = t_confidence_interval(s, 0.95).unwrap_or((s.mean(), s.mean()));
                csv.rowd(&[&"all", &strat, &size, &step, &s.mean(), &lo, &hi]).unwrap();
            }
            table.rowd(&[
                &size,
                &strat,
                &format!("{:.3}", stats[4].mean()),
                &format!("{:.3}", stats[6].mean()),
                &format!("{:.3}", stats[8].mean()),
            ]);
            findings.push((format!("{strat}_{size}_smape_at4", ), stats[4].mean()));
            findings.push((format!("{strat}_{size}_smape_at5"), stats[5].mean()));
            findings.push((format!("{strat}_{size}_smape_at8"), stats[8].mean()));
        }
    }
    csv.flush().unwrap();

    let rendered = table.render();
    ReproReport { id: "fig5", rendered, findings, csv_paths: vec![csv_path] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nms_beats_bs_and_bo_early() {
        let r = run(true);
        // Paper §III-B.3: "overall the NMS strategy performs best on the
        // pi4 node, for each configuration of profiling samples".
        for size in SAMPLE_SIZES {
            let nms = r.finding(&format!("NMS_{size}_smape_at4")).unwrap();
            let bs = r.finding(&format!("BS_{size}_smape_at4")).unwrap();
            let bo = r.finding(&format!("BO_{size}_smape_at4")).unwrap();
            assert!(
                nms <= bs + 0.05 && nms <= bo + 0.05,
                "size {size}: NMS {nms} vs BS {bs} / BO {bo}"
            );
        }
    }

    #[test]
    fn strategies_converge_after_five_steps() {
        let r = run(true);
        // Paper: "all selection strategies already start to converge one to
        // two steps after the initial three parallel profiling runs" — the
        // SMAPE at step 8 is not much better than at step 5.
        for strat in ["NMS", "BS", "BO"] {
            let s5 = r.finding(&format!("{strat}_10000_smape_at5")).unwrap();
            let s8 = r.finding(&format!("{strat}_10000_smape_at8")).unwrap();
            assert!(
                s5 - s8 < 0.25,
                "{strat}: step5 {s5} -> step8 {s8} should be near-converged"
            );
        }
    }
}
