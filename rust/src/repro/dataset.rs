//! Dataset acquisition + replay — the paper's evaluation methodology.
//!
//! §III-A.a: "For each algorithm we started with all available vCPUs ... and
//! used the dataset as input ... we measured the average processing time per
//! sample and subsequently decreased the allocated vCPUs by 0.1 for each
//! following execution. In the following experiments, the accumulated
//! results were used in order to evaluate our approach."
//!
//! [`AcquiredDataset`] performs that sweep once per (node, algorithm, seed)
//! and records, per grid limitation, the cumulative means over the first
//! 1000/3000/5000/10000 samples; [`DatasetBackend`] then replays those
//! means to the profiler, exactly like the paper replays its collected
//! datasets. Ground truth for SMAPE is the 10000-sample mean per limit.

use crate::coordinator::backend::{Measurement, ProfilingBackend};
use crate::earlystop::{EarlyStopConfig, EarlyStopMonitor};
use crate::fit::ProfilePoint;
use crate::simulator::{Algo, GroundTruth, NodeSpec};
use crate::util::Rng;

/// The sample-size scenarios of the evaluation (§III-B.2).
pub const SAMPLE_SIZES: [usize; 4] = [1000, 3000, 5000, 10_000];

/// One acquisition sweep for a (node, algorithm) pair.
pub struct AcquiredDataset {
    pub node: &'static NodeSpec,
    pub algo: Algo,
    pub limits: Vec<f64>,
    /// `means[s][l]` = mean over the first `SAMPLE_SIZES[s]` samples at
    /// `limits[l]` (cumulative on the same simulated stream).
    means: Vec<Vec<f64>>,
    truth: GroundTruth,
    seed: u64,
}

impl AcquiredDataset {
    /// Run the sweep (CLT-approximated segment sums — statistically
    /// equivalent to summing 10k lognormals, ~1000x faster).
    pub fn acquire(node: &'static NodeSpec, algo: Algo, seed: u64) -> Self {
        let truth = GroundTruth::derive(node, algo);
        let mut rng = Rng::new(seed ^ 0xD5AC_0001);
        let limits = node.limit_grid();
        let mut means = vec![vec![0.0; limits.len()]; SAMPLE_SIZES.len()];
        for (li, &limit) in limits.iter().enumerate() {
            let mean = truth.mean_runtime(limit);
            let mut cum_sum = 0.0;
            let mut cum_n = 0usize;
            for (si, &n) in SAMPLE_SIZES.iter().enumerate() {
                let seg = n - cum_n;
                // Segment mean ~ Normal(mean, se(seg)) with the
                // autocorrelation-adjusted standard error; the cumulative
                // means are therefore consistent across sample sizes.
                let seg_mean = mean + truth.mean_se(mean, seg) * rng.normal();
                cum_sum += seg_mean * seg as f64;
                cum_n = n;
                means[si][li] = (cum_sum / cum_n as f64).max(mean * 0.01);
            }
        }
        Self { node, algo, limits, means, truth, seed }
    }

    fn size_index(sample_size: usize) -> usize {
        SAMPLE_SIZES
            .iter()
            .position(|&s| s == sample_size)
            .unwrap_or_else(|| panic!("sample size {sample_size} not in {SAMPLE_SIZES:?}"))
    }

    /// Recorded mean at (limit, sample size); nearest grid limit is used.
    pub fn mean_at(&self, limit: f64, sample_size: usize) -> f64 {
        let si = Self::size_index(sample_size);
        let li = self
            .limits
            .iter()
            .position(|&l| (l - limit).abs() < 0.05)
            .unwrap_or_else(|| panic!("limit {limit} off-grid for {}", self.node.name));
        self.means[si][li]
    }

    /// Ground truth for SMAPE: the 10k-sample means across the grid.
    pub fn truth_points(&self) -> Vec<ProfilePoint> {
        let si = SAMPLE_SIZES.len() - 1;
        self.limits
            .iter()
            .enumerate()
            .map(|(li, &l)| ProfilePoint::new(l, self.means[si][li]))
            .collect()
    }

    /// The analytic curve (diagnostics).
    pub fn analytic_truth(&self) -> &GroundTruth {
        &self.truth
    }
}

/// Profiler backend replaying an acquired dataset at a fixed sample size.
pub struct DatasetBackend<'a> {
    ds: &'a AcquiredDataset,
    sample_size: usize,
    /// RNG for the early-stopping per-sample path.
    rng: Rng,
}

impl<'a> DatasetBackend<'a> {
    pub fn new(ds: &'a AcquiredDataset, sample_size: usize) -> Self {
        let rng = Rng::new(ds.seed ^ sample_size as u64);
        Self { ds, sample_size, rng }
    }
}

impl ProfilingBackend for DatasetBackend<'_> {
    fn measure(&mut self, limit: f64, _samples: usize) -> Measurement {
        let mean = self.ds.mean_at(limit, self.sample_size);
        Measurement {
            limit,
            mean_runtime: mean,
            samples: self.sample_size,
            wallclock: mean * self.sample_size as f64,
        }
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        let truth_mean = self.ds.analytic_truth().mean_runtime(limit);
        let cov = self.ds.analytic_truth().sample_cov();
        let mut mon = EarlyStopMonitor::new(*cfg);
        let mut wall = 0.0;
        for _ in 0..cap {
            let rt = self.rng.lognormal_mean_cov(truth_mean, cov);
            wall += rt;
            if mon.push(rt) {
                break;
            }
        }
        Measurement {
            limit,
            mean_runtime: mon.mean(),
            samples: mon.samples() as usize,
            wallclock: wall,
        }
    }

    fn l_max(&self) -> f64 {
        self.ds.node.cores
    }

    fn label(&self) -> String {
        format!(
            "dataset:{}/{}@{}",
            self.ds.node.name,
            self.ds.algo.name(),
            self.sample_size
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::node;

    #[test]
    fn acquisition_covers_grid_and_sizes() {
        let ds = AcquiredDataset::acquire(node("pi4").unwrap(), Algo::Arima, 1);
        assert_eq!(ds.limits.len(), 40);
        for &s in &SAMPLE_SIZES {
            for &l in &ds.limits {
                assert!(ds.mean_at(l, s) > 0.0);
            }
        }
    }

    #[test]
    fn larger_samples_closer_to_analytic_truth() {
        // Averaged over many acquisitions, the 10k mean must deviate less
        // from the analytic curve than the 1k mean.
        let n = node("pi4").unwrap();
        let (mut err1k, mut err10k) = (0.0, 0.0);
        for seed in 0..40 {
            let ds = AcquiredDataset::acquire(n, Algo::Lstm, seed);
            let t = ds.analytic_truth().mean_runtime(0.5);
            err1k += ((ds.mean_at(0.5, 1000) - t) / t).abs();
            err10k += ((ds.mean_at(0.5, 10_000) - t) / t).abs();
        }
        assert!(err10k < err1k, "10k {err10k} vs 1k {err1k}");
    }

    #[test]
    fn cumulative_means_are_consistent() {
        // The 10k mean is a convex combination of the 1k mean and the rest,
        // so it must lie within the extremes of the segment means; weaker
        // but sufficient: all sizes within 5 sigma of analytic truth.
        let ds = AcquiredDataset::acquire(node("e216").unwrap(), Algo::Birch, 3);
        for &s in &SAMPLE_SIZES {
            for &l in &[0.1, 1.0, 8.0, 16.0] {
                let m = ds.mean_at(l, s);
                let t = ds.analytic_truth().mean_runtime(l);
                // 5x the autocorrelation-adjusted standard error.
                let tol = 5.0 * ds.analytic_truth().mean_se(t, s);
                assert!((m - t).abs() < tol + 1e-12, "l={l} s={s}: {m} vs {t}");
            }
        }
    }

    #[test]
    fn backend_replays_recorded_means() {
        let ds = AcquiredDataset::acquire(node("n1").unwrap(), Algo::Arima, 5);
        let mut b = DatasetBackend::new(&ds, 3000);
        let m = b.measure(0.5, 3000);
        assert_eq!(m.mean_runtime, ds.mean_at(0.5, 3000));
        assert_eq!(m.samples, 3000);
        // Replay is deterministic.
        let m2 = b.measure(0.5, 3000);
        assert_eq!(m.mean_runtime, m2.mean_runtime);
    }

    #[test]
    fn truth_points_are_10k_means() {
        let ds = AcquiredDataset::acquire(node("wally").unwrap(), Algo::Lstm, 9);
        let pts = ds.truth_points();
        assert_eq!(pts.len(), 80);
        assert_eq!(pts[7].runtime, ds.mean_at(pts[7].limit, 10_000));
    }
}
