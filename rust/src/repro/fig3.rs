//! Fig. 3 — smallest achievable SMAPE for different synthetic targets and
//! initial parallel profiling runs, across all nodes and strategies.
//!
//! Sweep: node × strategy ∈ {NMS, BS, BO} × p ∈ {2.5..15%} × n ∈ {2,3,4},
//! 10000 profiling samples, SMAPE = min over profiling steps ≤ 8, averaged
//! over the three algorithms (and a few repetition seeds).

use crate::coordinator::smape_vs_dataset;
use crate::simulator::{Algo, NODES};
use crate::strategies::synthetic::{PARALLEL_RUNS, TARGET_PERCENTAGES};
use crate::util::{CsvWriter, Table};

use super::{results_dir, AcquiredDataset, ReproReport};

const STRATEGIES: [&str; 3] = ["NMS", "BS", "BO"];
const MAX_STEPS: usize = 8;

pub fn run(quick: bool) -> ReproReport {
    let reps: u64 = if quick { 2 } else { 5 };
    let csv_path = results_dir().join("fig3_synthetic_targets.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["node", "strategy", "p", "n_initial", "min_smape"],
    )
    .expect("csv");

    // findings: per-node best (p, n) and min SMAPE for NMS.
    let mut findings = Vec::new();
    let mut table = Table::new(&["node", "strategy", "best p", "best n", "min SMAPE"])
        .with_title("Fig. 3 — smallest achievable SMAPE per synthetic-target config");

    for node in NODES {
        for strat in STRATEGIES {
            let mut best = (f64::INFINITY, 0.0, 0usize);
            for &p in &TARGET_PERCENTAGES {
                for &n in &PARALLEL_RUNS {
                    let mut acc = 0.0;
                    let mut count = 0usize;
                    for algo in Algo::ALL {
                        for rep in 0..reps {
                            let ds = AcquiredDataset::acquire(node, algo, 1000 + rep);
                            let sess =
                                super::run_session(&ds, strat, 10_000, p, n, MAX_STEPS, rep + 7);
                            let truth = ds.truth_points();
                            let min_smape = sess
                                .steps
                                .iter()
                                .map(|s| smape_vs_dataset(&s.model, &truth))
                                .fold(f64::INFINITY, f64::min);
                            acc += min_smape;
                            count += 1;
                        }
                    }
                    let avg = acc / count as f64;
                    csv.rowd(&[&node.name, &strat, &p, &n, &avg]).unwrap();
                    if avg < best.0 {
                        best = (avg, p, n);
                    }
                }
            }
            table.rowd(&[
                &node.name,
                &strat,
                &format!("{:.1}%", best.1 * 100.0),
                &best.2,
                &format!("{:.3}", best.0),
            ]);
            findings.push((format!("{}_{}_best_p", node.name, strat), best.1));
            findings.push((format!("{}_{}_best_n", node.name, strat), best.2 as f64));
            findings.push((format!("{}_{}_min_smape", node.name, strat), best.0));
        }
    }
    csv.flush().unwrap();

    // Aggregate finding: average best-n across nodes (paper: 2-3 initial
    // runs best; 4 worst, esp. small nodes).
    let avg_best_n = findings
        .iter()
        .filter(|(k, _)| k.ends_with("_best_n"))
        .map(|(_, v)| *v)
        .sum::<f64>()
        / (NODES.len() * STRATEGIES.len()) as f64;
    findings.push(("avg_best_n".into(), avg_best_n));

    let mut rendered = table.render();
    rendered.push_str(&format!(
        "\nAverage best n across nodes/strategies: {avg_best_n:.2} \
         (paper: two to three initial parallel runs perform best)\n"
    ));
    ReproReport { id: "fig3", rendered, findings, csv_paths: vec![csv_path] }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qualitative_claims_hold() {
        let r = run(true);
        // e216 (16 cores) prefers the lowest synthetic target (paper: 2.5%).
        let e216_p = r.finding("e216_NMS_best_p").unwrap();
        assert!(e216_p <= 0.075, "e216 best p {e216_p}");
        // Best initial-parallelism averages to 2-3, not 4.
        let avg_n = r.finding("avg_best_n").unwrap();
        assert!(avg_n < 3.5, "avg best n {avg_n}");
        // NMS achieves a usable fit (SMAPE < 0.2) on every node.
        for node in NODES {
            let s = r.finding(&format!("{}_NMS_min_smape", node.name)).unwrap();
            assert!(s < 0.2, "{}: min SMAPE {s}", node.name);
        }
    }
}
