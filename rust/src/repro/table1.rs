//! Table I — hardware specifications of the evaluation machines.

use crate::simulator::NODES;
use crate::util::{CsvWriter, Table};

use super::{results_dir, ReproReport};

pub fn run() -> ReproReport {
    let mut table = Table::new(&["Hostname", "Type", "CPU", "Cores", "Memory"])
        .with_title("Table I — hardware specifications (modeled)");
    let csv_path = results_dir().join("table1_nodes.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["hostname", "kind", "cpu", "cores", "memory_gb", "speed", "scaling", "noise_cov"],
    )
    .expect("csv");
    for n in NODES {
        table.rowd(&[
            &n.name,
            &n.kind,
            &n.cpu_model,
            &n.cores,
            &format!("{} GB", n.memory_gb),
        ]);
        csv.rowd(&[
            &n.name,
            &n.kind,
            &n.cpu_model,
            &n.cores,
            &n.memory_gb,
            &n.speed,
            &n.scaling,
            &n.noise_cov,
        ])
        .unwrap();
    }
    csv.flush().unwrap();
    ReproReport {
        id: "table1",
        rendered: table.render(),
        findings: vec![
            ("n_nodes".into(), NODES.len() as f64),
            ("max_cores".into(), NODES.iter().map(|n| n.cores).fold(0.0, f64::max)),
        ],
        csv_paths: vec![csv_path],
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_seven_rows() {
        let r = super::run();
        assert_eq!(r.finding("n_nodes"), Some(7.0));
        assert_eq!(r.finding("max_cores"), Some(16.0));
        assert!(r.rendered.contains("pi4"));
        assert!(r.rendered.contains("e2-highcpu (16 vCPU)"));
    }
}
