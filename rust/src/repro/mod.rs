//! Experiment harness: regenerate every table and figure of the paper's
//! evaluation (§III). Each `figN` module produces CSV series under
//! `results/` plus an ASCII rendering, and returns a [`ReproReport`]
//! whose `findings` are compared against the paper's qualitative claims in
//! integration tests and EXPERIMENTS.md.

pub mod ablation;
pub mod dataset;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod table1;

pub use dataset::{AcquiredDataset, DatasetBackend, SAMPLE_SIZES};

use std::path::PathBuf;

use crate::coordinator::{Profiler, ProfilerConfig, SessionResult};
use crate::simulator::{Algo, NodeSpec};
use crate::strategies;

/// Output of one experiment regeneration.
pub struct ReproReport {
    /// Experiment id (e.g. "fig3").
    pub id: &'static str,
    /// Rendered ASCII tables / summaries.
    pub rendered: String,
    /// Machine-checkable findings (name -> value) used by tests.
    pub findings: Vec<(String, f64)>,
    /// CSV files written.
    pub csv_paths: Vec<PathBuf>,
}

impl ReproReport {
    pub fn finding(&self, name: &str) -> Option<f64> {
        self.findings.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// Where CSV output goes (`$STREAMPROF_RESULTS` or `./results`).
pub fn results_dir() -> PathBuf {
    std::env::var("STREAMPROF_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

/// Run one profiling session against an acquired dataset.
///
/// This is the evaluation workhorse shared by all figures: strategy by
/// name, Algorithm-1 initial placement with (p, n_initial), fixed sample
/// size, up to `max_steps` profiled limitations.
pub fn run_session(
    ds: &AcquiredDataset,
    strategy: &str,
    sample_size: usize,
    p: f64,
    n_initial: usize,
    max_steps: usize,
    seed: u64,
) -> SessionResult {
    let cfg = ProfilerConfig {
        p,
        n_initial,
        samples: sample_size,
        max_steps,
        ..Default::default()
    };
    let strat = strategies::by_name(strategy, seed).expect("strategy name");
    let mut backend = DatasetBackend::new(ds, sample_size);
    Profiler::new(cfg, strat).run(&mut backend)
}

/// Default experiment node/algo/config (the paper's exemplary setting:
/// pi4, 3 initial parallel runs, synthetic target 5%).
pub struct ExemplaryConfig {
    pub node: &'static NodeSpec,
    pub algo: Algo,
    pub p: f64,
    pub n_initial: usize,
}

impl Default for ExemplaryConfig {
    fn default() -> Self {
        Self {
            node: crate::simulator::node("pi4").expect("pi4 in registry"),
            algo: Algo::Arima,
            p: 0.05,
            n_initial: 3,
        }
    }
}

/// Run every experiment (the `repro all` CLI path).
pub fn run_all(quick: bool) -> Vec<ReproReport> {
    vec![
        table1::run(),
        fig2::run(),
        fig3::run(quick),
        fig4::run(),
        fig5::run(quick),
        fig6::run(),
        fig7::run(quick),
        ablation::run(),
    ]
}
