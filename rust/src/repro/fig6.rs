//! Fig. 6 + §III-B.4 — profiling time over consecutive steps for Arima on
//! pi4 (1000 and 10000 samples), NMS/BS/BO, plus the early-stopping run.
//!
//! Paper anchor numbers (Arima, pi4, 3 initial runs, target 5%):
//!   * 4 steps, 1000 samples:  NMS 268 s, BS 199 s, BO 263 s
//!   * 6 steps, NMS: 392 s (1000 samples) / 2451 s (10000 samples)
//!   * early stopping (95%, λ=10%): 1135 s total, SMAPE 0.13 @ 6 steps
//! We reproduce the *shape*: time ≈ linear in steps, ×~5-10 from 1k→10k,
//! NMS slightly slower than BS, early stopping ≈ halves the 10k time.

use crate::coordinator::{smape_vs_dataset, Profiler, ProfilerConfig};
use crate::earlystop::EarlyStopConfig;
use crate::strategies;
use crate::util::{CsvWriter, Table};

use super::{results_dir, AcquiredDataset, DatasetBackend, ExemplaryConfig, ReproReport};

const STRATEGIES: [&str; 3] = ["NMS", "BS", "BO"];

pub fn run() -> ReproReport {
    let cfg = ExemplaryConfig::default();
    let csv_path = results_dir().join("fig6_profiling_time.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["strategy", "sample_size", "steps", "cumulative_time_s", "smape"],
    )
    .expect("csv");

    let ds = AcquiredDataset::acquire(cfg.node, cfg.algo, 606);
    let truth = ds.truth_points();
    let mut findings = Vec::new();
    let mut table = Table::new(&["strategy", "samples", "t@4 (s)", "t@6 (s)", "SMAPE@4", "SMAPE@6"])
        .with_title("Fig. 6 — profiling time, Arima on pi4 (3 initial runs, target 5%)");

    for strat in STRATEGIES {
        for &size in &[1000usize, 10_000] {
            let sess = super::run_session(&ds, strat, size, cfg.p, cfg.n_initial, 6, 21);
            for k in cfg.n_initial..=sess.steps.len() {
                let t = sess.time_after(k).unwrap();
                let s = smape_vs_dataset(sess.model_after(k).unwrap(), &truth);
                csv.rowd(&[&strat, &size, &k, &t, &s]).unwrap();
            }
            let t4 = sess.time_after(4).unwrap();
            let t6 = sess.time_after(6).unwrap();
            let s4 = smape_vs_dataset(sess.model_after(4).unwrap(), &truth);
            let s6 = smape_vs_dataset(sess.model_after(6).unwrap(), &truth);
            findings.push((format!("{strat}_{size}_t4"), t4));
            findings.push((format!("{strat}_{size}_t6"), t6));
            findings.push((format!("{strat}_{size}_smape4"), s4));
            findings.push((format!("{strat}_{size}_smape6"), s6));
            table.rowd(&[
                &strat,
                &size,
                &format!("{t4:.0}"),
                &format!("{t6:.0}"),
                &format!("{s4:.2}"),
                &format!("{s6:.2}"),
            ]);
        }
    }

    // Early-stopping variant (95% CI, λ=10%), compared to 10k samples.
    let es_cfg = ProfilerConfig {
        p: cfg.p,
        n_initial: cfg.n_initial,
        samples: 10_000,
        early_stop: Some(EarlyStopConfig::new(0.95, 0.10)),
        early_stop_cap: 10_000,
        max_steps: 6,
        ..Default::default()
    };
    let mut backend = DatasetBackend::new(&ds, 10_000);
    let sess = Profiler::new(es_cfg, strategies::by_name("NMS", 21).unwrap()).run(&mut backend);
    let es_time = sess.total_time;
    let es_smape = smape_vs_dataset(sess.final_model(), &truth);
    csv.rowd(&[&"NMS+early-stop", &10_000, &6usize, &es_time, &es_smape]).unwrap();
    csv.flush().unwrap();
    table.rowd(&[
        &"NMS+ES",
        &"10000(cap)",
        &"-",
        &format!("{es_time:.0}"),
        &"-",
        &format!("{es_smape:.2}"),
    ]);
    findings.push(("es_time".into(), es_time));
    findings.push(("es_smape".into(), es_smape));

    let mut rendered = table.render();
    rendered.push_str(&format!(
        "\nPaper anchors: NMS 268s/BS 199s/BO 263s @4 steps (1k); NMS 392s @6 (1k), \
         2451s @6 (10k); early stopping 1135s, SMAPE 0.13.\n\
         Measured:      NMS {:.0}s/BS {:.0}s/BO {:.0}s @4 (1k); NMS {:.0}s @6 (1k), \
         {:.0}s @6 (10k); early stopping {:.0}s, SMAPE {:.2}.\n",
        findings.iter().find(|(k, _)| k == "NMS_1000_t4").unwrap().1,
        findings.iter().find(|(k, _)| k == "BS_1000_t4").unwrap().1,
        findings.iter().find(|(k, _)| k == "BO_1000_t4").unwrap().1,
        findings.iter().find(|(k, _)| k == "NMS_1000_t6").unwrap().1,
        findings.iter().find(|(k, _)| k == "NMS_10000_t6").unwrap().1,
        es_time,
        es_smape,
    ));
    ReproReport { id: "fig6", rendered, findings, csv_paths: vec![csv_path] }
}

#[cfg(test)]
mod tests {
    #[test]
    fn magnitudes_match_paper_anchors() {
        let r = super::run();
        // 4-step 1k-sample profiling in the low hundreds of seconds
        // (paper: 199-268 s). Allow a generous band — it's a simulator.
        let nms4 = r.finding("NMS_1000_t4").unwrap();
        assert!((80.0..700.0).contains(&nms4), "NMS t4 {nms4}");
        // 10k samples cost ~10x the 1k time (paper: 1690 vs 268 ~ x6 at 4
        // steps because of which limits get profiled; linear-in-n here).
        let t1k = r.finding("NMS_1000_t6").unwrap();
        let t10k = r.finding("NMS_10000_t6").unwrap();
        assert!(t10k / t1k > 4.0, "10k/1k ratio {}", t10k / t1k);
        // Early stopping cuts the 10k cost by > 40% at comparable SMAPE.
        let es = r.finding("es_time").unwrap();
        assert!(es < 0.6 * t10k, "early stop {es} vs full {t10k}");
        let es_smape = r.finding("es_smape").unwrap();
        let full_smape = r.finding("NMS_10000_smape6").unwrap();
        assert!(es_smape < full_smape + 0.15, "{es_smape} vs {full_smape}");
    }

    #[test]
    fn smape_improves_from_step4_to_step6() {
        let r = super::run();
        let s4 = r.finding("NMS_10000_smape4").unwrap();
        let s6 = r.finding("NMS_10000_smape6").unwrap();
        // Paper SIII-B.4: past step 4-5 the SMAPE barely moves; require
        // no significant regression.
        assert!(s6 <= s4 + 0.01, "{s4} -> {s6}");
    }
}
