//! Fig. 7 — number of wins per selection strategy and profiling-step count
//! across all nodes and algorithms, 50 repetitions, with 0% and 10%
//! tolerance policies (§III-B.5).
//!
//! A strategy "wins" a (node, algo, rep, steps) cell when it produces the
//! smallest SMAPE; with the 10% policy, every strategy within 10% of the
//! best is counted as a (near-)winner.

use crate::coordinator::smape_vs_dataset;
use crate::simulator::{Algo, NODES};
use crate::util::{CsvWriter, Table};

use super::{results_dir, AcquiredDataset, ReproReport};

const STRATEGIES: [&str; 4] = ["NMS", "BS", "BO", "Random"];
const STEPS_RANGE: std::ops::RangeInclusive<usize> = 4..=8;

pub fn run(quick: bool) -> ReproReport {
    let reps: u64 = if quick { 10 } else { 50 };
    let csv_path = results_dir().join("fig7_strategy_wins.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["steps", "strategy", "wins_strict", "wins_10pct"],
    )
    .expect("csv");

    // wins[steps][strategy] under both tolerance policies.
    let mut strict = vec![[0u32; STRATEGIES.len()]; *STEPS_RANGE.end() + 1];
    let mut tol10 = vec![[0u32; STRATEGIES.len()]; *STEPS_RANGE.end() + 1];

    for node in NODES {
        for algo in Algo::ALL {
            for rep in 0..reps {
                let ds = AcquiredDataset::acquire(node, algo, 7000 + rep);
                let truth = ds.truth_points();
                // One session per strategy; evaluate at each step count.
                let sessions: Vec<_> = STRATEGIES
                    .iter()
                    .map(|s| {
                        super::run_session(&ds, s, 10_000, 0.05, 3, *STEPS_RANGE.end(), 9000 + rep)
                    })
                    .collect();
                for steps in STEPS_RANGE {
                    let smapes: Vec<f64> = sessions
                        .iter()
                        .map(|sess| match sess.model_after(steps) {
                            Some(m) => smape_vs_dataset(m, &truth),
                            None => f64::INFINITY,
                        })
                        .collect();
                    let best = smapes.iter().cloned().fold(f64::INFINITY, f64::min);
                    for (i, &s) in smapes.iter().enumerate() {
                        if s <= best + 1e-12 {
                            strict[steps][i] += 1;
                        }
                        if s <= best * 1.10 + 1e-12 {
                            tol10[steps][i] += 1;
                        }
                    }
                }
            }
        }
    }

    let mut findings = Vec::new();
    let mut table = Table::new(&["steps", "NMS", "BS", "BO", "Random"])
        .with_title("Fig. 7 — wins per strategy (strict / within-10%)");
    for steps in STEPS_RANGE {
        let cells: Vec<String> = (0..STRATEGIES.len())
            .map(|i| format!("{} / {}", strict[steps][i], tol10[steps][i]))
            .collect();
        table.row(&[
            format!("{steps}"),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            cells[3].clone(),
        ]);
        for (i, strat) in STRATEGIES.iter().enumerate() {
            csv.rowd(&[&steps, strat, &strict[steps][i], &tol10[steps][i]]).unwrap();
            findings.push((format!("{strat}_wins_at{steps}"), strict[steps][i] as f64));
        }
    }
    csv.flush().unwrap();

    // Aggregate over step counts.
    for (i, strat) in STRATEGIES.iter().enumerate() {
        let total: u32 = STEPS_RANGE.map(|s| strict[s][i]).sum();
        findings.push((format!("{strat}_wins_total"), total as f64));
    }

    let rendered = table.render();
    ReproReport { id: "fig7", rendered, findings, csv_paths: vec![csv_path] }
}

#[cfg(test)]
mod tests {
    #[test]
    fn model_guided_strategies_beat_naive_ones() {
        // Reproducible qualitative claims (see EXPERIMENTS.md fig7 notes:
        // our BO baseline — paper reward + fixed well-chosen Matérn
        // hyperparameters — is stronger than the paper's, so the NMS-vs-BO
        // ordering deviates; NMS vs the naive baselines reproduces).
        let r = super::run(true);
        let total = |s: &str| r.finding(&format!("{s}_wins_total")).unwrap();
        let nms = total("NMS");
        let bo = total("BO");
        let bs = total("BS");
        let random = total("Random");
        // The model-guided methods dominate the naive ones overall.
        assert!(nms + bo > (bs + random) * 1.3, "guided {} vs naive {}", nms + bo, bs + random);
        // NMS stays clearly ahead of the Random control and competitive
        // with BS (paper: "BS and BO result in very similar errors",
        // Random only occasionally competitive).
        assert!(nms as f64 >= random as f64 * 0.8, "NMS {nms} vs Random {random}");
        assert!(nms as f64 >= bs as f64 * 0.8, "NMS {nms} vs BS {bs}");
    }

    #[test]
    fn every_strategy_wins_somewhere() {
        // Sanity: no strategy is degenerate (the paper's Fig. 7 shows all
        // four collecting wins at every step count).
        let r = super::run(true);
        for strat in super::STRATEGIES {
            let t = r.finding(&format!("{strat}_wins_total")).unwrap();
            assert!(t > 0.0, "{strat} never wins");
        }
    }
}
