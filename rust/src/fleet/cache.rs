//! Shared measurement cache: the fleet's amortization layer.
//!
//! Profiling is expensive — a single 10k-sample run at a small limitation
//! costs minutes of wallclock — and across a fleet the same `(job label,
//! cpu-limit bucket)` pair is probed over and over: re-profiling rounds
//! replay the deterministic initial placement, and replicas of one job
//! class on the same device type ask for identical measurements. The cache
//! stores every observed [`Measurement`] under that key so repeated
//! strategy probes reuse the observed runtime instead of re-executing the
//! job; a hit is returned with `wallclock = 0` (nothing ran) while the
//! wallclock it *would* have cost is accumulated as `saved_wallclock`.
//!
//! ## Lock-striped shards
//!
//! The map is split into [`SHARD_COUNT`] shards, each behind its own
//! mutex, keyed by the FNV-1a hash of the job label. Every label lives in
//! exactly one shard, so the per-label invariants (canonical width,
//! generation aging, exact stats accounting) are still serialized by a
//! single lock — but probe replay for *different* job classes no longer
//! serializes on one global mutex, which is what lets a worker pool drain
//! a large roster without convoying. Counters are plain fields under each
//! shard's lock and are aggregated on read ([`MeasurementCache::stats`]
//! locks the shards in index order), so the aggregate satisfies the same
//! exactness invariants as the old single-lock implementation.
//!
//! ## Generation-based aging
//!
//! Measurements go stale: when a job class drifts (model upgrade, heavier
//! input regime), replaying its old runtimes would silently poison every
//! re-profile. Each label therefore carries a **generation** counter, and
//! every entry is stamped with the generation current at insert time. A
//! drift verdict bumps the label's generation
//! ([`MeasurementCache::bump_generation`]); from that point `lookup`
//! refuses pre-bump entries (counted as `stale_hits_refused`, and as
//! misses, so the re-profile executes fresh probes) while
//! [`MeasurementCache::evict_stale`] reclaims whatever the re-profile did
//! not overwrite.
//!
//! ## Canonical bucket width
//!
//! Keys are quantized bucket indices derived from **one canonical `delta`
//! per label** — the first width a label is registered with. Keying by the
//! caller-supplied width would alias buckets when a job is reconfigured
//! (at `delta = 0.2` a probe at 0.8 lands in bucket 4, the bucket a
//! `delta = 0.1` probe at 0.4 already occupies) and serve measurements
//! from the wrong limitation.
//!
//! ## Persistence
//!
//! [`MeasurementCache::snapshot`] serializes every entry, the per-label
//! generations, *and* the lifetime runtime counters (version 2) through
//! [`crate::util::json`]; [`MeasurementCache::restore`] merges a snapshot
//! back — refusing entries stamped newer than the snapshot header declares
//! — so measurements **and their amortization history** survive engine
//! restarts (`streamprof fleet --cache-file f.json`). Version-1 snapshots
//! (pre-stats) still restore, with zeroed carried counters.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, ensure, Result};

use crate::coordinator::backend::{Measurement, ProfilingBackend};
use crate::earlystop::EarlyStopConfig;
use crate::fit::{ModelKind, RuntimeModel};
use crate::strategies::grid_bucket;
use crate::util::fnv1a;
use crate::util::json::Json;

/// Number of lock stripes. Labels hash onto stripes, so any fleet with
/// more than a handful of distinct job classes spreads its probe replay
/// across independent locks.
const SHARD_COUNT: usize = 8;

/// Cache key: job label (e.g. `"pi4/arima"`) + limitation-grid bucket
/// (quantized with the label's canonical `delta`).
pub type CacheKey = (String, i64);

/// Hit/miss counters plus aging bookkeeping and the profiling wallclock
/// hits avoided. Every `lookup` counts exactly one hit or one miss
/// (`hits + misses == lookups()`); a stale-generation refusal is a miss
/// that additionally increments `stale_hits_refused`.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Lookups that found an entry but refused it as pre-bump stale
    /// (also counted in `misses`).
    pub stale_hits_refused: u64,
    /// Stale entries reclaimed by `evict_stale` (≤ `inserts`).
    pub evictions: u64,
    pub inserts: u64,
    /// Wallclock (seconds) of re-executions avoided by cache hits.
    pub saved_wallclock: f64,
}

impl CacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.lookups();
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fold another stats delta into this one — how the daemon's
    /// overlapped completion path accumulates per-profile cache
    /// contributions into a deterministic virtual total.
    pub fn absorb(&mut self, d: &CacheStats) {
        self.hits += d.hits;
        self.misses += d.misses;
        self.stale_hits_refused += d.stale_hits_refused;
        self.evictions += d.evictions;
        self.inserts += d.inserts;
        self.saved_wallclock += d.saved_wallclock;
    }

    /// Counter deltas since an `earlier` snapshot of the same cache —
    /// how a persistent cache reports per-run statistics.
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            stale_hits_refused: self.stale_hits_refused - earlier.stale_hits_refused,
            evictions: self.evictions - earlier.evictions,
            inserts: self.inserts - earlier.inserts,
            saved_wallclock: self.saved_wallclock - earlier.saved_wallclock,
        }
    }
}

/// One stored measurement, stamped with the label generation it was
/// observed under.
struct Entry {
    m: Measurement,
    generation: u64,
}

/// Per-label aging state: the canonical bucket width, the current
/// generation, and (since snapshot v3) the label's fitted model metadata.
#[derive(Default)]
struct LabelState {
    /// Canonical `delta`, fixed by the first insert/lookup of the label.
    delta: Option<f64>,
    generation: u64,
    /// Fitted runtime model published by the last profile of this label
    /// ([`MeasurementCache::note_model`]) — carried by v3 snapshots so a
    /// restored transfer corpus gets its donor models verbatim.
    model: Option<RuntimeModel>,
}

/// One lock stripe: entries, label states, and the counters for every
/// operation this stripe served. All three live behind the stripe's
/// mutex, so per-label accounting is exact without atomics.
#[derive(Default)]
struct Shard {
    map: HashMap<CacheKey, Entry>,
    labels: HashMap<String, LabelState>,
    stats: CacheStats,
}

impl Shard {
    /// The label's canonical delta (registering `delta` if first contact)
    /// and current generation.
    fn label_state(&mut self, label: &str, delta: f64) -> (f64, u64) {
        let st = self.labels.entry(label.to_string()).or_default();
        (*st.delta.get_or_insert(delta), st.generation)
    }
}

/// Thread-safe, lock-striped measurement cache shared by every fleet
/// worker. The public API, snapshot compatibility, and generation
/// semantics are identical to the former single-mutex implementation.
pub struct MeasurementCache {
    shards: [Mutex<Shard>; SHARD_COUNT],
    /// Per-stripe hit/miss mirrors maintained *outside* the stripe locks:
    /// each `lookup` bumps exactly one atomic here (while holding its
    /// stripe lock, so the mirror never drifts from the locked counters).
    /// [`MeasurementCache::hits`] / [`MeasurementCache::misses`] sum these
    /// with relaxed loads — the fast path the daemon's replan tail and the
    /// telemetry cache-flush use instead of aggregating all eight stripes
    /// under their mutexes.
    fast_hits: [AtomicU64; SHARD_COUNT],
    fast_misses: [AtomicU64; SHARD_COUNT],
}

impl Default for MeasurementCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MeasurementCache {
    pub fn new() -> Self {
        Self {
            shards: std::array::from_fn(|_| Mutex::new(Shard::default())),
            fast_hits: std::array::from_fn(|_| AtomicU64::new(0)),
            fast_misses: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Lifetime cache hits — one relaxed atomic load per stripe, no
    /// stripe lock. Exact whenever no lookup is mid-flight (every
    /// increment happens under the stripe lock the full `stats()`
    /// aggregation would take anyway).
    pub fn hits(&self) -> u64 {
        self.fast_hits.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// Lifetime cache misses — the lock-free counterpart of
    /// `stats().misses`, see [`MeasurementCache::hits`].
    pub fn misses(&self) -> u64 {
        self.fast_misses.iter().map(|a| a.load(Ordering::Relaxed)).sum()
    }

    /// The stripe a label lives on. Deterministic (FNV-1a), so snapshots
    /// taken by one process shard identically in the next.
    fn shard_index(label: &str) -> usize {
        fnv1a(label.bytes()) as usize % SHARD_COUNT
    }

    fn shard(&self, label: &str) -> MutexGuard<'_, Shard> {
        self.shards[Self::shard_index(label)].lock().unwrap()
    }

    /// Every stripe guard, acquired in index order — the one lock order
    /// used by whole-cache operations (stats/snapshot/restore), which
    /// rules out deadlock between them.
    fn lock_all(&self) -> Vec<MutexGuard<'_, Shard>> {
        self.shards.iter().map(|s| s.lock().unwrap()).collect()
    }

    /// Aggregate stripe counters in index order (deterministic f64 sum).
    fn sum_stats(guards: &[MutexGuard<'_, Shard>]) -> CacheStats {
        let mut total = CacheStats::default();
        for g in guards {
            total.hits += g.stats.hits;
            total.misses += g.stats.misses;
            total.stale_hits_refused += g.stats.stale_hits_refused;
            total.evictions += g.stats.evictions;
            total.inserts += g.stats.inserts;
            total.saved_wallclock += g.stats.saved_wallclock;
        }
        total
    }

    /// Look up a measurement, recording a hit or miss. Only entries of the
    /// label's *current* generation are served; a pre-bump entry is refused
    /// (a miss, plus `stale_hits_refused`) so the caller re-executes. On a
    /// hit the original run's wallclock is credited to `saved_wallclock`.
    pub fn lookup(&self, label: &str, limit: f64, delta: f64) -> Option<Measurement> {
        self.lookup_tallied(label, limit, delta, &mut CacheStats::default())
    }

    /// [`MeasurementCache::lookup`], additionally mirroring the hit /
    /// miss / stale-refusal / saved-wallclock accounting of this single
    /// call into `tally` — how [`CachedBackend`] attributes cache traffic
    /// to the one profile that caused it (the per-outcome delta the
    /// overlapped daemon merges deterministically).
    pub fn lookup_tallied(
        &self,
        label: &str,
        limit: f64,
        delta: f64,
        tally: &mut CacheStats,
    ) -> Option<Measurement> {
        let idx = Self::shard_index(label);
        let mut shard = self.shards[idx].lock().unwrap();
        let (delta, generation) = shard.label_state(label, delta);
        let key = (label.to_string(), grid_bucket(limit, delta));
        let entry = shard.map.get(&key).map(|e| (e.m, e.generation));
        let found = match entry {
            Some((m, stamped)) if stamped == generation => Some(m),
            Some(_) => {
                shard.stats.stale_hits_refused += 1;
                tally.stale_hits_refused += 1;
                None
            }
            None => None,
        };
        match found {
            Some(m) => {
                shard.stats.hits += 1;
                shard.stats.saved_wallclock += m.wallclock;
                self.fast_hits[idx].fetch_add(1, Ordering::Relaxed);
                tally.hits += 1;
                tally.saved_wallclock += m.wallclock;
                Some(m)
            }
            None => {
                shard.stats.misses += 1;
                self.fast_misses[idx].fetch_add(1, Ordering::Relaxed);
                tally.misses += 1;
                None
            }
        }
    }

    /// Store an executed measurement (last write wins — concurrent workers
    /// probing the same key observe the same distribution, so either value
    /// is a valid sample). The entry is stamped with the label's current
    /// generation; overwriting a stale entry refreshes it.
    pub fn insert(&self, label: &str, delta: f64, m: Measurement) {
        let mut shard = self.shard(label);
        let (delta, generation) = shard.label_state(label, delta);
        let key = (label.to_string(), grid_bucket(m.limit, delta));
        shard.map.insert(key, Entry { m, generation });
        shard.stats.inserts += 1;
    }

    /// Age out a label: bump its generation so every existing entry of the
    /// label becomes stale (refused by `lookup`, reclaimed by
    /// `evict_stale`). Returns the new generation. Called by the adaptive
    /// loop when a drift verdict invalidates a job class's measurements.
    pub fn bump_generation(&self, label: &str) -> u64 {
        let mut shard = self.shard(label);
        let st = shard.labels.entry(label.to_string()).or_default();
        st.generation += 1;
        st.generation
    }

    /// The current generation of a label (0 until first bumped).
    pub fn generation(&self, label: &str) -> u64 {
        self.shard(label).labels.get(label).map_or(0, |st| st.generation)
    }

    /// Publish the label's fitted runtime model as aging-state metadata.
    /// A v3 [`MeasurementCache::snapshot`] carries it, so a restored
    /// transfer corpus can donate the exact curve instead of refitting
    /// from raw points. Overwrites any previous model for the label.
    pub fn note_model(&self, label: &str, model: &RuntimeModel) {
        let mut shard = self.shard(label);
        shard.labels.entry(label.to_string()).or_default().model = Some(model.clone());
    }

    /// The fitted model last noted for `label`, if any.
    pub fn model_of(&self, label: &str) -> Option<RuntimeModel> {
        self.shard(label).labels.get(label).and_then(|st| st.model.clone())
    }

    /// Reclaim every entry whose stamped generation is behind its label's
    /// current generation. Current-generation entries are never evicted.
    /// Returns the number of entries reclaimed.
    pub fn evict_stale(&self) -> usize {
        let mut evicted = 0usize;
        for stripe in &self.shards {
            let mut shard = stripe.lock().unwrap();
            let Shard { map, labels, stats } = &mut *shard;
            let before = map.len();
            map.retain(|(label, _), e| match labels.get(label) {
                Some(st) => e.generation == st.generation,
                None => true,
            });
            let reclaimed = before - map.len();
            stats.evictions += reclaimed as u64;
            evicted += reclaimed;
        }
        evicted
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        Self::sum_stats(&self.lock_all())
    }

    /// Serialize every entry, the per-label aging state, and the lifetime
    /// runtime counters as a [`Json`] tree — the persistence surface
    /// behind `streamprof fleet --cache-file f.json`. Deterministic output
    /// (labels and buckets sorted, stripe counters summed in index order).
    /// Version 2 added the `stats` block (hit/miss/eviction counters and
    /// the saved wallclock, so a restarted daemon keeps its amortization
    /// history); version 3 adds optional per-label `model` metadata — the
    /// fitted curve parameters the transfer-prior corpus donates from.
    pub fn snapshot(&self) -> Json {
        let guards = self.lock_all();
        let stats = Self::sum_stats(&guards);
        let mut labels: Vec<(&String, &LabelState)> =
            guards.iter().flat_map(|g| g.labels.iter()).collect();
        labels.sort_by(|x, y| x.0.cmp(y.0));
        let mut label_docs = Vec::with_capacity(labels.len());
        for (label, st) in labels {
            let mut fields = vec![
                ("label", Json::str(label)),
                ("generation", Json::num(st.generation as f64)),
            ];
            if let Some(d) = st.delta {
                fields.push(("delta", Json::num(d)));
            }
            if let Some(m) = &st.model {
                fields.push(("model", model_to_json(m)));
            }
            label_docs.push(Json::obj(fields));
        }
        let mut entries: Vec<(&CacheKey, &Entry)> =
            guards.iter().flat_map(|g| g.map.iter()).collect();
        entries.sort_by(|x, y| x.0.cmp(y.0));
        let mut entry_docs = Vec::with_capacity(entries.len());
        for ((label, bucket), e) in entries {
            entry_docs.push(Json::obj([
                ("label", Json::str(label)),
                ("bucket", Json::num(*bucket as f64)),
                ("generation", Json::num(e.generation as f64)),
                ("limit", Json::num(e.m.limit)),
                ("mean_runtime", Json::num(e.m.mean_runtime)),
                ("samples", Json::num(e.m.samples as f64)),
                ("wallclock", Json::num(e.m.wallclock)),
            ]));
        }
        Json::obj([
            ("version", Json::num(3.0)),
            (
                "stats",
                Json::obj([
                    ("hits", Json::num(stats.hits as f64)),
                    ("misses", Json::num(stats.misses as f64)),
                    ("stale_hits_refused", Json::num(stats.stale_hits_refused as f64)),
                    ("evictions", Json::num(stats.evictions as f64)),
                    ("inserts", Json::num(stats.inserts as f64)),
                    ("saved_wallclock", Json::num(stats.saved_wallclock)),
                ]),
            ),
            ("labels", Json::Arr(label_docs)),
            ("entries", Json::Arr(entry_docs)),
        ])
    }

    /// Merge a [`Self::snapshot`] back in. Returns a [`RestoreOutcome`]:
    /// the number of entries restored **and** the counts it refused, so a
    /// corrupted corpus is visible to the caller instead of silently
    /// shrinking.
    ///
    /// Refusals are per-label/per-entry, not whole-snapshot: an entry
    /// stamped with a **newer** generation than its label's header
    /// declares is skipped and counted (`refused_newer` — restoring it
    /// would serve measurements the aging protocol says were never valid),
    /// and a label whose canonical bucket width conflicts with the live
    /// cache is skipped entirely — header merge and entries — and its
    /// entries counted (`refused_width`). Older-generation entries restore
    /// as stale: `lookup` keeps refusing them and `evict_stale` can
    /// reclaim them.
    ///
    /// Merge policy when the cache is not empty: generations merge to the
    /// max of both sides, occupied buckets keep their live entry (the
    /// process's own measurements are never overwritten), and a label
    /// keeps its live model metadata over the snapshot's. Restored entries
    /// count as `inserts`, so `evictions ≤ inserts` still holds after a
    /// restore-then-age cycle. A v2+ snapshot's `stats` block is folded
    /// **additively** into the live counters (the restored process keeps
    /// its lifetime amortization history; per-run reporting goes through
    /// [`CacheStats::delta_since`] and is unaffected). Version-1 snapshots
    /// carry no stats and fold zeros. Structural corruption — wrong-typed
    /// fields, entries missing from the header, unknown versions — still
    /// fails the whole restore, and a failed restore is atomic: every such
    /// check runs before the first mutation, so an `Err` leaves the live
    /// cache exactly as it was.
    pub fn restore(&self, snap: &Json) -> Result<RestoreOutcome> {
        let version = snap.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        ensure!(
            version == 1.0 || version == 2.0 || version == 3.0,
            "unsupported cache snapshot version {version}"
        );
        // Strict field readers: a wrong-typed field is a corrupt snapshot
        // and must refuse, never coerce to a default measurement.
        let num = |v: &Json, key: &str| -> Result<f64> {
            v.req(key)
                .map_err(anyhow::Error::msg)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a number"))
        };
        let uint = |v: &Json, key: &str| -> Result<u64> {
            let n = num(v, key)?;
            ensure!(n >= 0.0 && n.fract() == 0.0, "field '{key}' is not a whole number: {n}");
            Ok(n as u64)
        };
        let text = |v: &Json, key: &str| -> Result<String> {
            let s = v
                .req(key)
                .map_err(anyhow::Error::msg)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("field '{key}' is not a string"))?;
            ensure!(!s.is_empty(), "field '{key}' is empty");
            Ok(s.to_string())
        };
        fn list<'a>(snap: &'a Json, key: &str) -> Result<&'a [Json]> {
            snap.req(key)
                .map_err(anyhow::Error::msg)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("field '{key}' is not an array"))
        }
        // A v2+ snapshot must carry a consistent stats block; the
        // carried counters themselves must satisfy the invariants a live
        // cache maintains, or the merged aggregate would violate them.
        let carried = if version >= 2.0 {
            let s = snap.req("stats").map_err(anyhow::Error::msg)?;
            let stats = CacheStats {
                hits: uint(s, "hits")?,
                misses: uint(s, "misses")?,
                stale_hits_refused: uint(s, "stale_hits_refused")?,
                evictions: uint(s, "evictions")?,
                inserts: uint(s, "inserts")?,
                saved_wallclock: num(s, "saved_wallclock")?,
            };
            ensure!(
                stats.saved_wallclock.is_finite() && stats.saved_wallclock >= 0.0,
                "field 'saved_wallclock' is not a non-negative time: {}",
                stats.saved_wallclock
            );
            ensure!(
                stats.evictions <= stats.inserts,
                "snapshot stats: evictions {} exceed inserts {}",
                stats.evictions,
                stats.inserts
            );
            ensure!(
                stats.stale_hits_refused <= stats.misses,
                "snapshot stats: stale refusals {} exceed misses {}",
                stats.stale_hits_refused,
                stats.misses
            );
            stats
        } else {
            CacheStats::default()
        };
        // Parse + validate the whole snapshot before touching any stripe.
        let mut header: HashMap<String, (Option<f64>, u64, Option<RuntimeModel>)> = HashMap::new();
        for l in list(snap, "labels")? {
            let label = text(l, "label")?;
            let generation = uint(l, "generation")?;
            let delta = match l.get("delta") {
                None => None,
                Some(_) => Some(num(l, "delta")?),
            };
            if let Some(d) = delta {
                ensure!(d > 0.0 && d.is_finite(), "label '{label}': bad delta {d}");
            }
            let model = match l.get("model") {
                None => None,
                Some(doc) => Some(model_from_json(doc).ok_or_else(|| {
                    anyhow::anyhow!("label '{label}': malformed model metadata")
                })?),
            };
            header.insert(label, (delta, generation, model));
        }
        struct Restored {
            label: String,
            bucket: i64,
            generation: u64,
            m: Measurement,
        }
        let mut restored: Vec<Restored> = Vec::new();
        let mut refused_newer = 0usize;
        for e in list(snap, "entries")? {
            let label = text(e, "label")?;
            let Some((delta, declared, _)) = header.get(&label) else {
                bail!("entry label '{label}' missing from the snapshot header");
            };
            ensure!(delta.is_some(), "label '{label}' has entries but no canonical delta");
            let generation = uint(e, "generation")?;
            let bucket = num(e, "bucket")?;
            ensure!(bucket.fract() == 0.0, "entry '{label}': bad bucket {bucket}");
            let m = Measurement {
                limit: num(e, "limit")?,
                mean_runtime: num(e, "mean_runtime")?,
                samples: uint(e, "samples")? as usize,
                wallclock: num(e, "wallclock")?,
            };
            if generation > *declared {
                // Stamped newer than the snapshot's own header: the aging
                // protocol says this measurement was never valid. Skip it
                // and surface the count — a corrupt or hand-edited corpus
                // must be visible, not silently trusted or silently fatal.
                refused_newer += 1;
                continue;
            }
            restored.push(Restored { bucket: bucket as i64, generation, m, label });
        }

        // Detect label width conflicts against the live store BEFORE
        // mutating anything. All stripes are held (in index order) for the
        // whole merge, so the restore is atomic across shards too.
        let mut guards = self.lock_all();
        let mut conflicted: std::collections::HashSet<String> = std::collections::HashSet::new();
        for (label, (delta, _, _)) in &header {
            let Some(snap_delta) = *delta else { continue };
            let live =
                guards[Self::shard_index(label)].labels.get(label).and_then(|st| st.delta);
            // A conflicting probe grid would alias buckets: skip the whole
            // label (header merge and entries) and count its entries.
            if live.is_some_and(|d| d != snap_delta) {
                conflicted.insert(label.clone());
            }
        }
        for (label, (delta, generation, model)) in &header {
            if conflicted.contains(label) {
                continue;
            }
            let shard = &mut guards[Self::shard_index(label)];
            let st = shard.labels.entry(label.clone()).or_default();
            if st.delta.is_none() {
                st.delta = *delta;
            }
            if st.model.is_none() {
                st.model = model.clone();
            }
            st.generation = st.generation.max(*generation);
        }
        let mut count = 0usize;
        let mut refused_width = 0usize;
        for r in restored {
            if conflicted.contains(&r.label) {
                refused_width += 1;
                continue;
            }
            let shard = &mut guards[Self::shard_index(&r.label)];
            if let std::collections::hash_map::Entry::Vacant(slot) =
                shard.map.entry((r.label, r.bucket))
            {
                slot.insert(Entry { m: r.m, generation: r.generation });
                count += 1;
            }
        }
        // Fold the carried counters (and the restored entries, which count
        // as inserts) into stripe 0; `stats()` sums the stripes, so where
        // the carry lands is invisible to every reader.
        self.fast_hits[0].fetch_add(carried.hits, Ordering::Relaxed);
        self.fast_misses[0].fetch_add(carried.misses, Ordering::Relaxed);
        let s = &mut guards[0].stats;
        s.hits += carried.hits;
        s.misses += carried.misses;
        s.stale_hits_refused += carried.stale_hits_refused;
        s.evictions += carried.evictions;
        s.inserts += carried.inserts + count as u64;
        s.saved_wallclock += carried.saved_wallclock;
        Ok(RestoreOutcome { restored: count, refused_newer, refused_width })
    }
}

/// What [`MeasurementCache::restore`] did: entries merged plus the counts
/// it refused — surfaced (CLI log, daemon journal) so a corrupted corpus
/// shrinks *visibly*.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RestoreOutcome {
    /// Entries merged into the live cache.
    pub restored: usize,
    /// Entries refused because they were stamped with a generation newer
    /// than their label's own snapshot header declares.
    pub refused_newer: usize,
    /// Entries refused because their label's canonical bucket width
    /// conflicts with the live cache (the whole label is skipped).
    pub refused_width: usize,
}

impl RestoreOutcome {
    /// Total refused entries.
    pub fn refused(&self) -> usize {
        self.refused_newer + self.refused_width
    }
}

/// Serialize a fitted model as a snapshot's per-label `model` block
/// (`fit_cost` is a diagnostic and is not persisted).
fn model_to_json(m: &RuntimeModel) -> Json {
    Json::obj([
        ("kind", Json::str(m.kind.name())),
        ("a", Json::num(m.a)),
        ("b", Json::num(m.b)),
        ("c", Json::num(m.c)),
        ("d", Json::num(m.d)),
    ])
}

/// Parse a per-label `model` block back into a [`RuntimeModel`]
/// (`fit_cost` restores as zero). `None` when any field is missing,
/// mistyped, or names an unknown model kind.
pub(crate) fn model_from_json(doc: &Json) -> Option<RuntimeModel> {
    let kind = ModelKind::from_name(doc.get("kind")?.as_str()?)?;
    Some(RuntimeModel {
        kind,
        a: doc.get("a")?.as_f64()?,
        b: doc.get("b")?.as_f64()?,
        c: doc.get("c")?.as_f64()?,
        d: doc.get("d")?.as_f64()?,
        fit_cost: 0.0,
    })
}

/// Backend decorator that consults the shared cache before executing.
///
/// On a hit the cached measurement is returned with `wallclock = 0` (the
/// session spends no time on it); on a miss — including a stale-generation
/// refusal — the inner backend executes and the result is stored (at the
/// current generation) for every later probe of the same key.
pub struct CachedBackend<'a, B: ProfilingBackend> {
    inner: B,
    cache: &'a MeasurementCache,
    label: String,
    delta: f64,
    /// Cache traffic caused by *this* backend: every lookup and insert is
    /// mirrored here, so the profile that owns the backend can report its
    /// exact cache contribution without re-aggregating global stats.
    tally: CacheStats,
}

impl<'a, B: ProfilingBackend> CachedBackend<'a, B> {
    pub fn new(inner: B, cache: &'a MeasurementCache, label: String, delta: f64) -> Self {
        Self { inner, cache, label, delta, tally: CacheStats::default() }
    }

    /// The cache traffic this backend generated so far (hits, misses =
    /// probes actually executed, inserts, stale refusals, wallclock
    /// saved). A session's tally equals the global stats delta across the
    /// session whenever no other worker touches the cache concurrently.
    pub fn tally(&self) -> CacheStats {
        self.tally
    }

    fn serve(&self, limit: f64, cached: Measurement) -> Measurement {
        Measurement { limit, wallclock: 0.0, ..cached }
    }
}

impl<B: ProfilingBackend> ProfilingBackend for CachedBackend<'_, B> {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        if let Some(m) = self.cache.lookup_tallied(&self.label, limit, self.delta, &mut self.tally)
        {
            return self.serve(limit, m);
        }
        let m = self.inner.measure(limit, samples);
        self.cache.insert(&self.label, self.delta, m);
        self.tally.inserts += 1;
        m
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        if let Some(m) = self.cache.lookup_tallied(&self.label, limit, self.delta, &mut self.tally)
        {
            return self.serve(limit, m);
        }
        let m = self.inner.measure_early_stop(limit, cfg, cap);
        self.cache.insert(&self.label, self.delta, m);
        self.tally.inserts += 1;
        m
    }

    fn l_max(&self) -> f64 {
        self.inner.l_max()
    }

    fn label(&self) -> String {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimulatedBackend;
    use crate::simulator::{node, Algo, SimulatedJob};

    fn backend(cache: &MeasurementCache, seed: u64) -> CachedBackend<'_, SimulatedBackend> {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, seed);
        CachedBackend::new(SimulatedBackend::new(job), cache, "pi4/arima".into(), 0.1)
    }

    fn meas(limit: f64, rt: f64) -> Measurement {
        Measurement { limit, mean_runtime: rt, samples: 1000, wallclock: rt * 1000.0 }
    }

    #[test]
    fn second_probe_is_a_hit_with_zero_wallclock() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 1);
        let m1 = b.measure(0.5, 1000);
        assert!(m1.wallclock > 0.0);
        let m2 = b.measure(0.5, 1000);
        assert_eq!(m2.mean_runtime, m1.mean_runtime, "hit must replay the observation");
        assert_eq!(m2.wallclock, 0.0, "hit must cost no profiling time");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.lookups(), 2);
        assert_eq!(s.inserts, 1);
        assert!((s.saved_wallclock - m1.wallclock).abs() < 1e-12);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fast_hit_miss_accessors_mirror_the_locked_stats() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 11);
        b.measure(0.5, 1000);
        b.measure(0.5, 1000);
        b.measure(0.7, 1000);
        let s = cache.stats();
        assert_eq!(cache.hits(), s.hits);
        assert_eq!(cache.misses(), s.misses);
        assert_eq!((cache.hits(), cache.misses()), (1, 2));
    }

    #[test]
    fn backend_tally_tracks_its_own_cache_traffic() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 12);
        b.measure(0.5, 1000); // miss + insert
        b.measure(0.5, 1000); // hit
        let t = b.tally();
        assert_eq!((t.hits, t.misses, t.inserts), (1, 1, 1));
        assert!(t.saved_wallclock > 0.0, "the hit credits the saved run");
        // Aging the label makes the next probe a stale refusal + miss.
        cache.bump_generation("pi4/arima");
        b.measure(0.5, 1000);
        let t = b.tally();
        assert_eq!((t.misses, t.stale_hits_refused, t.inserts), (2, 1, 2));
        // The backend's private tally matches the global lifetime stats
        // (nothing else touched this cache).
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.stale_hits_refused), (t.hits, t.misses, 1));
    }

    #[test]
    fn different_limits_and_labels_miss() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 2);
        b.measure(0.5, 1000);
        b.measure(0.6, 1000);
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(cache.stats().hits, 0);
        assert_eq!(cache.len(), 2);
        // Same node/algo but a different label key: distinct entry space.
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let mut other =
            CachedBackend::new(SimulatedBackend::new(job), &cache, "other-label".into(), 0.1);
        other.measure(0.5, 1000);
        assert_eq!(cache.stats().misses, 3);
    }

    #[test]
    fn drifted_limit_shares_the_bucket() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 4);
        b.measure(0.1 + 0.1 + 0.1, 1000); // 0.30000000000000004
        let m = b.measure(0.3, 1000);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(m.limit, 0.3, "hit is served at the requested limit");
    }

    #[test]
    fn grid_bucket_boundaries_are_stable_across_the_whole_grid() {
        // Every grid value, however it was computed — clean multiple,
        // repeated-addition drift (0.1+0.1+0.1 = 0.30000000000000004), or
        // scaled-down integer — must land in the bucket of its index, for
        // the full 16-core grid (160 buckets).
        let delta = 0.1;
        let mut acc = 0.0;
        for i in 1..=160i64 {
            acc += delta; // accumulates binary-representation drift
            let clean = i as f64 * delta;
            let scaled = (i as f64) / 10.0;
            assert_eq!(grid_bucket(acc, delta), i, "drifted {acc:.17}");
            assert_eq!(grid_bucket(clean, delta), i, "clean {clean}");
            assert_eq!(grid_bucket(scaled, delta), i, "scaled {scaled}");
        }
        // Off-grid probes bucket to the nearest cell, monotonically.
        let mut prev = grid_bucket(0.01, delta);
        for k in 1..400 {
            let r = 0.01 + k as f64 * 0.04;
            let b = grid_bucket(r, delta);
            assert!(b >= prev, "bucketing must be monotone in r");
            prev = b;
        }
    }

    #[test]
    fn boundary_drift_cannot_split_a_cache_entry() {
        // A probe at the drifted representation and a probe at the clean
        // grid value must share one entry — for every bucket of pi4's
        // grid, not just the famous 0.3 case.
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 9);
        let mut acc = 0.0;
        for _ in 0..40 {
            acc += 0.1;
            b.measure(acc, 1000);
        }
        assert_eq!(cache.len(), 40);
        assert_eq!(cache.stats().misses, 40);
        for i in 1..=40 {
            b.measure(i as f64 * 0.1, 1000);
        }
        assert_eq!(cache.len(), 40, "clean probes must not create new entries");
        assert_eq!(cache.stats().hits, 40);
    }

    #[test]
    fn reconfigured_delta_cannot_alias_old_buckets() {
        // Regression: `lookup` and `insert` used the caller-supplied
        // `delta` for the bucket index, so a job reconfigured to a wider
        // grid aliased old buckets — a probe at 0.8 with delta 0.2 landed
        // in bucket 4 and was served the measurement taken at limit 0.4.
        // The canonical per-label delta (first registration wins) keys
        // every later call consistently.
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44)); // bucket 4 at delta 0.1
        assert!(
            cache.lookup("cam", 0.8, 0.2).is_none(),
            "0.8 under the reconfigured width must not alias the 0.4 entry"
        );
        assert_eq!(cache.stats().stale_hits_refused, 0, "a width change is not staleness");
        // The same limit still resolves through the canonical width.
        let m = cache.lookup("cam", 0.4, 0.2).expect("canonical bucket still serves");
        assert_eq!(m.mean_runtime, 0.44);
        // Inserting at the new width quantizes with the canonical delta
        // too: 0.8 -> bucket 8, a fresh entry rather than overwriting 0.4.
        cache.insert("cam", 0.2, meas(0.8, 0.21));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.lookup("cam", 0.4, 0.1).unwrap().mean_runtime, 0.44);
        assert_eq!(cache.lookup("cam", 0.8, 0.1).unwrap().mean_runtime, 0.21);
        // A different label registers its own canonical width.
        cache.insert("lidar", 0.2, meas(0.8, 0.5));
        assert_eq!(cache.lookup("lidar", 0.7, 0.2).unwrap().mean_runtime, 0.5);
    }

    #[test]
    fn generation_bump_refuses_stale_hits_and_evicts() {
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.1, 1.0));
        cache.insert("cam", 0.1, meas(0.2, 0.5));
        assert!(cache.lookup("cam", 0.1, 0.1).is_some());
        assert_eq!(cache.generation("cam"), 0);

        assert_eq!(cache.bump_generation("cam"), 1);
        // Pre-bump entries are refused: a miss plus a stale refusal.
        assert!(cache.lookup("cam", 0.1, 0.1).is_none());
        let s = cache.stats();
        assert_eq!(s.stale_hits_refused, 1);
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.lookups(), 2);

        // Re-inserting a bucket refreshes it to the current generation;
        // the untouched bucket is reclaimed by evict_stale.
        cache.insert("cam", 0.1, meas(0.1, 3.0));
        assert_eq!(cache.lookup("cam", 0.1, 0.1).unwrap().mean_runtime, 3.0);
        assert_eq!(cache.evict_stale(), 1, "only the stale 0.2 bucket is reclaimed");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.stats().evictions <= cache.stats().inserts);
        // Evicting again is a no-op: current-generation entries survive.
        assert_eq!(cache.evict_stale(), 0);
        assert!(cache.lookup("cam", 0.1, 0.1).is_some());
    }

    #[test]
    fn generation_bump_is_per_label() {
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.3, 0.3));
        cache.insert("lidar", 0.1, meas(0.3, 0.9));
        cache.bump_generation("cam");
        assert!(cache.lookup("cam", 0.3, 0.1).is_none(), "bumped label refuses");
        assert!(cache.lookup("lidar", 0.3, 0.1).is_some(), "other labels unaffected");
        assert_eq!(cache.evict_stale(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cached_backend_re_executes_after_bump() {
        // The drift path end-to-end: probe, bump, probe again — the second
        // probe must re-execute (fresh wallclock) and repopulate the
        // bucket at the new generation.
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 6);
        let m1 = b.measure(0.5, 1000);
        assert!(m1.wallclock > 0.0);
        cache.bump_generation("pi4/arima");
        let m2 = b.measure(0.5, 1000);
        assert!(m2.wallclock > 0.0, "stale entry must not be served");
        let m3 = b.measure(0.5, 1000);
        assert_eq!(m3.wallclock, 0.0, "fresh-generation entry serves again");
        assert_eq!(m3.mean_runtime, m2.mean_runtime);
        let s = cache.stats();
        assert_eq!(s.stale_hits_refused, 1);
        assert_eq!((s.hits, s.misses), (1, 2));
    }

    #[test]
    fn concurrent_workers_account_stats_exactly() {
        // 8 workers × 100 probes over 10 buckets of one label. Regardless
        // of interleaving: every lookup is counted exactly once, the saved
        // wallclock equals hits × the (identical) cached wallclock, and
        // the map holds exactly one entry per bucket.
        let cache = MeasurementCache::new();
        let wall = 2.0;
        std::thread::scope(|s| {
            for w in 0..8usize {
                let cache = &cache;
                s.spawn(move || {
                    for k in 0..100usize {
                        let limit = 0.1 + ((k + w) % 10) as f64 * 0.1;
                        if cache.lookup("shared", limit, 0.1).is_none() {
                            cache.insert(
                                "shared",
                                0.1,
                                Measurement {
                                    limit,
                                    mean_runtime: 0.05,
                                    samples: 1000,
                                    wallclock: wall,
                                },
                            );
                        }
                        std::thread::yield_now();
                    }
                });
            }
        });
        let stats = cache.stats();
        assert_eq!(stats.lookups(), 800, "every lookup counted once");
        assert!(stats.misses >= 10, "each bucket misses at least once");
        assert!(stats.hits <= 790);
        assert_eq!(cache.len(), 10, "one entry per bucket");
        assert!(
            (stats.saved_wallclock - stats.hits as f64 * wall).abs() < 1e-9,
            "saved wallclock must equal hits x cached cost: {} vs {}",
            stats.saved_wallclock,
            stats.hits as f64 * wall
        );
        let rate = stats.hit_rate();
        assert!((0.0..=1.0).contains(&rate));
    }

    #[test]
    fn stats_aggregate_across_label_shards() {
        // Labels hash onto different stripes; the aggregated stats must
        // account every operation exactly once regardless of which stripe
        // served it, and aging must stay per-label across stripes.
        let cache = MeasurementCache::new();
        for i in 0..32 {
            let label = format!("node-{i:02}/algo");
            cache.insert(&label, 0.1, meas(0.4, 0.5));
            assert!(cache.lookup(&label, 0.4, 0.1).is_some());
            assert!(cache.lookup(&label, 0.8, 0.1).is_none());
        }
        let s = cache.stats();
        assert_eq!(s.inserts, 32);
        assert_eq!((s.hits, s.misses), (32, 32));
        assert_eq!(s.lookups(), 64);
        assert_eq!(cache.len(), 32);
        assert!((s.saved_wallclock - 32.0 * 500.0).abs() < 1e-9);
        cache.bump_generation("node-00/algo");
        assert_eq!(cache.evict_stale(), 1, "only the bumped label's entry is reclaimed");
        assert_eq!(cache.len(), 31);
    }

    #[test]
    fn snapshot_roundtrips_entries_generations_and_deltas() {
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44));
        cache.insert("cam", 0.1, meas(0.8, 0.21));
        cache.insert("lidar", 0.2, meas(0.6, 0.5));
        cache.bump_generation("lidar");
        cache.insert("lidar", 0.2, meas(0.8, 0.3)); // gen 1
        let text = crate::util::json::to_string(&cache.snapshot());

        let fresh = MeasurementCache::new();
        let snap = crate::util::json::parse(&text).expect("snapshot parses");
        let n = fresh.restore(&snap).expect("restore");
        assert_eq!(n, RestoreOutcome { restored: 4, refused_newer: 0, refused_width: 0 });
        assert_eq!(fresh.len(), 4);
        assert_eq!(fresh.stats().inserts, 8, "4 carried in the stats block + 4 restored");
        // Bit-exact measurements at the canonical widths.
        let restored = fresh.lookup("cam", 0.4, 0.1).unwrap();
        assert_eq!(restored.mean_runtime.to_bits(), 0.44f64.to_bits());
        assert_eq!(fresh.lookup("cam", 0.8, 0.1).unwrap().mean_runtime, 0.21);
        // Generations survive: lidar's pre-bump entry is still stale.
        assert_eq!(fresh.generation("lidar"), 1);
        assert!(fresh.lookup("lidar", 0.6, 0.2).is_none(), "stale entry stays refused");
        assert!(fresh.lookup("lidar", 0.8, 0.2).is_some(), "current-gen entry serves");
        assert_eq!(fresh.evict_stale(), 1);
        assert!(fresh.stats().evictions <= fresh.stats().inserts);
        // The canonical delta was restored too: the aliasing guard holds.
        assert!(fresh.lookup("cam", 0.8, 0.2).is_some(), "canonical width 0.1 still keys");
    }

    #[test]
    fn snapshot_v2_carries_runtime_stats() {
        // The PR 4 caveat, closed: hit/miss/saved-wallclock counters ride
        // the snapshot and restore additively, so a restarted daemon keeps
        // its lifetime amortization history.
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 11);
        b.measure(0.5, 1000);
        b.measure(0.5, 1000); // hit
        b.measure(1.0, 1000); // miss
        let before = cache.stats();
        assert_eq!((before.hits, before.misses, before.inserts), (1, 2, 2));
        assert!(before.saved_wallclock > 0.0);

        let text = crate::util::json::to_string(&cache.snapshot());
        let next = MeasurementCache::new();
        let n = next.restore(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(n.restored, 2);
        assert_eq!(n.refused(), 0);
        let s = next.stats();
        assert_eq!(s.hits, before.hits);
        assert_eq!(s.misses, before.misses);
        assert_eq!(s.stale_hits_refused, before.stale_hits_refused);
        assert_eq!(s.evictions, before.evictions);
        assert_eq!(s.inserts, before.inserts + 2, "carried + restored-as-inserts");
        assert_eq!(s.saved_wallclock.to_bits(), before.saved_wallclock.to_bits());
    }

    #[test]
    fn restore_reads_v1_snapshots_without_stats() {
        // Pre-v2 snapshots declare version 1 and carry no stats block;
        // they must still restore, with zeroed carried counters (restored
        // entries still count as inserts).
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 12);
        b.measure(0.5, 1000);
        b.measure(0.5, 1000);
        let mut snap = cache.snapshot();
        let Json::Obj(root) = &mut snap else { panic!() };
        root.insert("version".into(), Json::num(1.0));
        root.remove("stats");
        let next = MeasurementCache::new();
        assert_eq!(next.restore(&snap).unwrap().restored, 1);
        let s = next.stats();
        assert_eq!((s.hits, s.misses, s.inserts), (0, 0, 1));
        assert_eq!(s.saved_wallclock, 0.0);
        assert!(next.lookup("pi4/arima", 0.5, 0.1).is_some(), "entries restore without stats");
    }

    #[test]
    fn snapshot_v3_roundtrips_fitted_model_metadata() {
        // v3 snapshots carry the per-label fitted model so a restored
        // corpus can seed transfer priors without re-fitting from points.
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44));
        let model =
            RuntimeModel { kind: ModelKind::Full, a: 1.2, b: 0.9, c: 0.05, d: 1.5, fit_cost: 0.0 };
        cache.note_model("cam", &model);
        assert!(cache.model_of("cam").is_some());

        let text = crate::util::json::to_string(&cache.snapshot());
        let next = MeasurementCache::new();
        let out = next.restore(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(out.restored, 1);
        let back = next.model_of("cam").expect("model rides the snapshot");
        assert_eq!(back.kind, ModelKind::Full);
        for r in [0.3, 0.7, 1.4] {
            assert!((back.eval(r) - model.eval(r)).abs() < 1e-12);
        }
        // A live model is never clobbered by a restored one.
        let other = RuntimeModel { a: 9.0, ..model.clone() };
        next.note_model("cam", &other);
        next.restore(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert!((next.model_of("cam").unwrap().a - 9.0).abs() < 1e-12, "live model wins");
    }

    #[test]
    fn restore_reads_v2_snapshots_without_models() {
        // Pre-v3 snapshots declare version 2 and carry no model metadata;
        // they must still restore with empty model slots.
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44));
        let mut snap = cache.snapshot();
        let Json::Obj(root) = &mut snap else { panic!() };
        root.insert("version".into(), Json::num(2.0));
        let next = MeasurementCache::new();
        assert_eq!(next.restore(&snap).unwrap().restored, 1);
        assert!(next.model_of("cam").is_none());
        assert!(next.lookup("cam", 0.4, 0.1).is_some());
    }

    #[test]
    fn restore_refuses_corrupt_stats_blocks() {
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44));
        let corrupt = |key: &str, value: Json| {
            let mut snap = cache.snapshot();
            let Json::Obj(root) = &mut snap else { panic!() };
            let Some(Json::Obj(stats)) = root.get_mut("stats") else { panic!() };
            stats.insert(key.to_string(), value);
            snap
        };
        // Wrong-typed counters refuse, never coerce.
        let err = MeasurementCache::new()
            .restore(&corrupt("hits", Json::str("3")))
            .expect_err("string hits");
        assert!(err.to_string().contains("hits"), "{err:#}");
        assert!(MeasurementCache::new().restore(&corrupt("misses", Json::num(1.5))).is_err());
        assert!(MeasurementCache::new()
            .restore(&corrupt("saved_wallclock", Json::num(-1.0)))
            .is_err());
        // Counters that violate the cache invariants are forged.
        let err = MeasurementCache::new()
            .restore(&corrupt("evictions", Json::num(99.0)))
            .expect_err("forged evictions");
        assert!(err.to_string().contains("evictions"), "{err:#}");
        // A version-2 snapshot without the stats block is refused outright.
        let text = "{\"version\":2,\"labels\":[],\"entries\":[]}";
        let no_stats = crate::util::json::parse(text).unwrap();
        let err = MeasurementCache::new().restore(&no_stats).expect_err("v2 requires stats");
        assert!(err.to_string().contains("stats"), "{err:#}");
        // A refused stats block is atomic like every other refusal.
        let live = MeasurementCache::new();
        live.insert("lidar", 0.1, meas(0.2, 1.0));
        assert!(live.restore(&corrupt("hits", Json::str("3"))).is_err());
        assert_eq!(live.stats().hits, 0, "failed restore must not fold carried stats");
        assert_eq!(live.len(), 1);
    }

    #[test]
    fn restore_counts_entries_newer_than_the_header_declares() {
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44));
        let mut snap = cache.snapshot();
        // Forge the entry one generation past the header's declaration. A
        // corrupted corpus must not poison the live cache — the entry is
        // skipped, and the refusal is COUNTED so callers can surface it.
        if let Json::Obj(root) = &mut snap {
            let Some(Json::Arr(entries)) = root.get_mut("entries") else { panic!() };
            let Json::Obj(e) = &mut entries[0] else { panic!() };
            e.insert("generation".into(), Json::num(1.0));
        }
        let fresh = MeasurementCache::new();
        let out = fresh.restore(&snap).expect("forged entries skip, not abort");
        assert_eq!(out, RestoreOutcome { restored: 0, refused_newer: 1, refused_width: 0 });
        assert_eq!(out.refused(), 1);
        assert_eq!(fresh.len(), 0, "the forged entry must not land");
        // Unknown future versions still refuse the whole snapshot.
        let bad_version = crate::util::json::parse("{\"version\":4}").unwrap();
        assert!(MeasurementCache::new().restore(&bad_version).is_err());
    }

    #[test]
    fn width_conflicts_skip_the_label_but_merge_the_rest() {
        // Snapshot with TWO labels: "aaa" merges cleanly, "cam" conflicts
        // on the canonical width. The conflicted label is skipped and
        // counted; the clean label still merges in full.
        let old = MeasurementCache::new();
        old.insert("aaa", 0.1, meas(0.4, 0.44));
        old.bump_generation("aaa");
        old.insert("aaa", 0.1, meas(0.6, 0.5));
        old.insert("cam", 0.1, meas(0.4, 0.44));
        let snap = old.snapshot();

        let live = MeasurementCache::new();
        live.insert("aaa", 0.1, meas(0.2, 1.0)); // gen 0, clean merge target
        live.insert("cam", 0.2, meas(0.4, 1.0)); // conflicting width
        let out = live.restore(&snap).expect("width conflict skips, not aborts");
        assert_eq!(out.refused_width, 1, "cam's entry refused");
        assert_eq!(out.restored, 2, "both aaa entries land");
        assert_eq!(live.generation("aaa"), 1, "clean label merges generations");
        assert_eq!(live.len(), 4, "live 2 + restored 2");
        assert!(live.lookup("cam", 0.4, 0.2).is_some(), "live cam entry untouched");
        assert!(live.lookup("cam", 0.4, 0.1).is_none(), "snapshot cam entry refused");
        assert!(live.lookup("aaa", 0.6, 0.1).is_some(), "current-gen aaa entry serves");
    }

    #[test]
    fn restore_refuses_wrong_typed_fields() {
        let cache = MeasurementCache::new();
        cache.insert("cam", 0.1, meas(0.4, 0.44));
        let corrupt = |key: &str, value: Json| {
            let mut snap = cache.snapshot();
            let Json::Obj(root) = &mut snap else { panic!() };
            let Some(Json::Arr(entries)) = root.get_mut("entries") else { panic!() };
            let Json::Obj(e) = &mut entries[0] else { panic!() };
            e.insert(key.to_string(), value);
            snap
        };
        // A string where a number belongs must refuse, not coerce to 0.
        let snap = corrupt("mean_runtime", Json::str("0.44"));
        let err = MeasurementCache::new().restore(&snap).expect_err("string runtime");
        assert!(err.to_string().contains("mean_runtime"), "{err:#}");
        let snap = corrupt("bucket", Json::str("4"));
        assert!(MeasurementCache::new().restore(&snap).is_err(), "string bucket");
        let snap = corrupt("samples", Json::num(0.5));
        assert!(MeasurementCache::new().restore(&snap).is_err(), "fractional samples");
        // A missing field refuses too.
        let mut snap = cache.snapshot();
        let Json::Obj(root) = &mut snap else { panic!() };
        let Some(Json::Arr(entries)) = root.get_mut("entries") else { panic!() };
        let Json::Obj(e) = &mut entries[0] else { panic!() };
        e.remove("limit");
        assert!(MeasurementCache::new().restore(&snap).is_err(), "missing limit");
        // And wrong-typed top-level collections (not silently empty).
        let text = "{\"version\":1,\"labels\":[],\"entries\":\"junk\"}";
        let snap = crate::util::json::parse(text).unwrap();
        let err = MeasurementCache::new().restore(&snap).expect_err("non-array entries");
        assert!(err.to_string().contains("entries"), "{err:#}");
    }

    #[test]
    fn restore_merges_without_overwriting_live_entries() {
        let old = MeasurementCache::new();
        old.insert("cam", 0.1, meas(0.4, 0.44));
        old.insert("cam", 0.1, meas(0.8, 0.21));
        let snap = old.snapshot();

        let live = MeasurementCache::new();
        live.insert("cam", 0.1, meas(0.4, 9.0)); // fresher local measurement
        live.bump_generation("cam"); // live is one generation ahead
        live.insert("cam", 0.1, meas(0.4, 9.5));
        assert_eq!(live.restore(&snap).unwrap().restored, 1, "only the vacant 0.8 bucket restores");
        assert_eq!(live.lookup("cam", 0.4, 0.1).unwrap().mean_runtime, 9.5, "live entry wins");
        assert_eq!(live.generation("cam"), 1, "generations merge to the max");
        // The restored gen-0 entry is stale under the live generation.
        assert!(live.lookup("cam", 0.8, 0.1).is_none());
    }

    #[test]
    fn restored_cache_replays_probes_for_a_backend() {
        // The --cache-file contract end-to-end: profile, snapshot to text,
        // restore into a new process's cache, re-profile — every probe
        // replays, and the new process starts from the carried counters.
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 8);
        let m1 = b.measure(0.5, 1000);
        b.measure(1.0, 1000);
        let text = crate::util::json::to_string(&cache.snapshot());

        let next = MeasurementCache::new();
        next.restore(&crate::util::json::parse(&text).unwrap()).unwrap();
        let carried = next.stats();
        assert_eq!((carried.hits, carried.misses), (0, 2), "snapshot stats restored");
        let mut b2 = backend(&next, 8);
        let r = b2.measure(0.5, 1000);
        assert_eq!(r.mean_runtime.to_bits(), m1.mean_runtime.to_bits());
        assert_eq!(r.wallclock, 0.0, "restored entry serves at zero cost");
        let run = next.stats().delta_since(&carried);
        assert_eq!((run.hits, run.misses), (1, 0));
        assert!((run.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn early_stop_path_shares_the_cache() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 5);
        let cfg = EarlyStopConfig::new(0.95, 0.10);
        let m1 = b.measure_early_stop(0.4, &cfg, 10_000);
        let m2 = b.measure_early_stop(0.4, &cfg, 10_000);
        assert_eq!(m1.mean_runtime, m2.mean_runtime);
        assert_eq!(cache.stats().hits, 1);
        // Cross-path: a plain measure at the same bucket also hits.
        let m3 = b.measure(0.4, 1000);
        assert_eq!(m3.mean_runtime, m1.mean_runtime);
        assert_eq!(cache.stats().hits, 2);
    }
}
