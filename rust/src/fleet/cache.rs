//! Shared measurement cache: the fleet's amortization layer.
//!
//! Profiling is expensive — a single 10k-sample run at a small limitation
//! costs minutes of wallclock — and across a fleet the same `(job label,
//! cpu-limit bucket)` pair is probed over and over: re-profiling rounds
//! replay the deterministic initial placement, and replicas of one job
//! class on the same device type ask for identical measurements. The cache
//! stores every observed [`Measurement`] under that key so repeated
//! strategy probes reuse the observed runtime instead of re-executing the
//! job; a hit is returned with `wallclock = 0` (nothing ran) while the
//! wallclock it *would* have cost is accumulated as `saved_wallclock`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::backend::{Measurement, ProfilingBackend};
use crate::earlystop::EarlyStopConfig;
use crate::strategies::grid_bucket;

/// Cache key: job label (e.g. `"pi4/arima"`) + limitation-grid bucket.
pub type CacheKey = (String, i64);

/// Hit/miss counters plus the profiling wallclock hits avoided.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Wallclock (seconds) of re-executions avoided by cache hits.
    pub saved_wallclock: f64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe measurement cache shared by every fleet worker.
pub struct MeasurementCache {
    map: Mutex<HashMap<CacheKey, Measurement>>,
    hits: AtomicU64,
    misses: AtomicU64,
    saved_wallclock: Mutex<f64>,
}

impl Default for MeasurementCache {
    fn default() -> Self {
        Self::new()
    }
}

impl MeasurementCache {
    pub fn new() -> Self {
        Self {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            saved_wallclock: Mutex::new(0.0),
        }
    }

    /// Look up a measurement, recording a hit or miss. On a hit the
    /// original run's wallclock is credited to `saved_wallclock`.
    pub fn lookup(&self, label: &str, limit: f64, delta: f64) -> Option<Measurement> {
        let key = (label.to_string(), grid_bucket(limit, delta));
        let found = self.map.lock().unwrap().get(&key).copied();
        match found {
            Some(m) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                *self.saved_wallclock.lock().unwrap() += m.wallclock;
                Some(m)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store an executed measurement (last write wins — concurrent workers
    /// probing the same key observe the same distribution, so either value
    /// is a valid sample).
    pub fn insert(&self, label: &str, delta: f64, m: Measurement) {
        let key = (label.to_string(), grid_bucket(m.limit, delta));
        self.map.lock().unwrap().insert(key, m);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            saved_wallclock: *self.saved_wallclock.lock().unwrap(),
        }
    }
}

/// Backend decorator that consults the shared cache before executing.
///
/// On a hit the cached measurement is returned with `wallclock = 0` (the
/// session spends no time on it); on a miss the inner backend executes and
/// the result is stored for every later probe of the same key.
pub struct CachedBackend<'a, B: ProfilingBackend> {
    inner: B,
    cache: &'a MeasurementCache,
    label: String,
    delta: f64,
}

impl<'a, B: ProfilingBackend> CachedBackend<'a, B> {
    pub fn new(inner: B, cache: &'a MeasurementCache, label: String, delta: f64) -> Self {
        Self { inner, cache, label, delta }
    }

    fn serve(&self, limit: f64, cached: Measurement) -> Measurement {
        Measurement { limit, wallclock: 0.0, ..cached }
    }
}

impl<B: ProfilingBackend> ProfilingBackend for CachedBackend<'_, B> {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        if let Some(m) = self.cache.lookup(&self.label, limit, self.delta) {
            return self.serve(limit, m);
        }
        let m = self.inner.measure(limit, samples);
        self.cache.insert(&self.label, self.delta, m);
        m
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        if let Some(m) = self.cache.lookup(&self.label, limit, self.delta) {
            return self.serve(limit, m);
        }
        let m = self.inner.measure_early_stop(limit, cfg, cap);
        self.cache.insert(&self.label, self.delta, m);
        m
    }

    fn l_max(&self) -> f64 {
        self.inner.l_max()
    }

    fn label(&self) -> String {
        self.inner.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::SimulatedBackend;
    use crate::simulator::{node, Algo, SimulatedJob};

    fn backend(cache: &MeasurementCache, seed: u64) -> CachedBackend<'_, SimulatedBackend> {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, seed);
        CachedBackend::new(SimulatedBackend::new(job), cache, "pi4/arima".into(), 0.1)
    }

    #[test]
    fn second_probe_is_a_hit_with_zero_wallclock() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 1);
        let m1 = b.measure(0.5, 1000);
        assert!(m1.wallclock > 0.0);
        let m2 = b.measure(0.5, 1000);
        assert_eq!(m2.mean_runtime, m1.mean_runtime, "hit must replay the observation");
        assert_eq!(m2.wallclock, 0.0, "hit must cost no profiling time");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!((s.saved_wallclock - m1.wallclock).abs() < 1e-12);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn different_limits_and_labels_miss() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 2);
        b.measure(0.5, 1000);
        b.measure(0.6, 1000);
        assert_eq!(cache.stats().misses, 2);
        assert_eq!(cache.stats().hits, 0);
        assert_eq!(cache.len(), 2);
        // Same node/algo but a different label key: distinct entry space.
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let mut other =
            CachedBackend::new(SimulatedBackend::new(job), &cache, "other-label".into(), 0.1);
        other.measure(0.5, 1000);
        assert_eq!(cache.stats().misses, 3);
    }

    #[test]
    fn drifted_limit_shares_the_bucket() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 4);
        b.measure(0.1 + 0.1 + 0.1, 1000); // 0.30000000000000004
        let m = b.measure(0.3, 1000);
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(m.limit, 0.3, "hit is served at the requested limit");
    }

    #[test]
    fn grid_bucket_boundaries_are_stable_across_the_whole_grid() {
        // Every grid value, however it was computed — clean multiple,
        // repeated-addition drift (0.1+0.1+0.1 = 0.30000000000000004), or
        // scaled-down integer — must land in the bucket of its index, for
        // the full 16-core grid (160 buckets).
        let delta = 0.1;
        let mut acc = 0.0;
        for i in 1..=160i64 {
            acc += delta; // accumulates binary-representation drift
            let clean = i as f64 * delta;
            let scaled = (i as f64) / 10.0;
            assert_eq!(grid_bucket(acc, delta), i, "drifted {acc:.17}");
            assert_eq!(grid_bucket(clean, delta), i, "clean {clean}");
            assert_eq!(grid_bucket(scaled, delta), i, "scaled {scaled}");
        }
        // Off-grid probes bucket to the nearest cell, monotonically.
        let mut prev = grid_bucket(0.01, delta);
        for k in 1..400 {
            let r = 0.01 + k as f64 * 0.04;
            let b = grid_bucket(r, delta);
            assert!(b >= prev, "bucketing must be monotone in r");
            prev = b;
        }
    }

    #[test]
    fn boundary_drift_cannot_split_a_cache_entry() {
        // A probe at the drifted representation and a probe at the clean
        // grid value must share one entry — for every bucket of pi4's
        // grid, not just the famous 0.3 case.
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 9);
        let mut acc = 0.0;
        for _ in 0..40 {
            acc += 0.1;
            b.measure(acc, 1000);
        }
        assert_eq!(cache.len(), 40);
        assert_eq!(cache.stats().misses, 40);
        for i in 1..=40 {
            b.measure(i as f64 * 0.1, 1000);
        }
        assert_eq!(cache.len(), 40, "clean probes must not create new entries");
        assert_eq!(cache.stats().hits, 40);
    }

    #[test]
    fn concurrent_workers_account_stats_exactly() {
        // 8 workers × 100 probes over 10 buckets of one label. Regardless
        // of interleaving: every lookup is counted exactly once, the saved
        // wallclock equals hits × the (identical) cached wallclock, and
        // the map holds exactly one entry per bucket.
        let cache = MeasurementCache::new();
        let wall = 2.0;
        std::thread::scope(|s| {
            for w in 0..8usize {
                let cache = &cache;
                s.spawn(move || {
                    for k in 0..100usize {
                        let limit = 0.1 + ((k + w) % 10) as f64 * 0.1;
                        if cache.lookup("shared", limit, 0.1).is_none() {
                            cache.insert(
                                "shared",
                                0.1,
                                Measurement {
                                    limit,
                                    mean_runtime: 0.05,
                                    samples: 1000,
                                    wallclock: wall,
                                },
                            );
                        }
                        std::thread::yield_now();
                    }
                });
            }
        });
        let stats = cache.stats();
        assert_eq!(stats.hits + stats.misses, 800, "every lookup counted once");
        assert!(stats.misses >= 10, "each bucket misses at least once");
        assert!(stats.hits <= 790);
        assert_eq!(cache.len(), 10, "one entry per bucket");
        assert!(
            (stats.saved_wallclock - stats.hits as f64 * wall).abs() < 1e-9,
            "saved wallclock must equal hits x cached cost: {} vs {}",
            stats.saved_wallclock,
            stats.hits as f64 * wall
        );
        let rate = stats.hit_rate();
        assert!((0.0..=1.0).contains(&rate));
    }

    #[test]
    fn early_stop_path_shares_the_cache() {
        let cache = MeasurementCache::new();
        let mut b = backend(&cache, 5);
        let cfg = EarlyStopConfig::new(0.95, 0.10);
        let m1 = b.measure_early_stop(0.4, &cfg, 10_000);
        let m2 = b.measure_early_stop(0.4, &cfg, 10_000);
        assert_eq!(m1.mean_runtime, m2.mean_runtime);
        assert_eq!(cache.stats().hits, 1);
        // Cross-path: a plain measure at the same bucket also hits.
        let m3 = b.measure(0.4, 1000);
        assert_eq!(m3.mean_runtime, m1.mean_runtime);
        assert_eq!(cache.stats().hits, 2);
    }
}
