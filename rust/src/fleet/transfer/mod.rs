//! Transfer-learning priors: kill cold-start profiling with cross-job
//! runtime knowledge.
//!
//! Every fresh arrival used to pay a full cold profiling sweep even when
//! the fleet had already profiled a near-identical job — the exact cost the
//! paper's "short profiling phase" goal targets. This module closes that
//! gap with three pieces:
//!
//! * [`PriorCorpus`] — per-label runtime curves (probe points + fitted
//!   [`RuntimeModel`] + residual spread) assembled from the persisted
//!   [`MeasurementCache`] snapshot and from finished [`JobOutcome`]s.
//! * [`TransferSeed`] — the donor knowledge selected for one incoming
//!   [`FleetJobSpec`]: an exact-label curve when one exists, otherwise the
//!   best same-family curve translated across nodes via
//!   [`translate_model`]. `Clone + Debug`, so it rides a
//!   [`super::worker::ProfilePass`] into the probe pool.
//! * [`TransferPrior`] — a [`SessionPrior`] over the [`Gp`] module, seeded
//!   with the donor curve as pseudo-observations and recalibrated by the
//!   session's real probes. [`Profiler::run_with_prior`] probes only where
//!   its posterior stays uncertain, and its check probe turns the seed into
//!   a [`PriorVerdict`] — a mismatched donor falls back to the cold sweep
//!   at the cost of exactly the probes spent checking.
//!
//! [`Profiler::run_with_prior`]: crate::coordinator::Profiler::run_with_prior
//! [`Gp`]: crate::gp::Gp

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, Result};

use crate::coordinator::backend::Measurement;
use crate::coordinator::{PriorVerdict, SessionPrior};
use crate::fit::{ProfilePoint, RuntimeModel};
use crate::gp::{Gp, Matern52};
use crate::simulator::{node, NodeSpec};
use crate::strategies::grid_bucket;
use crate::util::json::Json;

use super::cache::{model_from_json, MeasurementCache};
use super::placement::translate_model;
use super::worker::JobOutcome;
use super::FleetJobSpec;

/// Grid width donor curves are deduplicated at — one point per cache-style
/// bucket, matching [`crate::coordinator::JobManager::DELTA`].
const CORPUS_DELTA: f64 = 0.1;

/// A donor curve must contribute at least this many pseudo-observations
/// inside the recipient's limitation range to seed a useful GP.
const MIN_DONOR_POINTS: usize = 2;

/// Floor on a donor's residual spread: even a perfectly-fitting donor
/// carries some cross-job uncertainty.
const MIN_SPREAD: f64 = 0.02;

/// The label family a donor must share with a recipient: the cache label
/// with its node prefix and any `@x` runtime-scale suffix stripped
/// (`"pi4/arima@x3"` → `"arima"`). Scaled variants stay in the family on
/// purpose — they describe the same job class in a shifted regime, and the
/// profiler's check probe is what decides whether the regime transfers.
pub fn family(label: &str) -> &str {
    let tail = label.split_once('/').map(|(_, t)| t).unwrap_or(label);
    match tail.rfind("@x") {
        Some(i) => &tail[..i],
        None => tail,
    }
}

/// Mean relative residual of `model` against `points` — the spread recorded
/// alongside each corpus curve and reused by quantile-aware planning.
fn residual_spread(model: &RuntimeModel, points: &[ProfilePoint]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for p in points {
        if p.runtime.abs() > 1e-12 {
            sum += ((model.eval(p.limit) - p.runtime) / p.runtime).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// One per-label runtime curve held by the [`PriorCorpus`].
#[derive(Clone, Debug)]
pub struct CurveRecord {
    /// The cache label the curve was measured under.
    pub label: String,
    /// Current-generation probe points, ascending by limitation.
    pub points: Vec<ProfilePoint>,
    /// Fitted runtime model for the curve.
    pub model: RuntimeModel,
    /// Mean relative residual of `model` against `points` (donor ranking
    /// key and the uncertainty a seeded GP starts from).
    pub spread: f64,
    /// Home node, when the label's node prefix names a known
    /// [`NodeSpec`] — required for cross-node donor translation.
    pub node: Option<&'static NodeSpec>,
}

/// The fleet's transfer-learning knowledge base: one [`CurveRecord`] per
/// cache label, assembled from persisted snapshots and finished job
/// outcomes. Deterministically ordered (BTreeMap) so donor selection is
/// reproducible across runs.
#[derive(Default)]
pub struct PriorCorpus {
    records: BTreeMap<String, CurveRecord>,
}

impl PriorCorpus {
    /// An empty corpus.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of labels with a usable curve.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// `true` when no curve is held.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The curve recorded for `label`, if any.
    pub fn record(&self, label: &str) -> Option<&CurveRecord> {
        self.records.get(label)
    }

    /// Build a corpus from a [`MeasurementCache`] snapshot (any supported
    /// snapshot version). Only current-generation entries contribute; a
    /// label needs at least two points to yield a curve. A v3 snapshot's
    /// per-label model metadata is used verbatim; older snapshots refit
    /// from the restored points.
    pub fn from_snapshot(snap: &Json) -> Result<Self> {
        let labels = snap
            .get("labels")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("corpus snapshot: no labels array"))?;
        let entries = snap
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("corpus snapshot: no entries array"))?;
        let mut gens: BTreeMap<String, u64> = BTreeMap::new();
        let mut models: BTreeMap<String, RuntimeModel> = BTreeMap::new();
        for doc in labels {
            let Some(name) = doc.get("label").and_then(Json::as_str) else { continue };
            let generation = doc.get("generation").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            gens.insert(name.to_string(), generation);
            if let Some(m) = doc.get("model").and_then(model_from_json) {
                models.insert(name.to_string(), m);
            }
        }
        let mut points: BTreeMap<String, Vec<ProfilePoint>> = BTreeMap::new();
        for doc in entries {
            let Some(label) = doc.get("label").and_then(Json::as_str) else { continue };
            let generation = doc.get("generation").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            if gens.get(label).copied() != Some(generation) {
                continue; // stale generation: not current knowledge
            }
            let (Some(limit), Some(runtime)) = (
                doc.get("limit").and_then(Json::as_f64),
                doc.get("mean_runtime").and_then(Json::as_f64),
            ) else {
                continue;
            };
            if limit > 0.0 && runtime.is_finite() {
                points
                    .entry(label.to_string())
                    .or_default()
                    .push(ProfilePoint::new(limit, runtime));
            }
        }
        let mut corpus = Self::new();
        for (label, mut pts) in points {
            pts.sort_by(|a, b| a.limit.partial_cmp(&b.limit).unwrap_or(std::cmp::Ordering::Equal));
            if pts.len() < MIN_DONOR_POINTS {
                continue;
            }
            let model = models.remove(&label).unwrap_or_else(|| RuntimeModel::fit(&pts));
            corpus.insert_curve(label, pts, model, None);
        }
        Ok(corpus)
    }

    /// [`PriorCorpus::from_snapshot`] over a live cache's own snapshot —
    /// how the daemon boots its corpus from a `--cache-file` restore.
    pub fn from_cache(cache: &MeasurementCache) -> Self {
        Self::from_snapshot(&cache.snapshot()).expect("a live cache snapshot is well-formed")
    }

    /// Fold a finished job into the corpus: the outcome's probe points
    /// (deduplicated per grid bucket, last round wins) under its fitted
    /// model replace any previous curve for the label. The outcome's node
    /// is recorded as the curve's home, enabling cross-node donation.
    pub fn absorb(&mut self, outcome: &JobOutcome) {
        let mut by_bucket: BTreeMap<i64, ProfilePoint> = BTreeMap::new();
        for session in &outcome.rounds {
            for step in &session.steps {
                if step.limit > 0.0 && step.mean_runtime.is_finite() {
                    by_bucket.insert(
                        grid_bucket(step.limit, CORPUS_DELTA),
                        ProfilePoint::new(step.limit, step.mean_runtime),
                    );
                }
            }
        }
        let pts: Vec<ProfilePoint> = by_bucket.into_values().collect();
        if pts.len() < MIN_DONOR_POINTS {
            return;
        }
        self.insert_curve(outcome.label.clone(), pts, outcome.model.clone(), Some(outcome.node));
    }

    fn insert_curve(
        &mut self,
        label: String,
        points: Vec<ProfilePoint>,
        model: RuntimeModel,
        home: Option<&'static NodeSpec>,
    ) {
        let spread = residual_spread(&model, &points);
        // Fall back to the label's node prefix when the caller has no
        // authoritative home (snapshot-restored curves).
        let node = home.or_else(|| label.split_once('/').and_then(|(head, _)| node(head)));
        self.records.insert(label.clone(), CurveRecord { label, points, model, spread, node });
    }

    /// Select the donor curve for an incoming job, or `None` when the
    /// corpus holds nothing transferable.
    ///
    /// Preference order: an exact-label curve (used untranslated — the
    /// label *is* the behaviour key), else the same-[`family`] curve with
    /// the smallest residual spread whose home node is known and whose
    /// points overlap the shared limitation range, translated to the
    /// recipient's node via [`translate_model`]. Pseudo-observations are
    /// the donor's probe limits (clipped to the shared range) evaluated
    /// under the translated model, so seed points and seed model agree.
    pub fn donor_for(&self, spec: &FleetJobSpec) -> Option<TransferSeed> {
        let label = spec.label();
        let cap = spec.node.l_max();
        if let Some(r) = self.records.get(&label) {
            if let Some(seed) = seed_from(r, r.model.clone(), false, cap) {
                return Some(seed);
            }
        }
        let fam = family(&label).to_string();
        let mut best: Option<(&CurveRecord, RuntimeModel, f64)> = None;
        for r in self.records.values() {
            if r.label == label || family(&r.label) != fam {
                continue;
            }
            let Some(from) = r.node else { continue };
            let shared = from.l_max().min(cap);
            let usable = r.points.iter().filter(|p| p.limit <= shared + 1e-9).count();
            if usable < MIN_DONOR_POINTS {
                continue;
            }
            let keep = match &best {
                None => true,
                Some((b, _, _)) => (r.spread, r.label.as_str()) < (b.spread, b.label.as_str()),
            };
            if keep {
                best = Some((r, translate_model(&r.model, from, spec.node), shared));
            }
        }
        best.and_then(|(r, m, shared)| seed_from(r, m, true, shared))
    }
}

fn seed_from(
    record: &CurveRecord,
    model: RuntimeModel,
    translated: bool,
    cap: f64,
) -> Option<TransferSeed> {
    let mut seen = BTreeSet::new();
    let mut points = Vec::new();
    for p in &record.points {
        if p.limit > cap + 1e-9 || !seen.insert(grid_bucket(p.limit, CORPUS_DELTA)) {
            continue;
        }
        let y = model.eval(p.limit);
        if y.is_finite() && y > 0.0 {
            points.push((p.limit, y));
        }
    }
    (points.len() >= MIN_DONOR_POINTS).then(|| TransferSeed {
        donor: record.label.clone(),
        translated,
        model,
        points,
        spread: record.spread.max(MIN_SPREAD),
    })
}

/// The donor knowledge selected for one incoming job — everything a
/// [`TransferPrior`] needs, in a `Clone + Debug` package that can ride a
/// [`super::worker::ProfilePass`] into the probe pool (the GP itself is
/// rebuilt per session).
#[derive(Clone, Debug)]
pub struct TransferSeed {
    /// Label of the donor curve.
    pub donor: String,
    /// `true` when the donor lived on a different node and the model was
    /// translated via [`translate_model`].
    pub translated: bool,
    /// Donor model on the recipient's node.
    pub model: RuntimeModel,
    /// Pseudo-observations `(limit, runtime)` on the recipient's node,
    /// ascending by limit, one per grid bucket.
    pub points: Vec<(f64, f64)>,
    /// Donor residual spread (floored at the corpus minimum) — sets the GP
    /// observation noise, so a sloppier donor starts less confident.
    pub spread: f64,
}

/// A [`SessionPrior`] over the GP substrate, seeded from a donor curve.
///
/// The GP conditions on **log**-runtimes (noise = spread²), so its
/// posterior sd is a *relative* spread — uniform across the curve's
/// exponential head and flat tail — and the profiler's `sd / mean`
/// confidence gate behaves the same at every limitation. Predictions are
/// mapped back through `exp` (the posterior median of the implied
/// lognormal). The session's first real probe sets a multiplicative
/// calibration (observed / predicted at the check limit); every real probe
/// then replaces the pseudo-observation in its grid bucket and the GP
/// refits, so the posterior tightens exactly where the session has looked.
pub struct TransferPrior {
    seed: TransferSeed,
    delta: f64,
    calibration: f64,
    observed: Vec<(f64, f64)>,
    gp: Gp,
}

impl TransferPrior {
    /// Build the prior for a session over `[0, l_max]` with probe grid
    /// width `delta`. `l_max` is the recipient backend's limit ceiling;
    /// seed points beyond it only widen the GP's input scaling.
    pub fn new(seed: TransferSeed, l_max: f64, delta: f64) -> Self {
        let hi = seed.points.iter().map(|&(x, _)| x).fold(l_max, f64::max).max(1e-6);
        // spread² as Gaussian observation noise, floored so the kernel
        // matrix stays strictly positive definite.
        let noise = (seed.spread * seed.spread).clamp(1e-4, 0.25);
        let gp = Gp::new(Matern52::default(), noise, 0.0, hi);
        let mut prior =
            Self { seed, delta: delta.max(1e-6), calibration: 1.0, observed: Vec::new(), gp };
        prior.refit();
        prior
    }

    /// The seed the prior was built from.
    pub fn seed(&self) -> &TransferSeed {
        &self.seed
    }

    /// Current multiplicative calibration (1.0 until the first real probe).
    pub fn calibration(&self) -> f64 {
        self.calibration
    }

    /// Posterior runtime quantile at limitation `x` — e.g. `q = 0.95` is
    /// the p95 runtime that quantile-aware capacity planning provisions
    /// for instead of the mean. Computed on the log-GP posterior and
    /// mapped back (quantiles commute with monotone transforms).
    pub fn predict_quantile(&self, x: f64, q: f64) -> f64 {
        self.gp.predict_quantile(x, q).exp()
    }

    fn refit(&mut self) {
        let taken: BTreeSet<i64> =
            self.observed.iter().map(|&(x, _)| grid_bucket(x, self.delta)).collect();
        let mut pts: Vec<(f64, f64)> = Vec::with_capacity(self.observed.len());
        for &(x, y) in &self.observed {
            if y > 0.0 {
                pts.push((x, y.ln()));
            }
        }
        for &(x, y) in &self.seed.points {
            // Real probes displace the pseudo-observation in their bucket;
            // the rest are carried at the current calibration.
            if !taken.contains(&grid_bucket(x, self.delta)) {
                pts.push((x, (y * self.calibration).ln()));
            }
        }
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        self.gp.fit(&pts);
    }
}

impl SessionPrior for TransferPrior {
    fn mean(&self, x: f64) -> f64 {
        self.gp.predict(x).0.exp()
    }

    fn sd(&self, x: f64) -> f64 {
        // Relative log-spread times the predicted magnitude: the profiler's
        // `sd / mean` gate then reads the log-sd directly.
        self.mean(x) * self.gp.predict_sd(x)
    }

    fn observe(&mut self, m: &Measurement) {
        if self.observed.is_empty() {
            let pred = self.seed.model.eval(m.limit);
            if pred.is_finite() && pred > 1e-12 && m.mean_runtime.is_finite() && m.mean_runtime > 0.0
            {
                self.calibration = (m.mean_runtime / pred).clamp(0.25, 4.0);
            }
        }
        let bucket = grid_bucket(m.limit, self.delta);
        match self.observed.iter().position(|&(x, _)| grid_bucket(x, self.delta) == bucket) {
            Some(i) => self.observed[i] = (m.limit, m.mean_runtime),
            None => self.observed.push((m.limit, m.mean_runtime)),
        }
        self.refit();
    }

    fn model(&self) -> RuntimeModel {
        self.seed.model.rescaled(self.calibration)
    }
}

/// How a transfer-primed profile used its donor — recorded on the
/// [`JobOutcome`] and journaled by the daemon.
#[derive(Clone, Debug)]
pub struct TransferOutcome {
    /// Label of the donor curve the session was primed from.
    pub donor: String,
    /// Whether the donor was translated across nodes.
    pub translated: bool,
    /// The profiler's verdict on the prior.
    pub verdict: PriorVerdict,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{PriorGate, Profiler, ProfilerConfig};
    use crate::fleet::cache::MeasurementCache;
    use crate::fleet::worker::profile_job;
    use crate::fleet::{FleetConfig, FleetJobSpec};
    use crate::simulator::Algo;
    use crate::strategies;

    fn one_cfg() -> FleetConfig {
        FleetConfig { workers: 1, rounds: 1, ..FleetConfig::default() }
    }

    #[test]
    fn family_strips_node_prefix_and_scale_suffix() {
        assert_eq!(family("pi4/arima"), "arima");
        assert_eq!(family("wally/arima"), "arima");
        assert_eq!(family("pi4/arima@x3"), "arima");
        assert_eq!(family("plain"), "plain");
    }

    #[test]
    fn exact_donor_comes_back_untranslated() {
        let cache = MeasurementCache::new();
        let spec = FleetJobSpec::simulated("donor", node("pi4").unwrap(), Algo::Arima, 11);
        let outcome = profile_job(&spec, &one_cfg(), &cache, 0).unwrap();
        let mut corpus = PriorCorpus::new();
        corpus.absorb(&outcome);
        assert_eq!(corpus.len(), 1);
        let seed = corpus.donor_for(&spec).expect("exact donor");
        assert_eq!(seed.donor, spec.label());
        assert!(!seed.translated);
        assert!(seed.points.len() >= MIN_DONOR_POINTS);
        for &(x, y) in &seed.points {
            assert!((y - seed.model.eval(x)).abs() < 1e-9, "seed points track the seed model");
        }
    }

    #[test]
    fn family_donor_translates_across_nodes() {
        let wally = node("wally").unwrap();
        let pi4 = node("pi4").unwrap();
        let cache = MeasurementCache::new();
        let donor_spec = FleetJobSpec::simulated("donor", wally, Algo::Arima, 7);
        let outcome = profile_job(&donor_spec, &one_cfg(), &cache, 0).unwrap();
        let mut corpus = PriorCorpus::new();
        corpus.absorb(&outcome);
        let recipient = FleetJobSpec::simulated("recipient", pi4, Algo::Arima, 9);
        let seed = corpus.donor_for(&recipient).expect("family donor");
        assert_eq!(seed.donor, donor_spec.label());
        assert!(seed.translated);
        let expected = translate_model(&outcome.model, wally, pi4);
        for &r in &[0.5f64, 1.0, 2.0] {
            assert!((seed.model.eval(r) - expected.eval(r)).abs() < 1e-9, "at {r}");
        }
        for &(x, _) in &seed.points {
            assert!(x <= pi4.l_max() + 1e-9, "pseudo points stay in the shared range");
        }
    }

    #[test]
    fn no_family_match_returns_none() {
        let cache = MeasurementCache::new();
        let donor = FleetJobSpec::simulated("donor", node("pi4").unwrap(), Algo::Arima, 3);
        let outcome = profile_job(&donor, &one_cfg(), &cache, 0).unwrap();
        let mut corpus = PriorCorpus::new();
        corpus.absorb(&outcome);
        let other = FleetJobSpec::simulated("other", node("pi4").unwrap(), Algo::Birch, 4);
        assert!(corpus.donor_for(&other).is_none());
    }

    #[test]
    fn corpus_from_cache_snapshot_uses_the_noted_model() {
        let cache = MeasurementCache::new();
        let spec = FleetJobSpec::simulated("snap", node("xeon").unwrap(), Algo::Arima, 5);
        let outcome = profile_job(&spec, &one_cfg(), &cache, 0).unwrap();
        cache.note_model(&spec.label(), &outcome.model);
        let corpus = PriorCorpus::from_cache(&cache);
        let record = corpus.record(&spec.label()).expect("label restored");
        assert!(record.points.len() >= MIN_DONOR_POINTS);
        for &r in &[0.5f64, 1.0, 2.0] {
            assert!(
                (record.model.eval(r) - outcome.model.eval(r)).abs() < 1e-12,
                "v3 model metadata restores verbatim at {r}"
            );
        }
        assert_eq!(record.node.map(|n| n.name), Some("xeon"));
    }

    #[test]
    fn calibration_rescales_the_prior_model() {
        let cache = MeasurementCache::new();
        let spec = FleetJobSpec::simulated("cal", node("pi4").unwrap(), Algo::Arima, 13);
        let outcome = profile_job(&spec, &one_cfg(), &cache, 0).unwrap();
        let mut corpus = PriorCorpus::new();
        corpus.absorb(&outcome);
        let seed = corpus.donor_for(&spec).unwrap();
        let mut prior = TransferPrior::new(seed.clone(), spec.node.l_max(), 0.1);
        let m = Measurement {
            limit: 0.5,
            mean_runtime: seed.model.eval(0.5) * 1.3,
            samples: 100,
            wallclock: 1.0,
        };
        prior.observe(&m);
        assert!((prior.calibration() - 1.3).abs() < 1e-9);
        assert!((prior.model().eval(2.0) - 1.3 * seed.model.eval(2.0)).abs() < 1e-9);
        let rel = (prior.mean(0.5) - m.mean_runtime).abs() / m.mean_runtime;
        assert!(rel < 0.1, "posterior tracks the real probe: {rel}");
    }

    #[test]
    fn quantiles_order_around_the_posterior_mean() {
        let cache = MeasurementCache::new();
        let spec = FleetJobSpec::simulated("q", node("pi4").unwrap(), Algo::Arima, 29);
        let outcome = profile_job(&spec, &one_cfg(), &cache, 0).unwrap();
        let mut corpus = PriorCorpus::new();
        corpus.absorb(&outcome);
        let seed = corpus.donor_for(&spec).unwrap();
        let prior = TransferPrior::new(seed, spec.node.l_max(), 0.1);
        for &x in &[0.5f64, 1.5, 3.0] {
            let p05 = prior.predict_quantile(x, 0.05);
            let p95 = prior.predict_quantile(x, 0.95);
            let mu = prior.mean(x);
            assert!(p05 < mu && mu < p95, "at {x}: {p05} {mu} {p95}");
        }
    }

    #[test]
    fn primed_session_spends_fewer_probes_and_mismatch_rejects() {
        let spec = FleetJobSpec::simulated("prime", node("pi4").unwrap(), Algo::Arima, 21);
        let cfg = ProfilerConfig { samples: 400, ..ProfilerConfig::default() };
        let run_cold = || {
            let mut backend = spec.backend.build().unwrap();
            Profiler::new(cfg.clone(), strategies::by_name("nms", spec.seed).unwrap())
                .run(&mut *backend)
        };
        let cold = run_cold();

        // Donor = the cold session's own curve (the best possible prior).
        let mut corpus = PriorCorpus::new();
        let cache = MeasurementCache::new();
        let outcome = profile_job(&spec, &one_cfg(), &cache, 0).unwrap();
        corpus.absorb(&outcome);
        let seed = corpus.donor_for(&spec).unwrap();

        let mut backend = spec.backend.build().unwrap();
        let mut prior = TransferPrior::new(seed.clone(), spec.node.l_max(), cfg.delta);
        let mut profiler = Profiler::new(cfg.clone(), strategies::by_name("nms", spec.seed).unwrap());
        let (primed, verdict) =
            profiler.run_with_prior(&mut *backend, &mut |_| {}, &mut prior, &PriorGate::default());
        assert!(
            matches!(verdict, PriorVerdict::Adopted | PriorVerdict::Tempered),
            "a same-label donor must not be rejected: {verdict:?}"
        );
        assert!(
            primed.steps.len() < cold.steps.len(),
            "primed {} probes vs cold {}",
            primed.steps.len(),
            cold.steps.len()
        );

        // Regime-shifted donor (3x runtimes): rejected, and the fallback is
        // the cold sweep with the check probe reused — same probe count.
        let mut wrong = seed.clone();
        wrong.model = wrong.model.rescaled(3.0);
        for p in &mut wrong.points {
            p.1 *= 3.0;
        }
        let mut backend = spec.backend.build().unwrap();
        let mut prior = TransferPrior::new(wrong, spec.node.l_max(), cfg.delta);
        let mut profiler = Profiler::new(cfg.clone(), strategies::by_name("nms", spec.seed).unwrap());
        let (fallback, verdict) =
            profiler.run_with_prior(&mut *backend, &mut |_| {}, &mut prior, &PriorGate::default());
        assert_eq!(verdict, PriorVerdict::Rejected);
        assert_eq!(fallback.steps.len(), cold.steps.len(), "mismatch costs exactly cold");
        for (f, c) in fallback.steps.iter().zip(&cold.steps) {
            assert_eq!(f.limit.to_bits(), c.limit.to_bits(), "fallback replays the cold sweep");
        }
    }
}
