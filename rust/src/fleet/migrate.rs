//! Shed-job migration: turn per-node capacity plans into a fleet plan.
//!
//! The per-node [`JobManager`] resolves over-subscription by shedding its
//! lowest-priority jobs to best-effort — locally optimal, fleet-wide
//! wasteful when another node has idle capacity. The rebalancer closes
//! that gap (LOS, arXiv 2109.13009, schedules periodic stream-ML work
//! across meshed edge nodes the same way — from local capacity knowledge):
//!
//! 1. plan every node and collect the shed (non-guaranteed) jobs,
//! 2. order them by priority (desc) with the job name as deterministic
//!    tie-break, so higher-priority shed jobs get first pick of capacity,
//! 3. for each, score candidate destinations by slack
//!    ([`candidates_for`]) and migrate into the best one,
//! 4. stop when no feasible move remains.
//!
//! A migrated job is admitted through [`JobManager::try_accept`], which
//! only grants limits from *residual* capacity — so a migration can never
//! displace a job that was already guaranteed anywhere, and in particular
//! a lower-priority migrant can never push out a higher-priority job. A
//! destination whose own baseline-shed jobs outrank the migrant can crowd
//! it back out when the node re-plans; such moves are rolled back and the
//! next candidate is tried. The shed set is fixed up front and residuals
//! only shrink, so one pass over the ordered shed jobs reaches the
//! fixpoint.
//!
//! Both front-ends reuse this pass unchanged: the batch session runs it
//! once after the sweep, and the event-driven [`super::FleetDaemon`] runs
//! it at the end of every coalesced replan — each localized replan ends
//! with a fresh fleet-wide [`FleetPlan`], so mid-stream arrivals and
//! drift verdicts can trigger migrations too.

use std::collections::BTreeMap;

use crate::coordinator::{Assignment, CapacityPlan, JobManager, ManagedJob};
use crate::simulator::NodeSpec;

use super::placement::{candidates_for, translate_model, FleetJob};

/// One applied migration.
#[derive(Clone, Debug)]
pub struct Migration {
    pub job: String,
    pub from: &'static str,
    pub to: &'static str,
    pub priority: i32,
    /// CPU limit granted on the destination (translated model).
    pub limit: f64,
    /// Destination residual capacity after the move.
    pub slack_after: f64,
    /// True when the granted limit lies outside the home/destination
    /// shared limit range — the translated model extrapolated, so the
    /// destination should re-profile before the limit is trusted (see
    /// [`super::placement::PlacementCandidate::needs_reprofile`]).
    pub needs_reprofile: bool,
}

/// Fleet-wide utilization / guarantee metrics of a [`FleetPlan`].
#[derive(Clone, Copy, Debug)]
pub struct FleetMetrics {
    pub jobs: usize,
    /// Guaranteed jobs before any migration (per-node planning only).
    pub guaranteed_before: usize,
    /// Guaranteed jobs in the final plan.
    pub guaranteed_after: usize,
    pub total_capacity: f64,
    /// Sum of guaranteed limits across the fleet.
    pub total_assigned: f64,
}

impl FleetMetrics {
    /// Fraction of fleet capacity committed to guaranteed jobs.
    pub fn utilization(&self) -> f64 {
        if self.total_capacity <= 0.0 {
            0.0
        } else {
            self.total_assigned / self.total_capacity
        }
    }

    /// Fraction of jobs served just-in-time after rebalancing.
    pub fn guarantee_rate(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.guaranteed_after as f64 / self.jobs as f64
        }
    }
}

/// Fleet-wide placement outcome: final per-node plans, the migration log,
/// and aggregate metrics.
#[derive(Clone, Debug)]
pub struct FleetPlan {
    /// Final per-node capacity plans, keyed by node name (sorted). Nodes
    /// with no jobs appear with an empty plan (visible idle capacity).
    pub plans: Vec<(String, CapacityPlan)>,
    /// Migrations in application order.
    pub migrations: Vec<Migration>,
    pub metrics: FleetMetrics,
}

impl FleetPlan {
    /// The final assignment for a job, with the node it landed on.
    pub fn assignment(&self, job: &str) -> Option<(&str, &Assignment)> {
        for (node, plan) in &self.plans {
            if let Some(a) = plan.assignments.iter().find(|a| a.name == job) {
                return Some((node.as_str(), a));
            }
        }
        None
    }

    /// The final plan of one node.
    pub fn node_plan(&self, node: &str) -> Option<&CapacityPlan> {
        self.plans.iter().find(|(n, _)| n == node).map(|(_, p)| p)
    }

    /// Jobs guaranteed in the final plan, sorted by name.
    pub fn guaranteed_jobs(&self) -> Vec<&str> {
        let mut out: Vec<&str> = self
            .plans
            .iter()
            .flat_map(|(_, p)| p.assignments.iter())
            .filter(|a| a.guaranteed)
            .map(|a| a.name.as_str())
            .collect();
        out.sort_unstable();
        out
    }
}

/// Rebalance across exactly the nodes that appear as some job's home.
pub fn rebalance(jobs: &[FleetJob]) -> FleetPlan {
    rebalance_across(jobs, &[])
}

/// Rebalance with an explicit additional node roster: `extra_nodes` are
/// available as migration destinations even when no job lives there yet
/// (a fresh fog node joining the fleet). Home nodes are always included.
pub fn rebalance_across(jobs: &[FleetJob], extra_nodes: &[&'static NodeSpec]) -> FleetPlan {
    let mut managers: BTreeMap<&'static str, (&'static NodeSpec, JobManager)> = BTreeMap::new();
    for &spec in extra_nodes {
        managers
            .entry(spec.name)
            .or_insert_with(|| (spec, JobManager::new(spec.cores)));
    }
    for job in jobs {
        let (_, mgr) = managers
            .entry(job.node.name)
            .or_insert_with(|| (job.node, JobManager::new(job.node.cores)));
        mgr.register(ManagedJob {
            name: job.name.clone(),
            model: job.model.clone(),
            rate_hz: job.rate_hz,
            priority: job.priority,
        });
    }

    // Baseline: per-node planning only. Collect the shed set. Jobs are
    // resolved by (name, home node) so a name collision across nodes can
    // never map a shed assignment onto the wrong job; within one node,
    // `register` keeps the last same-named spec, so resolve from the back
    // to pick the job the manager actually planned.
    let mut guaranteed_before = 0usize;
    let mut shed: Vec<&FleetJob> = Vec::new();
    for (&home, (_, mgr)) in &managers {
        for a in mgr.plan().assignments {
            if a.guaranteed {
                guaranteed_before += 1;
                continue;
            }
            let lost = jobs
                .iter()
                .rev()
                .find(|j| j.name == a.name && j.node.name == home);
            if let Some(job) = lost {
                shed.push(job);
            }
        }
    }
    // Higher priority first; name breaks ties deterministically.
    shed.sort_by(|x, y| y.priority.cmp(&x.priority).then_with(|| x.name.cmp(&y.name)));

    let mut migrations: Vec<Migration> = Vec::new();
    for job in shed {
        // Candidates best-first; a job with no feasible (or no sticking)
        // move stays best-effort at home.
        for cand in candidates_for(job, &managers) {
            let dest_spec = managers[cand.node].0;
            let translated = translate_model(&job.model, job.node, dest_spec);
            let dest = &mut managers.get_mut(cand.node).expect("candidate node exists").1;
            let accepted = dest.try_accept(ManagedJob {
                name: job.name.clone(),
                model: translated,
                rate_hz: job.rate_hz,
                priority: job.priority,
            });
            let Some(granted) = accepted else {
                continue;
            };
            // The destination re-plans from scratch, and a pre-existing
            // shed job with higher priority there can crowd the migrant
            // straight back out of the guaranteed set — roll such no-op
            // moves back and try the next candidate.
            let kept = dest
                .plan()
                .assignments
                .iter()
                .any(|a| a.name == job.name && a.guaranteed);
            if !kept {
                dest.deregister(&job.name);
                continue;
            }
            let slack_after = dest.residual_capacity();
            managers
                .get_mut(job.node.name)
                .expect("home node has a manager")
                .1
                .deregister(&job.name);
            migrations.push(Migration {
                job: job.name.clone(),
                from: job.node.name,
                to: cand.node,
                priority: job.priority,
                limit: granted,
                slack_after,
                needs_reprofile: cand.needs_reprofile,
            });
            break;
        }
    }

    let plans: Vec<(String, CapacityPlan)> = managers
        .iter()
        .map(|(&name, (_, mgr))| (name.to_string(), mgr.plan()))
        .collect();
    let guaranteed_after = plans
        .iter()
        .flat_map(|(_, p)| p.assignments.iter())
        .filter(|a| a.guaranteed)
        .count();
    let metrics = FleetMetrics {
        // Count registered jobs from the final plans (every job appears in
        // exactly one), not the input slice — immune to duplicate specs.
        jobs: plans.iter().map(|(_, p)| p.assignments.len()).sum(),
        guaranteed_before,
        guaranteed_after,
        total_capacity: plans.iter().map(|(_, p)| p.capacity).sum(),
        total_assigned: plans.iter().map(|(_, p)| p.total_assigned).sum(),
    };
    FleetPlan { plans, migrations, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{ModelKind, RuntimeModel};
    use crate::simulator::node;

    fn model(a: f64, b: f64) -> RuntimeModel {
        RuntimeModel { kind: ModelKind::Full, a, b, c: 0.001, d: 1.0, fit_cost: 0.0 }
    }

    fn job(name: &str, home: &'static NodeSpec, a: f64, rate: f64, prio: i32) -> FleetJob {
        FleetJob {
            name: name.into(),
            node: home,
            // Exponent = the home node's calibrated scaling, so translation
            // behaves exactly as for a fleet-fitted model.
            model: model(a, home.scaling),
            rate_hz: rate,
            priority: prio,
        }
    }

    /// Five identical jobs on n1 (1 core) needing ~0.6 CPU each at 10 Hz:
    /// one stays guaranteed, four shed. wally (8 idle cores, ~3x faster)
    /// can take them all.
    fn oversubscribed_fleet() -> Vec<FleetJob> {
        let n1 = node("n1").unwrap();
        let wally = node("wally").unwrap();
        let mut jobs: Vec<FleetJob> = (0..5usize)
            .map(|i| job(&format!("edge-{i}"), n1, 0.05, 10.0, 1 + (i % 2) as i32))
            .collect();
        jobs.push(job("anchor", wally, 0.05, 2.0, 5));
        jobs
    }

    #[test]
    fn migrations_rescue_shed_jobs() {
        let jobs = oversubscribed_fleet();
        let plan = rebalance(&jobs);
        assert!(
            plan.metrics.guaranteed_after > plan.metrics.guaranteed_before,
            "{:?}",
            plan.metrics
        );
        assert!(!plan.migrations.is_empty());
        for m in &plan.migrations {
            assert_eq!(m.from, "n1");
            assert_eq!(m.to, "wally");
            assert!(m.limit > 0.0 && m.slack_after >= -1e-9);
            assert!(!m.needs_reprofile, "limits stay inside n1/wally's shared range");
        }
        // Every migrated job is guaranteed at its destination.
        for m in &plan.migrations {
            let (node_name, a) = plan.assignment(&m.job).unwrap();
            assert_eq!(node_name, m.to);
            assert!(a.guaranteed, "{} migrated but not guaranteed", m.job);
        }
    }

    #[test]
    fn no_node_plan_exceeds_capacity() {
        let plan = rebalance(&oversubscribed_fleet());
        for (name, p) in &plan.plans {
            assert!(p.total_assigned <= p.capacity + 1e-9, "{name} over capacity");
        }
    }

    #[test]
    fn guaranteed_jobs_never_regress() {
        let jobs = oversubscribed_fleet();
        // Baseline: per-node planning only (no cross-node roster).
        let baseline = rebalance_across(&jobs[..0], &[]); // empty fleet sanity
        assert_eq!(baseline.metrics.jobs, 0);
        let plan = rebalance(&jobs);
        // "anchor" was guaranteed on wally before; still guaranteed after.
        let (_, anchor) = plan.assignment("anchor").unwrap();
        assert!(anchor.guaranteed);
    }

    #[test]
    fn higher_priority_shed_jobs_pick_first() {
        // Destination capacity for only ~2 migrants: the priority-2 shed
        // jobs must win the slots over the priority-1 ones.
        let n1 = node("n1").unwrap();
        let e2high = node("e2high").unwrap();
        let mut jobs: Vec<FleetJob> = (0..5usize)
            .map(|i| job(&format!("edge-{i}"), n1, 0.05, 10.0, 1 + (i % 2) as i32))
            .collect();
        // e2high: 2 cores, speed 0.9 vs n1's 0.7 -> each migrant needs
        // ~0.4-0.5 CPU; ballast eats most of one core.
        jobs.push(job("ballast", e2high, 0.05, 8.0, 3));
        let plan = rebalance(&jobs);
        let migrated_prios: Vec<i32> = plan.migrations.iter().map(|m| m.priority).collect();
        // The scenario must actually be capacity-constrained, or the
        // ordering property below would be vacuous: ballast (0.5) leaves
        // 1.5 CPUs, each migrant needs 0.4 -> exactly 3 of 4 fit.
        assert!(
            !migrated_prios.is_empty() && migrated_prios.len() < 4,
            "scenario must migrate some but not all shed jobs: {migrated_prios:?}"
        );
        // Not everyone fit: no migrated job may have lower priority than a
        // shed job left behind.
        let left_behind_max = plan
            .plans
            .iter()
            .flat_map(|(_, p)| p.assignments.iter())
            .filter(|a| !a.guaranteed)
            .map(|a| {
                jobs.iter()
                    .find(|j| j.name == a.name)
                    .map(|j| j.priority)
                    .unwrap_or(i32::MIN)
            })
            .max()
            .unwrap_or(i32::MIN);
        let migrated_min = migrated_prios.iter().copied().min().unwrap_or(i32::MAX);
        assert!(
            migrated_min >= left_behind_max,
            "lower-priority job migrated while higher-priority stayed shed"
        );
    }

    #[test]
    fn extra_nodes_open_new_destinations() {
        let n1 = node("n1").unwrap();
        let e216 = node("e216").unwrap();
        let jobs: Vec<FleetJob> = (0..5usize)
            .map(|i| job(&format!("edge-{i}"), n1, 0.05, 10.0, 1))
            .collect();
        let local_only = rebalance(&jobs);
        assert!(local_only.migrations.is_empty(), "single node: nowhere to go");
        let with_roster = rebalance_across(&jobs, &[e216]);
        assert!(!with_roster.migrations.is_empty());
        assert!(with_roster.migrations.iter().all(|m| m.to == "e216"));
        assert!(with_roster.metrics.guaranteed_after > local_only.metrics.guaranteed_after);
        // The empty destination shows up in the plan roster either way.
        assert!(with_roster.node_plan("e216").is_some());
    }

    #[test]
    fn crowded_out_migrant_is_rolled_back() {
        // Destination e2high: "a" (prio 5) guaranteed at 1.1, "x" (prio 3)
        // shed — residual 0.9. The migrant (prio 1, needs 0.4) fits the
        // residual, but re-planning sheds the lowest priority first, so
        // the migrant is crowded straight back out: the move must be
        // rolled back, leaving the fleet exactly at its baseline.
        let n1 = node("n1").unwrap();
        let e2high = node("e2high").unwrap();
        let jobs = vec![
            job("keeper", n1, 0.05, 10.0, 5),
            job("migrant", n1, 0.05, 10.0, 1),
            job("a", e2high, 0.05, 18.0, 5),
            job("x", e2high, 0.05, 18.0, 3),
        ];
        let plan = rebalance(&jobs);
        assert!(plan.migrations.is_empty(), "crowded move must roll back");
        assert_eq!(plan.metrics.guaranteed_after, plan.metrics.guaranteed_before);
        let (home, m) = plan.assignment("migrant").unwrap();
        assert_eq!(home, "n1", "rolled-back migrant stays registered at home");
        assert!(!m.guaranteed);
        // The destination was left untouched: "a" guaranteed, "x" shed.
        let dest = plan.node_plan("e2high").unwrap();
        assert_eq!(dest.assignments.len(), 2);
        let by = |n: &str| dest.assignments.iter().find(|a| a.name == n).unwrap();
        assert!(by("a").guaranteed);
        assert!(!by("x").guaranteed);
    }

    #[test]
    fn rebalance_is_deterministic() {
        let jobs = oversubscribed_fleet();
        let a = rebalance(&jobs);
        let b = rebalance(&jobs);
        assert_eq!(a.migrations.len(), b.migrations.len());
        for (x, y) in a.migrations.iter().zip(&b.migrations) {
            assert_eq!(x.job, y.job);
            assert_eq!(x.to, y.to);
            assert!((x.limit - y.limit).abs() < 1e-12);
        }
        assert_eq!(a.guaranteed_jobs(), b.guaranteed_jobs());
    }

    #[test]
    fn infeasible_everywhere_stays_home() {
        let n1 = node("n1").unwrap();
        let pi4 = node("pi4").unwrap();
        // 1 kHz stream: impossible on any machine.
        let jobs = vec![job("firehose", n1, 0.05, 1000.0, 5)];
        let plan = rebalance_across(&jobs, &[pi4]);
        assert!(plan.migrations.is_empty());
        let (home, a) = plan.assignment("firehose").unwrap();
        assert_eq!(home, "n1");
        assert!(!a.guaranteed);
    }
}
