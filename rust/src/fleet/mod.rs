//! Fleet-scale concurrent profiling engine.
//!
//! The single-job [`crate::coordinator::Profiler`] becomes a worker task:
//! N registered stream jobs are sharded across a persistent [`ProbePool`]
//! of worker threads pulling from a shared striped [`WorkQueue`], all
//! probing through one [`MeasurementCache`] keyed by `(job label,
//! cpu-limit bucket)` so repeated strategy probes — re-profiling rounds,
//! and replicas of a job class on the same device type — reuse observed
//! runtimes instead of re-executing the job. Each job's
//! [`crate::fit::RuntimeModel`] is refit *incrementally* (warm-started
//! from the previous parameters) as measurements land, and the finished
//! models feed straight into per-node [`JobManager`] registrations,
//! producing the fleet-wide [`CapacityPlan`]s that close the paper's
//! adaptive-adjustment loop.
//!
//! ```text
//!  FleetJobSpec*N ──► ProbePool::dispatch ──► WorkQueue lane (seq % workers)
//!                                               │  persistent workers (condvar-parked)
//!                                               │  Profiler::run_observed
//!                                               │   ├─ BackendFactory::build ─► CachedBackend
//!                                               │   │      ─► cache (sharded)
//!                                               │   └─ IncrementalModel (warm refits)
//!                                               ▼
//!                                 results[seq] ──► collect in dispatch order
//!                                               ▼
//!                                            JobOutcome*N ──► per-node JobManager ──► CapacityPlan
//! ```
//!
//! ## The session and daemon APIs
//!
//! [`FleetSession`] is the batch entry point: one composable pipeline
//! that runs the sweep and optionally layers rebalancing and the adaptive
//! drift loop on top, over **any** [`BackendFactory`] — the paper's
//! black-box claim made a type-level contract:
//!
//! ```no_run
//! use streamprof::fleet::{sim_fleet, AdaptiveConfig, FleetSession};
//!
//! let report = FleetSession::builder()
//!     .jobs(sim_fleet(12, 7))
//!     .rebalance(true)
//!     .adaptive(AdaptiveConfig::default())
//!     .run()?;
//! # anyhow::Ok(())
//! ```
//!
//! [`FleetDaemon`] is the long-lived, event-driven form of the same
//! engine: jobs arrive and retire mid-run, drift verdicts trigger
//! localized replans, and the whole schedule plays out on a deterministic
//! virtual clock. The session is a thin wrapper that replays its roster
//! as arrivals at `t = 0` and drains the daemon, so the two are
//! equivalent by construction.
//!
//! On top of the one-shot sweep, the [`drift`] module runs the fleet
//! *continuously*: the adaptive stage monitors every job's
//! observed-vs-predicted runtime and stream rate, re-profiles only jobs
//! whose [`DriftVerdict`] crosses a threshold, and ages the measurement
//! cache by label generation so stale observations are never replayed.

pub mod cache;
pub mod daemon;
pub mod drift;
pub mod gossip;
pub mod mesh;
pub mod migrate;
pub mod placement;
pub mod pool;
pub mod queue;
pub mod session;
pub mod telemetry;
pub mod transfer;
pub mod worker;

// The factory abstraction lives with the backends (coordinator); it is
// re-exported here because it is fleet vocabulary.
pub use crate::coordinator::backend::{BackendFactory, EngineBackendFactory, SimBackendFactory};

pub use cache::{CacheStats, CachedBackend, MeasurementCache, RestoreOutcome};
pub use daemon::{
    journal_json, DaemonMetrics, FleetDaemon, FleetDaemonBuilder, FleetEvent, JournalEntry,
};
pub use drift::{
    model_fingerprint, AdaptiveConfig, AdaptiveJobReport, AdaptiveSummary, DriftConfig,
    DriftMonitor, DriftVerdict, EpochReport, ReprofiledJob, RuntimeShift,
};
pub use gossip::{GossipBus, GossipCounters, NodeSummary};
pub use mesh::{
    mesh_rebalance, LocalScheduler, Mesh, MeshConfig, MeshFault, MeshStats, MeshTopology,
};
pub use migrate::{rebalance, rebalance_across, FleetMetrics, FleetPlan, Migration};
pub use placement::{
    candidates_among, candidates_for, translate_model, FleetJob, NodeView, PlacementCandidate,
};
pub use pool::ProbePool;
pub use queue::WorkQueue;
pub use session::{FleetReport, FleetSession, FleetSessionBuilder};
pub use telemetry::{
    Agg, Query, QueryResult, SeriesKey, SeriesKind, TelemetryRecorder, TelemetryServer,
    TelemetryStore,
};
pub use transfer::{CurveRecord, PriorCorpus, TransferOutcome, TransferPrior, TransferSeed};
pub use worker::{IncrementalModel, JobOutcome, ProfilePass, ScaledBackend, ScaledBackendFactory};

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::coordinator::{Assignment, CapacityPlan, JobManager, ManagedJob, ProfilerConfig};
use crate::simulator::{node, Algo, NodeSpec, NODES};
use crate::strategies;
use crate::stream::ArrivalProcess;

/// One stream job registered with the fleet.
///
/// The spec is backend-agnostic: *how* the job is measured lives behind
/// the [`BackendFactory`]; the spec itself carries only the fleet-level
/// facts — the placement home, the stream's arrival process, priority,
/// and the strategy seed.
#[derive(Clone)]
pub struct FleetJobSpec {
    /// Unique job name (e.g. `"cam-03"`).
    pub name: String,
    /// Placement home: the node whose [`JobManager`] the fitted model
    /// enters (and the calibration anchor for cross-node translation).
    pub node: &'static NodeSpec,
    /// How to measure the job — simulated, PJRT, or anything else.
    pub backend: Arc<dyn BackendFactory>,
    /// Seed of the selection strategy's own randomness (the backend
    /// carries its own observation seed).
    pub seed: u64,
    /// Larger = more important when the node is over-subscribed.
    pub priority: i32,
    /// The sensor stream's arrival process (drives the rate demand).
    pub arrivals: ArrivalProcess,
    /// Injected runtime regime change (drift scenarios); `None` = the
    /// job's behaviour never changes.
    pub runtime_shift: Option<RuntimeShift>,
}

impl FleetJobSpec {
    /// Simulated-backend spec with a fixed 2 Hz stream and default
    /// priority — the migration-friendly constructor every pre-session
    /// call site already used.
    pub fn simulated(name: &str, node: &'static NodeSpec, algo: Algo, seed: u64) -> Self {
        Self {
            name: name.to_string(),
            node,
            backend: SimBackendFactory::shared(node, algo, seed),
            seed,
            priority: 1,
            arrivals: ArrivalProcess::Fixed(2.0),
            runtime_shift: None,
        }
    }

    /// Spec over an arbitrary [`BackendFactory`] — no simulator types at
    /// the call site. `home` names the placement node (Table-I registry);
    /// the stream defaults to fixed 2 Hz and priority 1, both plain
    /// fields to override.
    pub fn with_backend(
        name: &str,
        home: &str,
        backend: Arc<dyn BackendFactory>,
        seed: u64,
    ) -> Result<Self> {
        let node = node(home).with_context(|| format!("unknown placement node '{home}'"))?;
        Ok(Self {
            name: name.to_string(),
            node,
            backend,
            seed,
            priority: 1,
            arrivals: ArrivalProcess::Fixed(2.0),
            runtime_shift: None,
        })
    }

    /// Measurement-cache label: jobs whose factories report the same
    /// label share runtime behaviour, so they share cache entries.
    pub fn label(&self) -> String {
        self.backend.label()
    }
}

/// Fleet engine configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Worker threads in the pool.
    pub workers: usize,
    /// Profiling rounds per job (round 0 cold; later rounds are the
    /// periodic re-profiles the cache absorbs).
    pub rounds: usize,
    /// Selection strategy name (`strategies::by_name`).
    pub strategy: String,
    /// Per-session profiler configuration.
    pub profiler: ProfilerConfig,
    /// Arrival-process horizon (samples) used to derive each job's peak
    /// rate demand.
    pub horizon: usize,
    /// Persistent probe-pool workers for the daemon's overlapped
    /// dispatch/completion path. `0` (the default) keeps probe execution
    /// synchronous inside each replan event and sizes the pool from
    /// `workers`; `N ≥ 1` sizes the pool explicitly **and** lets
    /// profiling overlap event processing across replans (capacity
    /// planning defers until the replan's batch drains).
    pub probe_workers: usize,
    /// Consult the transfer-prior corpus before profiling fresh daemon
    /// arrivals: donors seed a [`TransferPrior`] and probes are dispatched
    /// only where the posterior stays uncertain (a rejected prior falls
    /// back to the cold sweep). Bootstrap-roster jobs always profile cold
    /// — they *build* the corpus.
    pub transfer: bool,
    /// Plan capacity against this runtime quantile instead of the mean
    /// prediction (e.g. `Some(0.95)` provisions each job for its p95
    /// runtime, inflated by the model's residual spread). `None` keeps
    /// mean-based planning.
    pub plan_quantile: Option<f64>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            rounds: 2,
            strategy: "nms".to_string(),
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 1000,
            probe_workers: 0,
            transfer: false,
            plan_quantile: None,
        }
    }
}

/// Everything a completed fleet sweep reports.
pub struct FleetSummary {
    /// Per-job outcomes in submission order.
    pub outcomes: Vec<JobOutcome>,
    /// Measurement-cache statistics of this run (delta, not the engine's
    /// lifetime totals — the cache itself persists across runs).
    pub cache: CacheStats,
    /// Per-node capacity plans, keyed by node name (sorted).
    pub plans: Vec<(String, CapacityPlan)>,
}

impl FleetSummary {
    /// Fraction of probes served from the measurement cache.
    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Profiling wallclock actually executed (cache hits cost zero).
    pub fn executed_wallclock(&self) -> f64 {
        self.outcomes.iter().map(JobOutcome::executed_wallclock).sum()
    }

    /// The capacity-plan assignment for a job, if any.
    pub fn assignment(&self, job: &str) -> Option<&Assignment> {
        self.plans
            .iter()
            .flat_map(|(_, plan)| plan.assignments.iter())
            .find(|a| a.name == job)
    }

    /// The placement layer's view of every profiled job.
    pub fn fleet_jobs(&self) -> Vec<FleetJob> {
        self.outcomes.iter().map(FleetJob::from).collect()
    }

    /// Rebalance the fleet: migrate shed jobs to under-subscribed nodes
    /// (cross-node placement via translated models) and return the
    /// fleet-wide plan. The per-node plans in `self.plans` are the
    /// no-migration baseline this improves on.
    pub fn rebalanced(&self) -> FleetPlan {
        rebalance(&self.fleet_jobs())
    }
}

/// Register every outcome's fitted model with its home node's manager and
/// derive the per-node capacity plans (sorted by node name) — the
/// planning tail of [`run_sweep`], reused by [`FleetDaemon`] when a
/// localized replan recomputes plans over a merged outcome set.
///
/// `quantile`, when set, registers each job at that runtime quantile
/// ([`ManagedJob::at_quantile`] under the outcome's residual spread)
/// instead of the mean prediction — admission then reserves headroom for
/// the runtime tail, not just the expectation.
pub(crate) fn plan_capacity(
    outcomes: &[JobOutcome],
    quantile: Option<f64>,
) -> Vec<(String, CapacityPlan)> {
    let mut managers: BTreeMap<&'static str, JobManager> = BTreeMap::new();
    for o in outcomes {
        let mut job = ManagedJob {
            name: o.name.clone(),
            model: o.model.clone(),
            rate_hz: o.rate_hz,
            priority: o.priority,
        };
        if let Some(q) = quantile {
            job = job.at_quantile(q, o.residual_spread());
        }
        managers
            .entry(o.node.name)
            .or_insert_with(|| JobManager::new(o.node.cores))
            .register(job);
    }
    managers
        .into_iter()
        .map(|(name, mgr)| (name.to_string(), mgr.plan()))
        .collect()
}

/// Profile every job across the persistent [`ProbePool`] and derive
/// per-node capacity plans from the fitted models — the sweep stage
/// shared by [`FleetSession::run`] and [`FleetDaemon`] replans.
pub(crate) fn run_sweep(
    cfg: &FleetConfig,
    pool: &ProbePool,
    specs: Vec<FleetJobSpec>,
) -> Result<FleetSummary> {
    ensure!(!specs.is_empty(), "fleet run needs at least one job spec");
    ensure!(
        strategies::by_name(&cfg.strategy, 0).is_some(),
        "unknown strategy '{}'",
        cfg.strategy
    );
    ensure!(cfg.profiler.max_steps >= cfg.profiler.n_initial, "profiler max_steps < n_initial");
    if let Some(q) = cfg.plan_quantile {
        ensure!((0.0..1.0).contains(&q) && q > 0.0, "plan_quantile must be in (0, 1), got {q}");
    }
    // Snapshot so the summary reports THIS run's cache behaviour even
    // when the cache is reused across runs.
    let cache_before = pool.cache().stats();
    // Dispatch the whole roster, then collect strictly in dispatch order:
    // the pool stripes task `seq` onto lane `seq % workers` (the scoped
    // sweep's round-robin sharding), and seq-ordered collection keeps the
    // summary a pure function of the submission order, never of worker
    // scheduling.
    let pending: Vec<(u64, String)> = specs
        .into_iter()
        .enumerate()
        .map(|(index, spec)| {
            let name = spec.name.clone();
            (pool.dispatch(index, spec, cfg, ProfilePass::default(), None), name)
        })
        .collect();
    let mut outcomes = Vec::with_capacity(pending.len());
    let mut failures = Vec::new();
    for (seq, name) in pending {
        match pool.collect(seq) {
            Ok(outcome) => outcomes.push(outcome),
            Err(e) => failures.push(format!("{name}: {e:#}")),
        }
    }
    ensure!(failures.is_empty(), "fleet workers failed: {}", failures.join("; "));
    outcomes.sort_by_key(|o| o.index);
    // Report each task's home lane, not whichever thread ran it: work
    // stealing makes the latter vary run to run, and the summary must
    // stay a pure function of the submission order.
    let lanes = pool.workers();
    for o in &mut outcomes {
        o.worker = o.index % lanes;
    }

    // Feed the fitted models into per-node managers: this is where the
    // fleet engine hands over to the adaptive-adjustment layer.
    let plans = plan_capacity(&outcomes, cfg.plan_quantile);
    let cache = pool.cache().stats().delta_since(&cache_before);
    Ok(FleetSummary { outcomes, cache, plans })
}

/// Build a synthetic fleet of `n` jobs cycling through the Table-I node
/// set and the three IFTM algorithms, with varying arrival rates and mixed
/// priorities — the shared roster of the `fleet` CLI subcommand, the
/// `fleet_profiling` example, and the e2e tests.
pub fn sim_fleet(n: usize, seed: u64) -> Vec<FleetJobSpec> {
    (0..n)
        .map(|i| {
            let node = &NODES[i % NODES.len()];
            let algo = Algo::ALL[i % Algo::ALL.len()];
            let name = format!("job-{i:02}");
            // Per-job seed hashed from (fleet seed, name) — NOT the job's
            // roster position, so inserting or reordering jobs cannot
            // reshuffle every later job's runtime behaviour.
            let job_seed =
                crate::util::fnv1a(seed.to_le_bytes().into_iter().chain(name.bytes()));
            FleetJobSpec {
                node,
                backend: SimBackendFactory::shared(node, algo, job_seed),
                seed: job_seed,
                priority: 1 + (i % 3) as i32,
                arrivals: ArrivalProcess::Varying {
                    lo: 0.5,
                    hi: 1.5 + (i % 4) as f64,
                    period: 400.0,
                },
                runtime_shift: None,
                name,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_fleet_builds_unique_named_jobs() {
        let specs = sim_fleet(12, 7);
        assert_eq!(specs.len(), 12);
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 12, "job names must be unique");
        assert!(specs.iter().all(|s| s.priority >= 1));
    }

    #[test]
    fn sim_fleet_seeds_are_name_stable_not_positional() {
        // Regression: seeds used to derive from the roster position
        // (`i % 21`), so job-21 aliased job-00's noise stream and any
        // insertion reshuffled every later job's behaviour.
        let long = sim_fleet(22, 7);
        assert_ne!(long[21].seed, long[0].seed, "same class, distinct stream");
        let short = sim_fleet(5, 7);
        for i in 0..5 {
            assert_eq!(long[i].seed, short[i].seed, "seed depends on the name alone");
        }
        let other = sim_fleet(5, 8);
        assert_ne!(short[0].seed, other[0].seed, "fleet seed still matters");
    }

    #[test]
    fn with_backend_resolves_the_placement_home_by_name() {
        let factory = SimBackendFactory::shared(node("pi4").unwrap(), Algo::Arima, 3);
        let spec = FleetJobSpec::with_backend("cam", "pi4", factory, 3).unwrap();
        assert_eq!(spec.node.name, "pi4");
        assert_eq!(spec.label(), "pi4/arima");
        let missing = SimBackendFactory::shared(node("pi4").unwrap(), Algo::Arima, 3);
        assert!(FleetJobSpec::with_backend("cam", "gcp-tpu", missing, 3).is_err());
    }

    #[test]
    fn summary_cache_stats_are_per_run_not_lifetime() {
        let cfg = FleetConfig { workers: 1, rounds: 1, ..Default::default() };
        let pool = ProbePool::new(Arc::new(MeasurementCache::new()), 1);
        let first = run_sweep(&cfg, &pool, sim_fleet(2, 3)).unwrap();
        assert_eq!(first.cache.hits, 0, "distinct labels, single round: no hits");
        assert!(first.cache.misses > 0);
        // Same specs again through the same cache: a full replay. The
        // second summary must report only this run's (all-hit) stats, not
        // the blended lifetime counters.
        let second = run_sweep(&cfg, &pool, sim_fleet(2, 3)).unwrap();
        assert_eq!(second.cache.misses, 0, "replay run must not re-execute");
        assert_eq!(second.cache.hits, first.cache.misses);
        assert!((second.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_fleet_is_an_error() {
        let pool = ProbePool::new(Arc::new(MeasurementCache::new()), 1);
        assert!(run_sweep(&FleetConfig::default(), &pool, Vec::new()).is_err());
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let cfg = FleetConfig { strategy: "hillclimb".into(), ..FleetConfig::default() };
        let pool = ProbePool::new(Arc::new(MeasurementCache::new()), 1);
        assert!(run_sweep(&cfg, &pool, sim_fleet(2, 1)).is_err());
    }
}
