//! Fleet-scale concurrent profiling engine.
//!
//! The single-job [`crate::coordinator::Profiler`] becomes a worker task:
//! N registered stream jobs are sharded across a pool of scoped worker
//! threads pulling from a shared [`WorkQueue`], all probing through one
//! [`MeasurementCache`] keyed by `(job label, cpu-limit bucket)` so
//! repeated strategy probes — re-profiling rounds, and replicas of a job
//! class on the same device type — reuse observed runtimes instead of
//! re-executing the job. Each job's [`crate::fit::RuntimeModel`] is refit
//! *incrementally* (warm-started from the previous parameters) as
//! measurements land, and the finished models feed straight into per-node
//! [`JobManager`] registrations, producing the fleet-wide
//! [`CapacityPlan`]s that close the paper's adaptive-adjustment loop.
//!
//! ```text
//!  FleetJobSpec*N ──► WorkQueue ──► worker pool (scoped threads)
//!                                     │  Profiler::run_observed
//!                                     │   ├─ CachedBackend ──► MeasurementCache
//!                                     │   └─ IncrementalModel (warm refits)
//!                                     ▼
//!                                  JobOutcome*N ──► per-node JobManager ──► CapacityPlan
//! ```
//!
//! On top of the one-shot sweep, the [`drift`] module runs the engine
//! *continuously*: [`FleetEngine::run_adaptive`] monitors every job's
//! observed-vs-predicted runtime and stream rate, re-profiles only jobs
//! whose [`DriftVerdict`] crosses a threshold, and ages the measurement
//! cache by label generation so stale observations are never replayed.

pub mod cache;
pub mod drift;
pub mod migrate;
pub mod placement;
pub mod queue;
pub mod worker;

pub use cache::{CacheStats, CachedBackend, MeasurementCache};
pub use drift::{
    model_fingerprint, AdaptiveConfig, AdaptiveJobReport, AdaptiveSummary, DriftConfig,
    DriftMonitor, DriftVerdict, EpochReport, ReprofiledJob, RuntimeShift,
};
pub use migrate::{rebalance, rebalance_across, FleetMetrics, FleetPlan, Migration};
pub use placement::{candidates_for, translate_model, FleetJob, PlacementCandidate};
pub use queue::WorkQueue;
pub use worker::{IncrementalModel, JobOutcome, ProfilePass, ScaledBackend};

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::coordinator::{Assignment, CapacityPlan, JobManager, ManagedJob, ProfilerConfig};
use crate::simulator::{Algo, NodeSpec, NODES};
use crate::strategies;
use crate::stream::ArrivalProcess;

/// One stream job registered with the fleet engine.
#[derive(Clone)]
pub struct FleetJobSpec {
    /// Unique job name (e.g. `"cam-03"`).
    pub name: String,
    /// Device the job runs on.
    pub node: &'static NodeSpec,
    pub algo: Algo,
    /// Seed of the job's simulated runtime behaviour.
    pub seed: u64,
    /// Larger = more important when the node is over-subscribed.
    pub priority: i32,
    /// The sensor stream's arrival process (drives the rate demand).
    pub arrivals: ArrivalProcess,
    /// Injected runtime regime change (drift scenarios); `None` = the
    /// job's behaviour never changes.
    pub runtime_shift: Option<RuntimeShift>,
}

impl FleetJobSpec {
    /// Spec with a fixed 2 Hz stream and default priority.
    pub fn simulated(name: &str, node: &'static NodeSpec, algo: Algo, seed: u64) -> Self {
        Self {
            name: name.to_string(),
            node,
            algo,
            seed,
            priority: 1,
            arrivals: ArrivalProcess::Fixed(2.0),
            runtime_shift: None,
        }
    }

    /// Measurement-cache label: jobs of the same class on the same device
    /// type share runtime behaviour, so they share cache entries.
    pub fn label(&self) -> String {
        format!("{}/{}", self.node.name, self.algo.name())
    }
}

/// Fleet engine configuration.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Worker threads in the pool.
    pub workers: usize,
    /// Profiling rounds per job (round 0 cold; later rounds are the
    /// periodic re-profiles the cache absorbs).
    pub rounds: usize,
    /// Selection strategy name (`strategies::by_name`).
    pub strategy: String,
    /// Per-session profiler configuration.
    pub profiler: ProfilerConfig,
    /// Arrival-process horizon (samples) used to derive each job's peak
    /// rate demand.
    pub horizon: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            rounds: 2,
            strategy: "nms".to_string(),
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 1000,
        }
    }
}

/// Everything a completed fleet run reports.
pub struct FleetSummary {
    /// Per-job outcomes in submission order.
    pub outcomes: Vec<JobOutcome>,
    /// Measurement-cache statistics of this run (delta, not the engine's
    /// lifetime totals — the cache itself persists across runs).
    pub cache: CacheStats,
    /// Per-node capacity plans, keyed by node name (sorted).
    pub plans: Vec<(String, CapacityPlan)>,
}

impl FleetSummary {
    /// Fraction of probes served from the measurement cache.
    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Profiling wallclock actually executed (cache hits cost zero).
    pub fn executed_wallclock(&self) -> f64 {
        self.outcomes.iter().map(JobOutcome::executed_wallclock).sum()
    }

    /// The capacity-plan assignment for a job, if any.
    pub fn assignment(&self, job: &str) -> Option<&Assignment> {
        self.plans
            .iter()
            .flat_map(|(_, plan)| plan.assignments.iter())
            .find(|a| a.name == job)
    }

    /// The placement layer's view of every profiled job.
    pub fn fleet_jobs(&self) -> Vec<FleetJob> {
        self.outcomes.iter().map(FleetJob::from).collect()
    }

    /// Rebalance the fleet: migrate shed jobs to under-subscribed nodes
    /// (cross-node placement via translated models) and return the
    /// fleet-wide plan. The per-node plans in `self.plans` are the
    /// no-migration baseline this improves on.
    pub fn rebalanced(&self) -> FleetPlan {
        rebalance(&self.fleet_jobs())
    }
}

/// The fleet profiling engine.
pub struct FleetEngine {
    cfg: FleetConfig,
    cache: MeasurementCache,
}

impl FleetEngine {
    pub fn new(cfg: FleetConfig) -> Self {
        Self { cfg, cache: MeasurementCache::new() }
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Cache statistics so far (accumulates across `run` calls).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Profile every job across the worker pool and derive per-node
    /// capacity plans from the fitted models.
    pub fn run(&self, specs: Vec<FleetJobSpec>) -> Result<FleetSummary> {
        ensure!(!specs.is_empty(), "fleet run needs at least one job spec");
        ensure!(
            strategies::by_name(&self.cfg.strategy, 0).is_some(),
            "unknown strategy '{}'",
            self.cfg.strategy
        );
        ensure!(
            self.cfg.profiler.max_steps >= self.cfg.profiler.n_initial,
            "profiler max_steps < n_initial"
        );
        // Snapshot so the summary reports THIS run's cache behaviour even
        // when the engine (and its cache) is reused across runs.
        let cache_before = self.cache.stats();
        let n_workers = self.cfg.workers.clamp(1, specs.len());
        let n_jobs = specs.len();
        let queue = WorkQueue::new(specs.into_iter().enumerate());
        let results: Mutex<Vec<JobOutcome>> = Mutex::new(Vec::with_capacity(n_jobs));
        let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..n_workers {
                let queue = &queue;
                let results = &results;
                let failures = &failures;
                let cache = &self.cache;
                let cfg = &self.cfg;
                s.spawn(move || {
                    while let Some((index, spec)) = queue.pop() {
                        match worker::profile_job(&spec, cfg, cache, w) {
                            Ok(mut outcome) => {
                                outcome.index = index;
                                results.lock().unwrap().push(outcome);
                            }
                            Err(e) => {
                                failures.lock().unwrap().push(format!("{}: {e:#}", spec.name));
                            }
                        }
                    }
                });
            }
        });
        let failures = failures.into_inner().unwrap();
        ensure!(failures.is_empty(), "fleet workers failed: {}", failures.join("; "));
        let mut outcomes = results.into_inner().unwrap();
        outcomes.sort_by_key(|o| o.index);

        // Feed the fitted models into per-node managers: this is where the
        // fleet engine hands over to the adaptive-adjustment layer.
        let mut managers: BTreeMap<&'static str, JobManager> = BTreeMap::new();
        for o in &outcomes {
            managers
                .entry(o.node.name)
                .or_insert_with(|| JobManager::new(o.node.cores))
                .register(ManagedJob {
                    name: o.name.clone(),
                    model: o.model.clone(),
                    rate_hz: o.rate_hz,
                    priority: o.priority,
                });
        }
        let plans = managers
            .into_iter()
            .map(|(name, mgr)| (name.to_string(), mgr.plan()))
            .collect();
        let cache = self.cache.stats().delta_since(&cache_before);
        Ok(FleetSummary { outcomes, cache, plans })
    }

    /// Profile every job, then rebalance: shed jobs migrate to
    /// under-subscribed nodes via cross-node model translation. Returns the
    /// profiling summary (whose per-node plans are the no-migration
    /// baseline) together with the fleet-wide plan.
    pub fn run_rebalanced(&self, specs: Vec<FleetJobSpec>) -> Result<(FleetSummary, FleetPlan)> {
        let summary = self.run(specs)?;
        let plan = summary.rebalanced();
        Ok((summary, plan))
    }
}

/// Build a synthetic fleet of `n` jobs cycling through the Table-I node
/// set and the three IFTM algorithms, with varying arrival rates and mixed
/// priorities — the shared roster of the `fleet` CLI subcommand, the
/// `fleet_profiling` example, and the e2e tests.
pub fn sim_fleet(n: usize, seed: u64) -> Vec<FleetJobSpec> {
    (0..n)
        .map(|i| {
            let node = &NODES[i % NODES.len()];
            let algo = Algo::ALL[i % Algo::ALL.len()];
            FleetJobSpec {
                name: format!("job-{i:02}"),
                node,
                algo,
                // Same class on the same device type shares runtime
                // behaviour (and cache entries); distinct classes get
                // distinct seeds.
                seed: seed.wrapping_add((i % 21) as u64 * 7919),
                priority: 1 + (i % 3) as i32,
                arrivals: ArrivalProcess::Varying {
                    lo: 0.5,
                    hi: 1.5 + (i % 4) as f64,
                    period: 400.0,
                },
                runtime_shift: None,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_fleet_builds_unique_named_jobs() {
        let specs = sim_fleet(12, 7);
        assert_eq!(specs.len(), 12);
        let mut names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 12, "job names must be unique");
        assert!(specs.iter().all(|s| s.priority >= 1));
    }

    #[test]
    fn summary_cache_stats_are_per_run_not_lifetime() {
        let engine = FleetEngine::new(FleetConfig { workers: 1, rounds: 1, ..Default::default() });
        let first = engine.run(sim_fleet(2, 3)).unwrap();
        assert_eq!(first.cache.hits, 0, "distinct labels, single round: no hits");
        assert!(first.cache.misses > 0);
        // Same specs again on the same engine: a full cache replay. The
        // second summary must report only this run's (all-hit) stats, not
        // the blended lifetime counters.
        let second = engine.run(sim_fleet(2, 3)).unwrap();
        assert_eq!(second.cache.misses, 0, "replay run must not re-execute");
        assert_eq!(second.cache.hits, first.cache.misses);
        assert!((second.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_fleet_is_an_error() {
        let engine = FleetEngine::new(FleetConfig::default());
        assert!(engine.run(Vec::new()).is_err());
    }

    #[test]
    fn unknown_strategy_is_an_error() {
        let engine = FleetEngine::new(FleetConfig {
            strategy: "hillclimb".into(),
            ..FleetConfig::default()
        });
        assert!(engine.run(sim_fleet(2, 1)).is_err());
    }
}
