//! Latency-aware neighbor gossip for the mesh scheduler.
//!
//! [`super::mesh::LocalScheduler`]s never read fleet-global state: the only
//! thing a node learns about the rest of the mesh is the stream of
//! [`NodeSummary`] messages its direct topology neighbors publish. The
//! [`GossipBus`] models that exchange on the daemon's virtual clock — a
//! summary published at tick `t` over a link with latency `L` becomes
//! visible to the neighbor at `t + L`, a summary published into a
//! partitioned link is dropped (and counted), and a lost node neither
//! publishes nor receives. Staleness is therefore not simulated separately:
//! it *emerges* from latency, cadence, and partitions, exactly as it would
//! in a real meshed edge deployment.

use super::mesh::MeshTopology;

/// The compact capacity summary one node gossips to its neighbors — all a
/// [`super::mesh::LocalScheduler`] ever learns about another machine.
#[derive(Clone, Debug)]
pub struct NodeSummary {
    /// Origin node name.
    pub node: &'static str,
    /// Virtual tick the summary was published (staleness anchor).
    pub at: u64,
    /// Residual capacity the origin advertised at `at`.
    pub residual: f64,
    /// Total assignable capacity of the origin.
    pub capacity: f64,
}

/// One summary in flight toward a neighbor.
#[derive(Clone, Debug)]
struct InFlight {
    due: u64,
    to: &'static str,
    summary: NodeSummary,
}

/// Counters the bus accumulates over its lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct GossipCounters {
    /// Summaries delivered to a neighbor's view.
    pub delivered: u64,
    /// Summaries dropped on a partitioned link or at a lost endpoint.
    pub dropped: u64,
}

/// The in-flight summary queue between mesh nodes.
///
/// Deterministic by construction: publishes happen in node-name order (the
/// caller iterates schedulers in a `BTreeMap`), deliveries are drained in
/// `(due, to, from)` order, and no wallclock or randomness is consulted.
#[derive(Debug, Default)]
pub struct GossipBus {
    in_flight: Vec<InFlight>,
    counters: GossipCounters,
}

impl GossipBus {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `summary` from its origin node to every direct topology
    /// neighbor. Links that are cut, and endpoints that are lost, drop the
    /// message and bump the drop counter.
    pub fn publish(&mut self, topo: &MeshTopology, summary: &NodeSummary) {
        if topo.is_lost(summary.node) {
            return;
        }
        for neighbor in topo.neighbors(summary.node) {
            if !topo.link_up(summary.node, neighbor.name) || topo.is_lost(neighbor.name) {
                self.counters.dropped += 1;
                continue;
            }
            let latency = topo.link_latency(summary.node, neighbor.name).unwrap_or(0);
            self.in_flight.push(InFlight {
                due: summary.at.saturating_add(latency),
                to: neighbor.name,
                summary: summary.clone(),
            });
        }
    }

    /// Drain every summary due at or before `now`, in `(due, to, from)`
    /// order. The caller folds each into the receiver's view (newest wins).
    pub fn deliver_due(&mut self, now: u64) -> Vec<(&'static str, NodeSummary)> {
        let mut due: Vec<InFlight> = Vec::new();
        let mut rest: Vec<InFlight> = Vec::with_capacity(self.in_flight.len());
        for msg in self.in_flight.drain(..) {
            if msg.due <= now {
                due.push(msg);
            } else {
                rest.push(msg);
            }
        }
        self.in_flight = rest;
        due.sort_by(|x, y| {
            x.due
                .cmp(&y.due)
                .then_with(|| x.to.cmp(y.to))
                .then_with(|| x.summary.node.cmp(y.summary.node))
        });
        self.counters.delivered += due.len() as u64;
        due.into_iter().map(|m| (m.to, m.summary)).collect()
    }

    /// Summaries still in flight (scheduled but not yet due).
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Lifetime delivery/drop counters.
    pub fn counters(&self) -> GossipCounters {
        self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::mesh::MeshTopology;

    fn summary(topo: &MeshTopology, idx: usize, at: u64) -> NodeSummary {
        let spec = topo.nodes()[idx];
        NodeSummary { node: spec.name, at, residual: spec.cores, capacity: spec.cores }
    }

    #[test]
    fn zero_latency_delivery_is_immediate_and_ordered() {
        let topo = MeshTopology::parse("full:3").unwrap();
        let mut bus = GossipBus::new();
        for i in (0..3).rev() {
            bus.publish(&topo, &summary(&topo, i, 10));
        }
        let delivered = bus.deliver_due(10);
        // 3 nodes x 2 neighbors each.
        assert_eq!(delivered.len(), 6);
        assert_eq!(bus.in_flight(), 0);
        let order: Vec<(&str, &str)> = delivered.iter().map(|(to, s)| (*to, s.node)).collect();
        let mut sorted = order.clone();
        sorted.sort();
        assert_eq!(order, sorted, "deliveries sorted by (to, from)");
    }

    #[test]
    fn latency_delays_delivery() {
        let topo = MeshTopology::parse("ring:4@50").unwrap();
        let mut bus = GossipBus::new();
        bus.publish(&topo, &summary(&topo, 0, 100));
        assert!(bus.deliver_due(100).is_empty(), "nothing due before the latency elapses");
        assert_eq!(bus.in_flight(), 2);
        let late = bus.deliver_due(150);
        assert_eq!(late.len(), 2);
        assert!(late.iter().all(|(_, s)| s.at == 100), "summaries keep their publish tick");
    }

    #[test]
    fn cut_links_and_lost_nodes_drop_summaries() {
        let mut topo = MeshTopology::parse("line:3").unwrap();
        let (a, b, c) = (topo.nodes()[0].name, topo.nodes()[1].name, topo.nodes()[2].name);
        topo.cut(a, b).unwrap();
        let mut bus = GossipBus::new();
        bus.publish(&topo, &summary(&topo, 1, 5));
        assert_eq!(bus.counters().dropped, 1, "the cut a-b link eats one copy");
        let delivered = bus.deliver_due(5);
        assert_eq!(delivered.len(), 1);
        assert_eq!(delivered[0].0, c);
        // A lost node stops publishing outright.
        topo.lose(b);
        bus.publish(&topo, &summary(&topo, 1, 6));
        assert_eq!(bus.in_flight(), 0);
        // …and stops receiving: c's copy toward b is dropped.
        bus.publish(&topo, &summary(&topo, 2, 6));
        assert_eq!(bus.counters().dropped, 2);
        assert_eq!(bus.in_flight(), 0, "c's only neighbor is the lost b");
    }

    #[test]
    fn healed_links_carry_again() {
        let mut topo = MeshTopology::parse("ring:3").unwrap();
        let (a, b) = (topo.nodes()[0].name, topo.nodes()[1].name);
        topo.cut(a, b).unwrap();
        let mut bus = GossipBus::new();
        bus.publish(&topo, &summary(&topo, 0, 1));
        let before = bus.counters();
        assert_eq!(before.dropped, 1);
        topo.heal(a, b).unwrap();
        bus.publish(&topo, &summary(&topo, 0, 2));
        assert_eq!(bus.counters().dropped, 1, "no new drops after the heal");
        assert_eq!(bus.deliver_due(2).len(), 3);
    }
}
