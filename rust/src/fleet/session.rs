//! The composable fleet pipeline: one builder for sweep / rebalance /
//! adaptive over any [`BackendFactory`](super::BackendFactory).
//!
//! [`FleetSession`] is the batch form of the fleet engine — one pipeline
//! whose stages compose:
//!
//! ```text
//!  builder: jobs + config + cache ──► sweep ──► [adaptive epochs] ──► [rebalance]
//!                                        └────────── FleetReport ◄─────────┘
//! ```
//!
//! * the **sweep** profiles every [`FleetJobSpec`] through the shared
//!   [`MeasurementCache`] and plans each node;
//! * the **adaptive** stage (opt-in via [`AdaptiveConfig`]) replaces the
//!   sweep's fixed rounds with drift-gated re-profiling;
//! * the **rebalance** stage (opt-in) migrates shed jobs across nodes —
//!   from the final models, so it composes with adaptation.
//!
//! The unified [`FleetReport`] serializes through [`crate::util::json`]
//! (`streamprof fleet --out report.json`), giving the fleet layer a
//! stable machine-readable surface.
//!
//! Since the daemon landed, the session is a thin wrapper over
//! [`FleetDaemon`]: [`FleetSession::run`] replays the roster as arrivals
//! at `t = 0` and drains the event loop, so batch runs and event-driven
//! runs are the same engine by construction (`tests/fleet_e2e.rs` pins
//! the equivalence byte-for-byte). Setting
//! [`FleetConfig::probe_workers`](super::FleetConfig) overlaps probe
//! execution across replans inside the drain; the drained report stays
//! byte-identical because completions merge in dispatch order.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::CapacityPlan;
use crate::fit::RuntimeModel;
use crate::util::json::Json;

use super::cache::{CacheStats, MeasurementCache};
use super::daemon::FleetDaemon;
use super::drift::{model_fingerprint, AdaptiveConfig, AdaptiveSummary, DriftVerdict};
use super::mesh::{MeshConfig, MeshFault, MeshStats, MeshTopology};
use super::migrate::FleetPlan;
use super::telemetry::TelemetryStore;
use super::{FleetConfig, FleetJobSpec, FleetSummary};

/// Builder for a [`FleetSession`] — the single public entry point of the
/// fleet layer.
///
/// ```no_run
/// use streamprof::fleet::{sim_fleet, AdaptiveConfig, FleetSession};
///
/// let report = FleetSession::builder()
///     .jobs(sim_fleet(12, 7))
///     .rebalance(true)
///     .adaptive(AdaptiveConfig::default())
///     .run()?;
/// println!("{}/{} probes hit the cache", report.cache.hits, report.cache.lookups());
/// # anyhow::Ok(())
/// ```
#[derive(Default)]
pub struct FleetSessionBuilder {
    cfg: FleetConfig,
    specs: Vec<FleetJobSpec>,
    rebalance: bool,
    adaptive: Option<AdaptiveConfig>,
    cache: Option<Arc<MeasurementCache>>,
    telemetry: Option<Arc<TelemetryStore>>,
    mesh: Option<(MeshTopology, MeshConfig)>,
    faults: Vec<(u64, MeshFault)>,
}

impl FleetSessionBuilder {
    /// Engine configuration (workers, rounds, strategy, profiler, horizon).
    pub fn config(mut self, cfg: FleetConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Append job specs to the roster.
    pub fn jobs(mut self, specs: impl IntoIterator<Item = FleetJobSpec>) -> Self {
        self.specs.extend(specs);
        self
    }

    /// Append one job spec.
    pub fn job(mut self, spec: FleetJobSpec) -> Self {
        self.specs.push(spec);
        self
    }

    /// Enable the rebalance stage: migrate shed jobs across nodes after
    /// profiling (and after adaptation, when both are enabled).
    pub fn rebalance(mut self, enabled: bool) -> Self {
        self.rebalance = enabled;
        self
    }

    /// Enable the adaptive stage: drift-gated continuous re-profiling
    /// after the cold sweep.
    pub fn adaptive(mut self, acfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(acfg);
        self
    }

    /// Share (or persist) a measurement cache across sessions — the seam
    /// behind `--cache-file`: restore a snapshot into a cache, hand it to
    /// every session, snapshot it again on exit.
    pub fn cache(mut self, cache: Arc<MeasurementCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach a telemetry store: every run replays the roster through the
    /// daemon with a [`super::TelemetryRecorder`] attached, so the store
    /// fills with the same series an always-on daemon would emit.
    pub fn telemetry(mut self, store: Arc<TelemetryStore>) -> Self {
        self.telemetry = Some(store);
        self
    }

    /// Attach a decentralized mesh scheduler (sweep mode only): the run
    /// replays through a daemon with the mesh attached, gossip rounds
    /// play out during the drain, and the report's plan is the mesh's
    /// local-optimistic placement instead of the centralized rebalance.
    pub fn mesh(mut self, topo: MeshTopology, cfg: MeshConfig) -> Self {
        self.mesh = Some((topo, cfg));
        self
    }

    /// Inject a mesh fault (link partition/heal, node loss) at virtual
    /// tick `at` — requires [`FleetSessionBuilder::mesh`].
    pub fn mesh_fault_at(mut self, at: u64, fault: MeshFault) -> Self {
        self.faults.push((at, fault));
        self
    }

    /// Finalize into a reusable [`FleetSession`].
    pub fn build(self) -> FleetSession {
        FleetSession {
            cfg: self.cfg,
            specs: self.specs,
            rebalance: self.rebalance,
            adaptive: self.adaptive,
            cache: self.cache.unwrap_or_default(),
            telemetry: self.telemetry,
            mesh: self.mesh,
            faults: self.faults,
        }
    }

    /// Build and run once — the one-liner for the common case.
    pub fn run(self) -> Result<FleetReport> {
        self.build().run()
    }
}

/// A configured fleet pipeline. Reusable: every [`FleetSession::run`]
/// replays the roster through the session's persistent cache (a second
/// run replays measurements at a ~100% hit rate).
pub struct FleetSession {
    cfg: FleetConfig,
    specs: Vec<FleetJobSpec>,
    rebalance: bool,
    adaptive: Option<AdaptiveConfig>,
    cache: Arc<MeasurementCache>,
    telemetry: Option<Arc<TelemetryStore>>,
    mesh: Option<(MeshTopology, MeshConfig)>,
    faults: Vec<(u64, MeshFault)>,
}

impl FleetSession {
    pub fn builder() -> FleetSessionBuilder {
        FleetSessionBuilder::default()
    }

    /// The session's measurement cache (shared with whoever passed it in).
    pub fn cache(&self) -> &Arc<MeasurementCache> {
        &self.cache
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Run the configured pipeline: sweep, then the optional adaptive and
    /// rebalance stages. Implemented as a replay through the event-driven
    /// [`FleetDaemon`]: every spec arrives at `t = 0` and the daemon is
    /// drained, which performs exactly one bootstrap sweep (or adaptive
    /// run) over the full roster — byte-identical to the pre-daemon batch
    /// pipeline, and provably the same engine the always-on form runs.
    pub fn run(&self) -> Result<FleetReport> {
        let mut builder = FleetDaemon::builder()
            .config(self.cfg.clone())
            .jobs(self.specs.iter().cloned())
            .rebalance(self.rebalance)
            .cache(self.cache.clone());
        if let Some(acfg) = &self.adaptive {
            builder = builder.adaptive(acfg.clone());
        }
        if let Some(store) = &self.telemetry {
            builder = builder.telemetry(store.clone());
        }
        if let Some((topo, mcfg)) = &self.mesh {
            builder = builder.mesh(topo.clone(), *mcfg);
            for (at, fault) in &self.faults {
                builder = builder.mesh_fault_at(*at, fault.clone());
            }
        }
        builder.build().drain()
    }
}

/// Everything one [`FleetSession::run`] produced: the sweep summary,
/// the optional rebalanced fleet plan, the optional adaptive summary, and
/// this run's cache statistics. Serializes via [`FleetReport::to_json`].
pub struct FleetReport {
    /// The sweep summary when the adaptive stage was off (otherwise the
    /// cold sweep lives in `adaptive.initial`; use [`FleetReport::summary`]).
    sweep: Option<FleetSummary>,
    /// Present when the adaptive stage ran.
    pub adaptive: Option<AdaptiveSummary>,
    /// Present when the rebalance stage ran.
    pub plan: Option<FleetPlan>,
    /// Cache statistics of this run (sweep + adaptation), as a delta —
    /// the session's cache itself persists across runs.
    pub cache: CacheStats,
    /// Mesh-health counters when the decentralized mesh scheduler ran
    /// (its plan is in `plan`, replacing the centralized rebalance).
    pub mesh: Option<MeshStats>,
}

impl FleetReport {
    /// Assemble a report from the pipeline's pieces — the daemon's drain
    /// path and the session wrapper both end here.
    pub(crate) fn assemble(
        sweep: Option<FleetSummary>,
        adaptive: Option<AdaptiveSummary>,
        plan: Option<FleetPlan>,
        cache: CacheStats,
    ) -> Self {
        Self { sweep, adaptive, plan, cache, mesh: None }
    }

    /// The profiling sweep every stage built on (the cold sweep when the
    /// adaptive stage ran).
    pub fn summary(&self) -> &FleetSummary {
        self.sweep
            .as_ref()
            .unwrap_or_else(|| &self.adaptive.as_ref().expect("sweep or adaptive").initial)
    }

    /// Fraction of this run's probes served from the measurement cache.
    pub fn hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Serialize the whole report as a [`Json`] tree (stable field names;
    /// non-finite numbers become `null`). `streamprof fleet --out f.json`
    /// writes exactly this.
    pub fn to_json(&self) -> Json {
        let mut root = vec![
            ("version", Json::Num(1.0)),
            ("pjrt_enabled", Json::Bool(crate::runtime::pjrt_enabled())),
            ("summary", summary_json(self.summary())),
            ("cache", stats_json(&self.cache)),
        ];
        if let Some(plan) = &self.plan {
            root.push(("rebalance", fleet_plan_json(plan)));
        }
        if let Some(ad) = &self.adaptive {
            root.push(("adaptive", adaptive_json(ad)));
        }
        if let Some(m) = &self.mesh {
            root.push(("mesh", mesh_stats_json(m)));
        }
        Json::obj(root)
    }
}

fn mesh_stats_json(s: &MeshStats) -> Json {
    Json::obj([
        ("gossip_rounds", Json::num(s.gossip_rounds as f64)),
        ("summaries_delivered", Json::num(s.summaries_delivered as f64)),
        ("summaries_dropped", Json::num(s.summaries_dropped as f64)),
        ("staleness_ticks", Json::num(s.staleness_ticks as f64)),
        ("conflict_rollbacks", Json::num(s.conflict_rollbacks as f64)),
        ("moves", Json::num(s.moves as f64)),
    ])
}

/// Hex fingerprint: `u64` does not survive a round-trip through JSON's
/// f64 numbers, so fingerprints serialize as strings.
fn fingerprint_json(model: &RuntimeModel) -> Json {
    Json::str(&format!("{:016x}", model_fingerprint(model)))
}

fn model_json(m: &RuntimeModel) -> Json {
    Json::obj([
        ("kind", Json::str(m.kind.name())),
        ("a", Json::num(m.a)),
        ("b", Json::num(m.b)),
        ("c", Json::num(m.c)),
        ("d", Json::num(m.d)),
        ("fingerprint", fingerprint_json(m)),
    ])
}

fn stats_json(c: &CacheStats) -> Json {
    Json::obj([
        ("hits", Json::num(c.hits as f64)),
        ("misses", Json::num(c.misses as f64)),
        ("stale_hits_refused", Json::num(c.stale_hits_refused as f64)),
        ("evictions", Json::num(c.evictions as f64)),
        ("inserts", Json::num(c.inserts as f64)),
        ("saved_wallclock", Json::num(c.saved_wallclock)),
        ("hit_rate", Json::num(c.hit_rate())),
    ])
}

fn node_plan_json(node: &str, plan: &CapacityPlan) -> Json {
    let mut assignments = Vec::with_capacity(plan.assignments.len());
    for a in &plan.assignments {
        assignments.push(Json::obj([
            ("name", Json::str(&a.name)),
            ("limit", Json::num(a.adjustment.limit)),
            ("predicted_runtime", Json::num(a.adjustment.predicted_runtime)),
            ("guaranteed", Json::Bool(a.guaranteed)),
        ]));
    }
    Json::obj([
        ("node", Json::str(node)),
        ("capacity", Json::num(plan.capacity)),
        ("total_assigned", Json::num(plan.total_assigned)),
        ("assignments", Json::Arr(assignments)),
    ])
}

fn summary_json(s: &FleetSummary) -> Json {
    let mut outcomes = Vec::with_capacity(s.outcomes.len());
    for o in &s.outcomes {
        outcomes.push(Json::obj([
            ("name", Json::str(&o.name)),
            ("label", Json::str(&o.label)),
            ("node", Json::str(o.node.name)),
            ("worker", Json::num(o.worker as f64)),
            ("rate_hz", Json::num(o.rate_hz)),
            ("priority", Json::num(o.priority as f64)),
            ("points", Json::num(o.points as f64)),
            ("refits", Json::num(o.refits as f64)),
            ("executed_wallclock", Json::num(o.executed_wallclock())),
            ("model", model_json(&o.model)),
        ]));
    }
    let mut plans = Vec::with_capacity(s.plans.len());
    for (n, p) in &s.plans {
        plans.push(node_plan_json(n, p));
    }
    Json::obj([
        ("outcomes", Json::Arr(outcomes)),
        ("plans", Json::Arr(plans)),
        ("cache", stats_json(&s.cache)),
    ])
}

fn fleet_plan_json(p: &FleetPlan) -> Json {
    let mut plans = Vec::with_capacity(p.plans.len());
    for (n, pl) in &p.plans {
        plans.push(node_plan_json(n, pl));
    }
    let mut migrations = Vec::with_capacity(p.migrations.len());
    for m in &p.migrations {
        migrations.push(Json::obj([
            ("job", Json::str(&m.job)),
            ("from", Json::str(m.from)),
            ("to", Json::str(m.to)),
            ("priority", Json::num(m.priority as f64)),
            ("limit", Json::num(m.limit)),
            ("slack_after", Json::num(m.slack_after)),
            ("needs_reprofile", Json::Bool(m.needs_reprofile)),
        ]));
    }
    let metrics = Json::obj([
        ("jobs", Json::num(p.metrics.jobs as f64)),
        ("guaranteed_before", Json::num(p.metrics.guaranteed_before as f64)),
        ("guaranteed_after", Json::num(p.metrics.guaranteed_after as f64)),
        ("total_capacity", Json::num(p.metrics.total_capacity)),
        ("total_assigned", Json::num(p.metrics.total_assigned)),
        ("utilization", Json::num(p.metrics.utilization())),
    ]);
    Json::obj([
        ("plans", Json::Arr(plans)),
        ("migrations", Json::Arr(migrations)),
        ("metrics", metrics),
    ])
}

fn verdict_json(v: &DriftVerdict) -> Json {
    let mut fields = vec![("kind", Json::str(v.name()))];
    match v {
        DriftVerdict::Stable => {}
        DriftVerdict::RateShift { provisioned_hz, observed_hz } => {
            fields.push(("provisioned_hz", Json::num(*provisioned_hz)));
            fields.push(("observed_hz", Json::num(*observed_hz)));
        }
        DriftVerdict::ModelStale { rolling_smape } => {
            fields.push(("rolling_smape", Json::num(*rolling_smape)));
        }
    }
    Json::obj(fields)
}

fn adaptive_json(a: &AdaptiveSummary) -> Json {
    let mut epochs = Vec::with_capacity(a.epochs.len());
    for e in &a.epochs {
        let mut verdicts = Vec::with_capacity(e.verdicts.len());
        for (name, v) in &e.verdicts {
            verdicts.push(Json::obj([
                ("job", Json::str(name)),
                ("verdict", verdict_json(v)),
            ]));
        }
        let mut reprofiled = Vec::with_capacity(e.reprofiled.len());
        for r in &e.reprofiled {
            reprofiled.push(Json::obj([
                ("name", Json::str(&r.name)),
                ("verdict", verdict_json(&r.verdict)),
                ("pre_smape", Json::num(r.pre_smape)),
                ("post_smape", Json::num(r.post_smape)),
                ("executed_probes", Json::num(r.executed_probes as f64)),
            ]));
        }
        let mut fields = vec![
            ("epoch", Json::num(e.epoch as f64)),
            ("verdicts", Json::Arr(verdicts)),
            ("reprofiled", Json::Arr(reprofiled)),
        ];
        if let Some(plan) = &e.plan {
            fields.push(("plan", fleet_plan_json(plan)));
        }
        epochs.push(Json::obj(fields));
    }
    let mut jobs = Vec::with_capacity(a.jobs.len());
    for j in &a.jobs {
        jobs.push(Json::obj([
            ("name", Json::str(&j.name)),
            ("label", Json::str(&j.label)),
            ("reprofiles", Json::num(j.reprofiles as f64)),
            ("rate_hz", Json::num(j.rate_hz)),
            ("limit", Json::num(j.limit)),
            ("model", model_json(&j.model)),
        ]));
    }
    Json::obj([
        ("epochs", Json::Arr(epochs)),
        ("jobs", Json::Arr(jobs)),
        ("adaptive_probe_executions", Json::num(a.adaptive_probe_executions as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ProfilerConfig;
    use crate::fleet::sim_fleet;
    use crate::util::json;

    fn quick_cfg() -> FleetConfig {
        FleetConfig {
            workers: 2,
            rounds: 1,
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 500,
            ..Default::default()
        }
    }

    #[test]
    fn builder_composes_jobs_and_stages() {
        let session = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(2, 3))
            .job(sim_fleet(3, 3).pop().unwrap())
            .rebalance(true)
            .build();
        assert_eq!(session.specs.len(), 3);
        assert!(session.rebalance);
        assert!(session.adaptive.is_none());
        assert_eq!(session.config().workers, 2);
    }

    #[test]
    fn session_runs_are_cache_replays() {
        let session = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(3, 5))
            .build();
        let first = session.run().unwrap();
        assert_eq!(first.cache.hits, 0, "cold run, distinct labels, one round");
        assert!(first.summary().executed_wallclock() > 0.0);
        let second = session.run().unwrap();
        assert_eq!(second.cache.misses, 0, "second run replays the session cache");
        assert!((second.hit_rate() - 1.0).abs() < 1e-12);
        assert_eq!(second.summary().executed_wallclock(), 0.0);
    }

    #[test]
    fn rebalance_stage_matches_summary_rebalanced() {
        let report = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(6, 7))
            .rebalance(true)
            .run()
            .unwrap();
        let plan = report.plan.as_ref().expect("rebalance stage ran");
        let again = report.summary().rebalanced();
        assert_eq!(plan.metrics.jobs, again.metrics.jobs);
        assert_eq!(plan.metrics.guaranteed_after, again.metrics.guaranteed_after);
        assert_eq!(plan.migrations.len(), again.migrations.len());
    }

    #[test]
    fn adaptive_stage_with_zero_epochs_composes_with_rebalance() {
        // epochs = 0: the adaptive stage degenerates to the cold sweep, so
        // the composed rebalance must equal the sweep-only rebalance.
        let base = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(4, 9))
            .rebalance(true)
            .run()
            .unwrap();
        let composed = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(4, 9))
            .rebalance(true)
            .adaptive(AdaptiveConfig { epochs: 0, ..Default::default() })
            .run()
            .unwrap();
        let ad = composed.adaptive.as_ref().expect("adaptive stage ran");
        assert!(ad.epochs.is_empty());
        let (a, b) = (base.plan.unwrap(), composed.plan.unwrap());
        assert_eq!(a.metrics.guaranteed_after, b.metrics.guaranteed_after);
        assert_eq!(a.guaranteed_jobs(), b.guaranteed_jobs());
    }

    #[test]
    fn mesh_session_reports_stats_and_serializes() {
        let topo = MeshTopology::parse("full:4").unwrap();
        let report = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(4, 7))
            .mesh(topo, MeshConfig { every: 100, rounds: 2 })
            .run()
            .unwrap();
        let stats = report.mesh.expect("mesh stats ride along");
        assert_eq!(stats.gossip_rounds, 2);
        assert!(report.plan.is_some(), "mesh drain reports its plan");
        let tree = report.to_json();
        let mesh = tree.get("mesh").expect("mesh block serialized");
        assert_eq!(mesh.get("gossip_rounds").and_then(Json::as_usize), Some(2));
        assert!(tree.get("rebalance").is_some(), "the mesh plan serializes like any plan");
    }

    #[test]
    fn report_json_parses_back() {
        let report = FleetSession::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(3, 11))
            .rebalance(true)
            .adaptive(AdaptiveConfig { epochs: 1, ..Default::default() })
            .run()
            .unwrap();
        let tree = report.to_json();
        let text = json::to_string(&tree);
        let parsed = json::parse(&text).expect("report JSON must parse back");
        assert_eq!(parsed, tree, "round-trip preserves the tree");
        assert_eq!(parsed.get("version").unwrap().as_usize(), Some(1));
        let outcomes = parsed
            .get("summary")
            .unwrap()
            .get("outcomes")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(outcomes.len(), 3);
        assert!(parsed.get("rebalance").is_some());
        assert!(parsed.get("adaptive").is_some());
        // Fingerprints are strings (u64 does not survive f64 JSON numbers).
        let fp = outcomes[0]
            .get("model")
            .unwrap()
            .get("fingerprint")
            .unwrap()
            .as_str()
            .unwrap();
        assert_eq!(fp.len(), 16);
    }
}
