//! Job→node placement: cross-node model translation and slack scoring.
//!
//! The fleet engine fits every job's [`RuntimeModel`] on the job's *home*
//! node. Black-box performance models transfer across heterogeneous
//! machines once the machines themselves are calibrated (Witt et al.,
//! arXiv 1805.11877), and our [`NodeSpec`] registry carries exactly that
//! calibration: a single-core speed factor, a parallel-scaling exponent,
//! and the limitation-axis stretch. [`translate_model`] maps a fitted
//! model from one node onto another through those factors, which makes
//! cross-node placement decidable from fitted models alone — no probe on
//! the candidate node is needed to predict the CPU limit a job would
//! require there.
//!
//! Candidate placements are scored by **slack**: the residual capacity the
//! destination would retain after granting the job its tightest feasible
//! limit. Placing into maximum slack keeps the fleet's remaining headroom
//! as even as possible, so later migrations stay feasible.

use std::collections::BTreeMap;

use crate::coordinator::{quote_for, JobManager};
use crate::fit::RuntimeModel;
use crate::simulator::NodeSpec;

use super::worker::JobOutcome;

/// The placement layer's view of one profiled job: everything needed to
/// decide where it could run, decoupled from how it was profiled.
#[derive(Clone, Debug)]
pub struct FleetJob {
    pub name: String,
    /// Home node — where the model was fitted.
    pub node: &'static NodeSpec,
    /// Runtime model fitted on the home node.
    pub model: RuntimeModel,
    /// Peak arrival rate (Hz) the placement must sustain.
    pub rate_hz: f64,
    pub priority: i32,
}

impl From<&JobOutcome> for FleetJob {
    fn from(o: &JobOutcome) -> Self {
        Self {
            name: o.name.clone(),
            node: o.node,
            model: o.model.clone(),
            rate_hz: o.rate_hz,
            priority: o.priority,
        }
    }
}

/// Translate a runtime model fitted on `from` into the equivalent model on
/// `to`, using the node calibration:
///
/// * scale parameters `a`, `c` grow by the inverse speed ratio (a slower
///   CPU inflates every per-sample runtime uniformly),
/// * the exponent `b` is rescaled by the ratio of parallel-scaling
///   exponents (Amdahl behaviour belongs to the machine, not the job),
/// * the limitation stretch `d` is renormalized between the two machines'
///   calibrated stretches.
///
/// The translation is exact for the calibrated curve family; per-node
/// saturation, scheduler wiggle, and the low-limit knee differ between
/// machines and remain as (bounded) translation error — see the tests.
pub fn translate_model(model: &RuntimeModel, from: &NodeSpec, to: &NodeSpec) -> RuntimeModel {
    let speed = from.runtime_factor_to(to);
    let mut m = model.clone();
    m.a *= speed;
    m.c *= speed;
    m.b *= from.scaling_factor_to(to);
    m.d *= to.limit_stretch() / from.limit_stretch();
    m
}

/// One scored candidate placement for a job.
#[derive(Clone, Debug)]
pub struct PlacementCandidate {
    /// Destination node name.
    pub node: &'static str,
    /// Tightest feasible CPU limit on the destination (translated model).
    pub limit: f64,
    /// Residual capacity the destination would retain after the grant.
    pub slack: f64,
    /// True when the granted limit lies *outside* the limit range both the
    /// home and destination node can assign (`min(from.cores, to.cores)`).
    /// Translation is only validated as interpolation on that shared range
    /// (see [`translate_model`]); a tighter placement is still offered, but
    /// flagged so the destination re-profiles before the limit is trusted.
    pub needs_reprofile: bool,
}

/// One node as seen through the mesh's gossip layer: its spec (static
/// calibration) plus the residual capacity it last advertised — everything
/// a [`super::mesh::LocalScheduler`] knows about a neighbor.
#[derive(Clone, Copy, Debug)]
pub struct NodeView {
    /// The advertised node.
    pub spec: &'static NodeSpec,
    /// Residual capacity from the node's last gossiped summary (possibly
    /// stale — that is the point of the mesh scheduler's optimism).
    pub residual: f64,
}

fn score_one(job: &FleetJob, view: &NodeView) -> Option<PlacementCandidate> {
    if view.spec.name == job.node.name {
        return None;
    }
    let translated = translate_model(&job.model, job.node, view.spec);
    let quote = quote_for(view.spec.cores, &translated, job.rate_hz);
    if !quote.feasible || quote.limit > view.residual + 1e-9 {
        return None;
    }
    let shared = job.node.cores.min(view.spec.cores);
    Some(PlacementCandidate {
        node: view.spec.name,
        limit: quote.limit,
        slack: view.residual - quote.limit,
        needs_reprofile: quote.limit > shared + 1e-9,
    })
}

fn sort_candidates(out: &mut [PlacementCandidate]) {
    // Validated (in-shared-range) placements always outrank extrapolated
    // ones; within a tier, largest slack wins, node name tie-breaks.
    out.sort_by(|x, y| {
        x.needs_reprofile
            .cmp(&y.needs_reprofile)
            .then_with(|| y.slack.partial_cmp(&x.slack).unwrap())
            .then_with(|| x.node.cmp(y.node))
    });
}

/// Score every node (except the job's home) that could guarantee `job`
/// from its residual capacity. Returns candidates sorted best-first:
/// validated-translation placements before `needs_reprofile` ones, then
/// largest slack, node name as the deterministic tie-break.
pub fn candidates_for(
    job: &FleetJob,
    managers: &BTreeMap<&'static str, (&'static NodeSpec, JobManager)>,
) -> Vec<PlacementCandidate> {
    let views: Vec<NodeView> = managers
        .values()
        .map(|(spec, mgr)| NodeView { spec, residual: mgr.residual_capacity() })
        .collect();
    candidates_among(job, &views)
}

/// [`candidates_for`] over gossiped [`NodeView`]s instead of live managers
/// — the same scoring, computed from whatever (possibly stale) residuals
/// the views carry. This is the only placement input the mesh scheduler's
/// per-node deciders get.
pub fn candidates_among(job: &FleetJob, views: &[NodeView]) -> Vec<PlacementCandidate> {
    let mut out: Vec<PlacementCandidate> =
        views.iter().filter_map(|v| score_one(job, v)).collect();
    sort_candidates(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::smape_vs_dataset;
    use crate::fit::ProfilePoint;
    use crate::simulator::{node, Algo, GroundTruth, NODES};

    /// Fit the runtime model on a node's noise-free ground-truth curve over
    /// its whole limitation grid — isolates translation error from
    /// profiling error.
    fn fit_on_truth(spec: &'static NodeSpec, algo: Algo) -> RuntimeModel {
        let truth = GroundTruth::derive(spec, algo);
        let pts: Vec<ProfilePoint> = spec
            .limit_grid()
            .iter()
            .map(|&r| ProfilePoint::new(r, truth.mean_runtime(r)))
            .collect();
        RuntimeModel::fit(&pts)
    }

    /// Noise-free target-node dataset over the limit range both machines
    /// can assign (translation is interpolation there; extrapolating past
    /// the source grid is unreliable — the recorded caveat).
    fn shared_truth(from: &NodeSpec, to: &'static NodeSpec, algo: Algo) -> Vec<ProfilePoint> {
        let truth = GroundTruth::derive(to, algo);
        let hi = from.cores.min(to.cores);
        to.limit_grid()
            .iter()
            .filter(|&&r| r <= hi + 1e-9)
            .map(|&r| ProfilePoint::new(r, truth.mean_runtime(r)))
            .collect()
    }

    #[test]
    fn translation_tracks_ground_truth_for_every_node_pair() {
        // Satellite acceptance: a model fitted on one node predicts within
        // tolerance on every other node's ground-truth curve, for every
        // ordered NODES pair, over the shared assignable limit range.
        let mut worst: (f64, String) = (0.0, String::new());
        let mut total = 0.0;
        let mut pairs = 0usize;
        for from in NODES {
            let model = fit_on_truth(from, Algo::Birch);
            for to in NODES {
                if from.name == to.name {
                    continue;
                }
                let translated = translate_model(&model, from, to);
                let dataset = shared_truth(from, to, Algo::Birch);
                let smape = smape_vs_dataset(&translated, &dataset);
                assert!(
                    smape < 0.55,
                    "{} -> {}: translated SMAPE {smape:.3} out of tolerance",
                    from.name,
                    to.name
                );
                if smape > worst.0 {
                    worst = (smape, format!("{} -> {}", from.name, to.name));
                }
                total += smape;
                pairs += 1;
            }
        }
        let mean = total / pairs as f64;
        assert!(mean < 0.35, "mean translated SMAPE {mean:.3} (worst {worst:?})");
    }

    #[test]
    fn translation_beats_untranslated_across_speed_gaps() {
        // Wherever the speed calibration differs materially, reading the
        // home-node model verbatim on the other machine must be clearly
        // worse than translating it.
        for from in NODES {
            let model = fit_on_truth(from, Algo::Arima);
            for to in NODES {
                let ratio = from.runtime_factor_to(to).max(to.runtime_factor_to(from));
                if from.name == to.name || ratio < 1.5 {
                    continue;
                }
                let dataset = shared_truth(from, to, Algo::Arima);
                let raw = smape_vs_dataset(&model, &dataset);
                let fixed = smape_vs_dataset(&translate_model(&model, from, to), &dataset);
                assert!(
                    fixed < raw,
                    "{} -> {}: translated {fixed:.3} not better than raw {raw:.3}",
                    from.name,
                    to.name
                );
            }
        }
    }

    #[test]
    fn translated_limit_prediction_is_near_truth() {
        // The placement question itself: predict the CPU limit a job needs
        // on node B from the model fitted on node A, and compare against
        // the limit B's own ground truth would demand. Must agree within
        // two grid steps for a mid-range budget.
        let pairs = [("wally", "pi4"), ("pi4", "wally"), ("e216", "e2small")];
        for (f, t) in pairs {
            let from = node(f).unwrap();
            let to = node(t).unwrap();
            let translated = translate_model(&fit_on_truth(from, Algo::Lstm), from, to);
            let truth = GroundTruth::derive(to, Algo::Lstm);
            // Budget: the true runtime at a quarter of the shared range —
            // squarely on the steep part of the curve, where limit
            // prediction is well conditioned (inverting the saturated
            // plateau is not; see the ROADMAP caveat).
            let mid = (0.25 * from.cores.min(to.cores)).max(0.2);
            let budget = truth.mean_runtime(mid);
            let grid = to.limit_grid();
            let want = grid
                .iter()
                .copied()
                .find(|&r| truth.mean_runtime(r) <= budget)
                .expect("budget reachable on truth");
            let got = grid
                .iter()
                .copied()
                .find(|&r| translated.eval(r) <= budget)
                .expect("budget reachable on translated model");
            let tol = (0.35 * want).max(0.2);
            assert!(
                (got - want).abs() <= tol + 1e-9,
                "{f} -> {t}: predicted limit {got} vs true {want}"
            );
        }
    }

    #[test]
    fn self_translation_is_identity() {
        let wally = node("wally").unwrap();
        let model = fit_on_truth(wally, Algo::Arima);
        let same = translate_model(&model, wally, wally);
        for &r in &[0.1, 0.5, 1.0, 4.0, 8.0] {
            assert!((same.eval(r) - model.eval(r)).abs() < 1e-12);
        }
    }

    #[test]
    fn candidates_are_sorted_by_slack_and_skip_home() {
        use crate::coordinator::ManagedJob;
        let wally = node("wally").unwrap();
        let e216 = node("e216").unwrap();
        let pi4 = node("pi4").unwrap();
        let mut managers: BTreeMap<&'static str, (&'static NodeSpec, JobManager)> =
            BTreeMap::new();
        for spec in [wally, e216, pi4] {
            managers.insert(spec.name, (spec, JobManager::new(spec.cores)));
        }
        let model = fit_on_truth(pi4, Algo::Arima);
        // Load wally so e216 has more residual slack.
        managers.get_mut("wally").unwrap().1.register(ManagedJob {
            name: "ballast".into(),
            model: translate_model(&model, pi4, wally),
            rate_hz: 4.0,
            priority: 1,
        });
        let job = FleetJob { name: "cam".into(), node: pi4, model, rate_hz: 4.0, priority: 1 };
        let cands = candidates_for(&job, &managers);
        assert!(!cands.is_empty());
        assert!(cands.iter().all(|c| c.node != "pi4"), "home node excluded");
        for w in cands.windows(2) {
            assert!(w[0].slack >= w[1].slack, "sorted best-first");
        }
        assert_eq!(cands[0].node, "e216", "idle 16-core node has max slack");
        for c in &cands {
            let (spec, _) = &managers[c.node];
            assert!(c.limit <= spec.cores + 1e-9);
            assert!(c.slack >= -1e-9);
            assert!(!c.needs_reprofile, "mid-range limits stay inside the shared range");
        }
    }

    #[test]
    fn extrapolated_limits_are_flagged_and_outranked() {
        use crate::fit::ModelKind;
        // Regression for the extrapolated-translation bug: a heavy job
        // homed on pi4 (4 cores) quotes ~5.9 cores on wally (8 cores) —
        // *outside* the shared limit range min(4, 8) where translation is
        // validated. The old scorer trusted that limit silently; it must
        // now surface as `needs_reprofile`.
        let pi4 = node("pi4").unwrap();
        let wally = node("wally").unwrap();
        let heavy = FleetJob {
            name: "heavy".into(),
            node: pi4,
            model: RuntimeModel {
                kind: ModelKind::Full,
                a: 1.95,
                b: 0.85,
                c: 0.001,
                d: 1.0,
                fit_cost: 0.0,
            },
            rate_hz: 10.0,
            priority: 1,
        };
        let mut managers: BTreeMap<&'static str, (&'static NodeSpec, JobManager)> =
            BTreeMap::new();
        managers.insert(wally.name, (wally, JobManager::new(wally.cores)));
        let cands = candidates_for(&heavy, &managers);
        assert_eq!(cands.len(), 1);
        let c = &cands[0];
        assert_eq!(c.node, "wally");
        assert!(c.limit > pi4.cores.min(wally.cores) + 1e-9, "limit {} extrapolates", c.limit);
        assert!(c.needs_reprofile, "out-of-shared-range placement must be flagged");

        // And a validated placement outranks a flagged one even when the
        // flagged one has more slack: add a fast 16-core machine where the
        // same job's limit (~1.3) sits inside the shared range.
        let fastbig: &'static NodeSpec = Box::leak(Box::new(NodeSpec {
            name: "fastbig",
            cores: 16.0,
            speed: 4.0,
            ..wally.clone()
        }));
        let views = [
            NodeView { spec: wally, residual: wally.cores },
            NodeView { spec: fastbig, residual: 2.0 },
        ];
        let cands = candidates_among(&heavy, &views);
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0].node, "fastbig", "validated placement ranks first");
        assert!(!cands[0].needs_reprofile);
        assert!(cands[1].needs_reprofile);
        assert!(
            cands[1].slack > cands[0].slack,
            "slack alone would have ranked the extrapolated candidate first"
        );
    }
}
