//! Drift-aware continuous profiling: the adaptive fleet loop.
//!
//! A fitted [`RuntimeModel`] is a snapshot — input rates shift, model
//! versions change, co-located load comes and goes — and the paper's
//! "short profiling phase" promise only holds if staleness is *detected*
//! rather than scheduled away with fixed re-profiling rounds. LOS (Becker
//! et al., 2021) re-evaluates placements periodically from local
//! knowledge; Witt et al. (2018) argue black-box performance models must
//! be continuously checked against observed-vs-predicted error. This
//! module does both for the fleet:
//!
//! * a per-job [`DriftMonitor`] tracks a rolling SMAPE window of
//!   observed-vs-predicted runtimes plus the stream's per-epoch peak rate,
//!   and raises a typed [`DriftVerdict`] — `Stable`, `RateShift`, or
//!   `ModelStale` — against configurable thresholds;
//! * the adaptive stage of [`super::FleetSession`] and
//!   [`super::FleetDaemon`] replaces fixed rounds: after one cold
//!   sweep it re-profiles **only** jobs whose verdict crossed a threshold,
//!   warm-starting the refit from the stale fit, bumping the measurement
//!   cache's label generation on `ModelStale` (so the re-profile executes
//!   fresh probes instead of replaying poisoned ones), and re-entering
//!   [`JobManager`] / [`super::migrate::rebalance`] so a downgraded job
//!   can move nodes. Live probes come from each job's
//!   [`super::BackendFactory::probe`] source, so drift monitoring makes no
//!   simulator assumption either.
//!
//! ```text
//!  epoch e:  ArrivalProcess::max_rate_in ─┐    ┌─ Stable     -> nothing
//!            live probes vs model.eval ───┴─ DriftMonitor
//!                                               ├─ RateShift  -> warm re-profile (cache replays)
//!                                               └─ ModelStale -> bump gen + evict + re-profile
//!                                          then: JobManager update -> plans -> rebalance
//! ```

use std::collections::{BTreeMap, VecDeque};

use anyhow::{ensure, Result};

use crate::coordinator::backend::ProfilingBackend;
use crate::coordinator::{quantile_model, JobManager, ManagedJob};
use crate::fit::RuntimeModel;
use crate::stats::smape_guarded;

use super::cache::{CacheStats, MeasurementCache};
use super::migrate::{rebalance, FleetPlan};
use super::placement::FleetJob;
use super::worker::{self, ProfilePass};
use super::{FleetConfig, FleetJobSpec, FleetSummary};

/// Drift-detection thresholds.
#[derive(Clone, Debug)]
pub struct DriftConfig {
    /// Rolling window length (observed-vs-predicted runtime pairs).
    pub window: usize,
    /// Pairs required before a `ModelStale` verdict may fire (guards the
    /// first epochs against single-probe noise).
    pub min_observations: usize,
    /// Rolling SMAPE above this ⇒ `ModelStale`. 0.25 needs a sustained
    /// ~1.7x runtime deviation — far above fit error + probe noise
    /// (≲ 0.1 combined on the simulated nodes), far below a real regime
    /// shift (a 3x slowdown scores 0.5).
    pub smape_threshold: f64,
    /// Relative peak-rate change above this ⇒ `RateShift`.
    pub rate_threshold: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        Self { window: 12, min_observations: 4, smape_threshold: 0.25, rate_threshold: 0.25 }
    }
}

/// What the monitor concluded about one job, one epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DriftVerdict {
    /// Model and provisioning still describe the job.
    Stable,
    /// The stream's peak rate moved past the threshold: the model is fine
    /// but the provisioning is not.
    RateShift { provisioned_hz: f64, observed_hz: f64 },
    /// Observed runtimes diverged from predictions: the fitted model no
    /// longer describes the job.
    ModelStale { rolling_smape: f64 },
}

impl DriftVerdict {
    pub fn is_drift(&self) -> bool {
        !matches!(self, DriftVerdict::Stable)
    }

    pub fn name(&self) -> &'static str {
        match self {
            DriftVerdict::Stable => "stable",
            DriftVerdict::RateShift { .. } => "rate-shift",
            DriftVerdict::ModelStale { .. } => "model-stale",
        }
    }
}

/// Per-job drift tracker: a rolling observed-vs-predicted runtime window
/// plus the latest peak-rate observation, judged against [`DriftConfig`].
pub struct DriftMonitor {
    cfg: DriftConfig,
    provisioned_hz: f64,
    observed_hz: f64,
    /// `(observed, predicted)` runtime pairs, oldest first.
    window: VecDeque<(f64, f64)>,
}

impl DriftMonitor {
    pub fn new(cfg: DriftConfig, provisioned_hz: f64) -> Self {
        Self { cfg, provisioned_hz, observed_hz: provisioned_hz, window: VecDeque::new() }
    }

    /// Record the stream's peak rate over the latest epoch window.
    pub fn observe_rate(&mut self, hz: f64) {
        self.observed_hz = hz;
    }

    /// Record one live runtime observation against the model's prediction.
    pub fn observe_runtime(&mut self, observed: f64, predicted: f64) {
        self.window.push_back((observed, predicted));
        while self.window.len() > self.cfg.window {
            self.window.pop_front();
        }
    }

    /// SMAPE of the rolling window (0 while empty).
    pub fn rolling_smape(&self) -> f64 {
        if self.window.is_empty() {
            return 0.0;
        }
        let observed: Vec<f64> = self.window.iter().map(|&(o, _)| o).collect();
        let predicted: Vec<f64> = self.window.iter().map(|&(_, p)| p).collect();
        smape_guarded(&observed, &predicted, 1e-9)
    }

    /// Judge the current state. Rate shifts outrank model staleness: a
    /// rate change invalidates the provisioning regardless of model fit,
    /// and re-provisioning is the cheaper response.
    pub fn verdict(&self) -> DriftVerdict {
        let rel = (self.observed_hz - self.provisioned_hz).abs() / self.provisioned_hz.max(1e-9);
        if rel > self.cfg.rate_threshold {
            return DriftVerdict::RateShift {
                provisioned_hz: self.provisioned_hz,
                observed_hz: self.observed_hz,
            };
        }
        if self.window.len() >= self.cfg.min_observations {
            let s = self.rolling_smape();
            if s > self.cfg.smape_threshold {
                return DriftVerdict::ModelStale { rolling_smape: s };
            }
        }
        DriftVerdict::Stable
    }

    /// Re-arm after a re-profile: the window is cleared (old pairs judged
    /// a dead model) and the provisioned rate becomes the observed one.
    pub fn rearm(&mut self, provisioned_hz: f64) {
        self.window.clear();
        self.provisioned_hz = provisioned_hz;
        self.observed_hz = provisioned_hz;
    }
}

/// Stable fingerprint of a fitted model (FNV-1a over the member kind and
/// the exact parameter bits) — how the scenario tests assert that a job's
/// model was, or was not, touched.
pub fn model_fingerprint(m: &RuntimeModel) -> u64 {
    let params = [m.a, m.b, m.c, m.d]
        .into_iter()
        .flat_map(|v| v.to_bits().to_le_bytes());
    crate::util::fnv1a(m.kind.name().bytes().chain(params))
}

/// An injected runtime regime change for one job (a model-version upgrade
/// or a heavier input regime): from virtual tick `at_tick`, every observed
/// per-sample runtime is scaled by `scale`.
#[derive(Clone, Copy, Debug)]
pub struct RuntimeShift {
    pub at_tick: usize,
    pub scale: f64,
}

/// Configuration of the adaptive loop.
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Adaptation epochs after the cold sweep.
    pub epochs: usize,
    /// Virtual ticks per epoch. Epoch `e` observes runtime probes over
    /// the window `[horizon + (e-1)·epoch_ticks, horizon + e·epoch_ticks)`;
    /// the rate tracker looks back over `max(epoch_ticks, horizon)` ticks
    /// ending at the epoch boundary, so epochs shorter than a periodic
    /// stream's period cannot alias its trough into a rate-shift verdict
    /// (the flip side: rate *drops* only register once the old peak ages
    /// out of that lookback).
    pub epoch_ticks: usize,
    /// Live runtime probes per job per epoch.
    pub probes_per_epoch: usize,
    /// Samples averaged per live probe (tames per-sample noise).
    pub probe_samples: usize,
    pub drift: DriftConfig,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            epochs: 3,
            epoch_ticks: 500,
            probes_per_epoch: 6,
            probe_samples: 400,
            drift: DriftConfig::default(),
        }
    }
}

/// One drift-triggered re-profile.
#[derive(Clone, Debug)]
pub struct ReprofiledJob {
    pub name: String,
    pub verdict: DriftVerdict,
    /// Rolling SMAPE at verdict time (pre-adaptation).
    pub pre_smape: f64,
    /// Rolling SMAPE over fresh probes of the new fit (post-adaptation).
    pub post_smape: f64,
    /// Probes the re-profile actually executed (cache misses; a
    /// `RateShift` re-profile replays from the still-fresh cache).
    pub executed_probes: u64,
}

/// One adaptation epoch's outcome.
pub struct EpochReport {
    pub epoch: usize,
    /// Every job's verdict this epoch, in submission order.
    pub verdicts: Vec<(String, DriftVerdict)>,
    pub reprofiled: Vec<ReprofiledJob>,
    /// Fleet-wide rebalanced plan — present only when something was
    /// re-profiled (stable epochs change nothing).
    pub plan: Option<FleetPlan>,
}

/// Final per-job state after the adaptive run.
#[derive(Clone, Debug)]
pub struct AdaptiveJobReport {
    pub name: String,
    pub label: String,
    /// Drift-triggered re-profiles of this job (0 = untouched).
    pub reprofiles: usize,
    /// Fingerprint of the final model ([`model_fingerprint`]).
    pub fingerprint: u64,
    pub model: RuntimeModel,
    pub rate_hz: f64,
    /// CPU limit the job's node plan currently grants it.
    pub limit: f64,
}

/// Everything a completed adaptive run reports.
pub struct AdaptiveSummary {
    /// The cold sweep every epoch adapted from.
    pub initial: FleetSummary,
    pub epochs: Vec<EpochReport>,
    /// Final per-job state, in submission order.
    pub jobs: Vec<AdaptiveJobReport>,
    /// Cache statistics of the whole adaptive run (cold sweep included).
    pub cache: CacheStats,
    /// Probes executed during the adaptation epochs (cache misses — the
    /// cost the drift gating actually paid).
    pub adaptive_probe_executions: u64,
}

impl AdaptiveSummary {
    /// Names of jobs re-profiled at least once, in first-event order.
    pub fn reprofiled_names(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for e in &self.epochs {
            for r in &e.reprofiled {
                if !out.contains(&r.name.as_str()) {
                    out.push(&r.name);
                }
            }
        }
        out
    }

    /// What naive adaptation — re-profiling *every* job with invalidated
    /// caches in each epoch that saw drift — would have executed: the
    /// per-sweep probe count times the number of drift epochs.
    pub fn naive_probe_executions(&self) -> u64 {
        let per_sweep: u64 = self
            .initial
            .outcomes
            .iter()
            .map(|o| o.rounds.first().map_or(0, |r| r.steps.len()) as u64)
            .sum();
        let drift_epochs = self.epochs.iter().filter(|e| !e.reprofiled.is_empty()).count();
        per_sweep * drift_epochs as u64
    }

    pub fn job(&self, name: &str) -> Option<&AdaptiveJobReport> {
        self.jobs.iter().find(|j| j.name == name)
    }
}

/// Mutable per-job state the adaptive loop carries across epochs.
struct LiveJob {
    spec: FleetJobSpec,
    model: RuntimeModel,
    rate_hz: f64,
    limit: f64,
    monitor: DriftMonitor,
    /// Independent observation source for live probes
    /// ([`super::BackendFactory::probe`]) — distinct from the profiling
    /// replays, so probes are fresh draws, not cached ones. `None` when
    /// the adaptive run has zero epochs: no probe is ever drawn, so no
    /// backend is built (a PJRT probe costs a full engine load).
    probe: Option<Box<dyn ProfilingBackend>>,
    reprofiles: usize,
}

impl LiveJob {
    /// The injected runtime scale active for an epoch starting at `tick`.
    fn scale_at(&self, tick: usize) -> f64 {
        match self.spec.runtime_shift {
            Some(s) if tick >= s.at_tick => s.scale,
            _ => 1.0,
        }
    }

    /// Draw one live observation and feed the monitor.
    fn probe_once(&mut self, samples: usize, scale: f64) {
        let probe = self.probe.as_mut().expect("probes are only drawn when epochs > 0");
        let observed = probe.measure(self.limit, samples).mean_runtime * scale;
        self.monitor.observe_runtime(observed, self.model.eval(self.limit));
    }
}

/// Drift-aware continuous profiling: one cold sweep, then `epochs`
/// adaptation rounds that re-profile **only** drifted jobs — the adaptive
/// stage behind [`super::FleetSession`] and [`super::FleetDaemon`].
///
/// [`AdaptiveLoop::start`] validates the scenario and runs the cold
/// sweep; each [`AdaptiveLoop::run_epoch`] call performs one adaptation
/// epoch (the daemon fires one per `EpochTick` event, the batch session
/// replays them back-to-back); [`AdaptiveLoop::finish`] consumes the
/// loop into an [`AdaptiveSummary`].
///
/// Per epoch, per job: observe the stream's peak rate over the epoch
/// window and a handful of live runtimes against the model's
/// predictions; ask the [`DriftMonitor`] for a verdict. On drift:
/// `ModelStale` bumps the measurement cache's label generation and
/// evicts the stale entries (the re-profile must execute, not replay
/// poisoned measurements), `RateShift` keeps the cache (the behaviour
/// is unchanged — the warm re-profile replays at near-zero cost);
/// either way the session warm-starts from the stale fit, the job
/// re-enters its [`JobManager`] with the new model and rate, node
/// plans are recomputed, and the fleet is rebalanced so downgraded
/// jobs can move. With zero drift this performs zero re-profiles and
/// the `initial` summary is byte-identical to the plain sweep.
pub(crate) struct AdaptiveLoop {
    cfg: FleetConfig,
    acfg: AdaptiveConfig,
    managers: BTreeMap<&'static str, JobManager>,
    live: Vec<LiveJob>,
    epochs: Vec<EpochReport>,
    initial: FleetSummary,
    stats_start: CacheStats,
    stats_after_sweep: CacheStats,
}

impl AdaptiveLoop {
    /// Validate the scenario, run the cold sweep, and arm one
    /// [`DriftMonitor`] per job.
    pub(crate) fn start(
        cfg: &FleetConfig,
        cache: &MeasurementCache,
        pool: &super::ProbePool,
        specs: Vec<FleetJobSpec>,
        acfg: &AdaptiveConfig,
    ) -> Result<Self> {
        ensure!(acfg.epochs == 0 || acfg.epoch_ticks > 0, "adaptive epochs need epoch_ticks > 0");
        ensure!(acfg.drift.window > 0, "drift window must be non-empty");
        ensure!(
            acfg.drift.min_observations <= acfg.drift.window,
            "min_observations exceeds the rolling window"
        );
        // The measurement cache is shared per label (= job class): jobs of
        // one class on one device replay each other's probes, so a runtime
        // shift that applies to only some of them would let a drifted
        // re-profile poison its undrifted siblings' entries (and vice
        // versa). Reject such scenarios up front.
        for a in &specs {
            for b in &specs {
                if a.label() != b.label() {
                    continue;
                }
                let same = match (&a.runtime_shift, &b.runtime_shift) {
                    (None, None) => true,
                    (Some(x), Some(y)) => x.at_tick == y.at_tick && x.scale == y.scale,
                    _ => false,
                };
                ensure!(
                    same,
                    "jobs '{}' and '{}' share cache label '{}' but have different \
                     runtime shifts — a class drifts as a whole",
                    a.name,
                    b.name,
                    a.label()
                );
            }
        }
        let stats_start = cache.stats();
        let initial = super::run_sweep(cfg, pool, specs.clone())?;
        let stats_after_sweep = cache.stats();

        // Mirror the cold sweep's per-node managers: the adaptive loop
        // re-enters them in place instead of rebuilding the world.
        let mut managers: BTreeMap<&'static str, JobManager> = BTreeMap::new();
        let mut live: Vec<LiveJob> = Vec::with_capacity(initial.outcomes.len());
        for o in &initial.outcomes {
            let spec = specs
                .iter()
                .find(|s| s.name == o.name)
                .expect("outcome names mirror submitted specs")
                .clone();
            let mut managed = ManagedJob {
                name: o.name.clone(),
                model: o.model.clone(),
                rate_hz: o.rate_hz,
                priority: o.priority,
            };
            if let Some(q) = cfg.plan_quantile {
                // Quantile-aware admission: plan the tail, not the mean.
                managed = managed.at_quantile(q, o.residual_spread());
            }
            managers
                .entry(o.node.name)
                .or_insert_with(|| JobManager::new(o.node.cores))
                .register(managed);
            let limit = initial
                .assignment(&o.name)
                .map(|a| a.adjustment.limit)
                .unwrap_or(o.node.cores);
            let probe = match acfg.epochs {
                0 => None,
                _ => Some(spec.backend.probe()?),
            };
            live.push(LiveJob {
                monitor: DriftMonitor::new(acfg.drift.clone(), o.rate_hz),
                probe,
                model: o.model.clone(),
                rate_hz: o.rate_hz,
                limit,
                reprofiles: 0,
                spec,
            });
        }
        Ok(Self {
            cfg: cfg.clone(),
            acfg: acfg.clone(),
            managers,
            live,
            epochs: Vec::with_capacity(acfg.epochs),
            initial,
            stats_start,
            stats_after_sweep,
        })
    }

    /// The cold bootstrap sweep the loop adapted from (the daemon's
    /// telemetry recorder emits its runtime observations at bootstrap).
    pub(crate) fn initial_summary(&self) -> &FleetSummary {
        &self.initial
    }

    /// Run the next adaptation epoch (numbered from 1) and return its
    /// report. Errors once all configured epochs have run.
    pub(crate) fn run_epoch(&mut self, cache: &MeasurementCache) -> Result<&EpochReport> {
        let e = self.epochs.len() + 1;
        ensure!(e <= self.acfg.epochs, "adaptive loop already ran every configured epoch");
        let start = self.cfg.horizon + (e - 1) * self.acfg.epoch_ticks;
        let end = start + self.acfg.epoch_ticks;

        // Phase 1: observe every job, collect verdicts. The rate
        // tracker looks back over at least the provisioning horizon:
        // the provisioned rate is a peak over a horizon-length window,
        // so comparing it against the peak of a shorter epoch window
        // would alias the trough of a periodic (`Varying`) stream into
        // a spurious RateShift. Rises register immediately; drops
        // register once the old peak ages out of the lookback.
        let lookback = self.acfg.epoch_ticks.max(self.cfg.horizon);
        let mut verdicts: Vec<(String, DriftVerdict)> = Vec::with_capacity(self.live.len());
        let mut drifted: Vec<usize> = Vec::new();
        for (i, job) in self.live.iter_mut().enumerate() {
            let rate_window = (end.saturating_sub(lookback), end);
            job.monitor.observe_rate(
                job.spec
                    .arrivals
                    .max_rate_in(rate_window.0, rate_window.1)
                    .max(1e-6),
            );
            // Probes are spread across the epoch window, each under
            // the regime active at its own tick, so a mid-epoch
            // runtime shift is partially visible this epoch instead of
            // invisible until the next.
            for k in 0..self.acfg.probes_per_epoch {
                let tick = start + k * self.acfg.epoch_ticks / self.acfg.probes_per_epoch.max(1);
                job.probe_once(self.acfg.probe_samples, job.scale_at(tick));
            }
            let verdict = job.monitor.verdict();
            if verdict.is_drift() {
                drifted.push(i);
            }
            verdicts.push((job.spec.name.clone(), verdict));
        }

        // Phase 2: re-profile exactly the drifted jobs, warm-started.
        let mut reprofiled: Vec<ReprofiledJob> = Vec::with_capacity(drifted.len());
        for &i in &drifted {
            let job = &mut self.live[i];
            let verdict = verdicts[i].1;
            let pre_smape = job.monitor.rolling_smape();
            if matches!(verdict, DriftVerdict::ModelStale { .. }) {
                cache.bump_generation(&job.spec.label());
                cache.evict_stale();
            }
            let observed_hz = job.monitor.observed_hz;
            let pass = ProfilePass {
                // Profile the regime current at the END of the observed
                // window — a shift that landed mid-epoch must not leave
                // the re-profile measuring the dead old regime.
                runtime_scale: Some(job.scale_at(end - 1)),
                prior: Some(job.model.clone()),
                // A stale model's cached probes are poisoned, so the
                // session searches warm from the old fit; a rate shift
                // leaves behaviour (and cache) intact, so the session
                // replays the cold sweep's decisions for free.
                session_warm: matches!(verdict, DriftVerdict::ModelStale { .. }),
                rate_hz: Some(observed_hz),
                rounds: Some(1),
                transfer: None,
            };
            let outcome =
                worker::profile_job_with(&job.spec, &self.cfg, cache, 0, &pass)?;
            // The outcome's own cache tally, not a global before/after
            // miss delta: exact even while pool workers probe the shared
            // cache concurrently.
            let executed_probes = outcome.cache_delta.misses;
            let spread = outcome.residual_spread();
            job.model = outcome.model;
            job.rate_hz = observed_hz;
            job.reprofiles += 1;
            // The manager keeps planning at the configured quantile even
            // as re-profiles refresh the underlying mean curve.
            let planned = match self.cfg.plan_quantile {
                Some(q) => quantile_model(&job.model, q, spread),
                None => job.model.clone(),
            };
            let mgr = self.managers.get_mut(job.spec.node.name).expect("home manager exists");
            mgr.update_model(&job.spec.name, planned);
            mgr.update_rate(&job.spec.name, job.rate_hz);
            reprofiled.push(ReprofiledJob {
                name: job.spec.name.clone(),
                verdict,
                pre_smape,
                post_smape: f64::NAN, // filled in phase 3
                executed_probes,
            });
        }

        // Phase 3: with fresh models in the managers, recompute node
        // plans, refresh every job's granted limit, rebalance the
        // fleet, and re-arm + re-judge the re-profiled monitors.
        let plan = if reprofiled.is_empty() {
            None
        } else {
            let plans: BTreeMap<&str, crate::coordinator::CapacityPlan> =
                self.managers.iter().map(|(&n, m)| (n, m.plan())).collect();
            for job in self.live.iter_mut() {
                if let Some(a) = plans[job.spec.node.name]
                    .assignments
                    .iter()
                    .find(|a| a.name == job.spec.name)
                {
                    job.limit = a.adjustment.limit;
                }
            }
            for (r, &i) in reprofiled.iter_mut().zip(&drifted) {
                let job = &mut self.live[i];
                let scale = job.scale_at(end - 1);
                job.monitor.rearm(job.rate_hz);
                for _ in 0..self.acfg.drift.min_observations {
                    job.probe_once(self.acfg.probe_samples, scale);
                }
                r.post_smape = job.monitor.rolling_smape();
            }
            let fleet_jobs: Vec<FleetJob> = self
                .live
                .iter()
                .map(|j| FleetJob {
                    name: j.spec.name.clone(),
                    node: j.spec.node,
                    model: j.model.clone(),
                    rate_hz: j.rate_hz,
                    priority: j.spec.priority,
                })
                .collect();
            Some(rebalance(&fleet_jobs))
        };
        self.epochs.push(EpochReport { epoch: e, verdicts, reprofiled, plan });
        Ok(self.epochs.last().expect("epoch report just pushed"))
    }

    /// Consume the loop into its summary: final per-job state plus the
    /// cache traffic attributable to this adaptive run.
    pub(crate) fn finish(self, cache: &MeasurementCache) -> AdaptiveSummary {
        let stats_end = cache.stats();
        let jobs = self
            .live
            .into_iter()
            .map(|j| AdaptiveJobReport {
                name: j.spec.name.clone(),
                label: j.spec.label(),
                reprofiles: j.reprofiles,
                fingerprint: model_fingerprint(&j.model),
                model: j.model,
                rate_hz: j.rate_hz,
                limit: j.limit,
            })
            .collect();
        AdaptiveSummary {
            initial: self.initial,
            epochs: self.epochs,
            jobs,
            cache: stats_end.delta_since(&self.stats_start),
            adaptive_probe_executions: stats_end.misses - self.stats_after_sweep.misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::ModelKind;

    fn cfg() -> DriftConfig {
        DriftConfig::default()
    }

    fn model(a: f64) -> RuntimeModel {
        RuntimeModel { kind: ModelKind::Full, a, b: 1.0, c: 0.001, d: 1.0, fit_cost: 0.0 }
    }

    #[test]
    fn monitor_is_stable_on_accurate_predictions() {
        let mut mon = DriftMonitor::new(cfg(), 4.0);
        mon.observe_rate(4.0);
        for _ in 0..20 {
            mon.observe_runtime(0.102, 0.100); // 2% off: healthy fit noise
        }
        assert_eq!(mon.verdict(), DriftVerdict::Stable);
        assert!(mon.rolling_smape() < 0.02);
    }

    #[test]
    fn rate_shift_fires_past_the_threshold_and_outranks_staleness() {
        let mut mon = DriftMonitor::new(cfg(), 4.0);
        mon.observe_rate(4.9); // +22.5% < 25%
        assert_eq!(mon.verdict(), DriftVerdict::Stable);
        mon.observe_rate(5.2); // +30%
        assert!(matches!(
            mon.verdict(),
            DriftVerdict::RateShift { provisioned_hz, observed_hz }
                if provisioned_hz == 4.0 && observed_hz == 5.2
        ));
        // A rate drop of the same magnitude fires too.
        mon.observe_rate(2.0);
        assert!(matches!(mon.verdict(), DriftVerdict::RateShift { .. }));
        // With a simultaneously stale model, the rate shift wins.
        for _ in 0..12 {
            mon.observe_runtime(0.3, 0.1);
        }
        assert!(matches!(mon.verdict(), DriftVerdict::RateShift { .. }));
        mon.observe_rate(4.0);
        assert!(matches!(mon.verdict(), DriftVerdict::ModelStale { .. }));
    }

    #[test]
    fn staleness_needs_min_observations_and_a_real_deviation() {
        let mut mon = DriftMonitor::new(cfg(), 4.0);
        // Three wildly wrong pairs: below min_observations, still stable.
        for _ in 0..3 {
            mon.observe_runtime(0.5, 0.1);
        }
        assert_eq!(mon.verdict(), DriftVerdict::Stable, "needs min_observations");
        mon.observe_runtime(0.5, 0.1);
        let v = mon.verdict();
        assert!(matches!(v, DriftVerdict::ModelStale { rolling_smape } if rolling_smape > 0.6));
        assert!(v.is_drift());
        assert_eq!(v.name(), "model-stale");
    }

    #[test]
    fn window_rolls_and_rearm_clears() {
        let mut mon = DriftMonitor::new(cfg(), 4.0);
        // Fill the window with stale pairs, then push 12 accurate ones:
        // the stale pairs must roll out entirely.
        for _ in 0..12 {
            mon.observe_runtime(0.5, 0.1);
        }
        assert!(mon.verdict().is_drift());
        for _ in 0..12 {
            mon.observe_runtime(0.1, 0.1);
        }
        assert_eq!(mon.verdict(), DriftVerdict::Stable);
        assert!(mon.rolling_smape() < 1e-12);
        // rearm resets both the window and the provisioned rate.
        mon.observe_rate(9.0);
        for _ in 0..12 {
            mon.observe_runtime(0.5, 0.1);
        }
        mon.rearm(9.0);
        assert_eq!(mon.verdict(), DriftVerdict::Stable);
        assert_eq!(mon.rolling_smape(), 0.0);
    }

    #[test]
    fn smape_of_a_3x_shift_clears_the_default_threshold() {
        // The calibration the defaults rely on: a 3x regime shift scores
        // |3m - m| / (3m + m) = 0.5, twice the 0.25 threshold, while a
        // 20% fit error scores ~0.09, comfortably under it.
        let mut mon = DriftMonitor::new(cfg(), 4.0);
        for _ in 0..6 {
            mon.observe_runtime(0.3, 0.1);
        }
        assert!((mon.rolling_smape() - 0.5).abs() < 1e-12);
        let mut ok = DriftMonitor::new(cfg(), 4.0);
        for _ in 0..6 {
            ok.observe_runtime(0.12, 0.1);
        }
        assert!(ok.rolling_smape() < 0.1);
        assert_eq!(ok.verdict(), DriftVerdict::Stable);
    }

    #[test]
    fn fingerprint_is_stable_and_parameter_sensitive() {
        let m = model(0.05);
        assert_eq!(model_fingerprint(&m), model_fingerprint(&m.clone()));
        let mut other = model(0.05);
        other.a = 0.05 + 1e-15;
        assert_ne!(model_fingerprint(&m), model_fingerprint(&other), "ulp-sensitive");
        let mut kind = model(0.05);
        kind.kind = ModelKind::PowerLaw;
        assert_ne!(model_fingerprint(&m), model_fingerprint(&kind), "kind-sensitive");
        // fit_cost is bookkeeping, not identity.
        let mut cost = model(0.05);
        cost.fit_cost = 123.0;
        assert_eq!(model_fingerprint(&m), model_fingerprint(&cost));
    }
}
