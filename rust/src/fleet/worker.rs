//! The fleet worker task: one registered job, profiled through the shared
//! measurement cache with incremental model refits.
//!
//! A worker repeatedly pulls job tasks from the [`super::queue::WorkQueue`]
//! — its own striped lane first, stealing from the other lanes only once
//! that lane is dry — and runs `rounds` profiling sessions per job (round
//! 0 is the cold
//! profile; later rounds are the periodic re-profiles of the paper's
//! adaptive loop, which the cache turns into near-free replays). Every
//! measurement — cached or executed — lands in the job's
//! [`IncrementalModel`], which refits warm from the previous parameters
//! instead of from scratch.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::backend::{BackendFactory, Measurement, ProfilingBackend};
use crate::coordinator::{PriorGate, PriorVerdict, Profiler, SessionPrior, SessionResult};
use crate::earlystop::EarlyStopConfig;
use crate::fit::{ProfilePoint, RuntimeModel};
use crate::strategies::{self, grid_bucket};

use super::cache::{CacheStats, CachedBackend, MeasurementCache};
use super::transfer::{TransferOutcome, TransferPrior, TransferSeed};
use super::{FleetConfig, FleetJobSpec};

/// A runtime model maintained across measurements: each new observation
/// warm-starts the refit from the previous parameters (the NMS reuse,
/// §III-B.3, applied fleet-wide) instead of refitting cold.
pub struct IncrementalModel {
    delta: f64,
    points: Vec<ProfilePoint>,
    model: RuntimeModel,
    refits: usize,
}

impl IncrementalModel {
    pub fn new(delta: f64) -> Self {
        Self { delta, points: Vec::new(), model: RuntimeModel::identity(), refits: 0 }
    }

    /// Start from a stale fit instead of the neutral identity: the first
    /// observation already refits warm from `prior`'s parameters — how a
    /// drift-triggered re-profile reuses what the old model knew.
    pub fn warm(delta: f64, prior: RuntimeModel) -> Self {
        Self { delta, points: Vec::new(), model: prior, refits: 0 }
    }

    /// Fold one measurement in. A repeated probe of the same grid bucket
    /// (a re-profiling round or a cache replay) *replaces* the stale point
    /// rather than double-weighting it.
    pub fn observe(&mut self, m: &Measurement) {
        let bucket = grid_bucket(m.limit, self.delta);
        let point = ProfilePoint::new(m.limit, m.mean_runtime);
        match self
            .points
            .iter()
            .position(|p| grid_bucket(p.limit, self.delta) == bucket)
        {
            Some(i) => self.points[i] = point,
            None => self.points.push(point),
        }
        self.model = RuntimeModel::fit_warm(&self.points, Some(&self.model));
        self.refits += 1;
    }

    pub fn model(&self) -> &RuntimeModel {
        &self.model
    }

    pub fn points(&self) -> &[ProfilePoint] {
        &self.points
    }

    /// Total refits performed (one per observed measurement).
    pub fn refits(&self) -> usize {
        self.refits
    }
}

/// Outcome of profiling one fleet job (all rounds).
pub struct JobOutcome {
    /// Position of the job in the submitted spec list (used to restore a
    /// stable order after the pool finishes out of order).
    pub index: usize,
    pub name: String,
    /// Measurement-cache label reported by the job's [`BackendFactory`]
    /// (e.g. `"pi4/arima"`, `"pjrt/lstm"`).
    pub label: String,
    /// Placement home the fitted model was registered on.
    pub node: &'static crate::simulator::NodeSpec,
    /// One session per profiling round, in order.
    pub rounds: Vec<SessionResult>,
    /// Incrementally refit model over all rounds.
    pub model: RuntimeModel,
    /// Distinct grid points backing the model.
    pub points: usize,
    /// Model refits performed while measurements landed.
    pub refits: usize,
    /// Arrival rate (Hz) the job must sustain (peak over the horizon).
    pub rate_hz: f64,
    pub priority: i32,
    /// Home lane the job was dispatched on (sweeps normalize this to
    /// `index % workers`; the daemon's replan path reports lane 0),
    /// keeping reports independent of which thread stole the task.
    pub worker: usize,
    /// Measurement-cache traffic this profile caused (its `misses` are the
    /// probes actually executed). Not serialized into reports — it exists
    /// so the daemon's overlapped completion path can account cache deltas
    /// deterministically without re-aggregating the shared cache.
    pub cache_delta: CacheStats,
    /// How the transfer prior fared, when the profile was primed from a
    /// donor curve (`None` for cold profiles). Not serialized into reports
    /// — a rejected-prior report stays byte-identical to the cold path.
    pub transfer: Option<TransferOutcome>,
}

impl JobOutcome {
    /// Profiling wallclock actually spent (cache hits cost zero).
    pub fn executed_wallclock(&self) -> f64 {
        self.rounds.iter().map(|s| s.total_time).sum()
    }

    /// Mean relative residual of the fitted model against every probed
    /// step — the spread quantile-aware capacity planning inflates by
    /// ([`ManagedJob::at_quantile`]).
    ///
    /// [`ManagedJob::at_quantile`]: crate::coordinator::ManagedJob::at_quantile
    pub fn residual_spread(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for session in &self.rounds {
            for step in &session.steps {
                if step.mean_runtime.abs() > 1e-12 {
                    sum += ((self.model.eval(step.limit) - step.mean_runtime)
                        / step.mean_runtime)
                        .abs();
                    n += 1;
                }
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }
}

/// Backend decorator that scales every observed runtime (and the wallclock
/// spent observing it) by a constant factor — the injected regime shift of
/// the drift scenarios: a model-version upgrade or a heavier input regime
/// makes the same black box uniformly slower.
pub struct ScaledBackend<B: ProfilingBackend> {
    inner: B,
    scale: f64,
}

impl<B: ProfilingBackend> ScaledBackend<B> {
    pub fn new(inner: B, scale: f64) -> Self {
        debug_assert!(scale > 0.0);
        Self { inner, scale }
    }

    fn apply(&self, mut m: Measurement) -> Measurement {
        m.mean_runtime *= self.scale;
        m.wallclock *= self.scale;
        m
    }
}

impl<B: ProfilingBackend> ProfilingBackend for ScaledBackend<B> {
    fn measure(&mut self, limit: f64, samples: usize) -> Measurement {
        let m = self.inner.measure(limit, samples);
        self.apply(m)
    }

    fn measure_early_stop(
        &mut self,
        limit: f64,
        cfg: &EarlyStopConfig,
        cap: usize,
    ) -> Measurement {
        let m = self.inner.measure_early_stop(limit, cfg, cap);
        self.apply(m)
    }

    fn l_max(&self) -> f64 {
        self.inner.l_max()
    }

    fn label(&self) -> String {
        self.inner.label()
    }
}

/// [`BackendFactory`] decorator scaling every backend the inner factory
/// builds (probes included) — how a uniformly-slower variant of a job
/// class (heavier input regime, model-version upgrade) plugs into the
/// fleet pipeline without a dedicated backend type.
///
/// The label is suffixed with the scale: a scaled variant does **not**
/// describe runtime behaviour interchangeable with its base class, so it
/// must not share the base class's cache entries (the factory contract).
pub struct ScaledBackendFactory {
    inner: Arc<dyn BackendFactory>,
    scale: f64,
}

impl ScaledBackendFactory {
    pub fn new(inner: Arc<dyn BackendFactory>, scale: f64) -> Self {
        debug_assert!(scale > 0.0);
        Self { inner, scale }
    }

    pub fn shared(inner: Arc<dyn BackendFactory>, scale: f64) -> Arc<dyn BackendFactory> {
        Arc::new(Self::new(inner, scale))
    }
}

impl BackendFactory for ScaledBackendFactory {
    fn build(&self) -> Result<Box<dyn ProfilingBackend>> {
        Ok(Box::new(ScaledBackend::new(self.inner.build()?, self.scale)))
    }

    fn probe(&self) -> Result<Box<dyn ProfilingBackend>> {
        Ok(Box::new(ScaledBackend::new(self.inner.probe()?, self.scale)))
    }

    fn label(&self) -> String {
        format!("{}@x{}", self.inner.label(), self.scale)
    }
}

/// Options for a (re-)profiling pass beyond the cold-start defaults; the
/// adaptive loop's seam into [`profile_job_with`].
#[derive(Clone, Debug, Default)]
pub struct ProfilePass {
    /// Scale every observed runtime (the injected regime shift). `None` =
    /// unshifted behaviour.
    pub runtime_scale: Option<f64>,
    /// Warm-start the incremental model from a stale fit instead of
    /// fitting cold.
    pub prior: Option<RuntimeModel>,
    /// Additionally seed the *session's* own fits from `prior`
    /// ([`Profiler::run_observed_from`]), steering which limits the
    /// strategy picks. Leave `false` when the cached measurements are
    /// still valid (a rate shift): the session then replays the cold
    /// sweep's decisions byte-for-byte and the cache serves every probe.
    pub session_warm: bool,
    /// Provision for this arrival rate instead of the spec's horizon peak
    /// (the drift monitor's current observation).
    pub rate_hz: Option<f64>,
    /// Sessions to run (`None` = the engine's configured `rounds`); a
    /// drift-triggered re-profile runs exactly one.
    pub rounds: Option<usize>,
    /// Prime the session from a transfer-learning donor curve: each round
    /// rebuilds a [`TransferPrior`] from this seed (the GP itself is not
    /// `Clone`) and profiles via `Profiler::run_with_prior` — probes only
    /// where the posterior stays uncertain, with the cold sweep as the
    /// rejected-prior fallback.
    pub transfer: Option<TransferSeed>,
}

/// Profile one job: `rounds` sessions through the shared cache, feeding the
/// incremental model, then derive the rate the job must sustain.
pub fn profile_job(
    spec: &FleetJobSpec,
    cfg: &FleetConfig,
    cache: &MeasurementCache,
    worker: usize,
) -> Result<JobOutcome> {
    profile_job_with(spec, cfg, cache, worker, &ProfilePass::default())
}

/// [`profile_job`] with explicit pass options — scaled (drifted) runtime
/// behaviour, a warm-start prior, a rate override, and a round override.
pub fn profile_job_with(
    spec: &FleetJobSpec,
    cfg: &FleetConfig,
    cache: &MeasurementCache,
    worker: usize,
    pass: &ProfilePass,
) -> Result<JobOutcome> {
    let label = spec.label();
    let scale = pass.runtime_scale.unwrap_or(1.0);
    let n_rounds = pass.rounds.unwrap_or(cfg.rounds).max(1);
    let mut incremental = match &pass.prior {
        Some(prior) => IncrementalModel::warm(cfg.profiler.delta, prior.clone()),
        None => IncrementalModel::new(cfg.profiler.delta),
    };
    let mut rounds = Vec::with_capacity(n_rounds);
    let mut cache_delta = CacheStats::default();
    let mut transfer_outcome: Option<TransferOutcome> = None;
    let mut primed_model: Option<RuntimeModel> = None;
    for _round in 0..n_rounds {
        // A fresh factory build every round: the factory contract makes
        // builds deterministic replays, which is exactly what lets the
        // cache absorb the whole re-profile. (Scaling by 1.0 is bit-exact,
        // so the unshifted path is unchanged.)
        let backend = ScaledBackend::new(spec.backend.build()?, scale);
        let mut cached = CachedBackend::new(backend, cache, label.clone(), cfg.profiler.delta);
        let strategy = strategies::by_name(&cfg.strategy, spec.seed)
            .ok_or_else(|| anyhow!("unknown strategy '{}'", cfg.strategy))?;
        let mut profiler = Profiler::new(cfg.profiler.clone(), strategy);
        let session = match &pass.transfer {
            Some(seed) => {
                // Rebuilt per round: the seed is cheap to clone, and later
                // rounds replay the first round's probes through the cache
                // either way.
                let l_max = cached.l_max();
                let mut prior = TransferPrior::new(seed.clone(), l_max, cfg.profiler.delta);
                let (session, verdict) = profiler.run_with_prior(
                    &mut cached,
                    &mut |m: &Measurement| incremental.observe(m),
                    &mut prior,
                    &PriorGate::default(),
                );
                transfer_outcome.get_or_insert_with(|| TransferOutcome {
                    donor: seed.donor.clone(),
                    translated: seed.translated,
                    verdict,
                });
                // An adopted/tempered prior probes too few points for a
                // from-scratch refit to keep its model kind; the session's
                // own fitted curve IS the calibrated prior (what its step
                // records already carry). A rejected prior ran the cold
                // sweep, so the incremental fit stands.
                primed_model =
                    (verdict != PriorVerdict::Rejected).then(|| SessionPrior::model(&prior));
                session
            }
            None => {
                let session_prior = if pass.session_warm { pass.prior.as_ref() } else { None };
                profiler.run_observed_from(
                    &mut cached,
                    &mut |m: &Measurement| incremental.observe(m),
                    session_prior,
                )
            }
        };
        cache_delta.absorb(&cached.tally());
        rounds.push(session);
    }
    let model = primed_model.unwrap_or_else(|| incremental.model().clone());
    // Publish the fitted curve as the label's model metadata: a persisted
    // snapshot then carries it (v3), and a restored corpus can donate it
    // verbatim instead of refitting from the raw points.
    cache.note_model(&label, &model);
    let rate_hz = pass
        .rate_hz
        .unwrap_or_else(|| spec.arrivals.max_rate(cfg.horizon))
        .max(1e-6);
    Ok(JobOutcome {
        index: 0, // assigned by the engine when results are collected
        name: spec.name.clone(),
        label,
        node: spec.node,
        model,
        points: incremental.points().len(),
        refits: incremental.refits(),
        rounds,
        rate_hz,
        priority: spec.priority,
        worker,
        cache_delta,
        transfer: transfer_outcome,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{SimBackendFactory, SimulatedBackend};
    use crate::simulator::{node, Algo, SimulatedJob};

    fn meas(limit: f64, rt: f64) -> Measurement {
        Measurement { limit, mean_runtime: rt, samples: 1000, wallclock: rt * 1000.0 }
    }

    #[test]
    fn scaled_factory_wraps_builds_and_probes() {
        let inner = SimBackendFactory::shared(node("pi4").unwrap(), Algo::Arima, 3);
        let base = inner.build().unwrap().measure(0.5, 1000);
        let scaled = ScaledBackendFactory::shared(inner, 3.0);
        // The label must NOT alias the base class: scaled measurements in
        // the shared cache would otherwise poison the unscaled replicas.
        assert_eq!(scaled.label(), "pi4/arima@x3");
        let m = scaled.build().unwrap().measure(0.5, 1000);
        assert!((m.mean_runtime - 3.0 * base.mean_runtime).abs() < 1e-12);
        let p = scaled.probe().unwrap().measure(0.5, 1000);
        assert_ne!(p.mean_runtime.to_bits(), m.mean_runtime.to_bits(), "probe draws fresh");
    }

    #[test]
    fn incremental_model_replaces_repeated_buckets() {
        let mut im = IncrementalModel::new(0.1);
        im.observe(&meas(0.2, 0.5));
        im.observe(&meas(1.0, 0.11));
        im.observe(&meas(2.0, 0.06));
        assert_eq!(im.points().len(), 3);
        // Re-observing bucket 0.2 (with float drift) replaces, not appends.
        im.observe(&meas(0.1 + 0.1, 0.48));
        assert_eq!(im.points().len(), 3);
        assert_eq!(im.refits(), 4);
        let p = im
            .points()
            .iter()
            .find(|p| (p.limit - 0.2).abs() < 1e-9)
            .unwrap();
        assert_eq!(p.runtime, 0.48);
        assert!(im.model().eval(0.5).is_finite());
    }

    #[test]
    fn incremental_fit_tracks_the_curve() {
        // Feed points from a known curve; the incremental model should
        // describe it about as well as a cold fit of the same points.
        let mut im = IncrementalModel::new(0.1);
        let curve = |r: f64| 0.08 * r.powf(-0.9) + 0.01;
        for &r in &[0.2, 0.4, 1.0, 2.0, 4.0] {
            im.observe(&meas(r, curve(r)));
        }
        let cold = RuntimeModel::fit(im.points());
        for &r in &[0.3, 0.8, 3.0] {
            let want = curve(r);
            let got = im.model().eval(r);
            let cold_err = ((cold.eval(r) - want) / want).abs();
            let incr_err = ((got - want) / want).abs();
            assert!(
                incr_err < cold_err + 0.05,
                "incremental fit much worse than cold at {r}: {incr_err} vs {cold_err}"
            );
        }
    }

    #[test]
    fn scaled_backend_shifts_observed_runtimes() {
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let mut plain = SimulatedBackend::new(job);
        let base = plain.measure(0.5, 1000);
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let mut scaled = ScaledBackend::new(SimulatedBackend::new(job), 3.0);
        let m = scaled.measure(0.5, 1000);
        assert!((m.mean_runtime - 3.0 * base.mean_runtime).abs() < 1e-12);
        assert!((m.wallclock - 3.0 * base.wallclock).abs() < 1e-9);
        assert_eq!(m.samples, base.samples);
        assert_eq!(scaled.l_max(), 4.0);
        // Scale 1.0 is bit-exact: the unshifted fleet path is unchanged.
        let job = SimulatedJob::new(node("pi4").unwrap(), Algo::Arima, 3);
        let mut unit = ScaledBackend::new(SimulatedBackend::new(job), 1.0);
        let u = unit.measure(0.5, 1000);
        assert_eq!(u.mean_runtime, base.mean_runtime);
        assert_eq!(u.wallclock, base.wallclock);
    }

    #[test]
    fn warm_incremental_starts_from_prior() {
        let prior = RuntimeModel {
            kind: crate::fit::ModelKind::Full,
            a: 0.08,
            b: 0.9,
            c: 0.01,
            d: 1.0,
            fit_cost: 0.0,
        };
        let im = IncrementalModel::warm(0.1, prior.clone());
        assert_eq!(im.refits(), 0);
        assert!((im.model().eval(0.5) - prior.eval(0.5)).abs() < 1e-12);
        // Observations then refit from that starting point.
        let mut im = IncrementalModel::warm(0.1, prior);
        for &r in &[0.2, 0.5, 1.0, 2.0, 4.0] {
            im.observe(&meas(r, 0.08 * r.powf(-0.9) + 0.01));
        }
        assert_eq!(im.refits(), 5);
        assert!(im.model().eval(0.3).is_finite());
    }

    #[test]
    fn reprofile_pass_tracks_a_shifted_regime() {
        // Cold profile, then a 3x regime shift: a warm single-round
        // re-profile (through a bumped-generation cache) must land a model
        // that predicts roughly 3x the old runtimes.
        let cache = MeasurementCache::new();
        let cfg = FleetConfig { workers: 1, rounds: 1, ..FleetConfig::default() };
        let spec = FleetJobSpec::simulated("shifty", node("pi4").unwrap(), Algo::Arima, 17);
        let cold = profile_job(&spec, &cfg, &cache, 0).unwrap();
        cache.bump_generation(&spec.label());
        let pass = ProfilePass {
            runtime_scale: Some(3.0),
            prior: Some(cold.model.clone()),
            session_warm: true,
            rate_hz: Some(6.0),
            rounds: Some(1),
            transfer: None,
        };
        let hot = profile_job_with(&spec, &cfg, &cache, 0, &pass).unwrap();
        assert_eq!(hot.rounds.len(), 1, "a re-profile runs exactly one session");
        assert!((hot.rate_hz - 6.0).abs() < 1e-12, "rate override respected");
        for &r in &[0.5, 1.0, 2.0] {
            let ratio = hot.model.eval(r) / cold.model.eval(r);
            assert!(
                (2.0..4.5).contains(&ratio),
                "re-profiled model should track the 3x shift at {r}: ratio {ratio}"
            );
        }
        // The stale generation was refused, so the re-profile executed.
        let s = cache.stats();
        assert!(s.stale_hits_refused > 0);
        assert!(hot.rounds[0].total_time > 0.0);
    }

    #[test]
    fn rate_shift_reprofile_replays_the_cold_session_for_free() {
        // prior set but session_warm = false: the session makes the cold
        // sweep's exact decisions, so every probe hits the (still valid)
        // cache and nothing re-executes.
        let cache = MeasurementCache::new();
        let cfg = FleetConfig { workers: 1, rounds: 1, ..FleetConfig::default() };
        let spec = FleetJobSpec::simulated("rated", node("wally").unwrap(), Algo::Birch, 23);
        let cold = profile_job(&spec, &cfg, &cache, 0).unwrap();
        let misses_before = cache.stats().misses;
        let pass = ProfilePass {
            prior: Some(cold.model.clone()),
            rate_hz: Some(9.0),
            rounds: Some(1),
            ..ProfilePass::default()
        };
        let re = profile_job_with(&spec, &cfg, &cache, 0, &pass).unwrap();
        assert_eq!(cache.stats().misses, misses_before, "replay executes nothing");
        assert_eq!(re.rounds[0].total_time, 0.0, "cache hits cost zero wallclock");
        assert_eq!(re.rounds[0].steps.len(), cold.rounds[0].steps.len());
        assert!((re.rate_hz - 9.0).abs() < 1e-12);
    }

    #[test]
    fn profile_job_replays_later_rounds_from_cache() {
        let cache = MeasurementCache::new();
        let cfg = FleetConfig {
            workers: 1,
            rounds: 2,
            ..FleetConfig::default()
        };
        let spec = FleetJobSpec::simulated("solo", node("pi4").unwrap(), Algo::Arima, 11);
        let out = profile_job(&spec, &cfg, &cache, 0).unwrap();
        assert_eq!(out.rounds.len(), 2);
        let s = cache.stats();
        // Round 1 misses everything; round 2 replays identically -> every
        // probe hits and the session costs zero wallclock.
        assert_eq!(s.misses as usize, out.rounds[0].steps.len());
        assert_eq!(s.hits as usize, out.rounds[1].steps.len());
        assert_eq!(out.rounds[1].total_time, 0.0);
        assert!(out.rounds[0].total_time > 0.0);
        assert!(out.points >= out.rounds[0].steps.len());
        assert_eq!(out.refits, out.rounds[0].steps.len() + out.rounds[1].steps.len());
    }
}
