//! The fleet worker task: one registered job, profiled through the shared
//! measurement cache with incremental model refits.
//!
//! A worker repeatedly pulls job tasks from the [`super::queue::WorkQueue`]
//! and runs `rounds` profiling sessions per job (round 0 is the cold
//! profile; later rounds are the periodic re-profiles of the paper's
//! adaptive loop, which the cache turns into near-free replays). Every
//! measurement — cached or executed — lands in the job's
//! [`IncrementalModel`], which refits warm from the previous parameters
//! instead of from scratch.

use anyhow::{anyhow, Result};

use crate::coordinator::backend::{Measurement, SimulatedBackend};
use crate::coordinator::{Profiler, SessionResult};
use crate::fit::{ProfilePoint, RuntimeModel};
use crate::simulator::SimulatedJob;
use crate::strategies::{self, grid_bucket};

use super::cache::{CachedBackend, MeasurementCache};
use super::{FleetConfig, FleetJobSpec};

/// A runtime model maintained across measurements: each new observation
/// warm-starts the refit from the previous parameters (the NMS reuse,
/// §III-B.3, applied fleet-wide) instead of refitting cold.
pub struct IncrementalModel {
    delta: f64,
    points: Vec<ProfilePoint>,
    model: RuntimeModel,
    refits: usize,
}

impl IncrementalModel {
    pub fn new(delta: f64) -> Self {
        Self { delta, points: Vec::new(), model: RuntimeModel::identity(), refits: 0 }
    }

    /// Fold one measurement in. A repeated probe of the same grid bucket
    /// (a re-profiling round or a cache replay) *replaces* the stale point
    /// rather than double-weighting it.
    pub fn observe(&mut self, m: &Measurement) {
        let bucket = grid_bucket(m.limit, self.delta);
        let point = ProfilePoint::new(m.limit, m.mean_runtime);
        match self
            .points
            .iter()
            .position(|p| grid_bucket(p.limit, self.delta) == bucket)
        {
            Some(i) => self.points[i] = point,
            None => self.points.push(point),
        }
        self.model = RuntimeModel::fit_warm(&self.points, Some(&self.model));
        self.refits += 1;
    }

    pub fn model(&self) -> &RuntimeModel {
        &self.model
    }

    pub fn points(&self) -> &[ProfilePoint] {
        &self.points
    }

    /// Total refits performed (one per observed measurement).
    pub fn refits(&self) -> usize {
        self.refits
    }
}

/// Outcome of profiling one fleet job (all rounds).
pub struct JobOutcome {
    /// Position of the job in the submitted spec list (used to restore a
    /// stable order after the pool finishes out of order).
    pub index: usize,
    pub name: String,
    /// Cache label: `node/algo`.
    pub label: String,
    pub node: &'static crate::simulator::NodeSpec,
    pub algo: crate::simulator::Algo,
    /// One session per profiling round, in order.
    pub rounds: Vec<SessionResult>,
    /// Incrementally refit model over all rounds.
    pub model: RuntimeModel,
    /// Distinct grid points backing the model.
    pub points: usize,
    /// Model refits performed while measurements landed.
    pub refits: usize,
    /// Arrival rate (Hz) the job must sustain (peak over the horizon).
    pub rate_hz: f64,
    pub priority: i32,
    /// Worker that processed this job.
    pub worker: usize,
}

impl JobOutcome {
    /// Profiling wallclock actually spent (cache hits cost zero).
    pub fn executed_wallclock(&self) -> f64 {
        self.rounds.iter().map(|s| s.total_time).sum()
    }
}

/// Profile one job: `rounds` sessions through the shared cache, feeding the
/// incremental model, then derive the rate the job must sustain.
pub fn profile_job(
    spec: &FleetJobSpec,
    cfg: &FleetConfig,
    cache: &MeasurementCache,
    worker: usize,
) -> Result<JobOutcome> {
    let label = spec.label();
    let mut incremental = IncrementalModel::new(cfg.profiler.delta);
    let mut rounds = Vec::with_capacity(cfg.rounds);
    for _round in 0..cfg.rounds.max(1) {
        // Same seed every round: the job's runtime distribution does not
        // change between rounds, and a deterministic replay is exactly what
        // lets the cache absorb the whole re-profile.
        let job = SimulatedJob::new(spec.node, spec.algo, spec.seed);
        let backend = SimulatedBackend::new(job);
        let mut cached = CachedBackend::new(backend, cache, label.clone(), cfg.profiler.delta);
        let strategy = strategies::by_name(&cfg.strategy, spec.seed)
            .ok_or_else(|| anyhow!("unknown strategy '{}'", cfg.strategy))?;
        let mut profiler = Profiler::new(cfg.profiler.clone(), strategy);
        let session =
            profiler.run_observed(&mut cached, &mut |m: &Measurement| incremental.observe(m));
        rounds.push(session);
    }
    let rate_hz = spec.arrivals.max_rate(cfg.horizon).max(1e-6);
    Ok(JobOutcome {
        index: 0, // assigned by the engine when results are collected
        name: spec.name.clone(),
        label,
        node: spec.node,
        algo: spec.algo,
        model: incremental.model().clone(),
        points: incremental.points().len(),
        refits: incremental.refits(),
        rounds,
        rate_hz,
        priority: spec.priority,
        worker,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{node, Algo};

    fn meas(limit: f64, rt: f64) -> Measurement {
        Measurement { limit, mean_runtime: rt, samples: 1000, wallclock: rt * 1000.0 }
    }

    #[test]
    fn incremental_model_replaces_repeated_buckets() {
        let mut im = IncrementalModel::new(0.1);
        im.observe(&meas(0.2, 0.5));
        im.observe(&meas(1.0, 0.11));
        im.observe(&meas(2.0, 0.06));
        assert_eq!(im.points().len(), 3);
        // Re-observing bucket 0.2 (with float drift) replaces, not appends.
        im.observe(&meas(0.1 + 0.1, 0.48));
        assert_eq!(im.points().len(), 3);
        assert_eq!(im.refits(), 4);
        let p = im
            .points()
            .iter()
            .find(|p| (p.limit - 0.2).abs() < 1e-9)
            .unwrap();
        assert_eq!(p.runtime, 0.48);
        assert!(im.model().eval(0.5).is_finite());
    }

    #[test]
    fn incremental_fit_tracks_the_curve() {
        // Feed points from a known curve; the incremental model should
        // describe it about as well as a cold fit of the same points.
        let mut im = IncrementalModel::new(0.1);
        let curve = |r: f64| 0.08 * r.powf(-0.9) + 0.01;
        for &r in &[0.2, 0.4, 1.0, 2.0, 4.0] {
            im.observe(&meas(r, curve(r)));
        }
        let cold = RuntimeModel::fit(im.points());
        for &r in &[0.3, 0.8, 3.0] {
            let want = curve(r);
            let got = im.model().eval(r);
            let cold_err = ((cold.eval(r) - want) / want).abs();
            let incr_err = ((got - want) / want).abs();
            assert!(
                incr_err < cold_err + 0.05,
                "incremental fit much worse than cold at {r}: {incr_err} vs {cold_err}"
            );
        }
    }

    #[test]
    fn profile_job_replays_later_rounds_from_cache() {
        let cache = MeasurementCache::new();
        let cfg = FleetConfig {
            workers: 1,
            rounds: 2,
            ..FleetConfig::default()
        };
        let spec = FleetJobSpec::simulated("solo", node("pi4").unwrap(), Algo::Arima, 11);
        let out = profile_job(&spec, &cfg, &cache, 0).unwrap();
        assert_eq!(out.rounds.len(), 2);
        let s = cache.stats();
        // Round 1 misses everything; round 2 replays identically -> every
        // probe hits and the session costs zero wallclock.
        assert_eq!(s.misses as usize, out.rounds[0].steps.len());
        assert_eq!(s.hits as usize, out.rounds[1].steps.len());
        assert_eq!(out.rounds[1].total_time, 0.0);
        assert!(out.rounds[0].total_time > 0.0);
        assert!(out.points >= out.rounds[0].steps.len());
        assert_eq!(out.refits, out.rounds[0].steps.len() + out.rounds[1].steps.len());
    }
}
