//! The always-on fleet engine: a deterministic, event-driven control loop
//! over the same sweep / adaptive / rebalance pipeline the batch
//! [`FleetSession`](super::FleetSession) runs — but long-lived, on a
//! virtual clock, replanning incrementally as the world changes.
//!
//! ```text
//!  submit/retire/observe_verdict ──► BinaryHeap<FleetEvent>  (virtual time)
//!                                        │ step / run_until / drain
//!                                        ▼
//!   JobArrival ──┐                 coalesced Replan ──► run_sweep (bootstrap)
//!   JobDeparture ├─► roster edits ─► plan_capacity    dispatch / profile (drift)
//!   DriftVerdict ┘                                    rebalance (on drain)
//!   EpochTick ──────► AdaptiveLoop::run_epoch (drift-gated re-profiling)
//!   ProbeCompletion ► settle pool results in dispatch order (overlapped)
//! ```
//!
//! Determinism is load-bearing: events are ordered by `(tick, class,
//! submission seq)` and the clock only moves when an event is popped, so
//! a schedule replayed twice produces bit-identical reports. Replans are
//! a *later* class than every other event, which both coalesces the
//! replan work of a burst of same-tick arrivals into one sweep and makes
//! the batch session a provable special case: replaying a whole roster
//! as arrivals at `t = 0` and draining performs exactly one bootstrap
//! sweep over the full roster — byte-identical to
//! [`FleetSession::run`](super::FleetSession::run), which is now
//! implemented as exactly that wrapper (enforced by `tests/fleet_e2e.rs`).
//!
//! ## Overlapped profiling (`probe_workers > 0`)
//!
//! With [`FleetConfig::probe_workers`] set, a replan's pending profiles
//! are *dispatched* to the persistent [`ProbePool`] (journaled as
//! `probe-dispatched`) instead of executed inline, and the event loop
//! moves on — new arrivals and verdicts keep dispatching while earlier
//! probes are still running, so profiling overlaps event processing
//! across replans. Finished work re-enters the loop through
//! [`FleetEvent::ProbeCompletion`] events and is **settled strictly in
//! dispatch order** regardless of worker finish order; capacity planning
//! defers until the replan's whole batch has settled. The drained report
//! is byte-identical to the synchronous path at `probe_workers == 1`
//! (cache-delta accounting uses deterministic per-outcome tallies, not
//! wallclock-dependent global snapshots).

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::PriorVerdict;
use crate::fit::RuntimeModel;
use crate::util::json::Json;

use super::cache::{CacheStats, MeasurementCache, RestoreOutcome};
use super::drift::{AdaptiveConfig, AdaptiveLoop, AdaptiveSummary, DriftVerdict};
use super::mesh::{Mesh, MeshConfig, MeshFault, MeshStats, MeshTopology};
use super::migrate::rebalance;
use super::placement::FleetJob;
use super::pool::ProbePool;
use super::session::FleetReport;
use super::telemetry::{TelemetryRecorder, TelemetryStore};
use super::transfer::{PriorCorpus, TransferOutcome};
use super::worker::{self, JobOutcome, ProfilePass};
use super::{plan_capacity, run_sweep, FleetConfig, FleetJobSpec};

/// One event on the daemon's virtual-time schedule.
///
/// Events are what the outside world (or the daemon itself) feeds the
/// loop; [`FleetDaemon::step`] pops them in deterministic order and
/// reacts. `Replan` is special: it is scheduled *by* the daemon whenever
/// roster or model state changed, coalesced so a burst of same-tick
/// changes is replanned once.
pub enum FleetEvent {
    /// A job joins the fleet (boxed: specs carry a backend handle).
    JobArrival(Box<FleetJobSpec>),
    /// The named job leaves the fleet.
    JobDeparture(String),
    /// An external monitor's drift verdict for the named job. Drift
    /// verdicts queue a warm re-profile and a replan; `Stable` verdicts
    /// are recorded and dropped.
    DriftVerdict {
        /// Name of the judged job.
        job: String,
        /// What the monitor concluded.
        verdict: DriftVerdict,
    },
    /// One adaptation epoch boundary (scheduled at build time when the
    /// adaptive stage is configured).
    EpochTick {
        /// Epoch number, counted from 1.
        epoch: usize,
    },
    /// A dispatched probe finished (overlapped mode): settle every
    /// outstanding pool result up to `seq` back into the live state, in
    /// dispatch order. Class 2: same-tick mutations and the replan that
    /// dispatched the probe sort first; gossip rounds after, so a round
    /// always sees fully merged outcomes. The synchronous path journals
    /// its `probe-completion` entries inline and never schedules this.
    ProbeCompletion {
        /// Name of the profiled job (journal/display only).
        job: String,
        /// Pool dispatch sequence number to settle through.
        seq: u64,
    },
    /// A mesh fault lands on the topology (link partition/heal, node
    /// loss). Class 0, like every other world mutation, so a same-tick
    /// gossip round sees the post-fault topology.
    MeshFault(MeshFault),
    /// Re-plan request: profile pending work, recompute node plans.
    Replan,
    /// One mesh gossip round (pre-scheduled at build on the configured
    /// cadence). Class 3: a same-tick coalesced replan (class 1) and any
    /// probe completions (class 2) run *first*, so the round gossips
    /// fresh post-replan capacity summaries.
    GossipRound,
}

impl FleetEvent {
    /// Stable journal/display tag of the event kind.
    pub fn kind(&self) -> &'static str {
        match self {
            FleetEvent::JobArrival(_) => "arrival",
            FleetEvent::JobDeparture(_) => "departure",
            FleetEvent::DriftVerdict { .. } => "verdict",
            FleetEvent::EpochTick { .. } => "epoch-tick",
            FleetEvent::ProbeCompletion { .. } => "probe-completion",
            FleetEvent::MeshFault(MeshFault::Cut(..)) => "link-cut",
            FleetEvent::MeshFault(MeshFault::Heal(..)) => "link-heal",
            FleetEvent::MeshFault(MeshFault::Lose(..)) => "node-loss",
            FleetEvent::Replan => "replan",
            FleetEvent::GossipRound => "gossip-round",
        }
    }
}

/// Heap key: virtual tick, then event class (replans sort after every
/// same-tick mutation they coalesce), then submission order.
struct Scheduled {
    at: u64,
    class: u8,
    seq: u64,
    event: FleetEvent,
}

impl Scheduled {
    fn key(&self) -> (u64, u8, u64) {
        (self.at, self.class, self.seq)
    }
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        self.key().cmp(&other.key())
    }
}

/// One line of the daemon's append-only event journal.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    /// Virtual tick the event was handled at.
    pub at: u64,
    /// Event kind tag ([`FleetEvent::kind`] vocabulary).
    pub kind: &'static str,
    /// Human-readable detail.
    pub detail: String,
}

/// Monotonic counters over everything the daemon processed.
#[derive(Clone, Copy, Debug, Default)]
pub struct DaemonMetrics {
    /// Events popped off the schedule.
    pub events_processed: u64,
    /// Job arrivals handled.
    pub arrivals: u64,
    /// Job departures handled.
    pub departures: u64,
    /// Drift verdicts handled (stable ones included).
    pub verdicts: u64,
    /// Replans performed (the bootstrap sweep counts as the first).
    pub replans: u64,
}

/// Re-profiling work queued for the next replan.
struct PendingWork {
    spec: FleetJobSpec,
    /// `None` = fresh arrival (full cold profile); `Some` = drift
    /// verdict (warm single-round re-profile).
    verdict: Option<DriftVerdict>,
}

/// A probe dispatched to the pool but not yet merged back — the
/// daemon-side record of in-flight work, settled strictly in dispatch
/// order (overlapped mode only).
struct OutstandingProbe {
    /// Pool dispatch sequence number.
    seq: u64,
    /// Job name (journal + conflict detection).
    name: String,
    /// Home-node name at dispatch time (telemetry key).
    node: &'static str,
    /// Whether this was a fresh arrival (cold-start telemetry key): only
    /// fresh arrivals consult the transfer corpus, so only they count
    /// toward `cold_start_probes` / `prior_adoptions`.
    fresh: bool,
}

/// Builder for a [`FleetDaemon`] — deliberately the same vocabulary as
/// [`FleetSession::builder`](super::FleetSession::builder)
/// (`config` / `jobs` / `job` / `rebalance` / `adaptive` / `cache`), so
/// a batch call site migrates by swapping the type and choosing when
/// events fire.
#[derive(Default)]
pub struct FleetDaemonBuilder {
    cfg: FleetConfig,
    specs: Vec<FleetJobSpec>,
    rebalance: bool,
    adaptive: Option<AdaptiveConfig>,
    cache: Option<Arc<MeasurementCache>>,
    telemetry: Option<Arc<TelemetryStore>>,
    mesh: Option<(MeshTopology, MeshConfig)>,
    faults: Vec<(u64, MeshFault)>,
}

impl FleetDaemonBuilder {
    /// Engine configuration (workers, rounds, strategy, profiler, horizon).
    pub fn config(mut self, cfg: FleetConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Append job specs to the initial roster (arrivals at `t = 0`).
    pub fn jobs(mut self, specs: impl IntoIterator<Item = FleetJobSpec>) -> Self {
        self.specs.extend(specs);
        self
    }

    /// Append one job spec to the initial roster.
    pub fn job(mut self, spec: FleetJobSpec) -> Self {
        self.specs.push(spec);
        self
    }

    /// Enable the rebalance stage: [`FleetDaemon::drain`] migrates shed
    /// jobs across nodes from the final models.
    pub fn rebalance(mut self, enabled: bool) -> Self {
        self.rebalance = enabled;
        self
    }

    /// Enable the adaptive stage: the bootstrap replan arms the
    /// drift-gated adaptive loop and schedules one `EpochTick` per epoch.
    pub fn adaptive(mut self, acfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(acfg);
        self
    }

    /// Share (or persist) a measurement cache across daemons and
    /// sessions — the seam behind `--cache-file`.
    pub fn cache(mut self, cache: Arc<MeasurementCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach a telemetry store: every journaled event also emits its
    /// observable series (probes, runtimes, verdicts, headroom, cache
    /// deltas, migrations) into `store`. Off by default — without a
    /// store the hot path pays only an `Option` check.
    pub fn telemetry(mut self, store: Arc<TelemetryStore>) -> Self {
        self.telemetry = Some(store);
        self
    }

    /// Attach a decentralized mesh scheduler over `topo`: per-node local
    /// schedulers gossip capacity summaries on `cfg`'s cadence (one
    /// [`FleetEvent::GossipRound`] per round, pre-scheduled at build so
    /// `drain` terminates) and place shed jobs local-optimistically.
    /// `drain` then reports the mesh plan instead of the centralized
    /// rebalance. Sweep mode only — `build` panics if combined with
    /// [`FleetDaemonBuilder::adaptive`].
    pub fn mesh(mut self, topo: MeshTopology, cfg: MeshConfig) -> Self {
        self.mesh = Some((topo, cfg));
        self
    }

    /// Inject a mesh fault (link partition/heal, node loss) at virtual
    /// tick `at`. Requires [`FleetDaemonBuilder::mesh`] — `build` panics
    /// on faults without a topology to land on.
    pub fn mesh_fault_at(mut self, at: u64, fault: MeshFault) -> Self {
        self.faults.push((at, fault));
        self
    }

    /// Finalize: schedule the initial roster as arrivals at `t = 0`
    /// followed by the bootstrap replan. Nothing runs until the daemon
    /// is stepped or drained.
    pub fn build(self) -> FleetDaemon {
        assert!(
            self.mesh.is_none() || self.adaptive.is_none(),
            "mesh scheduling is sweep-mode only: drop .adaptive() or .mesh()"
        );
        assert!(
            self.faults.is_empty() || self.mesh.is_some(),
            "mesh faults need a topology to land on: call .mesh() first"
        );
        let cache = self.cache.unwrap_or_default();
        let stats_at_build = cache.stats();
        let telemetry = self.telemetry.map(|s| TelemetryRecorder::new(s, stats_at_build));
        // One persistent pool for the daemon's whole lifetime — bootstrap
        // sweeps included. `probe_workers == 0` (synchronous mode) sizes
        // it like the old per-sweep scoped pool.
        let pool_workers = match self.cfg.probe_workers {
            0 => self.cfg.workers.max(1),
            n => n,
        };
        let pool = ProbePool::new(Arc::clone(&cache), pool_workers);
        // With transfer enabled, the corpus boots from whatever curves a
        // restored cache snapshot already carries — the cross-process
        // path that kills cold starts after a daemon restart.
        let corpus = self.cfg.transfer.then(|| PriorCorpus::from_cache(&cache));
        let mut daemon = FleetDaemon {
            cfg: self.cfg,
            rebalance: self.rebalance,
            adaptive: self.adaptive,
            cache,
            pool,
            stats_at_build,
            sweep_base: stats_at_build,
            clock: 0,
            seq: 0,
            heap: BinaryHeap::new(),
            roster: Vec::new(),
            pending: Vec::new(),
            outstanding: VecDeque::new(),
            batches: VecDeque::new(),
            settled_below: 0,
            virt: stats_at_build,
            bootstrapped: false,
            replan_queued: false,
            sweep: None,
            next_index: 0,
            adaptive_loop: None,
            extras: Vec::new(),
            mesh: None,
            corpus,
            journal: Vec::new(),
            metrics: DaemonMetrics::default(),
            telemetry,
        };
        for spec in self.specs {
            daemon.schedule(0, FleetEvent::JobArrival(Box::new(spec)));
        }
        // The bootstrap replan is unconditional: an empty roster must
        // fail exactly like the batch sweep does, on drain.
        daemon.replan_queued = true;
        daemon.schedule(0, FleetEvent::Replan);
        if let Some((topo, mcfg)) = self.mesh {
            // Finitely pre-scheduled rounds keep `drain` terminating; the
            // first lands one cadence after the bootstrap replan.
            let every = mcfg.every.max(1);
            for k in 1..=mcfg.rounds {
                daemon.schedule(k as u64 * every, FleetEvent::GossipRound);
            }
            for (at, fault) in self.faults {
                daemon.schedule(at, FleetEvent::MeshFault(fault));
            }
            daemon.mesh = Some(Mesh::new(topo));
        }
        daemon
    }
}

/// The long-lived, event-driven fleet engine.
///
/// Feed it [`FleetEvent`]s (directly or via the [`FleetDaemon::submit`] /
/// [`FleetDaemon::retire`] / [`FleetDaemon::observe_verdict`] helpers),
/// advance virtual time with [`FleetDaemon::step`] or
/// [`FleetDaemon::run_until`], and finish with [`FleetDaemon::drain`],
/// which plays out every remaining event and assembles the same
/// [`FleetReport`] the batch session returns.
pub struct FleetDaemon {
    cfg: FleetConfig,
    rebalance: bool,
    adaptive: Option<AdaptiveConfig>,
    cache: Arc<MeasurementCache>,
    /// Persistent profiling workers, shared by every replan (bootstrap
    /// sweeps included) for the daemon's whole lifetime.
    pool: ProbePool,
    /// Cache stats when the daemon was built — the report's delta base.
    stats_at_build: CacheStats,
    /// Cache stats immediately before the bootstrap sweep — the sweep
    /// summary's delta base (mirrors `run_sweep`'s own snapshot).
    sweep_base: CacheStats,
    clock: u64,
    seq: u64,
    heap: BinaryHeap<Reverse<Scheduled>>,
    /// Current fleet roster, in arrival order.
    roster: Vec<FleetJobSpec>,
    pending: Vec<PendingWork>,
    /// Dispatched-but-unmerged probes, in dispatch order (overlapped
    /// mode; always empty when `probe_workers == 0`).
    outstanding: VecDeque<OutstandingProbe>,
    /// Last dispatch seq of each replan batch whose planning tail is
    /// still deferred; a batch's tail runs once every seq up to its
    /// marker has settled.
    batches: VecDeque<u64>,
    /// Watermark: every dispatch seq `< settled_below` has been settled.
    settled_below: u64,
    /// Deterministic view of the cache's lifetime stats in overlapped
    /// mode: accumulated from per-outcome tallies strictly in dispatch
    /// order, so planning tails never read wallclock-dependent global
    /// counters while later probes are still in flight.
    virt: CacheStats,
    bootstrapped: bool,
    replan_queued: bool,
    /// Live sweep state (sweep mode; adaptive mode keeps its state in
    /// `adaptive_loop`).
    sweep: Option<super::FleetSummary>,
    next_index: usize,
    adaptive_loop: Option<AdaptiveLoop>,
    /// Adaptive-mode outcomes for jobs the loop does not track: mid-run
    /// arrivals and externally-verdicted re-profiles (override by name).
    extras: Vec<JobOutcome>,
    /// Decentralized mesh scheduler, when configured. Gossip rounds and
    /// faults mutate it; `drain` reports its plan instead of `rebalance`.
    mesh: Option<Mesh>,
    /// Cross-job runtime-prior corpus ([`FleetConfig::transfer`]): every
    /// merged outcome feeds it, and fresh arrivals consult it for a
    /// donor curve before their profile dispatches. `None` = transfer
    /// learning off, every arrival profiles cold.
    corpus: Option<PriorCorpus>,
    journal: Vec<JournalEntry>,
    metrics: DaemonMetrics,
    /// Telemetry hooks, when a store is attached. Emission points sit
    /// adjacent to every `record()` call so the store and the journal
    /// describe the same timeline (the `telemetry_e2e` contract).
    telemetry: Option<TelemetryRecorder>,
}

impl FleetDaemon {
    /// Start building a daemon.
    pub fn builder() -> FleetDaemonBuilder {
        FleetDaemonBuilder::default()
    }

    /// The daemon's measurement cache (shared with whoever passed it in).
    pub fn cache(&self) -> &Arc<MeasurementCache> {
        &self.cache
    }

    /// Current virtual time (the tick of the last handled event).
    pub fn now(&self) -> u64 {
        self.clock
    }

    /// Events still on the schedule.
    pub fn pending_events(&self) -> usize {
        self.heap.len()
    }

    /// The append-only journal of every handled event.
    pub fn journal(&self) -> &[JournalEntry] {
        &self.journal
    }

    /// Journal the outcome of a cache-snapshot restore performed by the
    /// embedding process (the `--cache-file` path) so refused entries —
    /// a corrupted or conflicting corpus — are visible on the daemon's
    /// own timeline, not just on stdout.
    pub fn note_cache_restore(&mut self, outcome: RestoreOutcome) {
        let detail = format!(
            "{} restored, {} refused ({} newer than header, {} width conflicts)",
            outcome.restored,
            outcome.refused(),
            outcome.refused_newer,
            outcome.refused_width
        );
        self.record("cache-restore", detail);
    }

    /// Counters over everything processed so far.
    pub fn metrics(&self) -> DaemonMetrics {
        self.metrics
    }

    /// The attached telemetry store, if any.
    pub fn telemetry(&self) -> Option<&Arc<TelemetryStore>> {
        self.telemetry.as_ref().map(TelemetryRecorder::store)
    }

    /// The attached mesh scheduler, if any.
    pub fn mesh(&self) -> Option<&Mesh> {
        self.mesh.as_ref()
    }

    /// Submit a job now (arrival at the current tick).
    pub fn submit(&mut self, spec: FleetJobSpec) {
        let at = self.clock;
        self.submit_at(spec, at);
    }

    /// Submit a job at virtual tick `at` (clamped to now if in the past).
    pub fn submit_at(&mut self, spec: FleetJobSpec, at: u64) {
        self.schedule(at, FleetEvent::JobArrival(Box::new(spec)));
    }

    /// Retire a job now (departure at the current tick).
    pub fn retire(&mut self, name: &str) {
        let at = self.clock;
        self.retire_at(name, at);
    }

    /// Retire a job at virtual tick `at` (clamped to now if in the past).
    pub fn retire_at(&mut self, name: &str, at: u64) {
        self.schedule(at, FleetEvent::JobDeparture(name.to_string()));
    }

    /// Report an external drift verdict for a job now.
    pub fn observe_verdict(&mut self, job: &str, verdict: DriftVerdict) {
        let at = self.clock;
        self.observe_verdict_at(job, verdict, at);
    }

    /// Report an external drift verdict at virtual tick `at`.
    pub fn observe_verdict_at(&mut self, job: &str, verdict: DriftVerdict, at: u64) {
        self.schedule(at, FleetEvent::DriftVerdict { job: job.to_string(), verdict });
    }

    /// Handle the next scheduled event. Returns `false` once the
    /// schedule is empty.
    pub fn step(&mut self) -> Result<bool> {
        match self.heap.pop() {
            Some(Reverse(s)) => {
                self.handle(s.at, s.event)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Handle every event scheduled at or before virtual tick `t`;
    /// returns how many events were processed.
    pub fn run_until(&mut self, t: u64) -> Result<usize> {
        let mut handled = 0;
        while self.heap.peek().is_some_and(|Reverse(s)| s.at <= t) {
            let Reverse(s) = self.heap.pop().expect("peeked event exists");
            self.handle(s.at, s.event)?;
            handled += 1;
        }
        self.clock = self.clock.max(t);
        Ok(handled)
    }

    /// Play out every remaining event and assemble the final report —
    /// the daemon's terminal operation, mirroring what the batch
    /// session returns for the equivalent schedule.
    pub fn drain(mut self) -> Result<FleetReport> {
        while self.step()? {}
        // Every completion event has popped, so every dispatched probe
        // has settled and the pool is quiescent.
        debug_assert!(self.outstanding.is_empty(), "drain left probes unsettled");
        debug_assert!(self.batches.is_empty(), "drain left a planning tail deferred");
        let adaptive = match self.adaptive_loop.take() {
            Some(al) => Some(al.finish(&self.cache)),
            None => None,
        };
        let plan = if self.mesh.is_some() {
            // Mesh mode (sweep-only): sync the final profiled state into
            // the mesh and report *its* accumulated placement — the
            // decentralized counterpart of the centralized rebalance.
            let jobs = self.mesh_jobs();
            let mesh = self.mesh.as_mut().expect("checked above");
            mesh.sync_jobs(&jobs);
            Some(mesh.fleet_plan())
        } else if self.rebalance {
            Some(match (&self.sweep, &adaptive) {
                // After adaptation, rebalance from the *final* models
                // and rates, not the cold sweep's.
                (_, Some(ad)) => rebalance(&self.final_fleet_jobs(ad)),
                (Some(s), None) => s.rebalanced(),
                (None, None) => unreachable!("the bootstrap replan always ran one of the two"),
            })
        } else {
            None
        };
        if let Some(t) = self.telemetry.as_mut() {
            let now = self.clock;
            if let Some(p) = &plan {
                t.headroom(now, &p.plans);
                t.migrations(now, p);
            }
            // Quiescent pool: the wait-free accessors are exact here.
            t.cache_flush(now, self.cache.hits(), self.cache.misses());
        }
        let cache = self.cache.stats().delta_since(&self.stats_at_build);
        let mut report = FleetReport::assemble(self.sweep, adaptive, plan, cache);
        report.mesh = self.mesh.as_ref().map(Mesh::stats);
        Ok(report)
    }

    fn schedule(&mut self, at: u64, event: FleetEvent) {
        let class = match event {
            FleetEvent::Replan => 1,
            FleetEvent::ProbeCompletion { .. } => 2,
            FleetEvent::GossipRound => 3,
            _ => 0,
        };
        let at = at.max(self.clock);
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { at, class, seq: self.seq, event }));
    }

    /// Schedule a coalesced replan at the current tick: one replan
    /// absorbs every same-tick mutation queued before it.
    fn schedule_replan(&mut self) {
        if !self.replan_queued {
            self.replan_queued = true;
            let at = self.clock;
            self.schedule(at, FleetEvent::Replan);
        }
    }

    fn record(&mut self, kind: &'static str, detail: String) {
        self.journal.push(JournalEntry { at: self.clock, kind, detail });
    }

    fn handle(&mut self, at: u64, event: FleetEvent) -> Result<()> {
        self.clock = self.clock.max(at);
        self.metrics.events_processed += 1;
        match event {
            FleetEvent::JobArrival(spec) => self.on_arrival(*spec),
            FleetEvent::JobDeparture(name) => self.on_departure(&name)?,
            FleetEvent::DriftVerdict { job, verdict } => self.on_verdict(&job, verdict),
            FleetEvent::EpochTick { epoch } => self.on_epoch_tick(epoch)?,
            FleetEvent::ProbeCompletion { job, seq } => self.on_probe_completion(&job, seq)?,
            FleetEvent::MeshFault(fault) => self.on_mesh_fault(fault)?,
            FleetEvent::Replan => self.on_replan()?,
            FleetEvent::GossipRound => self.on_gossip_round()?,
        }
        Ok(())
    }

    fn on_arrival(&mut self, spec: FleetJobSpec) {
        self.metrics.arrivals += 1;
        self.record("arrival", format!("{} ({}) on {}", spec.name, spec.label(), spec.node.name));
        if let Some(t) = &self.telemetry {
            t.arrival(self.clock, &spec.name, spec.node.name);
        }
        if self.bootstrapped {
            self.pending.push(PendingWork { spec: spec.clone(), verdict: None });
        }
        self.roster.push(spec);
        self.schedule_replan();
    }

    fn on_departure(&mut self, name: &str) -> Result<()> {
        // Departures consume profiled state (they purge outcomes by
        // name), so every in-flight probe must merge first — otherwise a
        // settle after this purge would resurrect the departed job.
        self.settle_all()?;
        self.metrics.departures += 1;
        self.record("departure", name.to_string());
        if let Some(t) = &self.telemetry {
            t.departure(self.clock, name, roster_node(&self.roster, name));
        }
        self.roster.retain(|s| s.name != name);
        let (kept, dropped): (Vec<_>, Vec<_>) =
            std::mem::take(&mut self.pending).into_iter().partition(|w| w.spec.name != name);
        self.pending = kept;
        for w in dropped {
            // A queued verdict the departure supersedes must not silently
            // vanish — nor re-profile a job that just left (its cache
            // aging is deferred to replan time, see `apply_pending`).
            if let Some(v) = w.verdict {
                let detail = format!("{name}: {} superseded by departure", v.name());
                self.record("verdict-dropped", detail);
            }
        }
        self.extras.retain(|o| o.name != name);
        if let Some(sweep) = &mut self.sweep {
            sweep.outcomes.retain(|o| o.name != name);
        }
        if self.bootstrapped {
            self.schedule_replan();
        }
        Ok(())
    }

    fn on_verdict(&mut self, job: &str, verdict: DriftVerdict) {
        self.metrics.verdicts += 1;
        self.record("verdict", format!("{job}: {}", verdict.name()));
        if let Some(t) = &self.telemetry {
            t.verdict(self.clock, job, roster_node(&self.roster, job), &verdict);
        }
        if !verdict.is_drift() {
            return;
        }
        let Some(spec) = self.roster.iter().find(|s| s.name == job).cloned() else {
            // A verdict for a job not (or not yet) on the roster — e.g.
            // one arriving the same tick but *before* the job's arrival,
            // or after its departure. Drop it loudly, never re-profile.
            let detail = format!("{job}: {} — no such job on the roster", verdict.name());
            self.record("verdict-dropped", detail);
            return;
        };
        self.pending.push(PendingWork { spec, verdict: Some(verdict) });
        self.schedule_replan();
    }

    fn on_epoch_tick(&mut self, epoch: usize) -> Result<()> {
        // The epoch probes and re-profiles through the shared cache on
        // this thread; in-flight pool work must land first.
        self.settle_all()?;
        self.record("epoch-tick", format!("epoch {epoch}"));
        let Some(al) = self.adaptive_loop.as_mut() else {
            return Ok(());
        };
        let report = al.run_epoch(&self.cache)?;
        let mut entries: Vec<(&'static str, String)> = Vec::new();
        for (name, v) in &report.verdicts {
            if v.is_drift() {
                entries.push(("verdict", format!("{name}: {}", v.name())));
            }
        }
        for r in &report.reprofiled {
            let detail = format!("{}: {} probes executed", r.name, r.executed_probes);
            entries.push(("probe-completion", detail));
        }
        let replanned = report.plan.is_some();
        for (kind, detail) in entries {
            self.journal.push(JournalEntry { at: self.clock, kind, detail });
        }
        if replanned {
            self.metrics.replans += 1;
        }
        let now = self.clock;
        if let Some(t) = self.telemetry.as_mut() {
            // Only drift verdicts, mirroring the epoch's journal entries.
            for (name, v) in &report.verdicts {
                if v.is_drift() {
                    t.verdict(now, name, roster_node(&self.roster, name), v);
                }
            }
            for r in &report.reprofiled {
                let node = roster_node(&self.roster, &r.name);
                t.probes(now, &r.name, node, r.executed_probes);
                t.smape(now, &r.name, node, r.post_smape);
            }
            if let Some(plan) = &report.plan {
                t.headroom(now, &plan.plans);
                t.migrations(now, plan);
            }
            // Pool quiescent after settle_all: the wait-free accessors
            // are exact.
            t.cache_flush(now, self.cache.hits(), self.cache.misses());
        }
        // The epoch mutated the cache outside the dispatch/settle
        // protocol; with the pool quiescent the real counters are safe
        // to resynchronize into the deterministic view.
        if self.overlap() {
            self.virt = self.cache.stats();
        }
        Ok(())
    }

    fn on_replan(&mut self) -> Result<()> {
        self.replan_queued = false;
        self.metrics.replans += 1;
        if !self.bootstrapped {
            self.bootstrapped = true;
            self.record("replan", format!("bootstrap over {} jobs", self.roster.len()));
            match self.adaptive.clone() {
                Some(acfg) => {
                    let al = AdaptiveLoop::start(
                        &self.cfg,
                        &self.cache,
                        &self.pool,
                        self.roster.clone(),
                        &acfg,
                    )?;
                    for e in 1..=acfg.epochs {
                        let at = (self.cfg.horizon + e * acfg.epoch_ticks) as u64;
                        self.schedule(at, FleetEvent::EpochTick { epoch: e });
                    }
                    if let Some(t) = &self.telemetry {
                        for o in &al.initial_summary().outcomes {
                            t.outcome_runtimes(self.clock, o);
                        }
                    }
                    if let Some(c) = self.corpus.as_mut() {
                        for o in &al.initial_summary().outcomes {
                            c.absorb(o);
                        }
                    }
                    self.adaptive_loop = Some(al);
                }
                None => {
                    self.sweep_base = self.cache.stats();
                    let sweep = run_sweep(&self.cfg, &self.pool, self.roster.clone())?;
                    self.next_index = sweep.outcomes.len();
                    if let Some(t) = &self.telemetry {
                        for o in &sweep.outcomes {
                            t.outcome_runtimes(self.clock, o);
                        }
                    }
                    if let Some(c) = self.corpus.as_mut() {
                        // The bootstrap roster profiles cold by design —
                        // its outcomes ARE the corpus later arrivals
                        // draw donors from.
                        for o in &sweep.outcomes {
                            c.absorb(o);
                        }
                    }
                    self.sweep = Some(sweep);
                }
            }
            // The bootstrap sweep ran to completion through the pool, so
            // the real counters are exact — seed the deterministic view.
            if self.overlap() {
                self.virt = self.cache.stats();
            }
        } else {
            self.record("replan", format!("{} pending updates", self.pending.len()));
        }
        let work = std::mem::take(&mut self.pending);
        if self.overlap() {
            // Dispatch phase: hand every pending profile to the pool and
            // return to the event loop; the planning tail runs once the
            // batch's last dispatch settles (or immediately when nothing
            // was dispatched — matching the synchronous tail count).
            let mut last_dispatched = None;
            for w in work {
                if let Some(seq) = self.dispatch_pending(w)? {
                    last_dispatched = Some(seq);
                }
            }
            match last_dispatched {
                Some(last) => self.batches.push_back(last),
                None => self.replan_tail(),
            }
        } else {
            for w in work {
                self.apply_pending(w)?;
            }
            self.replan_tail();
        }
        Ok(())
    }

    /// Whether probe execution is overlapped (dispatch/completion split)
    /// rather than synchronous inside each replan event.
    fn overlap(&self) -> bool {
        self.cfg.probe_workers > 0
    }

    /// The planning tail of a replan: recompute capacity plans over the
    /// merged outcomes and emit the planning telemetry. Overlapped mode
    /// defers this until the replan's whole batch has settled.
    fn replan_tail(&mut self) {
        let cache_now = if self.overlap() { self.virt } else { self.cache.stats() };
        if let Some(sweep) = &mut self.sweep {
            sweep.plans = plan_capacity(&sweep.outcomes, self.cfg.plan_quantile);
            sweep.cache = cache_now.delta_since(&self.sweep_base);
        }
        let now = self.clock;
        if let Some(t) = self.telemetry.as_mut() {
            if let Some(sweep) = &self.sweep {
                t.headroom(now, &sweep.plans);
            }
            t.cache_flush(now, cache_now.hits, cache_now.misses);
        }
    }

    /// Profile one pending unit of work: a fresh arrival cold (the full
    /// configured rounds) or a drift verdict warm (one round, primed
    /// from the job's current model — exactly the adaptive loop's pass).
    fn apply_pending(&mut self, work: PendingWork) -> Result<()> {
        let PendingWork { spec, verdict } = work;
        if !self.roster.iter().any(|s| s.name == spec.name) {
            // Retired while queued (departures also purge the queue, so
            // this is a defensive backstop — journaled all the same).
            if let Some(v) = &verdict {
                let detail = format!("{}: {} — job retired before the replan", spec.name, v.name());
                self.record("verdict-dropped", detail);
            }
            return Ok(());
        }
        if matches!(verdict, Some(DriftVerdict::ModelStale { .. })) {
            // Stale model ⇒ poisoned measurements: age the label so the
            // re-profile executes instead of replaying them. Deferred
            // from verdict arrival to replan time so a verdict a
            // same-tick departure supersedes can never age the cache of
            // a job that already left.
            self.cache.bump_generation(&spec.label());
            self.cache.evict_stale();
        }
        let fresh = verdict.is_none();
        let pass = match verdict {
            // Fresh arrival: consult the transfer corpus for a donor
            // curve before profiling cold.
            None => ProfilePass {
                transfer: self.corpus.as_ref().and_then(|c| c.donor_for(&spec)),
                ..ProfilePass::default()
            },
            Some(v) => ProfilePass {
                runtime_scale: None,
                prior: self.model_of(&spec.name),
                session_warm: matches!(v, DriftVerdict::ModelStale { .. }),
                rate_hz: match v {
                    DriftVerdict::RateShift { observed_hz, .. } => Some(observed_hz),
                    _ => None,
                },
                rounds: Some(1),
                transfer: None,
            },
        };
        let outcome = worker::profile_job_with(&spec, &self.cfg, &self.cache, 0, &pass)?;
        // The outcome's own tally, not two full sharded-stats
        // aggregations around the profile: same value (this thread is
        // the only prober here) at zero lock traffic.
        let executed = outcome.cache_delta.misses;
        self.record("probe-completion", format!("{}: {executed} probes executed", spec.name));
        if let Some(t) = &self.telemetry {
            t.probes(self.clock, &spec.name, spec.node.name, executed);
            t.outcome_runtimes(self.clock, &outcome);
        }
        self.record_transfer(&spec.name, spec.node.name, fresh, outcome.transfer.clone(), executed);
        self.merge_outcome(outcome);
        Ok(())
    }

    /// Journal and telemetry for one settled profile's transfer-prior
    /// decision. Fresh arrivals (the only path that consults the corpus)
    /// also land in the cold-start accounting: a primed profile counts
    /// one `prior_adoptions` point, anything else counts its executed
    /// probes as `cold_start_probes`.
    fn record_transfer(
        &mut self,
        name: &str,
        node: &'static str,
        fresh: bool,
        transfer: Option<TransferOutcome>,
        executed: u64,
    ) {
        if let Some(tr) = &transfer {
            let kind = match tr.verdict {
                PriorVerdict::Adopted => "prior-adopted",
                PriorVerdict::Tempered => "prior-tempered",
                PriorVerdict::Rejected => "prior-rejected",
            };
            let how = if tr.translated { "translated donor" } else { "donor" };
            self.record(kind, format!("{name}: {how} {}", tr.donor));
        }
        if !fresh {
            return;
        }
        let primed = matches!(
            transfer.map(|t| t.verdict),
            Some(PriorVerdict::Adopted | PriorVerdict::Tempered)
        );
        if let Some(t) = &self.telemetry {
            if primed {
                t.prior_adoption(self.clock, name, node);
            } else {
                t.cold_start_probes(self.clock, name, node, executed);
            }
        }
    }

    /// Overlapped counterpart of [`FleetDaemon::apply_pending`]: the same
    /// validation and pass construction, but the profile is *dispatched*
    /// to the pool (journaled as `probe-dispatched`) and merges later, at
    /// settle time. Returns the dispatch seq, or `None` when the work was
    /// dropped (job retired while queued).
    fn dispatch_pending(&mut self, work: PendingWork) -> Result<Option<u64>> {
        let PendingWork { spec, verdict } = work;
        if !self.roster.iter().any(|s| s.name == spec.name) {
            if let Some(v) = &verdict {
                let detail = format!("{}: {} — job retired before the replan", spec.name, v.name());
                self.record("verdict-dropped", detail);
            }
            return Ok(None);
        }
        // An in-flight probe of the same job must merge before this one
        // dispatches: the new pass warm-starts from the job's *current*
        // model, and that includes any result still inside the pool.
        while self.outstanding.iter().any(|o| o.name == spec.name) {
            self.settle_next()?;
        }
        // Cache aging for a stale model rides inside the task (the pool
        // worker ages right before profiling), keeping the age/profile
        // pair adjacent in dispatch order.
        let age_label =
            matches!(verdict, Some(DriftVerdict::ModelStale { .. })).then(|| spec.label());
        let fresh = verdict.is_none();
        let pass = match verdict {
            // Fresh arrival: consult the transfer corpus for a donor
            // curve before the probe ever reaches the pool.
            None => ProfilePass {
                transfer: self.corpus.as_ref().and_then(|c| c.donor_for(&spec)),
                ..ProfilePass::default()
            },
            Some(v) => ProfilePass {
                runtime_scale: None,
                prior: self.model_of(&spec.name),
                session_warm: matches!(v, DriftVerdict::ModelStale { .. }),
                rate_hz: match v {
                    DriftVerdict::RateShift { observed_hz, .. } => Some(observed_hz),
                    _ => None,
                },
                rounds: Some(1),
                transfer: None,
            },
        };
        let name = spec.name.clone();
        let node = spec.node.name;
        let seq = self.pool.dispatch(0, spec, &self.cfg, pass, age_label);
        self.record("probe-dispatched", format!("{name}: seq {seq}"));
        self.outstanding.push_back(OutstandingProbe { seq, name: name.clone(), node, fresh });
        if let Some(t) = &self.telemetry {
            // Outstanding count, not the racy pool queue length: the
            // series must be a pure function of the event schedule.
            t.probe_queue_depth(self.clock, self.outstanding.len() as u64);
        }
        let at = self.clock;
        self.schedule(at, FleetEvent::ProbeCompletion { job: name, seq });
        Ok(Some(seq))
    }

    /// Settle the oldest outstanding probe: block on its pool result,
    /// merge it, journal its completion, and run any replan tail whose
    /// batch just drained. Settling is the ONLY way pool results re-enter
    /// daemon state, and it always proceeds in dispatch order.
    fn settle_next(&mut self) -> Result<()> {
        let o = self.outstanding.pop_front().expect("settle_next needs outstanding work");
        let mut outcome = self
            .pool
            .collect(o.seq)
            .with_context(|| format!("profiling '{}' (dispatch seq {})", o.name, o.seq))?;
        // Match the synchronous path's hardcoded worker id so merged
        // reports never depend on which pool thread ran the probe.
        outcome.worker = 0;
        let executed = outcome.cache_delta.misses;
        self.virt.absorb(&outcome.cache_delta);
        self.settled_below = o.seq + 1;
        self.record("probe-completion", format!("{}: {executed} probes executed", o.name));
        if let Some(t) = &self.telemetry {
            t.probes(self.clock, &o.name, o.node, executed);
            t.outcome_runtimes(self.clock, &outcome);
        }
        self.record_transfer(&o.name, o.node, o.fresh, outcome.transfer.clone(), executed);
        self.merge_outcome(outcome);
        self.flush_drained_batches();
        Ok(())
    }

    /// Settle every outstanding probe (consumer events and drain).
    fn settle_all(&mut self) -> Result<()> {
        while !self.outstanding.is_empty() {
            self.settle_next()?;
        }
        Ok(())
    }

    /// Run the deferred planning tail of every replan batch whose last
    /// dispatch has now settled.
    fn flush_drained_batches(&mut self) {
        while self.batches.front().is_some_and(|&last| last < self.settled_below) {
            self.batches.pop_front();
            self.replan_tail();
        }
    }

    /// A `ProbeCompletion` event popped. If the next scheduled event is
    /// *transparent* — one that only dispatches or mutates the roster
    /// without consuming profiled state (arrival, verdict, mesh fault,
    /// replan) — defer the settle past it by re-scheduling this event at
    /// that tick: this is what lets profiling overlap across replans.
    /// Otherwise settle everything up to `seq` now.
    fn on_probe_completion(&mut self, job: &str, seq: u64) -> Result<()> {
        if seq < self.settled_below {
            return Ok(()); // already settled eagerly (conflict or consumer)
        }
        let defer_to = self.heap.peek().and_then(|Reverse(s)| {
            matches!(
                s.event,
                FleetEvent::JobArrival(_)
                    | FleetEvent::DriftVerdict { .. }
                    | FleetEvent::MeshFault(_)
                    | FleetEvent::Replan
            )
            .then_some(s.at)
        });
        if let Some(at) = defer_to {
            self.schedule(at, FleetEvent::ProbeCompletion { job: job.to_string(), seq });
            return Ok(());
        }
        while self.outstanding.front().is_some_and(|o| o.seq <= seq) {
            self.settle_next()?;
        }
        Ok(())
    }

    /// A mesh fault event lands: journal it, then mutate the topology.
    fn on_mesh_fault(&mut self, fault: MeshFault) -> Result<()> {
        let kind = match &fault {
            MeshFault::Cut(..) => "link-cut",
            MeshFault::Heal(..) => "link-heal",
            MeshFault::Lose(..) => "node-loss",
        };
        self.record(kind, fault.to_string());
        if let Some(mesh) = self.mesh.as_mut() {
            fault.apply(mesh.topology_mut())?;
        }
        Ok(())
    }

    /// One gossip round: sync the mesh's job view from the live sweep
    /// state (a same-tick replan sorts first, so summaries are fresh),
    /// run the publish → deliver → decide → resolve cycle, and emit the
    /// round's health series.
    fn on_gossip_round(&mut self) -> Result<()> {
        let jobs = self.mesh_jobs();
        let now = self.clock;
        let Some(mesh) = self.mesh.as_mut() else {
            return Ok(());
        };
        mesh.sync_jobs(&jobs);
        let out = mesh.round(now);
        let round = mesh.stats().gossip_rounds;
        let detail = format!(
            "round {round}: {} delivered / {} dropped, {} moved, {} rolled back, staleness {}",
            out.delivered,
            out.dropped,
            out.moves.len(),
            out.rollbacks.len(),
            out.staleness_ticks
        );
        self.record("gossip-round", detail);
        if let Some(t) = &self.telemetry {
            t.gossip_round(now, out.delivered);
            t.staleness(now, out.staleness_ticks);
            for (job, dest) in &out.rollbacks {
                t.rollback(now, job, dest);
            }
        }
        Ok(())
    }

    /// The mesh's placement view of the live sweep state (mesh mode is
    /// sweep-only, enforced at build).
    fn mesh_jobs(&self) -> Vec<FleetJob> {
        self.sweep
            .as_ref()
            .map(|s| s.outcomes.iter().map(FleetJob::from).collect())
            .unwrap_or_default()
    }

    /// The job's current fitted model, wherever it last landed.
    fn model_of(&self, name: &str) -> Option<RuntimeModel> {
        if let Some(x) = self.extras.iter().find(|o| o.name == name) {
            return Some(x.model.clone());
        }
        self.sweep
            .as_ref()
            .and_then(|s| s.outcomes.iter().find(|o| o.name == name))
            .map(|o| o.model.clone())
    }

    /// Fold a freshly profiled outcome into the live state: replace by
    /// name keeping the original submission index, or append with the
    /// next index so the outcome order stays the arrival order.
    fn merge_outcome(&mut self, mut outcome: JobOutcome) {
        if let Some(c) = self.corpus.as_mut() {
            // Every settled profile becomes donor material for later
            // arrivals — including re-profiles, whose fresher curve
            // replaces the label's previous record.
            c.absorb(&outcome);
        }
        if let Some(sweep) = &mut self.sweep {
            if let Some(old) = sweep.outcomes.iter_mut().find(|o| o.name == outcome.name) {
                outcome.index = old.index;
                *old = outcome;
            } else {
                outcome.index = self.next_index;
                self.next_index += 1;
                sweep.outcomes.push(outcome);
            }
        } else if let Some(old) = self.extras.iter_mut().find(|o| o.name == outcome.name) {
            *old = outcome;
        } else {
            self.extras.push(outcome);
        }
    }

    /// The placement view of the fleet's final per-job state in adaptive
    /// mode: the loop's final models, overridden by any later external
    /// re-profile (`extras`), restricted to jobs still on the roster,
    /// plus mid-run arrivals the loop never tracked.
    fn final_fleet_jobs(&self, ad: &AdaptiveSummary) -> Vec<FleetJob> {
        let mut jobs: Vec<FleetJob> = Vec::new();
        for j in &ad.jobs {
            let Some(spec) = self.roster.iter().find(|s| s.name == j.name) else {
                continue; // retired after the bootstrap
            };
            if let Some(x) = self.extras.iter().find(|o| o.name == j.name) {
                jobs.push(FleetJob::from(x));
            } else {
                jobs.push(FleetJob {
                    name: j.name.clone(),
                    node: spec.node,
                    model: j.model.clone(),
                    rate_hz: j.rate_hz,
                    priority: spec.priority,
                });
            }
        }
        for x in &self.extras {
            let tracked = ad.jobs.iter().any(|j| j.name == x.name);
            let live = self.roster.iter().any(|s| s.name == x.name);
            if !tracked && live {
                jobs.push(FleetJob::from(x));
            }
        }
        jobs
    }
}

/// Home-node name of a rostered job, or `""` for unknown jobs (e.g. a
/// verdict naming a job that never joined — journaled all the same).
fn roster_node(roster: &[FleetJobSpec], job: &str) -> &'static str {
    roster.iter().find(|s| s.name == job).map(|s| s.node.name).unwrap_or("")
}

/// Serialize a daemon journal as JSON — the `--journal-out` schema.
/// Entries keep the journal's exact vocabulary (`at` / `kind` /
/// `detail`), which is also the vocabulary the telemetry store records
/// under, so a journal dump and a store snapshot diff directly (the
/// `telemetry_e2e` test does exactly that).
pub fn journal_json(entries: &[JournalEntry]) -> Json {
    let rows = entries.iter().map(|e| {
        Json::obj([
            ("at", Json::num(e.at as f64)),
            ("kind", Json::str(e.kind)),
            ("detail", Json::str(&e.detail)),
        ])
    });
    Json::obj([("version", Json::num(1.0)), ("entries", Json::arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CapacityPlan, ProfilerConfig};
    use crate::fleet::{sim_fleet, FleetSummary};

    fn planned(sweep: &FleetSummary, job: &str) -> bool {
        let in_plan = |p: &CapacityPlan| p.assignments.iter().any(|a| a.name == job);
        sweep.plans.iter().any(|(_, p)| in_plan(p))
    }

    fn quick_cfg() -> FleetConfig {
        FleetConfig {
            workers: 2,
            rounds: 1,
            profiler: ProfilerConfig { samples: 1000, max_steps: 6, ..Default::default() },
            horizon: 500,
            ..Default::default()
        }
    }

    #[test]
    fn empty_roster_fails_like_the_batch_sweep() {
        let err = FleetDaemon::builder().config(quick_cfg()).build().drain().unwrap_err();
        assert!(err.to_string().contains("at least one job spec"), "got: {err:#}");
    }

    #[test]
    fn events_process_in_virtual_time_order_not_submission_order() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(2, 7)).build();
        let tail: Vec<_> = sim_fleet(4, 7).into_iter().skip(2).collect();
        let mut tail = tail.into_iter();
        // Submitted later-tick first: the schedule must reorder them.
        d.submit_at(tail.next().unwrap(), 300); // job-02
        d.submit_at(tail.next().unwrap(), 100); // job-03
        assert_eq!(d.run_until(50).unwrap(), 3, "2 arrivals + the coalesced bootstrap replan");
        let arrivals: Vec<&str> = d
            .journal()
            .iter()
            .filter(|e| e.kind == "arrival")
            .map(|e| e.detail.split_whitespace().next().unwrap())
            .collect();
        assert_eq!(arrivals, ["job-00", "job-01"]);
        assert_eq!(d.now(), 50, "run_until advances the clock even when idle");
        d.run_until(400).unwrap();
        let arrivals: Vec<&str> = d
            .journal()
            .iter()
            .filter(|e| e.kind == "arrival")
            .map(|e| e.detail.split_whitespace().next().unwrap())
            .collect();
        assert_eq!(arrivals, ["job-00", "job-01", "job-03", "job-02"], "time order wins");
        let report = d.drain().unwrap();
        assert_eq!(report.summary().outcomes.len(), 4);
    }

    #[test]
    fn mid_run_arrivals_merge_into_the_live_sweep_in_arrival_order() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(3, 7)).build();
        d.run_until(0).unwrap();
        assert_eq!(d.metrics().replans, 1, "bootstrap replan ran");
        let extra = sim_fleet(4, 7).pop().unwrap();
        d.submit_at(extra, 600);
        d.run_until(600).unwrap();
        assert_eq!(d.metrics().replans, 2, "arrival triggered a second replan");
        let sweep = d.sweep.as_ref().expect("sweep mode");
        let names: Vec<&str> = sweep.outcomes.iter().map(|o| o.name.as_str()).collect();
        assert_eq!(names, ["job-00", "job-01", "job-02", "job-03"]);
        assert!(planned(sweep, "job-03"), "newcomer entered the node plans");
        let report = d.drain().unwrap();
        assert_eq!(report.summary().outcomes.len(), 4);
    }

    #[test]
    fn departures_leave_the_plans_and_report() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(3, 7)).build();
        d.run_until(0).unwrap();
        d.retire_at("job-01", 500);
        d.run_until(500).unwrap();
        assert_eq!(d.metrics().departures, 1);
        let sweep = d.sweep.as_ref().expect("sweep mode");
        assert_eq!(sweep.outcomes.len(), 2);
        assert!(!planned(sweep, "job-01"), "departed job must leave the node plans");
        let report = d.drain().unwrap();
        assert_eq!(report.summary().outcomes.len(), 2);
    }

    #[test]
    fn stale_verdict_reprofiles_warm_with_an_aged_cache() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(2, 7)).build();
        d.run_until(0).unwrap();
        let cold = d.cache.stats();
        d.observe_verdict_at("job-00", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 700);
        d.run_until(700).unwrap();
        let after = d.cache.stats();
        assert!(after.evictions > cold.evictions, "stale label entries evicted");
        assert!(after.misses > cold.misses, "re-profile executed fresh probes");
        let probes: Vec<&JournalEntry> = d
            .journal()
            .iter()
            .filter(|e| e.kind == "probe-completion")
            .collect();
        assert_eq!(probes.len(), 1);
        assert!(probes[0].detail.starts_with("job-00:"));
        // Stable and unknown-job verdicts are recorded but change nothing.
        d.observe_verdict_at("job-01", DriftVerdict::Stable, 800);
        d.observe_verdict_at(
            "job-99",
            DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 8.0 },
            800,
        );
        d.run_until(800).unwrap();
        assert_eq!(d.metrics().verdicts, 3);
        assert_eq!(d.metrics().replans, 2, "neither late verdict queued work");
        let report = d.drain().unwrap();
        assert_eq!(report.summary().outcomes.len(), 2);
    }

    #[test]
    fn rate_shift_verdict_replans_against_the_observed_rate() {
        let mut d = FleetDaemon::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(2, 7))
            .rebalance(true)
            .build();
        d.run_until(0).unwrap();
        let verdict = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 9.0 };
        d.observe_verdict_at("job-01", verdict, 400);
        d.run_until(400).unwrap();
        let sweep = d.sweep.as_ref().expect("sweep mode");
        let job = sweep.outcomes.iter().find(|o| o.name == "job-01").unwrap();
        assert_eq!(job.rate_hz, 9.0, "re-profile provisioned for the observed rate");
        assert_eq!(job.index, 1, "in-place update keeps the submission index");
        let report = d.drain().unwrap();
        let plan = report.plan.expect("rebalance stage ran");
        assert_eq!(plan.metrics.jobs, 2);
    }

    #[test]
    fn journal_json_round_trips_the_processed_timeline() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(2, 7)).build();
        d.run_until(0).unwrap();
        let text = crate::util::json::to_string(&journal_json(d.journal()));
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("version").and_then(Json::as_usize), Some(1));
        let entries = back.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), d.journal().len());
        assert_eq!(entries[0].get("kind").and_then(Json::as_str), Some("arrival"));
    }

    #[test]
    fn attached_telemetry_store_tracks_probe_journal_entries() {
        use crate::fleet::telemetry::SeriesKind;
        let store = Arc::new(TelemetryStore::new());
        let mut d = FleetDaemon::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(2, 7))
            .telemetry(store.clone())
            .build();
        d.run_until(0).unwrap();
        d.observe_verdict_at("job-00", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 700);
        d.run_until(700).unwrap();
        let journal_probes = d.journal().iter().filter(|e| e.kind == "probe-completion").count();
        let node = sim_fleet(1, 7)[0].node.name;
        let stored = store.points(SeriesKind::Probes, "job-00", node);
        assert_eq!(stored.len(), journal_probes);
        assert_eq!(stored[0].0, 700);
        assert!(stored[0].1 > 0.0, "stale re-profile executed fresh probes");
        assert_eq!(d.telemetry().unwrap().total_points(), store.total_points());
        d.drain().unwrap();
    }

    #[test]
    fn same_tick_retire_and_verdict_drops_the_verdict() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(2, 7)).build();
        d.run_until(0).unwrap();
        let cold = d.cache.stats();
        // Verdict first, departure second, same tick: the departure must
        // supersede the queued re-profile without aging the cache.
        d.observe_verdict_at("job-01", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 600);
        d.retire_at("job-01", 600);
        d.run_until(600).unwrap();
        let after = d.cache.stats();
        assert_eq!(after.evictions, cold.evictions, "no cache aging for a departed job");
        assert_eq!(after.misses, cold.misses, "no re-profile executed");
        assert_eq!(d.journal().iter().filter(|e| e.kind == "probe-completion").count(), 0);
        let drops: Vec<&JournalEntry> =
            d.journal().iter().filter(|e| e.kind == "verdict-dropped").collect();
        assert_eq!(drops.len(), 1);
        assert!(drops[0].detail.starts_with("job-01:"), "got: {}", drops[0].detail);
        assert_eq!(d.metrics().replans, 2, "verdict and departure coalesced into one replan");
        // Reversed order (the departure pops first): the verdict finds
        // no rostered job and is dropped at arrival, journaled too.
        d.retire_at("job-00", 700);
        d.observe_verdict_at("job-00", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 700);
        d.run_until(700).unwrap();
        assert_eq!(d.cache.stats().evictions, cold.evictions);
        assert_eq!(d.journal().iter().filter(|e| e.kind == "verdict-dropped").count(), 2);
    }

    #[test]
    fn same_tick_submit_and_verdict_coalesce_into_one_replan() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(2, 7)).build();
        d.run_until(0).unwrap();
        // A verdict scheduled *before* the newcomer's same-tick arrival
        // targets a job not yet rostered: dropped with a journal entry.
        d.observe_verdict_at("job-02", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 500);
        let newcomer = sim_fleet(3, 7).pop().unwrap();
        d.submit_at(newcomer, 500);
        d.observe_verdict_at("job-00", DriftVerdict::ModelStale { rolling_smape: 0.9 }, 500);
        d.run_until(500).unwrap();
        assert_eq!(d.metrics().replans, 2, "arrival + verdict coalesced into one replan");
        let probes: Vec<&JournalEntry> =
            d.journal().iter().filter(|e| e.kind == "probe-completion").collect();
        assert_eq!(probes.len(), 2, "newcomer cold profile + job-00 warm re-profile");
        assert!(probes.iter().any(|e| e.detail.starts_with("job-02:")));
        assert!(probes.iter().any(|e| e.detail.starts_with("job-00:")));
        let drops: Vec<&JournalEntry> =
            d.journal().iter().filter(|e| e.kind == "verdict-dropped").collect();
        assert_eq!(drops.len(), 1, "the pre-arrival verdict was dropped");
        assert!(drops[0].detail.starts_with("job-02:"), "got: {}", drops[0].detail);
        let report = d.drain().unwrap();
        assert_eq!(report.summary().outcomes.len(), 3);
    }

    #[test]
    fn mesh_daemon_gossips_on_cadence_and_drains_a_mesh_plan() {
        let topo = MeshTopology::parse("ring:4").unwrap();
        let mut d = FleetDaemon::builder()
            .config(quick_cfg())
            .jobs(sim_fleet(3, 7))
            .mesh(topo, MeshConfig { every: 200, rounds: 3 })
            .mesh_fault_at(400, MeshFault::Cut("wally.0".into(), "asok.1".into()))
            .build();
        d.run_until(650).unwrap();
        assert_eq!(d.journal().iter().filter(|e| e.kind == "gossip-round").count(), 3);
        assert!(d.journal().iter().any(|e| e.kind == "link-cut"));
        let topo = d.mesh().expect("mesh attached").topology();
        assert!(!topo.link_up("wally.0", "asok.1"), "fault landed before the same-tick round");
        let report = d.drain().unwrap();
        let plan = report.plan.expect("mesh drain reports the mesh plan");
        assert_eq!(plan.metrics.jobs, 3);
        let stats = report.mesh.expect("mesh stats ride along in the report");
        assert_eq!(stats.gossip_rounds, 3);
        assert!(stats.summaries_delivered > 0, "ring neighbors exchanged summaries");
    }

    #[test]
    fn past_events_clamp_to_the_current_tick() {
        let mut d = FleetDaemon::builder().config(quick_cfg()).jobs(sim_fleet(1, 7)).build();
        d.run_until(900).unwrap();
        let late = sim_fleet(2, 7).pop().unwrap();
        d.submit_at(late, 100); // in the past: clamps to t = 900
        assert_eq!(d.run_until(899).unwrap(), 0);
        assert!(d.run_until(900).unwrap() > 0);
        assert_eq!(d.sweep.as_ref().unwrap().outcomes.len(), 2);
    }

    /// The mixed-mutation scenario shared by the overlap tests: a drift
    /// verdict at t=700, then a fresh arrival at t=800 — two replans
    /// whose probes can overlap. `workers: 1` keeps the bootstrap pool
    /// the same size in both modes, so even the `worker` field matches.
    fn overlap_scenario(probe_workers: usize) -> FleetDaemon {
        let cfg = FleetConfig { probe_workers, workers: 1, ..quick_cfg() };
        let mut d = FleetDaemon::builder().config(cfg).jobs(sim_fleet(2, 7)).build();
        let shift = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 9.0 };
        d.observe_verdict_at("job-00", shift, 700);
        d.submit_at(sim_fleet(3, 7).pop().unwrap(), 800);
        d
    }

    #[test]
    fn overlapped_drain_is_byte_identical_to_the_synchronous_report() {
        let sync = overlap_scenario(0).drain().unwrap();
        let overlapped = overlap_scenario(1).drain().unwrap();
        assert_eq!(
            crate::util::json::to_string(&sync.to_json()),
            crate::util::json::to_string(&overlapped.to_json()),
            "overlapped replay diverged from the synchronous path"
        );
    }

    #[test]
    fn completions_defer_past_transparent_events_so_replans_overlap() {
        let mut d = overlap_scenario(1);
        d.run_until(1_000).unwrap();
        let kinds: Vec<(&str, String)> = d
            .journal()
            .iter()
            .map(|e| (e.kind, e.detail.split(':').next().unwrap_or("").to_string()))
            .collect();
        let dispatched_new = kinds
            .iter()
            .position(|(k, job)| *k == "probe-dispatched" && job == "job-02")
            .expect("the arrival's probe was dispatched");
        let completed_old = kinds
            .iter()
            .position(|(k, job)| *k == "probe-completion" && job == "job-00")
            .expect("the verdict's probe completed");
        assert!(
            dispatched_new < completed_old,
            "the second replan dispatched before the first batch settled: {kinds:?}"
        );
        let report = d.drain().unwrap();
        assert_eq!(report.summary().outcomes.len(), 3);
    }
}
