//! Decentralized local-optimistic mesh scheduling for edge fleets.
//!
//! [`super::migrate::rebalance`] is a centralized planner with global
//! knowledge — realistic for one datacenter, wrong for the paper's
//! edge/fog setting. This module implements a LOS-style scheduler (LOS:
//! Local-Optimistic Scheduling of Periodic Model Training in Meshed Edge
//! Networks, arXiv 2109.13009): every node runs a [`LocalScheduler`] that
//! knows its *direct topology neighbors only*, learns their residual
//! capacity from gossiped [`NodeSummary`] messages
//! ([`super::gossip::GossipBus`]), and makes **local-optimistic** placement
//! decisions — it offers its shed jobs to the best neighbor its (possibly
//! stale) view suggests, and resolves the inevitable accept conflicts
//! optimistically through [`JobManager::try_accept`] with a deterministic
//! loser-retry on the next gossip round.
//!
//! Faults are first-class scenario axes, not test hacks: link partitions
//! ([`MeshTopology::cut`] / [`MeshTopology::heal`]), delayed gossip (link
//! latency in the topology spec), and node loss ([`MeshTopology::lose`])
//! all flow through the same [`MeshFault`] events the daemon schedules on
//! its virtual clock.
//!
//! Invariants (property-tested in `tests/proptests.rs`):
//! * a node only ever reads its neighbors' gossiped summaries — migrations
//!   always follow topology links;
//! * no guaranteed job is ever displaced ([`JobManager::try_accept`] grants
//!   from residual capacity only, and crowded-out migrants roll back);
//! * the whole round is deterministic — node-name, priority, and job-name
//!   orderings everywhere, no wallclock, no randomness;
//! * a fully-connected zero-latency mesh converges to within tolerance of
//!   the centralized [`FleetPlan`].

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Mutex, OnceLock};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::{JobManager, ManagedJob};
use crate::simulator::{NodeSpec, NODES};

use super::gossip::{GossipBus, NodeSummary};
use super::migrate::{FleetMetrics, FleetPlan, Migration};
use super::placement::{candidates_among, translate_model, FleetJob, NodeView};

/// Interned mesh nodes: clones of the Table-I base machines renamed
/// `<base>.<idx>`, leaked to the `&'static` lifetime the placement layer
/// works with. Interning dedupes, so re-parsing a topology (tests, benches,
/// repeated CLI runs) never grows the leak.
static MESH_NODES: OnceLock<Mutex<BTreeMap<String, &'static NodeSpec>>> = OnceLock::new();

fn intern_node(base: &'static NodeSpec, name: &str) -> &'static NodeSpec {
    let mut map = MESH_NODES.get_or_init(|| Mutex::new(BTreeMap::new())).lock().unwrap();
    if let Some(&spec) = map.get(name) {
        return spec;
    }
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    let spec: &'static NodeSpec = Box::leak(Box::new(NodeSpec { name: leaked, ..base.clone() }));
    map.insert(name.to_string(), spec);
    spec
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// State of one named link between two mesh nodes.
#[derive(Clone, Copy, Debug)]
struct Link {
    latency: u64,
    up: bool,
}

/// A mesh of named nodes and links with latency and partition state.
///
/// Topologies are built from a compact spec string:
///
/// ```text
/// full:<n> | ring:<n> | line:<n> | star:<n> | grid:<w>x<h>   [@<latency>]
/// ```
///
/// Node `i` is a clone of Table-I machine `NODES[i % 7]` named
/// `<base>.<i>` (e.g. `wally.0`, `asok.1`, `pi4.2`, …), so a 100-node mesh
/// cycles the calibrated machine zoo. The optional `@<latency>` suffix
/// applies the same gossip latency (in virtual ticks) to every link;
/// without it links deliver within the publishing round.
#[derive(Clone, Debug)]
pub struct MeshTopology {
    spec: String,
    nodes: Vec<&'static NodeSpec>,
    adjacency: BTreeMap<&'static str, Vec<&'static str>>,
    links: BTreeMap<(&'static str, &'static str), Link>,
    lost: BTreeSet<&'static str>,
}

impl MeshTopology {
    /// Parse a topology spec (see the type docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self> {
        let (body, latency) = match spec.split_once('@') {
            Some((b, l)) => {
                let lat = l
                    .trim()
                    .parse::<u64>()
                    .map_err(|_| anyhow!("mesh spec '{spec}': bad latency '{l}'"))?;
                (b.trim(), lat)
            }
            None => (spec.trim(), 0),
        };
        let (shape, size) = body
            .split_once(':')
            .ok_or_else(|| anyhow!("mesh spec '{spec}': expected <shape>:<size>[@latency]"))?;
        let mut edges: BTreeSet<(usize, usize)> = BTreeSet::new();
        let n = if shape == "grid" {
            let (w, h) = size
                .split_once('x')
                .ok_or_else(|| anyhow!("mesh spec '{spec}': grid wants <w>x<h>"))?;
            let w: usize =
                w.parse().map_err(|_| anyhow!("mesh spec '{spec}': bad grid width '{w}'"))?;
            let h: usize =
                h.parse().map_err(|_| anyhow!("mesh spec '{spec}': bad grid height '{h}'"))?;
            if w * h < 2 {
                bail!("mesh spec '{spec}': a mesh needs at least 2 nodes");
            }
            for r in 0..h {
                for c in 0..w {
                    let i = r * w + c;
                    if c + 1 < w {
                        edges.insert((i, i + 1));
                    }
                    if r + 1 < h {
                        edges.insert((i, i + w));
                    }
                }
            }
            w * h
        } else {
            let n: usize =
                size.parse().map_err(|_| anyhow!("mesh spec '{spec}': bad size '{size}'"))?;
            if n < 2 {
                bail!("mesh spec '{spec}': a mesh needs at least 2 nodes");
            }
            match shape {
                "full" => {
                    for i in 0..n {
                        for j in i + 1..n {
                            edges.insert((i, j));
                        }
                    }
                }
                "ring" => {
                    for i in 0..n {
                        let j = (i + 1) % n;
                        edges.insert((i.min(j), i.max(j)));
                    }
                }
                "line" => {
                    for i in 0..n - 1 {
                        edges.insert((i, i + 1));
                    }
                }
                "star" => {
                    for i in 1..n {
                        edges.insert((0, i));
                    }
                }
                other => bail!("mesh spec '{spec}': unknown shape '{other}' \
                     (full|ring|line|star|grid)"),
            }
            n
        };

        let nodes: Vec<&'static NodeSpec> = (0..n)
            .map(|i| {
                let base = &NODES[i % NODES.len()];
                intern_node(base, &format!("{}.{}", base.name, i))
            })
            .collect();
        let mut adjacency: BTreeMap<&'static str, Vec<&'static str>> =
            nodes.iter().map(|s| (s.name, Vec::new())).collect();
        let mut links = BTreeMap::new();
        for &(i, j) in &edges {
            let (a, b) = (nodes[i].name, nodes[j].name);
            adjacency.get_mut(a).unwrap().push(b);
            adjacency.get_mut(b).unwrap().push(a);
            links.insert(Self::key(a, b), Link { latency, up: true });
        }
        for neighbors in adjacency.values_mut() {
            neighbors.sort_unstable();
        }
        Ok(Self { spec: spec.to_string(), nodes, adjacency, links, lost: BTreeSet::new() })
    }

    fn key(a: &'static str, b: &'static str) -> (&'static str, &'static str) {
        if a <= b {
            (a, b)
        } else {
            (b, a)
        }
    }

    fn link_key(&self, a: &str, b: &str) -> Result<(&'static str, &'static str)> {
        let a = self.resolve(a)?;
        let b = self.resolve(b)?;
        let key = Self::key(a.name, b.name);
        if !self.links.contains_key(&key) {
            bail!("no mesh link {}-{}", a.name, b.name);
        }
        Ok(key)
    }

    fn resolve(&self, name: &str) -> Result<&'static NodeSpec> {
        self.nodes
            .iter()
            .find(|s| s.name == name)
            .copied()
            .ok_or_else(|| anyhow!("unknown mesh node '{name}' in topology '{}'", self.spec))
    }

    /// The spec string this topology was parsed from.
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// All mesh nodes, in index order.
    pub fn nodes(&self) -> &[&'static NodeSpec] {
        &self.nodes
    }

    /// Whether `name` is a member of this mesh.
    pub fn contains(&self, name: &str) -> bool {
        self.adjacency.contains_key(name)
    }

    /// Number of (undirected) links in the topology.
    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Direct topology neighbors of `name`, in name order — regardless of
    /// link/partition state (callers filter with [`Self::link_up`]).
    pub fn neighbors(&self, name: &str) -> Vec<&'static NodeSpec> {
        self.adjacency
            .get(name)
            .map(|ns| ns.iter().map(|n| self.resolve(n).expect("adjacency is closed")).collect())
            .unwrap_or_default()
    }

    /// Whether `a` and `b` share a topology link (up or down).
    pub fn are_linked(&self, a: &str, b: &str) -> bool {
        self.adjacency.get(a).is_some_and(|ns| ns.iter().any(|n| *n == b))
    }

    /// Whether the `a`-`b` link exists and is currently up.
    pub fn link_up(&self, a: &str, b: &str) -> bool {
        match (self.resolve(a), self.resolve(b)) {
            (Ok(a), Ok(b)) => {
                self.links.get(&Self::key(a.name, b.name)).map(|l| l.up).unwrap_or(false)
            }
            _ => false,
        }
    }

    /// Gossip latency of the `a`-`b` link, if the link exists.
    pub fn link_latency(&self, a: &str, b: &str) -> Option<u64> {
        let (a, b) = (self.resolve(a).ok()?, self.resolve(b).ok()?);
        self.links.get(&Self::key(a.name, b.name)).map(|l| l.latency)
    }

    /// Partition the `a`-`b` link: summaries published across it are
    /// dropped until [`Self::heal`].
    pub fn cut(&mut self, a: &str, b: &str) -> Result<()> {
        let key = self.link_key(a, b)?;
        self.links.get_mut(&key).expect("validated").up = false;
        Ok(())
    }

    /// Restore a previously [`Self::cut`] link.
    pub fn heal(&mut self, a: &str, b: &str) -> Result<()> {
        let key = self.link_key(a, b)?;
        self.links.get_mut(&key).expect("validated").up = true;
        Ok(())
    }

    /// Mark a node lost: it stops publishing and receiving gossip, accepts
    /// no placements, and its resident jobs drop out of the mesh plan.
    pub fn lose(&mut self, name: &str) {
        if let Ok(spec) = self.resolve(name) {
            self.lost.insert(spec.name);
        }
    }

    /// Whether `name` has been [`Self::lose`]d.
    pub fn is_lost(&self, name: &str) -> bool {
        self.lost.contains(name)
    }
}

/// A fault injected into the mesh at a scheduled virtual tick — the
/// scenario axes behind `fleet --mesh --partition`.
#[derive(Clone, Debug)]
pub enum MeshFault {
    /// Partition the named link.
    Cut(String, String),
    /// Restore the named link.
    Heal(String, String),
    /// Lose the named node.
    Lose(String),
}

impl MeshFault {
    /// Apply this fault to a topology.
    pub fn apply(&self, topo: &mut MeshTopology) -> Result<()> {
        match self {
            MeshFault::Cut(a, b) => topo.cut(a, b),
            MeshFault::Heal(a, b) => topo.heal(a, b),
            MeshFault::Lose(n) => {
                topo.resolve(n)?;
                topo.lose(n);
                Ok(())
            }
        }
    }

    /// Short verb tag for journals and logs.
    pub fn verb(&self) -> &'static str {
        match self {
            MeshFault::Cut(..) => "cut",
            MeshFault::Heal(..) => "heal",
            MeshFault::Lose(..) => "lose",
        }
    }
}

impl std::fmt::Display for MeshFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MeshFault::Cut(a, b) => write!(f, "cut {a}-{b}"),
            MeshFault::Heal(a, b) => write!(f, "heal {a}-{b}"),
            MeshFault::Lose(n) => write!(f, "lose {n}"),
        }
    }
}

/// Gossip cadence of a mesh run.
#[derive(Clone, Copy, Debug)]
pub struct MeshConfig {
    /// Virtual ticks between gossip rounds.
    pub every: u64,
    /// Number of gossip rounds to schedule.
    pub rounds: usize,
}

impl Default for MeshConfig {
    fn default() -> Self {
        Self { every: 200, rounds: 5 }
    }
}

/// The per-node scheduler: one machine's neighbor-local view of the mesh.
#[derive(Clone, Debug)]
pub struct LocalScheduler {
    /// The node this scheduler runs on.
    pub spec: &'static NodeSpec,
    views: BTreeMap<&'static str, NodeSummary>,
}

impl LocalScheduler {
    fn new(spec: &'static NodeSpec) -> Self {
        Self { spec, views: BTreeMap::new() }
    }

    /// Fold a delivered summary into the view; the newest publish wins.
    fn observe(&mut self, summary: NodeSummary) {
        match self.views.get(summary.node) {
            Some(old) if old.at > summary.at => {}
            _ => {
                self.views.insert(summary.node, summary);
            }
        }
    }

    /// The neighbor summaries this node currently holds, in name order.
    pub fn views(&self) -> impl Iterator<Item = &NodeSummary> {
        self.views.values()
    }

    /// Aggregate age of the held views at `now` (staleness, in ticks).
    pub fn view_age(&self, now: u64) -> u64 {
        self.views.values().map(|v| now.saturating_sub(v.at)).sum()
    }
}

/// Lifetime counters of one mesh run — mirrored into the telemetry store
/// so `streamprof serve` can answer mesh-health queries.
#[derive(Clone, Copy, Debug, Default)]
pub struct MeshStats {
    /// Gossip rounds executed.
    pub gossip_rounds: u64,
    /// Summaries delivered into neighbor views.
    pub summaries_delivered: u64,
    /// Summaries dropped on partitioned links or lost endpoints.
    pub summaries_dropped: u64,
    /// Aggregate view age (ticks) summed over nodes at each round.
    pub staleness_ticks: u64,
    /// Optimistic offers refused or crowded out and rolled back.
    pub conflict_rollbacks: u64,
    /// Accepted cross-node moves.
    pub moves: u64,
}

/// What one gossip round did — the telemetry/journal payload.
#[derive(Clone, Debug, Default)]
pub struct RoundOutcome {
    /// Summaries delivered this round.
    pub delivered: u64,
    /// Summaries dropped this round.
    pub dropped: u64,
    /// Aggregate view age (ticks) across nodes at this round.
    pub staleness_ticks: u64,
    /// `(job, refused destination)` pairs rolled back this round.
    pub rollbacks: Vec<(String, &'static str)>,
    /// Migrations accepted this round.
    pub moves: Vec<Migration>,
}

/// One placement offer a node makes for a shed job.
struct Offer {
    job: String,
    from: &'static str,
    to: &'static str,
    priority: i32,
    needs_reprofile: bool,
}

/// The mesh scheduler: topology + gossip bus + one [`LocalScheduler`] per
/// node, advancing in discrete gossip rounds on the virtual clock.
#[derive(Debug)]
pub struct Mesh {
    topo: MeshTopology,
    bus: GossipBus,
    schedulers: BTreeMap<&'static str, LocalScheduler>,
    jobs: BTreeMap<String, FleetJob>,
    placement: BTreeMap<String, &'static str>,
    attempted: BTreeMap<String, BTreeSet<&'static str>>,
    migrations: Vec<Migration>,
    baseline_guaranteed: Option<usize>,
    stats: MeshStats,
}

impl Mesh {
    /// Build a mesh over `topo` with empty views and no jobs.
    pub fn new(topo: MeshTopology) -> Self {
        let schedulers =
            topo.nodes().iter().map(|&spec| (spec.name, LocalScheduler::new(spec))).collect();
        Self {
            topo,
            bus: GossipBus::new(),
            schedulers,
            jobs: BTreeMap::new(),
            placement: BTreeMap::new(),
            attempted: BTreeMap::new(),
            migrations: Vec::new(),
            baseline_guaranteed: None,
            stats: MeshStats::default(),
        }
    }

    /// The topology (for fault injection and introspection).
    pub fn topology(&self) -> &MeshTopology {
        &self.topo
    }

    /// Mutable topology access — how scheduled [`MeshFault`]s land.
    pub fn topology_mut(&mut self) -> &mut MeshTopology {
        &mut self.topo
    }

    /// Current job placements (job name → mesh node name).
    pub fn placements(&self) -> &BTreeMap<String, &'static str> {
        &self.placement
    }

    /// Accumulated run counters.
    pub fn stats(&self) -> MeshStats {
        self.stats
    }

    /// Sync the mesh's job set with the fleet's current roster. Jobs keep
    /// their existing placement; new jobs start on their home node when it
    /// is a mesh member, otherwise on a deterministic (name-hashed) node.
    /// Departed jobs leave the placement map.
    pub fn sync_jobs(&mut self, jobs: &[FleetJob]) {
        self.jobs = jobs.iter().map(|j| (j.name.clone(), j.clone())).collect();
        self.placement.retain(|name, _| self.jobs.contains_key(name));
        self.attempted.retain(|name, _| self.jobs.contains_key(name));
        let n = self.topo.nodes().len();
        for job in self.jobs.values() {
            if self.placement.contains_key(&job.name) {
                continue;
            }
            let node = if self.topo.contains(job.node.name) {
                self.topo.resolve(job.node.name).expect("member").name
            } else {
                self.topo.nodes()[(fnv1a(job.name.as_bytes()) % n as u64) as usize].name
            };
            self.placement.insert(job.name.clone(), node);
        }
        if self.baseline_guaranteed.is_none() && !self.jobs.is_empty() {
            let managers = self.managers();
            self.baseline_guaranteed = Some(guaranteed_count(&managers));
        }
    }

    /// Rebuild per-node managers from the current placement. Jobs resident
    /// on lost nodes are excluded — their guarantees died with the node.
    fn managers(&self) -> BTreeMap<&'static str, (&'static NodeSpec, JobManager)> {
        let mut managers: BTreeMap<&'static str, (&'static NodeSpec, JobManager)> = self
            .topo
            .nodes()
            .iter()
            .filter(|s| !self.topo.is_lost(s.name))
            .map(|&s| (s.name, (s, JobManager::new(s.cores))))
            .collect();
        for (name, &node) in &self.placement {
            let Some((spec, mgr)) = managers.get_mut(node) else {
                continue; // resident node lost
            };
            let job = &self.jobs[name];
            mgr.register(ManagedJob {
                name: job.name.clone(),
                model: translate_model(&job.model, job.node, spec),
                rate_hz: job.rate_hz,
                priority: job.priority,
            });
        }
        managers
    }

    /// Run one gossip round at virtual tick `now`: publish summaries to
    /// neighbors, deliver everything due, let each node offer its shed
    /// jobs to the best neighbor its view suggests, and resolve the offers
    /// optimistically (losers retry next round).
    pub fn round(&mut self, now: u64) -> RoundOutcome {
        let mut managers = self.managers();
        let before = self.bus.counters();

        // Publish: every live node advertises its residual to neighbors.
        for (spec, mgr) in managers.values() {
            let summary = NodeSummary {
                node: spec.name,
                at: now,
                residual: mgr.residual_capacity(),
                capacity: mgr.capacity(),
            };
            self.bus.publish(&self.topo, &summary);
        }

        // Deliver everything due (zero-latency links deliver in-round).
        for (to, summary) in self.bus.deliver_due(now) {
            if let Some(sched) = self.schedulers.get_mut(to) {
                sched.observe(summary);
            }
        }

        // Decide: each node, in name order, offers its shed jobs (priority
        // desc, name asc) to the best *reachable neighbor* its view
        // suggests. Nothing outside the neighbor views is consulted.
        let mut staleness = 0u64;
        let mut offers: Vec<Offer> = Vec::new();
        for (&node, (_, mgr)) in &managers {
            if self.topo.is_lost(node) {
                continue;
            }
            let sched = &self.schedulers[node];
            staleness += sched.view_age(now);
            let views: Vec<NodeView> = sched
                .views()
                .filter(|v| {
                    self.topo.link_up(node, v.node)
                        && !self.topo.is_lost(v.node)
                        && v.node != node
                })
                .map(|v| NodeView {
                    spec: self.topo.resolve(v.node).expect("view of a member"),
                    residual: v.residual,
                })
                .collect();
            if views.is_empty() {
                continue;
            }
            let plan = mgr.plan();
            let mut shed: Vec<&str> = plan
                .assignments
                .iter()
                .filter(|a| !a.guaranteed)
                .map(|a| a.name.as_str())
                .collect();
            shed.sort_by(|x, y| {
                let (px, py) = (self.jobs[*x].priority, self.jobs[*y].priority);
                py.cmp(&px).then_with(|| x.cmp(y))
            });
            for name in shed {
                let job = &self.jobs[name];
                let candidates = candidates_among(job, &views);
                if candidates.is_empty() {
                    continue;
                }
                let tried = self.attempted.entry(name.to_string()).or_default();
                let pick = match candidates.iter().find(|c| !tried.contains(c.node)) {
                    Some(c) => c,
                    None => {
                        // Every candidate has been refused before: reset the
                        // retry memory and start over from the best one —
                        // fresh gossip may have changed the picture.
                        tried.clear();
                        &candidates[0]
                    }
                };
                offers.push(Offer {
                    job: name.to_string(),
                    from: node,
                    to: pick.node,
                    priority: job.priority,
                    needs_reprofile: pick.needs_reprofile,
                });
            }
        }

        // Resolve: offers grouped by destination (name order); within a
        // destination, higher priority first, job name as tie-break. An
        // offer the destination refuses — someone else took the capacity
        // first, or the view was stale — rolls back; the loser records the
        // refusal and retries a different candidate next round.
        offers.sort_by(|x, y| {
            x.to
                .cmp(y.to)
                .then_with(|| y.priority.cmp(&x.priority))
                .then_with(|| x.job.cmp(&y.job))
        });
        let mut outcome = RoundOutcome { staleness_ticks: staleness, ..Default::default() };
        for offer in offers {
            let job = &self.jobs[&offer.job];
            let dest_spec = self.topo.resolve(offer.to).expect("offer to a member");
            let translated = translate_model(&job.model, job.node, dest_spec);
            let dest = &mut managers.get_mut(offer.to).expect("live destination").1;
            let accepted = dest.try_accept(ManagedJob {
                name: job.name.clone(),
                model: translated,
                rate_hz: job.rate_hz,
                priority: job.priority,
            });
            let granted = match accepted {
                Some(limit) => limit,
                None => {
                    self.attempted.entry(offer.job.clone()).or_default().insert(offer.to);
                    outcome.rollbacks.push((offer.job, offer.to));
                    continue;
                }
            };
            // Crowd-out recheck: the destination re-plans from scratch and
            // a resident shed job with higher priority can push the migrant
            // straight back out — roll such no-op moves back.
            let kept =
                dest.plan().assignments.iter().any(|a| a.name == offer.job && a.guaranteed);
            if !kept {
                dest.deregister(&offer.job);
                self.attempted.entry(offer.job.clone()).or_default().insert(offer.to);
                outcome.rollbacks.push((offer.job, offer.to));
                continue;
            }
            let slack_after = dest.residual_capacity();
            managers.get_mut(offer.from).expect("offer origin").1.deregister(&offer.job);
            self.placement.insert(offer.job.clone(), offer.to);
            self.attempted.remove(&offer.job);
            outcome.moves.push(Migration {
                job: offer.job,
                from: offer.from,
                to: offer.to,
                priority: offer.priority,
                limit: granted,
                slack_after,
                needs_reprofile: offer.needs_reprofile,
            });
        }

        let after = self.bus.counters();
        outcome.delivered = after.delivered - before.delivered;
        outcome.dropped = after.dropped - before.dropped;
        self.stats.gossip_rounds += 1;
        self.stats.summaries_delivered += outcome.delivered;
        self.stats.summaries_dropped += outcome.dropped;
        self.stats.staleness_ticks += outcome.staleness_ticks;
        self.stats.conflict_rollbacks += outcome.rollbacks.len() as u64;
        self.stats.moves += outcome.moves.len() as u64;
        self.migrations.extend(outcome.moves.iter().cloned());
        outcome
    }

    /// Assemble the current placement into a [`FleetPlan`] — same shape as
    /// the centralized rebalancer's, so the two are directly comparable.
    /// Lost nodes (and their resident jobs) are excluded.
    pub fn fleet_plan(&self) -> FleetPlan {
        let managers = self.managers();
        let plans: Vec<_> =
            managers.iter().map(|(&name, (_, mgr))| (name.to_string(), mgr.plan())).collect();
        let guaranteed_after = plans
            .iter()
            .flat_map(|(_, p)| p.assignments.iter())
            .filter(|a| a.guaranteed)
            .count();
        let metrics = FleetMetrics {
            jobs: plans.iter().map(|(_, p)| p.assignments.len()).sum(),
            guaranteed_before: self.baseline_guaranteed.unwrap_or(guaranteed_after),
            guaranteed_after,
            total_capacity: plans.iter().map(|(_, p)| p.capacity).sum(),
            total_assigned: plans.iter().map(|(_, p)| p.total_assigned).sum(),
        };
        FleetPlan { plans, migrations: self.migrations.clone(), metrics }
    }
}

fn guaranteed_count(managers: &BTreeMap<&'static str, (&'static NodeSpec, JobManager)>) -> usize {
    managers
        .values()
        .map(|(_, mgr)| mgr.plan().assignments.iter().filter(|a| a.guaranteed).count())
        .sum()
}

/// Run a standalone mesh schedule over `jobs`: `cfg.rounds` gossip rounds
/// at `cfg.every`-tick cadence starting at tick 0, applying each fault in
/// `faults` (a `(tick, fault)` list) before the first round at or after
/// its tick. Returns the final plan and the run counters — the benchable,
/// property-testable form of the scheduler (the daemon drives the same
/// [`Mesh`] from its event loop instead).
pub fn mesh_rebalance(
    jobs: &[FleetJob],
    topo: MeshTopology,
    cfg: &MeshConfig,
    faults: &[(u64, MeshFault)],
) -> Result<(FleetPlan, MeshStats)> {
    let mut mesh = Mesh::new(topo);
    mesh.sync_jobs(jobs);
    let mut pending: Vec<&(u64, MeshFault)> = faults.iter().collect();
    pending.sort_by_key(|(at, _)| *at);
    let mut next_fault = 0usize;
    for k in 0..cfg.rounds {
        let now = k as u64 * cfg.every.max(1);
        while next_fault < pending.len() && pending[next_fault].0 <= now {
            pending[next_fault].1.apply(mesh.topology_mut())?;
            next_fault += 1;
        }
        mesh.round(now);
    }
    Ok((mesh.fleet_plan(), mesh.stats()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{ModelKind, RuntimeModel};

    fn model(a: f64, b: f64) -> RuntimeModel {
        RuntimeModel { kind: ModelKind::Full, a, b, c: 0.001, d: 1.0, fit_cost: 0.0 }
    }

    fn job(name: &str, home: &'static NodeSpec, a: f64, rate: f64, prio: i32) -> FleetJob {
        let model = model(a, home.scaling);
        FleetJob { name: name.into(), node: home, model, rate_hz: rate, priority: prio }
    }

    #[test]
    fn topology_shapes_parse_with_expected_links() {
        let full = MeshTopology::parse("full:5").unwrap();
        assert_eq!(full.nodes().len(), 5);
        assert_eq!(full.link_count(), 10);
        let ring = MeshTopology::parse("ring:4").unwrap();
        assert_eq!(ring.link_count(), 4);
        for spec in ring.nodes() {
            assert_eq!(ring.neighbors(spec.name).len(), 2);
        }
        let line = MeshTopology::parse("line:4").unwrap();
        assert_eq!(line.link_count(), 3);
        let star = MeshTopology::parse("star:5").unwrap();
        assert_eq!(star.link_count(), 4);
        assert_eq!(star.neighbors(star.nodes()[0].name).len(), 4);
        let grid = MeshTopology::parse("grid:2x3").unwrap();
        assert_eq!(grid.nodes().len(), 6);
        assert_eq!(grid.link_count(), 7);
        let latency = MeshTopology::parse("ring:3@40").unwrap();
        let (a, b) = (latency.nodes()[0].name, latency.nodes()[1].name);
        assert_eq!(latency.link_latency(a, b), Some(40));
    }

    #[test]
    fn node_naming_cycles_the_machine_zoo() {
        let topo = MeshTopology::parse("full:9").unwrap();
        let names: Vec<&str> = topo.nodes().iter().map(|s| s.name).collect();
        assert_eq!(names[0], "wally.0");
        assert_eq!(names[2], "pi4.2");
        assert_eq!(names[7], "wally.7", "node 7 cycles back to wally");
        assert_eq!(topo.nodes()[7].cores, topo.nodes()[0].cores);
    }

    #[test]
    fn interned_nodes_are_deduped() {
        let a = MeshTopology::parse("ring:3").unwrap();
        let b = MeshTopology::parse("full:3").unwrap();
        for (x, y) in a.nodes().iter().zip(b.nodes()) {
            assert!(std::ptr::eq(*x, *y), "same name must intern to the same spec");
        }
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in ["", "full", "full:", "full:1", "blob:4", "grid:3", "grid:0x1", "ring:3@soon"] {
            assert!(MeshTopology::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn faults_flip_topology_state() {
        let mut topo = MeshTopology::parse("ring:3").unwrap();
        let (a, b) = (topo.nodes()[0].name, topo.nodes()[1].name);
        assert!(topo.link_up(a, b));
        MeshFault::Cut(a.into(), b.into()).apply(&mut topo).unwrap();
        assert!(!topo.link_up(a, b));
        MeshFault::Heal(a.into(), b.into()).apply(&mut topo).unwrap();
        assert!(topo.link_up(a, b));
        MeshFault::Lose(topo.nodes()[2].name.into()).apply(&mut topo).unwrap();
        assert!(topo.is_lost(topo.nodes()[2].name));
        assert!(MeshFault::Cut(a.into(), "ghost".into()).apply(&mut topo).is_err());
        assert!(MeshFault::Lose("ghost".into()).apply(&mut topo).is_err());
    }

    /// star:5 = wally.0 center with asok.1/pi4.2/e2high.3/e2small.4 leaves.
    /// Six residents fill the center to residual 0.8; pi4.2 carries three
    /// jobs and sheds two, each needing 0.6 on the center — capacity for
    /// exactly one, so the optimistic offers must conflict.
    fn conflict_mesh() -> (MeshTopology, Vec<FleetJob>) {
        let topo = MeshTopology::parse("star:5").unwrap();
        let center = topo.nodes()[0];
        let pi = topo.nodes()[2];
        let mut jobs: Vec<FleetJob> = (0..6)
            .map(|i| job(&format!("w-{i}"), center, 0.05, 20.0, 5))
            .collect();
        for i in 0..3 {
            jobs.push(job(&format!("m-{i}"), pi, 0.05, 40.0, 3 - i as i32));
        }
        (topo, jobs)
    }

    #[test]
    fn conflicting_offers_resolve_with_deterministic_loser_retry() {
        let (topo, jobs) = conflict_mesh();
        let mut mesh = Mesh::new(topo);
        mesh.sync_jobs(&jobs);
        let round = mesh.round(0);
        assert_eq!(round.moves.len(), 1, "center capacity fits exactly one migrant");
        assert_eq!(round.moves[0].job, "m-1", "higher-priority shed job wins the slot");
        assert_eq!(round.moves[0].to, "wally.0");
        assert!(!round.moves[0].needs_reprofile, "0.6 is inside the shared pi4/wally range");
        assert_eq!(round.rollbacks, vec![("m-2".to_string(), "wally.0")]);
        let stats = mesh.stats();
        assert_eq!(stats.conflict_rollbacks, 1);
        assert_eq!(stats.moves, 1);
        // The loser keeps retrying its only neighbor on later rounds.
        let again = mesh.round(200);
        assert!(again.moves.is_empty(), "no capacity freed; the retry must fail again");
        assert_eq!(again.rollbacks.len(), 1);
    }

    #[test]
    fn fleet_plan_reports_the_migrated_state() {
        let (topo, jobs) = conflict_mesh();
        let mut mesh = Mesh::new(topo);
        mesh.sync_jobs(&jobs);
        mesh.round(0);
        let plan = mesh.fleet_plan();
        assert_eq!(plan.metrics.jobs, 9);
        assert_eq!(
            plan.metrics.guaranteed_after,
            plan.metrics.guaranteed_before + 1,
            "{:?}",
            plan.metrics
        );
        let (node, a) = plan.assignment("m-1").expect("migrant planned");
        assert_eq!(node, "wally.0");
        assert!(a.guaranteed);
        assert_eq!(plan.migrations.len(), 1);
        for (name, p) in &plan.plans {
            assert!(p.total_assigned <= p.capacity + 1e-9, "{name} over capacity");
        }
    }

    #[test]
    fn lost_nodes_drop_out_of_the_plan() {
        let (topo, jobs) = conflict_mesh();
        let mut mesh = Mesh::new(topo);
        mesh.sync_jobs(&jobs);
        mesh.round(0);
        mesh.topology_mut().lose("pi4.2");
        let plan = mesh.fleet_plan();
        assert!(plan.node_plan("pi4.2").is_none(), "lost node leaves the plan roster");
        assert_eq!(plan.metrics.jobs, 7, "m-1 migrated out in time; m-0 and m-2 died with pi4.2");
        let next = mesh.round(400);
        assert!(next.moves.is_empty(), "nobody offers to (or from) a lost node");
    }

    #[test]
    fn standalone_driver_is_deterministic() {
        let (topo_a, jobs) = conflict_mesh();
        let (topo_b, _) = conflict_mesh();
        let cfg = MeshConfig { every: 100, rounds: 3 };
        let (plan_a, stats_a) = mesh_rebalance(&jobs, topo_a, &cfg, &[]).unwrap();
        let (plan_b, stats_b) = mesh_rebalance(&jobs, topo_b, &cfg, &[]).unwrap();
        assert_eq!(plan_a.guaranteed_jobs(), plan_b.guaranteed_jobs());
        assert_eq!(plan_a.migrations.len(), plan_b.migrations.len());
        assert_eq!(stats_a.conflict_rollbacks, stats_b.conflict_rollbacks);
        assert_eq!(stats_a.gossip_rounds, 3);
    }

    #[test]
    fn latency_delays_convergence_but_not_correctness() {
        // With @150 links and rounds every 100 ticks, round 0 publishes
        // into the void: views arrive one round late, so the first move
        // can only happen in round 2 — and staleness is visible.
        let (mut topo, jobs) = conflict_mesh();
        topo = MeshTopology::parse(&format!("{}@150", topo.spec())).unwrap();
        let mut mesh = Mesh::new(topo);
        mesh.sync_jobs(&jobs);
        let r0 = mesh.round(0);
        assert!(r0.moves.is_empty(), "no views yet");
        assert_eq!(r0.delivered, 0);
        let r1 = mesh.round(100);
        assert!(r1.moves.is_empty(), "round-0 summaries are still in flight at t=100");
        let r2 = mesh.round(200);
        assert_eq!(r2.moves.len(), 1, "views finally arrived");
        assert!(r2.staleness_ticks > 0, "delivered views are stale by construction");
    }
}
