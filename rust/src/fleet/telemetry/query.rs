//! Tiny query expression language over the telemetry store.
//!
//! Grammar (stages separated by `|`):
//!
//! ```text
//! select <series|*> [where label=<job> node=<node>]
//!     [| window <ticks>] [| agg count|sum|mean|min|max|p99|rate|last]
//! ```
//!
//! `window <ticks>` restricts evaluation to the trailing `[latest - ticks,
//! latest]` interval, where `latest` is the newest timestamp across the
//! *matched* series (the daemon's virtual clock, not wallclock). `agg`
//! folds each matched series to one number; without it the query returns
//! the raw points. Aggregates are computed over the compressed buffers —
//! blocks fully inside the window fold their value runs without decoding
//! timestamps; only `p99` (which needs a sort) and boundary blocks decode
//! points.
//!
//! `agg rate` is **points per tick**: point count divided by the window
//! size when a `window` stage is present, by the matched span
//! `t_last - t_first` otherwise. A single-point or same-tick series has no
//! span to rate over without a window — such degenerate spans evaluate to
//! `null`, never to a bogus `rate == count`.

use crate::util::json::Json;

use super::store::{SeriesBuf, SeriesKey, SeriesKind, TelemetryStore};

/// Per-series fold selected by the `agg` stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Agg {
    /// Number of points in the window.
    Count,
    /// Sum of values.
    Sum,
    /// Arithmetic mean of values.
    Mean,
    /// Minimum value.
    Min,
    /// Maximum value.
    Max,
    /// 99th percentile by the nearest-rank rule (`ceil(0.99 n) - 1` after
    /// sorting) — the same estimator the fleet throughput bench reports.
    P99,
    /// Points per tick over the window span.
    Rate,
    /// Value of the newest point in the window.
    Last,
}

impl Agg {
    const ALL: [Agg; 8] =
        [Agg::Count, Agg::Sum, Agg::Mean, Agg::Min, Agg::Max, Agg::P99, Agg::Rate, Agg::Last];

    /// Wire name used in the grammar and JSON output.
    pub fn name(self) -> &'static str {
        match self {
            Agg::Count => "count",
            Agg::Sum => "sum",
            Agg::Mean => "mean",
            Agg::Min => "min",
            Agg::Max => "max",
            Agg::P99 => "p99",
            Agg::Rate => "rate",
            Agg::Last => "last",
        }
    }

    /// Inverse of [`Agg::name`].
    pub fn from_name(name: &str) -> Option<Agg> {
        Agg::ALL.iter().copied().find(|a| a.name() == name)
    }
}

/// A parsed query. `kind: None` means `select *`.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    /// Series kind filter, or `None` for all kinds.
    pub kind: Option<SeriesKind>,
    /// `where label=..` filter (exact match), if any.
    pub label: Option<String>,
    /// `where node=..` filter (exact match), if any.
    pub node: Option<String>,
    /// Trailing window size in ticks, if any.
    pub window: Option<u64>,
    /// Aggregate stage, if any.
    pub agg: Option<Agg>,
}

impl Query {
    /// Parse a query expression. Errors are human-readable strings in the
    /// same style as `util::json::parse`.
    pub fn parse(text: &str) -> Result<Query, String> {
        let mut stages = text.split('|');
        let select = stages.next().unwrap_or("");
        let toks: Vec<&str> = select.split_whitespace().collect();
        if toks.first() != Some(&"select") {
            return Err("query must start with 'select <series>'".to_string());
        }
        let Some(&series) = toks.get(1) else {
            return Err("select needs a series name or *".to_string());
        };
        let kind = if series == "*" {
            None
        } else {
            match SeriesKind::from_name(series) {
                Some(k) => Some(k),
                None => return Err(format!("unknown series '{series}' (see /series)")),
            }
        };
        let mut query = Query { kind, label: None, node: None, window: None, agg: None };
        if toks.len() > 2 {
            if toks[2] != "where" {
                return Err(format!("expected 'where', got '{}'", toks[2]));
            }
            if toks.len() == 3 {
                return Err("'where' needs at least one label=/node= filter".to_string());
            }
            for tok in &toks[3..] {
                let Some((field, value)) = tok.split_once('=') else {
                    return Err(format!("bad filter '{tok}': expected field=value"));
                };
                match field {
                    "label" => query.label = Some(value.to_string()),
                    "node" => query.node = Some(value.to_string()),
                    _ => return Err(format!("unknown filter field '{field}'")),
                }
            }
        }
        for stage in stages {
            let toks: Vec<&str> = stage.split_whitespace().collect();
            match toks.as_slice() {
                ["window", ticks] => {
                    if query.window.is_some() {
                        return Err("duplicate window stage".to_string());
                    }
                    match ticks.parse::<u64>() {
                        Ok(t) => query.window = Some(t),
                        Err(_) => return Err(format!("bad window '{ticks}': expected ticks")),
                    }
                }
                ["agg", name] => {
                    if query.agg.is_some() {
                        return Err("duplicate agg stage".to_string());
                    }
                    match Agg::from_name(name) {
                        Some(a) => query.agg = Some(a),
                        None => return Err(format!("unknown agg '{name}'")),
                    }
                }
                [] => return Err("empty query stage".to_string()),
                other => return Err(format!("unknown stage '{}'", other.join(" "))),
            }
        }
        Ok(query)
    }

    fn matches(&self, key: &SeriesKey) -> bool {
        self.kind.map_or(true, |k| k == key.kind)
            && self.label.as_deref().map_or(true, |l| l == key.label)
            && self.node.as_deref().map_or(true, |n| n == key.node)
    }

    /// Evaluate against a store. Two passes under the shard locks: one to
    /// find the newest matched timestamp (window anchor), one to fold each
    /// matched series.
    pub fn run(&self, store: &TelemetryStore) -> QueryResult {
        let mut latest = 0u64;
        let mut matched = 0usize;
        store.for_each(|key, buf| {
            if self.matches(key) {
                matched += 1;
                latest = latest.max(buf.latest().unwrap_or(0));
            }
        });
        let bounds = self.window.map(|w| (latest.saturating_sub(w), latest));
        let (lo, hi) = bounds.unwrap_or((0, u64::MAX));
        let mut series = Vec::with_capacity(matched);
        store.for_each(|key, buf| {
            if self.matches(key) {
                series.push(eval_series(key, buf, lo, hi, self.agg, self.window));
            }
        });
        QueryResult { query: self.clone(), window: bounds, series }
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("select", Json::str(self.kind.map(SeriesKind::name).unwrap_or("*"))),
            ("label", self.label.as_deref().map(Json::str).unwrap_or(Json::Null)),
            ("node", self.node.as_deref().map(Json::str).unwrap_or(Json::Null)),
            ("window", self.window.map(|w| Json::num(w as f64)).unwrap_or(Json::Null)),
            ("agg", self.agg.map(|a| Json::str(a.name())).unwrap_or(Json::Null)),
        ])
    }
}

fn eval_series(
    key: &SeriesKey,
    buf: &SeriesBuf,
    lo: u64,
    hi: u64,
    agg: Option<Agg>,
    window: Option<u64>,
) -> SeriesResult {
    let Some(agg) = agg else {
        let points = buf.points_in(lo, hi);
        let count = points.len() as u64;
        return SeriesResult { key: key.clone(), count, value: None, points };
    };
    let stats = buf.stats_in(lo, hi);
    let value = if stats.count == 0 {
        None
    } else {
        match agg {
            Agg::Count => Some(stats.count as f64),
            Agg::Sum => Some(stats.sum),
            Agg::Mean => Some(stats.sum / stats.count as f64),
            Agg::Min => Some(stats.min),
            Agg::Max => Some(stats.max),
            Agg::Last => Some(stats.v_last),
            Agg::Rate => match window {
                Some(w) => Some(stats.count as f64 / w.max(1) as f64),
                None if stats.t_last > stats.t_first => {
                    Some(stats.count as f64 / (stats.t_last - stats.t_first) as f64)
                }
                // No window and a single-point / same-tick series: there
                // is no span to rate over — null, not `rate == count`.
                None => None,
            },
            Agg::P99 => {
                let mut values: Vec<f64> =
                    buf.points_in(lo, hi).into_iter().map(|(_, v)| v).collect();
                values.sort_by(f64::total_cmp);
                let rank = ((values.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
                Some(values[rank])
            }
        }
    };
    SeriesResult { key: key.clone(), count: stats.count, value, points: Vec::new() }
}

/// One matched series in a [`QueryResult`].
#[derive(Clone, Debug)]
pub struct SeriesResult {
    /// The series identity.
    pub key: SeriesKey,
    /// Points inside the evaluated window.
    pub count: u64,
    /// Aggregate value; `None` without an `agg` stage or on an empty
    /// window.
    pub value: Option<f64>,
    /// Raw in-window points; populated only without an `agg` stage.
    pub points: Vec<(u64, f64)>,
}

impl SeriesResult {
    fn to_json(&self, with_points: bool) -> Json {
        let mut fields = vec![
            ("kind", Json::str(self.key.kind.name())),
            ("label", Json::str(&self.key.label)),
            ("node", Json::str(&self.key.node)),
            ("count", Json::num(self.count as f64)),
        ];
        if with_points {
            let pts = self.points.iter();
            let arr = pts.map(|&(t, v)| Json::arr([Json::num(t as f64), Json::num(v)]));
            fields.push(("points", Json::arr(arr)));
        } else {
            fields.push(("value", self.value.map(Json::num).unwrap_or(Json::Null)));
        }
        Json::obj(fields)
    }
}

/// The result of evaluating a [`Query`]: one entry per matched series, in
/// key order.
#[derive(Clone, Debug)]
pub struct QueryResult {
    /// The query that produced this result.
    pub query: Query,
    /// Evaluated `[lo, hi]` bounds when a window stage was present.
    pub window: Option<(u64, u64)>,
    /// Matched series, sorted by key.
    pub series: Vec<SeriesResult>,
}

impl QueryResult {
    /// The aggregate of the single matched series, if the query matched
    /// exactly one and carried an `agg` stage.
    pub fn single(&self) -> Option<f64> {
        match self.series.as_slice() {
            [one] => one.value,
            _ => None,
        }
    }

    /// Serialize for the CLI and the HTTP endpoint.
    pub fn to_json(&self) -> Json {
        let with_points = self.query.agg.is_none();
        let window = match self.window {
            Some((lo, hi)) => Json::arr([Json::num(lo as f64), Json::num(hi as f64)]),
            None => Json::Null,
        };
        Json::obj([
            ("query", self.query.to_json()),
            ("window", window),
            ("matched", Json::num(self.series.len() as f64)),
            ("series", Json::arr(self.series.iter().map(|s| s.to_json(with_points)))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> TelemetryStore {
        let s = TelemetryStore::new();
        for t in 0..10u64 {
            s.append(SeriesKind::Probes, "job-00", "pi4", t * 100, 4.0);
            s.append(SeriesKind::Probes, "job-01", "nano", t * 100, 6.0);
        }
        s.append(SeriesKind::Verdicts, "job-00", "pi4", 450, 2.0);
        s
    }

    #[test]
    fn parse_full_grammar() {
        let q = Query::parse("select probes where label=job-00 node=pi4 | window 600 | agg p99")
            .unwrap();
        assert_eq!(q.kind, Some(SeriesKind::Probes));
        assert_eq!(q.label.as_deref(), Some("job-00"));
        assert_eq!(q.node.as_deref(), Some("pi4"));
        assert_eq!(q.window, Some(600));
        assert_eq!(q.agg, Some(Agg::P99));
        let star = Query::parse("select *").unwrap();
        assert_eq!(star.kind, None);
        assert_eq!(star.agg, None);
    }

    #[test]
    fn parse_rejects_malformed_queries() {
        for bad in [
            "",
            "probes",
            "select",
            "select nope",
            "select probes where",
            "select probes where label",
            "select probes where job=job-00",
            "select probes whence label=x",
            "select probes | window x",
            "select probes | window 1 | window 2",
            "select probes | agg p50",
            "select probes | agg sum | agg sum",
            "select probes | ",
            "select probes | group by node",
        ] {
            assert!(Query::parse(bad).is_err(), "accepted: {bad:?}");
        }
    }

    #[test]
    fn select_returns_points_in_key_order() {
        let r = Query::parse("select probes").unwrap().run(&store());
        assert_eq!(r.series.len(), 2);
        assert_eq!(r.series[0].key.label, "job-00");
        assert_eq!(r.series[1].key.label, "job-01");
        assert_eq!(r.series[0].points.len(), 10);
        assert_eq!(r.window, None);
    }

    #[test]
    fn filters_and_aggregates() {
        let s = store();
        let sum = Query::parse("select probes where label=job-00 | agg sum").unwrap().run(&s);
        assert_eq!(sum.single(), Some(40.0));
        let mean = Query::parse("select probes where node=nano | agg mean").unwrap().run(&s);
        assert_eq!(mean.single(), Some(6.0));
        let last = Query::parse("select verdicts | agg last").unwrap().run(&s);
        assert_eq!(last.single(), Some(2.0));
        let none = Query::parse("select smape | agg sum").unwrap().run(&s);
        assert_eq!(none.series.len(), 0);
        assert_eq!(none.single(), None);
    }

    #[test]
    fn window_anchors_on_newest_matched_timestamp() {
        let s = store();
        let q = Query::parse("select probes where label=job-00 | window 300 | agg count").unwrap();
        let r = q.run(&s);
        // latest = 900, window = [600, 900] -> points at 600/700/800/900.
        assert_eq!(r.window, Some((600, 900)));
        assert_eq!(r.single(), Some(4.0));
        let rate = Query::parse("select probes where label=job-00 | window 300 | agg rate")
            .unwrap()
            .run(&s);
        assert_eq!(rate.single(), Some(4.0 / 300.0));
    }

    #[test]
    fn rate_is_null_for_degenerate_spans() {
        let s = TelemetryStore::new();
        s.append(SeriesKind::Probes, "solo", "pi4", 500, 4.0);
        // Single point, no window: no span to rate over.
        let one = Query::parse("select probes where label=solo | agg rate").unwrap().run(&s);
        assert_eq!(one.single(), None, "single-point rate must be null, not count");
        assert_eq!(one.series[0].count, 1);
        // Same-tick burst: span is still zero.
        s.append(SeriesKind::Probes, "solo", "pi4", 500, 2.0);
        let burst = Query::parse("select probes where label=solo | agg rate").unwrap().run(&s);
        assert_eq!(burst.single(), None);
        // An explicit window supplies the denominator.
        let windowed = Query::parse("select probes where label=solo | window 100 | agg rate")
            .unwrap()
            .run(&s);
        assert_eq!(windowed.single(), Some(2.0 / 100.0));
        // And the null serializes as JSON null, not as a number.
        let text = crate::util::json::to_string(&burst.to_json());
        let doc = crate::util::json::parse(&text).unwrap();
        let series = doc.get("series").and_then(Json::as_arr).unwrap();
        assert!(matches!(series[0].get("value"), Some(Json::Null)));
        // A real span rates over t_last - t_first as before.
        s.append(SeriesKind::Probes, "solo", "pi4", 700, 2.0);
        let spanned = Query::parse("select probes where label=solo | agg rate").unwrap().run(&s);
        assert_eq!(spanned.single(), Some(3.0 / 200.0));
    }

    #[test]
    fn p99_matches_the_bench_estimator() {
        let s = TelemetryStore::new();
        for t in 0..200u64 {
            s.append(SeriesKind::Runtime, "job-00", "pi4", t, t as f64);
        }
        let r = Query::parse("select runtime | agg p99").unwrap().run(&s);
        // ceil(200 * 0.99) - 1 = 197 -> value 197.0 of the sorted 0..200.
        assert_eq!(r.single(), Some(197.0));
    }

    #[test]
    fn result_json_parses_back() {
        let r = Query::parse("select probes | agg sum").unwrap().run(&store());
        let text = crate::util::json::to_string(&r.to_json());
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("matched").and_then(Json::as_f64), Some(2.0));
        let series = doc.get("series").and_then(Json::as_arr).unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].get("value").and_then(Json::as_f64), Some(40.0));
        let raw = Query::parse("select verdicts").unwrap().run(&store());
        let doc = crate::util::json::parse(&crate::util::json::to_string(&raw.to_json())).unwrap();
        let series = doc.get("series").and_then(Json::as_arr).unwrap();
        let points = series[0].get("points").and_then(Json::as_arr).unwrap();
        assert_eq!(points.len(), 1);
    }
}
