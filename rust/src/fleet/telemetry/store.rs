//! Columnar in-memory time-series store for fleet telemetry.
//!
//! Every series is keyed `(kind, label, node)` and held in a [`SeriesBuf`]
//! ring of compressed blocks: timestamps are delta-of-delta encoded as
//! zigzag varints (ticks on the daemon's virtual clock compress to ~1
//! byte each), values are run-length encoded over their raw `f64` bits
//! (counters and repeated gauge readings collapse to a single run).
//! Retention is a fixed per-series point budget; eviction drops whole
//! oldest blocks, so the store is lossless *within* the retention window
//! and explicit about what it dropped (`evicted()`).
//!
//! Appends are lock-striped across 8 shards by FNV-1a of the full key —
//! the daemon's replan hot path only ever contends on one shard with a
//! concurrent reader, mirroring the sharded [`MeasurementCache`]
//! (`crate::fleet::MeasurementCache`) design.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use crate::util::fnv1a;

/// Shards in the store; appends hash the full series key to pick one.
const STORE_SHARDS: usize = 8;

/// Default per-series retention, in points. At one point per processed
/// daemon event this covers thousands of ticks per series.
pub const DEFAULT_RETENTION: usize = 4096;

/// Points per sealed block (capped by the series capacity so tiny
/// retention windows still evict at a useful granularity).
const BLOCK_POINTS: usize = 256;

/// What a telemetry series measures. The `name()` strings are the public
/// vocabulary shared by the query grammar, the HTTP endpoints, and the
/// `--journal-out` diff in the e2e tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SeriesKind {
    /// Job arrivals (value 1 per admitted job).
    Arrivals,
    /// Job retirements (value 1 per retired job).
    Departures,
    /// Drift verdicts, encoded 0=stable / 1=rate-shift / 2=model-stale.
    Verdicts,
    /// Probes actually executed by a (re-)profile of a job.
    Probes,
    /// Observed mean runtimes from profiling steps (seconds).
    Runtime,
    /// Rolling SMAPE after a drift-triggered re-profile.
    Smape,
    /// Per-node residual capacity after each replan.
    Headroom,
    /// Cross-node migrations (value 1; node = destination).
    Migrations,
    /// Measurement-cache hit delta since the previous flush.
    CacheHits,
    /// Measurement-cache miss delta since the previous flush.
    CacheMisses,
    /// Mesh gossip rounds (value = summaries delivered that round).
    GossipRounds,
    /// Aggregate mesh view age in ticks at each gossip round (staleness).
    StalenessTicks,
    /// Optimistic mesh placements refused and rolled back (value 1 per
    /// rollback; node = the refusing destination).
    ConflictRollbacks,
    /// Async probes outstanding (dispatched to the probe pool, not yet
    /// merged) right after each dispatch — the overlapped daemon's
    /// backlog signal.
    ProbeQueueDepth,
    /// Probes a fresh arrival executed *without* an adopted transfer
    /// prior (value = executed probe count) — the cold-start cost the
    /// transfer corpus exists to kill.
    ColdStartProbes,
    /// Fresh arrivals whose profile adopted (or tempered) a transfer
    /// prior (value 1 per primed profile).
    PriorAdoptions,
}

impl SeriesKind {
    /// Every kind, in serialization order.
    pub const ALL: [SeriesKind; 16] = [
        SeriesKind::Arrivals,
        SeriesKind::Departures,
        SeriesKind::Verdicts,
        SeriesKind::Probes,
        SeriesKind::Runtime,
        SeriesKind::Smape,
        SeriesKind::Headroom,
        SeriesKind::Migrations,
        SeriesKind::CacheHits,
        SeriesKind::CacheMisses,
        SeriesKind::GossipRounds,
        SeriesKind::StalenessTicks,
        SeriesKind::ConflictRollbacks,
        SeriesKind::ProbeQueueDepth,
        SeriesKind::ColdStartProbes,
        SeriesKind::PriorAdoptions,
    ];

    /// Stable wire name used by queries, JSON output, and docs.
    pub fn name(self) -> &'static str {
        match self {
            SeriesKind::Arrivals => "arrivals",
            SeriesKind::Departures => "departures",
            SeriesKind::Verdicts => "verdicts",
            SeriesKind::Probes => "probes",
            SeriesKind::Runtime => "runtime",
            SeriesKind::Smape => "smape",
            SeriesKind::Headroom => "headroom",
            SeriesKind::Migrations => "migrations",
            SeriesKind::CacheHits => "cache_hits",
            SeriesKind::CacheMisses => "cache_misses",
            SeriesKind::GossipRounds => "gossip_rounds",
            SeriesKind::StalenessTicks => "staleness_ticks",
            SeriesKind::ConflictRollbacks => "conflict_rollbacks",
            SeriesKind::ProbeQueueDepth => "probe_queue_depth",
            SeriesKind::ColdStartProbes => "cold_start_probes",
            SeriesKind::PriorAdoptions => "prior_adoptions",
        }
    }

    /// Inverse of [`SeriesKind::name`].
    pub fn from_name(name: &str) -> Option<SeriesKind> {
        SeriesKind::ALL.iter().copied().find(|k| k.name() == name)
    }
}

/// Full identity of one series. `label` is the job name for job-scoped
/// kinds, empty for node- or fleet-scoped ones; `node` is empty for
/// fleet-scoped kinds (cache deltas).
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    /// What the series measures.
    pub kind: SeriesKind,
    /// Job name, or empty when the series is not job-scoped.
    pub label: String,
    /// Node name, or empty when the series is fleet-scoped.
    pub node: String,
}

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        out.push((v as u8) | 0x80);
        v >>= 7;
    }
    out.push(v as u8);
}

/// Reads one LEB128 varint at `*pos`, advancing it. Inputs are only ever
/// produced by [`write_varint`], so truncation cannot occur; a malformed
/// slice decodes to whatever prefix was present rather than panicking.
fn read_varint(buf: &[u8], pos: &mut usize) -> u64 {
    let mut v = 0u64;
    let mut shift = 0u32;
    while *pos < buf.len() {
        let byte = buf[*pos];
        *pos += 1;
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            break;
        }
        shift += 7;
    }
    v
}

/// One sealed, immutable compressed block of points.
#[derive(Clone, Debug)]
struct Block {
    /// Varint stream: first timestamp raw, then zigzag delta-of-delta.
    ts: Vec<u8>,
    /// Run-length encoded values as (f64 bits, run length).
    runs: Vec<(u64, u32)>,
    len: u32,
    t_min: u64,
    t_max: u64,
}

/// Mutable tail block accepting appends until it reaches the block size.
#[derive(Clone, Debug, Default)]
struct BlockBuilder {
    ts: Vec<u8>,
    runs: Vec<(u64, u32)>,
    len: u32,
    t_min: u64,
    t_max: u64,
    t_prev: u64,
    delta_prev: i64,
}

impl BlockBuilder {
    fn push(&mut self, t: u64, v: f64) {
        if self.len == 0 {
            write_varint(&mut self.ts, t);
            self.t_min = t;
            self.t_max = t;
            self.delta_prev = 0;
        } else {
            // Wrapping i64 arithmetic round-trips ANY u64 timestamp, so
            // out-of-order appends (concurrent writers sharing a series)
            // stay lossless rather than corrupting the stream.
            let delta = (t as i64).wrapping_sub(self.t_prev as i64);
            write_varint(&mut self.ts, zigzag(delta.wrapping_sub(self.delta_prev)));
            self.delta_prev = delta;
            self.t_min = self.t_min.min(t);
            self.t_max = self.t_max.max(t);
        }
        self.t_prev = t;
        let bits = v.to_bits();
        match self.runs.last_mut() {
            Some((run_bits, n)) if *run_bits == bits && *n < u32::MAX => *n += 1,
            _ => self.runs.push((bits, 1)),
        }
        self.len += 1;
    }

    fn seal(&mut self) -> Block {
        let b = std::mem::take(self);
        Block { ts: b.ts, runs: b.runs, len: b.len, t_min: b.t_min, t_max: b.t_max }
    }
}

/// Streaming decoder over one block's compressed representation.
struct PointIter<'a> {
    ts: &'a [u8],
    pos: usize,
    runs: &'a [(u64, u32)],
    run_idx: usize,
    run_off: u32,
    emitted: u32,
    len: u32,
    t_prev: u64,
    delta_prev: i64,
}

impl<'a> PointIter<'a> {
    fn new(ts: &'a [u8], runs: &'a [(u64, u32)], len: u32) -> Self {
        PointIter {
            ts,
            pos: 0,
            runs,
            run_idx: 0,
            run_off: 0,
            emitted: 0,
            len,
            t_prev: 0,
            delta_prev: 0,
        }
    }
}

/// Borrowed view of one block's compressed streams, either sealed or the
/// open tail.
struct BlockView<'a> {
    ts: &'a [u8],
    runs: &'a [(u64, u32)],
    len: u32,
    t_min: u64,
    t_max: u64,
}

impl Iterator for PointIter<'_> {
    type Item = (u64, f64);

    fn next(&mut self) -> Option<(u64, f64)> {
        if self.emitted == self.len {
            return None;
        }
        let t = if self.emitted == 0 {
            read_varint(self.ts, &mut self.pos)
        } else {
            let dod = unzigzag(read_varint(self.ts, &mut self.pos));
            self.delta_prev = self.delta_prev.wrapping_add(dod);
            (self.t_prev as i64).wrapping_add(self.delta_prev) as u64
        };
        self.t_prev = t;
        let (bits, n) = self.runs[self.run_idx];
        self.run_off += 1;
        if self.run_off == n {
            self.run_idx += 1;
            self.run_off = 0;
        }
        self.emitted += 1;
        Some((t, f64::from_bits(bits)))
    }
}

/// Window aggregates computed without materializing points. All value
/// fields are meaningless when `count == 0`; `t_first`/`t_last`/`v_last`
/// assume the series was appended in non-decreasing time order (true for
/// the daemon, whose virtual clock is monotone).
#[derive(Clone, Copy, Debug, Default)]
pub struct SeriesStats {
    /// Points inside the window.
    pub count: u64,
    /// Sum of values inside the window.
    pub sum: f64,
    /// Minimum value inside the window.
    pub min: f64,
    /// Maximum value inside the window.
    pub max: f64,
    /// Timestamp of the first in-window point.
    pub t_first: u64,
    /// Timestamp of the last in-window point.
    pub t_last: u64,
    /// Value of the last in-window point.
    pub v_last: f64,
}

impl SeriesStats {
    fn absorb_point(&mut self, t: u64, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
            self.t_first = t;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
        self.t_last = t;
        self.v_last = v;
    }

    /// Fast path for a block fully inside the window: fold the value
    /// runs directly, never touching the timestamp stream.
    fn absorb_runs(&mut self, runs: &[(u64, u32)], len: u32, t_min: u64, t_max: u64) {
        if len == 0 {
            return;
        }
        if self.count == 0 {
            self.t_first = t_min;
            self.min = f64::INFINITY;
            self.max = f64::NEG_INFINITY;
        }
        for &(bits, n) in runs {
            let v = f64::from_bits(bits);
            self.min = self.min.min(v);
            self.max = self.max.max(v);
            self.sum += v * f64::from(n);
        }
        self.count += u64::from(len);
        self.t_last = t_max;
        self.v_last = f64::from_bits(runs[runs.len() - 1].0);
    }
}

/// Ring buffer of compressed blocks holding one series.
#[derive(Debug)]
pub struct SeriesBuf {
    sealed: VecDeque<Block>,
    open: BlockBuilder,
    block_points: u32,
    capacity: usize,
    total: usize,
    evicted: u64,
}

impl SeriesBuf {
    /// A ring retaining at most `capacity` points (clamped to ≥ 1).
    pub fn new(capacity: usize) -> SeriesBuf {
        let capacity = capacity.max(1);
        SeriesBuf {
            sealed: VecDeque::new(),
            open: BlockBuilder::default(),
            block_points: capacity.min(BLOCK_POINTS) as u32,
            capacity,
            total: 0,
            evicted: 0,
        }
    }

    /// Append one point. Seals the open block at the block size and
    /// evicts whole oldest blocks while over capacity.
    pub fn append(&mut self, t: u64, v: f64) {
        self.open.push(t, v);
        self.total += 1;
        if self.open.len >= self.block_points {
            let sealed = self.open.seal();
            self.sealed.push_back(sealed);
        }
        while self.total > self.capacity {
            match self.sealed.pop_front() {
                Some(b) => {
                    self.total -= b.len as usize;
                    self.evicted += u64::from(b.len);
                }
                None => break,
            }
        }
    }

    /// Retained points.
    pub fn len(&self) -> usize {
        self.total
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Retention budget in points.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Points dropped by retention so far.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Largest retained timestamp, if any.
    pub fn latest(&self) -> Option<u64> {
        let sealed = self.sealed.iter().map(|b| b.t_max).max();
        let open = (self.open.len > 0).then_some(self.open.t_max);
        sealed.into_iter().chain(open).max()
    }

    /// Smallest retained timestamp, if any.
    pub fn earliest(&self) -> Option<u64> {
        let sealed = self.sealed.iter().map(|b| b.t_min).min();
        let open = (self.open.len > 0).then_some(self.open.t_min);
        sealed.into_iter().chain(open).min()
    }

    /// Compressed footprint in bytes (timestamp streams + value runs).
    pub fn compressed_bytes(&self) -> usize {
        let mut total = self.open.ts.len() + self.open.runs.len() * 12;
        for b in &self.sealed {
            total += b.ts.len() + b.runs.len() * 12;
        }
        total
    }

    fn blocks(&self) -> Vec<BlockView<'_>> {
        let mut out = Vec::with_capacity(self.sealed.len() + 1);
        for b in &self.sealed {
            out.push(BlockView {
                ts: &b.ts,
                runs: &b.runs,
                len: b.len,
                t_min: b.t_min,
                t_max: b.t_max,
            });
        }
        if self.open.len > 0 {
            let o = &self.open;
            out.push(BlockView {
                ts: &o.ts,
                runs: &o.runs,
                len: o.len,
                t_min: o.t_min,
                t_max: o.t_max,
            });
        }
        out
    }

    /// Decode every retained point in append order.
    pub fn points(&self) -> Vec<(u64, f64)> {
        let mut out = Vec::with_capacity(self.total);
        for b in self.blocks() {
            out.extend(PointIter::new(b.ts, b.runs, b.len));
        }
        out
    }

    /// Decode only the points with `lo <= t <= hi`, skipping blocks whose
    /// time range is disjoint from the window.
    pub fn points_in(&self, lo: u64, hi: u64) -> Vec<(u64, f64)> {
        let mut out = Vec::new();
        for b in self.blocks() {
            if b.t_min > hi || b.t_max < lo {
                continue;
            }
            let pts = PointIter::new(b.ts, b.runs, b.len);
            out.extend(pts.filter(|&(t, _)| t >= lo && t <= hi));
        }
        out
    }

    /// Window aggregates. Blocks fully inside `[lo, hi]` fold their value
    /// runs without decoding timestamps; only boundary blocks decode.
    pub fn stats_in(&self, lo: u64, hi: u64) -> SeriesStats {
        let mut stats = SeriesStats::default();
        for b in self.blocks() {
            if b.t_min > hi || b.t_max < lo {
                continue;
            }
            if b.t_min >= lo && b.t_max <= hi {
                stats.absorb_runs(b.runs, b.len, b.t_min, b.t_max);
            } else {
                for (t, v) in PointIter::new(b.ts, b.runs, b.len) {
                    if t >= lo && t <= hi {
                        stats.absorb_point(t, v);
                    }
                }
            }
        }
        stats
    }
}

/// Lock-striped map of series rings. Shared by the recording daemon and
/// any number of query readers; a reader only blocks appends that hash
/// to the same shard.
pub struct TelemetryStore {
    shards: [Mutex<BTreeMap<SeriesKey, SeriesBuf>>; STORE_SHARDS],
    retention: usize,
}

impl TelemetryStore {
    /// Store with the [`DEFAULT_RETENTION`] point budget per series.
    pub fn new() -> TelemetryStore {
        TelemetryStore::with_retention(DEFAULT_RETENTION)
    }

    /// Store retaining at most `points` per series (clamped to ≥ 1).
    pub fn with_retention(points: usize) -> TelemetryStore {
        TelemetryStore {
            shards: std::array::from_fn(|_| Mutex::new(BTreeMap::new())),
            retention: points.max(1),
        }
    }

    fn shard_index(kind: SeriesKind, label: &str, node: &str) -> usize {
        let bytes = kind
            .name()
            .bytes()
            .chain(std::iter::once(0))
            .chain(label.bytes())
            .chain(std::iter::once(0))
            .chain(node.bytes());
        (fnv1a(bytes) % STORE_SHARDS as u64) as usize
    }

    /// Append one point to the series `(kind, label, node)`, creating it
    /// on first touch.
    pub fn append(&self, kind: SeriesKind, label: &str, node: &str, at: u64, value: f64) {
        let idx = TelemetryStore::shard_index(kind, label, node);
        let mut shard = self.shards[idx].lock().unwrap();
        shard
            .entry(SeriesKey { kind, label: label.to_string(), node: node.to_string() })
            .or_insert_with(|| SeriesBuf::new(self.retention))
            .append(at, value);
    }

    /// Visit every series in key order. Holds one shard lock at a time;
    /// the callback sees a consistent view of each shard, not of the
    /// whole store.
    pub fn for_each<F: FnMut(&SeriesKey, &SeriesBuf)>(&self, mut f: F) {
        let mut all: Vec<(SeriesKey, usize)> = Vec::new();
        for (idx, shard) in self.shards.iter().enumerate() {
            let shard = shard.lock().unwrap();
            all.extend(shard.keys().map(|k| (k.clone(), idx)));
        }
        all.sort();
        for (key, idx) in all {
            let shard = self.shards[idx].lock().unwrap();
            if let Some(buf) = shard.get(&key) {
                f(&key, buf);
            }
        }
    }

    /// Every series key, sorted.
    pub fn keys(&self) -> Vec<SeriesKey> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.lock().unwrap().keys().cloned());
        }
        out.sort();
        out
    }

    /// Decoded points of one series, or empty if it does not exist.
    pub fn points(&self, kind: SeriesKind, label: &str, node: &str) -> Vec<(u64, f64)> {
        let idx = TelemetryStore::shard_index(kind, label, node);
        let shard = self.shards[idx].lock().unwrap();
        let key = SeriesKey { kind, label: label.to_string(), node: node.to_string() };
        shard.get(&key).map(|b| b.points()).unwrap_or_default()
    }

    /// Number of series.
    pub fn series_count(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Retained points across all series.
    pub fn total_points(&self) -> usize {
        let mut total = 0;
        for shard in &self.shards {
            total += shard.lock().unwrap().values().map(SeriesBuf::len).sum::<usize>();
        }
        total
    }

    /// Points dropped by retention across all series.
    pub fn total_evicted(&self) -> u64 {
        let mut total = 0;
        for shard in &self.shards {
            total += shard.lock().unwrap().values().map(SeriesBuf::evicted).sum::<u64>();
        }
        total
    }

    /// Compressed footprint across all series, in bytes.
    pub fn compressed_bytes(&self) -> usize {
        let mut total = 0;
        for shard in &self.shards {
            for buf in shard.lock().unwrap().values() {
                total += buf.compressed_bytes();
            }
        }
        total
    }

    /// Largest timestamp across all series, if any point exists.
    pub fn latest(&self) -> Option<u64> {
        let mut best: Option<u64> = None;
        for shard in &self.shards {
            for buf in shard.lock().unwrap().values() {
                best = best.max(buf.latest());
            }
        }
        best
    }
}

impl Default for TelemetryStore {
    fn default() -> TelemetryStore {
        TelemetryStore::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, 300, -300, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 16383, 16384, u64::MAX] {
            buf.clear();
            write_varint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn series_roundtrips_in_append_order() {
        let pts = [(10u64, 1.0), (10, 1.0), (12, 1.0), (500, 2.5), (500, 2.5), (501, -3.0)];
        let mut buf = SeriesBuf::new(64);
        for &(t, v) in &pts {
            buf.append(t, v);
        }
        assert_eq!(buf.points(), pts);
        assert_eq!(buf.len(), pts.len());
        assert_eq!(buf.evicted(), 0);
        assert_eq!(buf.earliest(), Some(10));
        assert_eq!(buf.latest(), Some(501));
    }

    #[test]
    fn repeated_values_collapse_to_one_run() {
        let mut buf = SeriesBuf::new(1024);
        for t in 0..500u64 {
            buf.append(t * 100, 7.0);
        }
        // One open block run + regular deltas: far smaller than 500 raw points.
        assert!(buf.compressed_bytes() < 500, "got {}", buf.compressed_bytes());
        assert_eq!(buf.points().len(), 500);
    }

    #[test]
    fn eviction_is_block_granular_and_oldest_first() {
        let mut buf = SeriesBuf::new(10); // block_points = 10
        for t in 0..35u64 {
            buf.append(t, t as f64);
            assert!(buf.len() <= 10);
        }
        assert_eq!(buf.len() as u64 + buf.evicted(), 35);
        let pts = buf.points();
        // Whatever is retained is exactly the newest suffix.
        let first = 35 - pts.len() as u64;
        let expect: Vec<(u64, f64)> = (first..35).map(|t| (t, t as f64)).collect();
        assert_eq!(pts, expect);
    }

    #[test]
    fn window_queries_skip_disjoint_blocks() {
        let mut buf = SeriesBuf::new(4096);
        for t in 0..1000u64 {
            buf.append(t, (t % 5) as f64);
        }
        let pts = buf.points_in(600, 699);
        assert_eq!(pts.len(), 100);
        assert!(pts.iter().all(|&(t, _)| (600..=699).contains(&t)));
        let stats = buf.stats_in(600, 699);
        assert_eq!(stats.count, 100);
        assert_eq!(stats.t_first, 600);
        assert_eq!(stats.t_last, 699);
        assert_eq!(stats.min, 0.0);
        assert_eq!(stats.max, 4.0);
        assert_eq!(stats.sum, pts.iter().map(|&(_, v)| v).sum::<f64>());
        assert_eq!(stats.v_last, (699 % 5) as f64);
    }

    #[test]
    fn stats_full_block_fast_path_matches_decode() {
        let mut buf = SeriesBuf::new(4096);
        for t in 0..777u64 {
            buf.append(t * 3, ((t * 7) % 11) as f64 - 5.0);
        }
        let all = buf.stats_in(0, u64::MAX);
        let pts = buf.points();
        assert_eq!(all.count as usize, pts.len());
        assert_eq!(all.sum, pts.iter().map(|&(_, v)| v).sum::<f64>());
        let min = pts.iter().map(|&(_, v)| v).fold(f64::INFINITY, f64::min);
        let max = pts.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(all.min, min);
        assert_eq!(all.max, max);
        assert_eq!(all.v_last, pts.last().unwrap().1);
    }

    #[test]
    fn store_keys_series_independently() {
        let store = TelemetryStore::new();
        store.append(SeriesKind::Probes, "job-00", "pi4", 10, 4.0);
        store.append(SeriesKind::Probes, "job-01", "pi4", 11, 5.0);
        store.append(SeriesKind::Verdicts, "job-00", "pi4", 12, 2.0);
        assert_eq!(store.series_count(), 3);
        assert_eq!(store.total_points(), 3);
        assert_eq!(store.points(SeriesKind::Probes, "job-00", "pi4"), vec![(10, 4.0)]);
        assert_eq!(store.points(SeriesKind::Probes, "job-02", "pi4"), Vec::new());
        assert_eq!(store.latest(), Some(12));
        let keys = store.keys();
        assert_eq!(keys.len(), 3);
        assert!(keys.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in SeriesKind::ALL {
            assert_eq!(SeriesKind::from_name(k.name()), Some(k));
        }
        assert_eq!(SeriesKind::from_name("nope"), None);
    }
}
