//! Std-only HTTP/JSON endpoint over the telemetry store.
//!
//! A deliberately tiny server — `TcpListener` + hand-parsed GET requests,
//! one connection at a time, `Connection: close` — because the crate's
//! only dependency is `anyhow` and the query surface is four read-only
//! routes:
//!
//! | route            | returns                                          |
//! |------------------|--------------------------------------------------|
//! | `/healthz`       | store size, evicted points, compressed footprint |
//! | `/series`        | every series key with its retained point count   |
//! | `/snapshot`      | the drained fleet report JSON                    |
//! | `/query?q=<expr>`| a [`Query`] result (expression percent-encoded)  |

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

use super::query::Query;
use super::store::TelemetryStore;

/// Serves telemetry queries and a fleet snapshot over HTTP.
pub struct TelemetryServer {
    listener: TcpListener,
    store: Arc<TelemetryStore>,
    snapshot: String,
}

impl TelemetryServer {
    /// Bind `addr` (e.g. `127.0.0.1:7878`, or port 0 for an ephemeral
    /// port in tests). `snapshot` is served verbatim at `/snapshot`.
    pub fn bind(addr: &str, store: Arc<TelemetryStore>, snapshot: &Json) -> Result<Self> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding telemetry server on {addr}"))?;
        Ok(TelemetryServer { listener, store, snapshot: json::to_string(snapshot) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("telemetry server has a local address")
    }

    /// Accept and answer exactly one connection. Per-connection I/O
    /// errors are reported on stderr but do not take the server down.
    pub fn serve_one(&self) -> Result<()> {
        let (stream, _) = self.listener.accept().context("telemetry server accept")?;
        if let Err(e) = self.handle(stream) {
            eprintln!("telemetry serve: {e:#}");
        }
        Ok(())
    }

    /// Accept and answer exactly `n` connections (test harness helper).
    pub fn serve_requests(&self, n: usize) -> Result<()> {
        for _ in 0..n {
            self.serve_one()?;
        }
        Ok(())
    }

    /// Serve until the process exits (the `streamprof serve` loop).
    pub fn serve_forever(&self) -> Result<()> {
        loop {
            self.serve_one()?;
        }
    }

    fn handle(&self, stream: TcpStream) -> Result<()> {
        stream.set_read_timeout(Some(Duration::from_secs(5)))?;
        stream.set_write_timeout(Some(Duration::from_secs(5)))?;
        let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);
        let mut request_line = String::new();
        reader.read_line(&mut request_line).context("reading request line")?;
        let mut parts = request_line.split_whitespace();
        let method = parts.next().unwrap_or("").to_string();
        let target = parts.next().unwrap_or("/").to_string();
        // Drain request headers so well-behaved clients see a clean close.
        loop {
            let mut header = String::new();
            let n = reader.read_line(&mut header).unwrap_or(0);
            if n == 0 || header == "\r\n" || header == "\n" {
                break;
            }
        }
        let (status, body) = self.route(&method, &target);
        let mut out = stream;
        write!(
            out,
            "HTTP/1.1 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .context("writing response")?;
        out.flush().context("flushing response")?;
        Ok(())
    }

    fn route(&self, method: &str, target: &str) -> (&'static str, String) {
        if method != "GET" {
            let err = error_body("only GET is supported");
            return ("405 Method Not Allowed", err);
        }
        let (path, params) = match target.split_once('?') {
            Some((p, q)) => (p, q),
            None => (target, ""),
        };
        match path {
            "/healthz" => ("200 OK", json::to_string(&self.healthz())),
            "/series" => ("200 OK", json::to_string(&self.series())),
            "/snapshot" => ("200 OK", self.snapshot.clone()),
            "/query" => self.query(params),
            _ => ("404 Not Found", error_body(&format!("no route {path}"))),
        }
    }

    fn healthz(&self) -> Json {
        Json::obj([
            ("ok", Json::Bool(true)),
            ("series", Json::num(self.store.series_count() as f64)),
            ("points", Json::num(self.store.total_points() as f64)),
            ("evicted", Json::num(self.store.total_evicted() as f64)),
            ("compressed_bytes", Json::num(self.store.compressed_bytes() as f64)),
        ])
    }

    fn series(&self) -> Json {
        let mut rows = Vec::new();
        self.store.for_each(|key, buf| {
            rows.push(Json::obj([
                ("kind", Json::str(key.kind.name())),
                ("label", Json::str(&key.label)),
                ("node", Json::str(&key.node)),
                ("points", Json::num(buf.len() as f64)),
                ("evicted", Json::num(buf.evicted() as f64)),
            ]));
        });
        Json::obj([("series", Json::Arr(rows))])
    }

    fn query(&self, params: &str) -> (&'static str, String) {
        let Some(expr) = query_param(params, "q") else {
            return ("400 Bad Request", error_body("missing q= parameter"));
        };
        match Query::parse(&expr) {
            Ok(q) => ("200 OK", json::to_string(&q.run(&self.store).to_json())),
            Err(e) => ("400 Bad Request", error_body(&e)),
        }
    }
}

fn error_body(message: &str) -> String {
    json::to_string(&Json::obj([("error", Json::str(message))]))
}

/// Value of `name` in a `k=v&k=v` query string, percent-decoded.
fn query_param(params: &str, name: &str) -> Option<String> {
    for pair in params.split('&') {
        let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
        if k == name {
            return Some(percent_decode(v));
        }
    }
    None
}

/// Decodes `%XX` escapes and `+`-as-space; malformed escapes pass through
/// verbatim (the query parser then reports them).
fn percent_decode(s: &str) -> String {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            b'%' => {
                let hex = bytes.get(i + 1..i + 3).and_then(|h| std::str::from_utf8(h).ok());
                match hex.and_then(|h| u8::from_str_radix(h, 16).ok()) {
                    Some(b) => {
                        out.push(b);
                        i += 3;
                    }
                    None => {
                        out.push(b'%');
                        i += 1;
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::telemetry::store::SeriesKind;
    use std::io::Read;

    fn request(addr: SocketAddr, target: &str) -> (String, String) {
        let mut stream = TcpStream::connect(addr).unwrap();
        write!(stream, "GET {target} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
        let mut response = String::new();
        stream.read_to_string(&mut response).unwrap();
        let (head, body) = response.split_once("\r\n\r\n").unwrap();
        let status = head.lines().next().unwrap().to_string();
        (status, body.to_string())
    }

    fn test_server() -> (TelemetryServer, SocketAddr) {
        let store = Arc::new(TelemetryStore::new());
        for t in 0..5u64 {
            store.append(SeriesKind::Probes, "job-00", "pi4", t * 100, 4.0);
        }
        let snapshot = Json::obj([("fleet", Json::str("test"))]);
        let server = TelemetryServer::bind("127.0.0.1:0", store, &snapshot).unwrap();
        let addr = server.local_addr();
        (server, addr)
    }

    #[test]
    fn percent_decoding() {
        assert_eq!(percent_decode("select%20probes%20%7C%20agg%20sum"), "select probes | agg sum");
        assert_eq!(percent_decode("a+b%3Dc"), "a b=c");
        assert_eq!(percent_decode("100%"), "100%");
        assert_eq!(percent_decode("%zz"), "%zz");
    }

    #[test]
    fn routes_answer_over_real_sockets() {
        let (server, addr) = test_server();
        let serving = std::thread::spawn(move || server.serve_requests(6).unwrap());
        let (status, body) = request(addr, "/healthz");
        assert!(status.contains("200"), "{status}");
        let doc = json::parse(&body).unwrap();
        assert_eq!(doc.get("points").and_then(Json::as_usize), Some(5));
        let (_, body) = request(addr, "/series");
        let doc = json::parse(&body).unwrap();
        let rows = doc.get("series").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("kind").and_then(Json::as_str), Some("probes"));
        let (_, body) = request(addr, "/snapshot");
        let doc = json::parse(&body).unwrap();
        assert_eq!(doc.get("fleet").and_then(Json::as_str), Some("test"));
        let (_, body) = request(addr, "/query?q=select%20probes%20%7C%20agg%20sum");
        let doc = json::parse(&body).unwrap();
        let series = doc.get("series").and_then(Json::as_arr).unwrap();
        assert_eq!(series[0].get("value").and_then(Json::as_f64), Some(20.0));
        let (status, _) = request(addr, "/nope");
        assert!(status.contains("404"), "{status}");
        let (status, body) = request(addr, "/query?q=select%20nope");
        assert!(status.contains("400"), "{status}");
        assert!(json::parse(&body).unwrap().get("error").is_some());
        serving.join().unwrap();
    }
}
