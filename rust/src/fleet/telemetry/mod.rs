//! Queryable fleet telemetry: a columnar time-series store fed by the
//! daemon, a small query language, and a std-only HTTP serve mode.
//!
//! The paper's profiling loop produces a stream of runtime observations,
//! drift verdicts, and placement decisions that — before this module —
//! only survived as a one-shot report. Telemetry keeps them queryable:
//!
//! - [`TelemetryStore`] ([`store`]): per-series ring buffers keyed
//!   `(kind, label, node)`, delta-of-delta timestamps + run-length
//!   values, fixed retention, lock-striped appends.
//! - [`TelemetryRecorder`] ([`recorder`]): the daemon-side hooks that
//!   emit one point per journaled event, keeping store and `journal()`
//!   byte-consistent.
//! - [`Query`] ([`query`]): `select <series> where label=.. node=.. |
//!   window 600 | agg p99` evaluated over the compressed blocks.
//! - [`TelemetryServer`] ([`serve`]): `streamprof serve --port N`
//!   exposing `/healthz`, `/series`, `/snapshot`, and `/query?q=..`.

pub mod query;
pub mod recorder;
pub mod serve;
pub mod store;

pub use query::{Agg, Query, QueryResult, SeriesResult};
pub use recorder::{verdict_code, TelemetryRecorder};
pub use serve::TelemetryServer;
pub use store::{SeriesBuf, SeriesKey, SeriesKind, SeriesStats, TelemetryStore, DEFAULT_RETENTION};
