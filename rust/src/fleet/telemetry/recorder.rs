//! Bridges daemon events into the telemetry store.
//!
//! The recorder is owned by the [`FleetDaemon`](crate::fleet::FleetDaemon)
//! and invoked adjacent to every `journal()` entry, so the store is a
//! lossless-within-retention columnar view of the same timeline — the
//! `telemetry_e2e` test diffs the two. All methods take the daemon's
//! virtual-clock tick explicitly; the recorder never reads wallclock.

use std::sync::Arc;

use crate::coordinator::CapacityPlan;
use crate::fleet::cache::CacheStats;
use crate::fleet::drift::DriftVerdict;
use crate::fleet::migrate::FleetPlan;
use crate::fleet::worker::JobOutcome;

use super::store::{SeriesKind, TelemetryStore};

/// Numeric encoding of a [`DriftVerdict`] in the `verdicts` series:
/// 0 = stable, 1 = rate-shift, 2 = model-stale.
pub fn verdict_code(verdict: &DriftVerdict) -> f64 {
    match verdict {
        DriftVerdict::Stable => 0.0,
        DriftVerdict::RateShift { .. } => 1.0,
        DriftVerdict::ModelStale { .. } => 2.0,
    }
}

/// Emits fleet observations into a shared [`TelemetryStore`].
pub struct TelemetryRecorder {
    store: Arc<TelemetryStore>,
    last_hits: u64,
    last_misses: u64,
}

impl TelemetryRecorder {
    /// Recorder over `store`. `cache_base` is the cache's stats at attach
    /// time; the first [`TelemetryRecorder::cache_flush`] emits deltas
    /// relative to it, so restored lifetime counters never pollute the
    /// series.
    pub fn new(store: Arc<TelemetryStore>, cache_base: CacheStats) -> TelemetryRecorder {
        TelemetryRecorder { store, last_hits: cache_base.hits, last_misses: cache_base.misses }
    }

    /// The shared store (for query handlers and tests).
    pub fn store(&self) -> &Arc<TelemetryStore> {
        &self.store
    }

    /// A job was admitted to the roster.
    pub fn arrival(&self, at: u64, job: &str, node: &str) {
        self.store.append(SeriesKind::Arrivals, job, node, at, 1.0);
    }

    /// A job was retired from the roster.
    pub fn departure(&self, at: u64, job: &str, node: &str) {
        self.store.append(SeriesKind::Departures, job, node, at, 1.0);
    }

    /// A drift verdict was observed (externally or by an epoch tick).
    pub fn verdict(&self, at: u64, job: &str, node: &str, verdict: &DriftVerdict) {
        self.store.append(SeriesKind::Verdicts, job, node, at, verdict_code(verdict));
        if let DriftVerdict::ModelStale { rolling_smape } = verdict {
            self.store.append(SeriesKind::Smape, job, node, at, *rolling_smape);
        }
    }

    /// A (re-)profile of `job` executed `executed` fresh probes (cache
    /// replays excluded — a fully warm profile records 0).
    pub fn probes(&self, at: u64, job: &str, node: &str, executed: u64) {
        self.store.append(SeriesKind::Probes, job, node, at, executed as f64);
    }

    /// Rolling SMAPE after a drift-triggered re-profile.
    pub fn smape(&self, at: u64, job: &str, node: &str, smape: f64) {
        self.store.append(SeriesKind::Smape, job, node, at, smape);
    }

    /// Every observed mean step runtime of a finished profile, as one
    /// `runtime` point per step at the completion tick.
    pub fn outcome_runtimes(&self, at: u64, outcome: &JobOutcome) {
        for round in &outcome.rounds {
            for step in &round.steps {
                let node = outcome.node.name;
                self.store.append(SeriesKind::Runtime, &outcome.name, node, at, step.mean_runtime);
            }
        }
    }

    /// Residual capacity per node after a replan.
    pub fn headroom(&self, at: u64, plans: &[(String, CapacityPlan)]) {
        for (node, plan) in plans {
            let headroom = plan.capacity - plan.total_assigned;
            self.store.append(SeriesKind::Headroom, "", node, at, headroom);
        }
    }

    /// Cross-node migrations of a rebalance plan (one point per move,
    /// keyed by the destination node).
    pub fn migrations(&self, at: u64, plan: &FleetPlan) {
        for m in &plan.migrations {
            self.store.append(SeriesKind::Migrations, &m.job, m.to, at, 1.0);
        }
    }

    /// A mesh gossip round ran; `delivered` summaries reached a neighbor
    /// view this round.
    pub fn gossip_round(&self, at: u64, delivered: u64) {
        self.store.append(SeriesKind::GossipRounds, "", "", at, delivered as f64);
    }

    /// Aggregate mesh view age (ticks) observed at a gossip round.
    pub fn staleness(&self, at: u64, ticks: u64) {
        self.store.append(SeriesKind::StalenessTicks, "", "", at, ticks as f64);
    }

    /// An optimistic mesh placement of `job` was refused by `dest` and
    /// rolled back.
    pub fn rollback(&self, at: u64, job: &str, dest: &str) {
        self.store.append(SeriesKind::ConflictRollbacks, job, dest, at, 1.0);
    }

    /// Async probes outstanding (dispatched, not yet merged) right after
    /// a dispatch — the overlapped daemon's backlog signal.
    pub fn probe_queue_depth(&self, at: u64, depth: u64) {
        self.store.append(SeriesKind::ProbeQueueDepth, "", "", at, depth as f64);
    }

    /// A fresh arrival profiled without an adopted transfer prior,
    /// spending `executed` cold probes.
    pub fn cold_start_probes(&self, at: u64, job: &str, node: &str, executed: u64) {
        self.store.append(SeriesKind::ColdStartProbes, job, node, at, executed as f64);
    }

    /// A fresh arrival's profile adopted (or tempered) a transfer prior.
    pub fn prior_adoption(&self, at: u64, job: &str, node: &str) {
        self.store.append(SeriesKind::PriorAdoptions, job, node, at, 1.0);
    }

    /// Cache hit/miss deltas since the previous flush, from the lifetime
    /// `hits` / `misses` counters (the caller reads them off the cache's
    /// wait-free fast accessors, or its deterministic virtual stats in
    /// overlapped mode). Zero deltas are recorded too — the run-length
    /// codec collapses them, and the sum of the series then exactly
    /// equals the drained report's cache delta.
    pub fn cache_flush(&mut self, at: u64, hits: u64, misses: u64) {
        self.store.append(SeriesKind::CacheHits, "", "", at, (hits - self.last_hits) as f64);
        self.store.append(SeriesKind::CacheMisses, "", "", at, (misses - self.last_misses) as f64);
        self.last_hits = hits;
        self.last_misses = misses;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::MeasurementCache;

    #[test]
    fn verdict_codes_are_stable() {
        assert_eq!(verdict_code(&DriftVerdict::Stable), 0.0);
        let rate = DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 8.0 };
        assert_eq!(verdict_code(&rate), 1.0);
        let stale = DriftVerdict::ModelStale { rolling_smape: 0.9 };
        assert_eq!(verdict_code(&stale), 2.0);
    }

    #[test]
    fn model_stale_verdicts_also_record_smape() {
        let store = Arc::new(TelemetryStore::new());
        let rec = TelemetryRecorder::new(store.clone(), CacheStats::default());
        rec.verdict(700, "job-01", "pi4", &DriftVerdict::ModelStale { rolling_smape: 0.9 });
        assert_eq!(store.points(SeriesKind::Verdicts, "job-01", "pi4"), vec![(700, 2.0)]);
        assert_eq!(store.points(SeriesKind::Smape, "job-01", "pi4"), vec![(700, 0.9)]);
    }

    #[test]
    fn mesh_hooks_record_health_series() {
        let store = Arc::new(TelemetryStore::new());
        let rec = TelemetryRecorder::new(store.clone(), CacheStats::default());
        rec.gossip_round(200, 6);
        rec.staleness(200, 40);
        rec.rollback(200, "m-2", "wally.0");
        assert_eq!(store.points(SeriesKind::GossipRounds, "", ""), vec![(200, 6.0)]);
        assert_eq!(store.points(SeriesKind::StalenessTicks, "", ""), vec![(200, 40.0)]);
        assert_eq!(
            store.points(SeriesKind::ConflictRollbacks, "m-2", "wally.0"),
            vec![(200, 1.0)]
        );
    }

    #[test]
    fn cache_flush_emits_deltas_not_lifetime_totals() {
        let cache = MeasurementCache::new();
        let base = cache.stats();
        let store = Arc::new(TelemetryStore::new());
        let mut rec = TelemetryRecorder::new(store.clone(), base);
        rec.cache_flush(100, cache.hits(), cache.misses());
        rec.cache_flush(200, cache.hits(), cache.misses());
        assert_eq!(store.points(SeriesKind::CacheHits, "", ""), vec![(100, 0.0), (200, 0.0)]);
        assert_eq!(store.points(SeriesKind::CacheMisses, "", ""), vec![(100, 0.0), (200, 0.0)]);
    }

    #[test]
    fn cache_flush_deltas_follow_the_lifetime_counters() {
        let store = Arc::new(TelemetryStore::new());
        let mut rec = TelemetryRecorder::new(store.clone(), CacheStats::default());
        rec.cache_flush(100, 3, 7);
        rec.cache_flush(200, 10, 7);
        assert_eq!(store.points(SeriesKind::CacheHits, "", ""), vec![(100, 3.0), (200, 7.0)]);
        assert_eq!(store.points(SeriesKind::CacheMisses, "", ""), vec![(100, 7.0), (200, 0.0)]);
    }

    #[test]
    fn probe_queue_depth_records_the_backlog() {
        let store = Arc::new(TelemetryStore::new());
        let rec = TelemetryRecorder::new(store.clone(), CacheStats::default());
        rec.probe_queue_depth(500, 3);
        rec.probe_queue_depth(510, 0);
        assert_eq!(
            store.points(SeriesKind::ProbeQueueDepth, "", ""),
            vec![(500, 3.0), (510, 0.0)]
        );
    }
}
