//! Persistent probe pool: long-lived profiling workers the daemon keeps
//! across replans.
//!
//! [`run_sweep`](super::run_sweep) used to spawn a scoped thread pool per
//! batch — fine for one-shot sessions, but a long-lived [`FleetDaemon`]
//! replans hundreds of times, and re-spawning OS threads per replan both
//! costs wallclock and forces every batch to *complete* before the event
//! loop can move on. The [`ProbePool`] keeps the same striped
//! [`WorkQueue`] shape but parks persistent workers on a condvar between
//! batches:
//!
//! ```text
//!  dispatch(seq, spec, pass) ──► WorkQueue lane (seq % stripes)
//!                                      │ notify
//!                  parked worker ◄─────┘
//!                      │ profile_job_with (through the shared cache)
//!                      ▼
//!                  results[seq] ──► collect(seq)   (blocks until done)
//! ```
//!
//! Ordering contract: the pool itself completes tasks in whatever order
//! the workers finish, but every result is keyed by its **dispatch
//! sequence number** and callers collect in that order — so downstream
//! state (reports, journals, capacity plans) is a pure function of the
//! dispatch order, never of worker scheduling. With one worker the pool
//! executes tasks in exact dispatch order, which is what makes the
//! overlapped daemon byte-identical to the synchronous path at
//! `--probe-workers 1`.
//!
//! [`FleetDaemon`]: super::FleetDaemon

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::cache::MeasurementCache;
use super::queue::WorkQueue;
use super::worker::{self, JobOutcome, ProfilePass};
use super::{FleetConfig, FleetJobSpec};

/// One unit of profiling work handed to the pool.
struct ProbeTask {
    /// Dispatch sequence number — the key results are collected under.
    seq: u64,
    /// Roster index stamped onto the outcome (`JobOutcome::index`).
    index: usize,
    spec: FleetJobSpec,
    cfg: FleetConfig,
    pass: ProfilePass,
    /// When set, the worker bumps this cache label's generation and
    /// evicts its stale entries *immediately before* profiling — cache
    /// aging for `ModelStale` re-profiles, moved onto the pool thread so
    /// the age-then-profile pair stays adjacent in dispatch order even
    /// while the daemon races ahead dispatching more work.
    age_label: Option<String>,
}

/// State shared between the pool handle and its worker threads.
struct Shared {
    cache: Arc<MeasurementCache>,
    queue: WorkQueue<ProbeTask>,
    state: Mutex<PoolState>,
    /// Signalled on dispatch (and shutdown): parked workers re-check the
    /// queue.
    work: Condvar,
    /// Signalled when a result lands: blocked collectors re-check.
    done: Condvar,
}

#[derive(Default)]
struct PoolState {
    /// Finished outcomes keyed by dispatch sequence, awaiting collection.
    results: BTreeMap<u64, Result<JobOutcome>>,
    shutdown: bool,
}

/// A fixed set of persistent profiling workers over a striped
/// [`WorkQueue`], condvar-parked when idle. Dropping the pool shuts the
/// workers down and joins them.
pub struct ProbePool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    next_seq: AtomicU64,
}

impl ProbePool {
    /// Spawn `workers` persistent threads (clamped to at least one), all
    /// probing through `cache`.
    pub fn new(cache: Arc<MeasurementCache>, workers: usize) -> Self {
        let n = workers.max(1);
        let shared = Arc::new(Shared {
            cache,
            queue: WorkQueue::striped(std::iter::empty(), n),
            state: Mutex::new(PoolState::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..n)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, w))
            })
            .collect();
        Self { shared, handles, next_seq: AtomicU64::new(0) }
    }

    /// Worker threads in the pool (== queue stripes).
    pub fn workers(&self) -> usize {
        self.shared.queue.stripes()
    }

    /// The measurement cache every worker probes through.
    pub fn cache(&self) -> &MeasurementCache {
        &self.shared.cache
    }

    /// Tasks dispatched but not yet picked up by a worker — the
    /// `probe_queue_depth` telemetry signal. Wait-free (one atomic load).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }

    /// Hand one profiling task to the pool and return its dispatch
    /// sequence number. Tasks land on lane `seq % workers`, preserving
    /// the striped sweep's round-robin sharding; `age_label` requests
    /// pre-profile cache aging on the worker (see [`ProbeTask`] — the
    /// `ModelStale` path).
    pub fn dispatch(
        &self,
        index: usize,
        spec: FleetJobSpec,
        cfg: &FleetConfig,
        pass: ProfilePass,
        age_label: Option<String>,
    ) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::SeqCst);
        let lane = (seq % self.shared.queue.stripes() as u64) as usize;
        self.shared.queue.push_to(
            lane,
            ProbeTask { seq, index, spec, cfg: cfg.clone(), pass, age_label },
        );
        // Notify under the state lock: a worker that just found the queue
        // empty is either still holding the lock (it will re-check after
        // we release) or already waiting (it gets the wakeup) — no missed
        // notification window.
        let _state = self.shared.state.lock().unwrap();
        self.shared.work.notify_one();
        seq
    }

    /// Block until the task dispatched as `seq` finishes and take its
    /// outcome. Each sequence number can be collected exactly once.
    pub fn collect(&self, seq: u64) -> Result<JobOutcome> {
        let mut state = self.shared.state.lock().unwrap();
        loop {
            if let Some(result) = state.results.remove(&seq) {
                return result;
            }
            state = self.shared.done.wait(state).unwrap();
        }
    }
}

impl Drop for ProbePool {
    fn drop(&mut self) {
        {
            let mut state = self.shared.state.lock().unwrap();
            state.shutdown = true;
        }
        self.shared.work.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Park on the condvar until work (or shutdown) arrives, run the task,
/// publish the result under its dispatch sequence.
fn worker_loop(shared: &Shared, w: usize) {
    loop {
        let task = {
            let mut state = shared.state.lock().unwrap();
            loop {
                if let Some(task) = shared.queue.pop_for(w) {
                    break task;
                }
                if state.shutdown {
                    return;
                }
                state = shared.work.wait(state).unwrap();
            }
        };
        let mut aged_evictions = 0u64;
        if let Some(label) = &task.age_label {
            shared.cache.bump_generation(label);
            aged_evictions = shared.cache.evict_stale() as u64;
        }
        let result = worker::profile_job_with(&task.spec, &task.cfg, &shared.cache, w, &task.pass)
            .map(|mut outcome| {
                outcome.index = task.index;
                // Aging happened on behalf of this task: charge its
                // evictions to the task's cache delta so the daemon's
                // deterministic accounting sees them.
                outcome.cache_delta.evictions += aged_evictions;
                outcome
            });
        let mut state = shared.state.lock().unwrap();
        state.results.insert(task.seq, result);
        drop(state);
        shared.done.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::{node, Algo};

    fn pool_with(workers: usize) -> ProbePool {
        ProbePool::new(Arc::new(MeasurementCache::new()), workers)
    }

    #[test]
    fn dispatch_and_collect_round_trips_one_job() {
        let pool = pool_with(2);
        let cfg = FleetConfig { workers: 2, rounds: 1, ..FleetConfig::default() };
        let spec = FleetJobSpec::simulated("solo", node("pi4").unwrap(), Algo::Arima, 7);
        let seq = pool.dispatch(5, spec, &cfg, ProfilePass::default(), None);
        let outcome = pool.collect(seq).unwrap();
        assert_eq!(outcome.index, 5, "roster index stamped onto the outcome");
        assert_eq!(outcome.name, "solo");
        assert!(outcome.cache_delta.misses > 0, "cold profile executes probes");
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn results_collect_in_dispatch_order_regardless_of_finish_order() {
        let pool = pool_with(4);
        let cfg = FleetConfig { workers: 4, rounds: 1, ..FleetConfig::default() };
        let specs = super::super::sim_fleet(8, 3);
        let seqs: Vec<(u64, String)> = specs
            .into_iter()
            .enumerate()
            .map(|(i, s)| {
                let name = s.name.clone();
                (pool.dispatch(i, s, &cfg, ProfilePass::default(), None), name)
            })
            .collect();
        for (i, (seq, name)) in seqs.into_iter().enumerate() {
            let outcome = pool.collect(seq).unwrap();
            assert_eq!(outcome.index, i);
            assert_eq!(outcome.name, name);
        }
    }

    #[test]
    fn pool_survives_a_failing_task_and_reports_the_error() {
        let pool = pool_with(1);
        let cfg = FleetConfig { strategy: "bogus".into(), ..FleetConfig::default() };
        let bad = FleetJobSpec::simulated("broken", node("pi4").unwrap(), Algo::Arima, 1);
        let seq = pool.dispatch(0, bad, &cfg, ProfilePass::default(), None);
        assert!(pool.collect(seq).is_err());
        // The worker is still alive: a well-formed task after the failure
        // completes normally.
        let ok_cfg = FleetConfig { rounds: 1, ..FleetConfig::default() };
        let good = FleetJobSpec::simulated("fine", node("pi4").unwrap(), Algo::Arima, 2);
        let seq = pool.dispatch(1, good, &ok_cfg, ProfilePass::default(), None);
        assert_eq!(pool.collect(seq).unwrap().name, "fine");
    }

    #[test]
    fn age_label_refuses_stale_entries_before_profiling() {
        let cache = Arc::new(MeasurementCache::new());
        let pool = ProbePool::new(Arc::clone(&cache), 1);
        let cfg = FleetConfig { rounds: 1, ..FleetConfig::default() };
        let spec = FleetJobSpec::simulated("aging", node("pi4").unwrap(), Algo::Arima, 9);
        let label = spec.label();
        let cold = pool.dispatch(0, spec.clone(), &cfg, ProfilePass::default(), None);
        let cold = pool.collect(cold).unwrap();
        // Re-profile with aging: the stale generation must be refused and
        // re-executed, and the evictions charged to this task's delta.
        let hot = pool.dispatch(1, spec, &cfg, ProfilePass::default(), Some(label));
        let hot = pool.collect(hot).unwrap();
        assert_eq!(hot.cache_delta.hits, 0, "aged entries must not replay");
        assert_eq!(hot.cache_delta.misses, cold.cache_delta.misses);
        assert!(hot.cache_delta.evictions > 0, "aging evicts the stale label");
    }

    #[test]
    fn drop_joins_idle_workers() {
        let pool = pool_with(4);
        assert_eq!(pool.workers(), 4);
        drop(pool); // must not hang on parked workers
    }
}
