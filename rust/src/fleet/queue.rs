//! Shared work queue for the fleet worker pool.
//!
//! Deliberately minimal: profiling tasks are coarse (seconds to minutes of
//! simulated work each), so mutex-guarded deques are far below contention
//! range and keep the pool dependency-free. Workers pull until the queue
//! is drained; there is no re-enqueue, so termination is trivial.
//!
//! [`WorkQueue::new`] builds a single global FIFO (the original shape).
//! [`WorkQueue::striped`] splits the backlog round-robin across one lane
//! per worker, and [`WorkQueue::pop_for`] serves a worker from its home
//! lane first, **stealing** from the other lanes in cyclic order once it
//! runs dry — so a large roster drains without every pop serializing on
//! one mutex, mirroring the measurement cache's lock striping.

use std::collections::VecDeque;
use std::sync::Mutex;

/// A multi-consumer FIFO (optionally striped into per-worker lanes with
/// work stealing) drained by the worker pool.
pub struct WorkQueue<T> {
    lanes: Vec<Mutex<VecDeque<T>>>,
}

impl<T> WorkQueue<T> {
    /// One global FIFO lane: strict arrival order under a single consumer.
    pub fn new<I: IntoIterator<Item = T>>(items: I) -> Self {
        Self::striped(items, 1)
    }

    /// Distribute `items` round-robin across `stripes` lanes (clamped to
    /// at least one). Item `i` lands in lane `i % stripes`, so a pool
    /// whose worker `w` calls [`Self::pop_for`]`(w)` starts on disjoint
    /// slices of the backlog.
    pub fn striped<I: IntoIterator<Item = T>>(items: I, stripes: usize) -> Self {
        let n = stripes.max(1);
        let mut lanes: Vec<VecDeque<T>> = (0..n).map(|_| VecDeque::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            lanes[i % n].push_back(item);
        }
        Self { lanes: lanes.into_iter().map(Mutex::new).collect() }
    }

    /// Pop the next task; `None` once the queue is drained. Equivalent to
    /// `pop_for(0)` — strict FIFO on an unstriped queue.
    pub fn pop(&self) -> Option<T> {
        self.pop_for(0)
    }

    /// Pop from `worker`'s home lane, stealing from the other lanes in
    /// cyclic order once it is empty. `None` only when every lane is
    /// drained.
    pub fn pop_for(&self, worker: usize) -> Option<T> {
        let n = self.lanes.len();
        let home = worker % n;
        for k in 0..n {
            if let Some(item) = self.lanes[(home + k) % n].lock().unwrap().pop_front() {
                return Some(item);
            }
        }
        None
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn fifo_order_single_consumer() {
        let q = WorkQueue::new(0..5);
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn concurrent_drain_with_more_tasks_than_workers() {
        // 32 tasks, 4 workers: every task is consumed exactly once and
        // every worker that can make progress gets some share.
        let q = WorkQueue::new(0..32u32);
        let taken: Mutex<Vec<(usize, u32)>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let taken = &taken;
                s.spawn(move || {
                    while let Some(item) = q.pop() {
                        taken.lock().unwrap().push((w, item));
                        // Yield so the drain interleaves across workers.
                        std::thread::yield_now();
                    }
                });
            }
        });
        let taken = taken.into_inner().unwrap();
        assert_eq!(taken.len(), 32);
        let mut items: Vec<u32> = taken.iter().map(|&(_, i)| i).collect();
        items.sort_unstable();
        assert_eq!(items, (0..32).collect::<Vec<_>>(), "each task exactly once");
        assert!(q.is_empty());
    }

    #[test]
    fn striped_lanes_serve_home_worker_in_fifo_order() {
        // 8 items over 3 lanes: lane 0 = {0,3,6}, lane 1 = {1,4,7},
        // lane 2 = {2,5}. Each worker drains its home lane FIFO first.
        let q = WorkQueue::striped(0..8, 3);
        assert_eq!(q.len(), 8);
        assert_eq!(q.pop_for(1), Some(1));
        assert_eq!(q.pop_for(1), Some(4));
        assert_eq!(q.pop_for(2), Some(2));
        assert_eq!(q.pop_for(0), Some(0));
        assert_eq!(q.pop_for(3), Some(3), "worker ids wrap onto the lane count");
    }

    #[test]
    fn exhausted_worker_steals_from_the_next_lane() {
        let q = WorkQueue::striped(0..4, 2); // lane 0 = {0,2}, lane 1 = {1,3}
        assert_eq!(q.pop_for(0), Some(0));
        assert_eq!(q.pop_for(0), Some(2));
        // Home lane dry: steal lane 1's backlog, oldest first.
        assert_eq!(q.pop_for(0), Some(1));
        assert_eq!(q.pop_for(0), Some(3));
        assert_eq!(q.pop_for(0), None);
        assert!(q.is_empty());
    }

    #[test]
    fn striped_concurrent_drain_consumes_each_task_once() {
        // 64 tasks, 4 workers on their own lanes with stealing: the drain
        // must cover every task exactly once even when fast workers steal.
        let q = WorkQueue::striped(0..64u32, 4);
        let taken: Mutex<Vec<u32>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let taken = &taken;
                s.spawn(move || {
                    while let Some(item) = q.pop_for(w) {
                        taken.lock().unwrap().push(item);
                        std::thread::yield_now();
                    }
                });
            }
        });
        let mut items = taken.into_inner().unwrap();
        items.sort_unstable();
        assert_eq!(items, (0..64).collect::<Vec<_>>(), "each task exactly once");
        assert!(q.is_empty());
    }
}
