//! Shared work queue for the fleet worker pool.
//!
//! Deliberately minimal: profiling tasks are coarse (seconds to minutes of
//! simulated work each), so a mutex-guarded deque is far below contention
//! range and keeps the pool dependency-free. Workers pull until the queue
//! is drained; there is no re-enqueue, so termination is trivial.

use std::collections::VecDeque;
use std::sync::Mutex;

/// A multi-consumer FIFO drained by the worker pool.
pub struct WorkQueue<T> {
    inner: Mutex<VecDeque<T>>,
}

impl<T> WorkQueue<T> {
    pub fn new<I: IntoIterator<Item = T>>(items: I) -> Self {
        Self { inner: Mutex::new(items.into_iter().collect()) }
    }

    /// Pop the next task; `None` once the queue is drained.
    pub fn pop(&self) -> Option<T> {
        self.inner.lock().unwrap().pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn fifo_order_single_consumer() {
        let q = WorkQueue::new(0..5);
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn concurrent_drain_with_more_tasks_than_workers() {
        // 32 tasks, 4 workers: every task is consumed exactly once and
        // every worker that can make progress gets some share.
        let q = WorkQueue::new(0..32u32);
        let taken: Mutex<Vec<(usize, u32)>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let taken = &taken;
                s.spawn(move || {
                    while let Some(item) = q.pop() {
                        taken.lock().unwrap().push((w, item));
                        // Yield so the drain interleaves across workers.
                        std::thread::yield_now();
                    }
                });
            }
        });
        let taken = taken.into_inner().unwrap();
        assert_eq!(taken.len(), 32);
        let mut items: Vec<u32> = taken.iter().map(|&(_, i)| i).collect();
        items.sort_unstable();
        assert_eq!(items, (0..32).collect::<Vec<_>>(), "each task exactly once");
        assert!(q.is_empty());
    }
}
