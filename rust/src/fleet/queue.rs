//! Shared work queue for the fleet worker pool.
//!
//! Deliberately minimal: profiling tasks are coarse (seconds to minutes of
//! simulated work each), so mutex-guarded deques are far below contention
//! range and keep the pool dependency-free. Workers pull until the queue
//! is drained; [`WorkQueue::push_to`] lets a long-lived pool re-fill lanes
//! between batches (the probe pool's dispatch path).
//!
//! [`WorkQueue::new`] builds a single global FIFO (the original shape).
//! [`WorkQueue::striped`] splits the backlog round-robin across one lane
//! per worker, and [`WorkQueue::pop_for`] serves a worker from its home
//! lane first, **stealing** from the other lanes in cyclic order once it
//! runs dry — so a large roster drains without every pop serializing on
//! one mutex, mirroring the measurement cache's lock striping.
//!
//! Occupancy is tracked by one shared atomic counter, so [`WorkQueue::len`]
//! and [`WorkQueue::is_empty`] never touch a lane mutex: the lane locks
//! guard only push/pop/steal. The counter moves *before* an item becomes
//! visible on push and *after* it was taken on pop, so it never undercounts
//! a task that a concurrent consumer could still observe.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A multi-consumer FIFO (optionally striped into per-worker lanes with
/// work stealing) drained by the worker pool.
pub struct WorkQueue<T> {
    lanes: Vec<Mutex<VecDeque<T>>>,
    /// Tasks currently queued across every lane. Kept exact: incremented
    /// before a pushed item is published, decremented after a popped item
    /// was removed, both under no lane lock — reads are wait-free.
    count: AtomicUsize,
}

impl<T> WorkQueue<T> {
    /// One global FIFO lane: strict arrival order under a single consumer.
    pub fn new<I: IntoIterator<Item = T>>(items: I) -> Self {
        Self::striped(items, 1)
    }

    /// Distribute `items` round-robin across `stripes` lanes (clamped to
    /// at least one). Item `i` lands in lane `i % stripes`, so a pool
    /// whose worker `w` calls [`Self::pop_for`]`(w)` starts on disjoint
    /// slices of the backlog.
    pub fn striped<I: IntoIterator<Item = T>>(items: I, stripes: usize) -> Self {
        let n = stripes.max(1);
        let mut lanes: Vec<VecDeque<T>> = (0..n).map(|_| VecDeque::new()).collect();
        let mut count = 0;
        for (i, item) in items.into_iter().enumerate() {
            lanes[i % n].push_back(item);
            count += 1;
        }
        Self {
            lanes: lanes.into_iter().map(Mutex::new).collect(),
            count: AtomicUsize::new(count),
        }
    }

    /// Lanes this queue was striped into.
    pub fn stripes(&self) -> usize {
        self.lanes.len()
    }

    /// Append one task to `lane` (wrapped onto the stripe count) — how a
    /// persistent pool feeds new work to parked workers. The occupancy
    /// counter is bumped before the lane mutex is taken, so a concurrent
    /// `len()` never reports the queue empty while a published task is
    /// still poppable.
    pub fn push_to(&self, lane: usize, item: T) {
        self.count.fetch_add(1, Ordering::SeqCst);
        let n = self.lanes.len();
        self.lanes[lane % n].lock().unwrap().push_back(item);
    }

    /// Pop the next task; `None` once the queue is drained. Equivalent to
    /// `pop_for(0)` — strict FIFO on an unstriped queue.
    pub fn pop(&self) -> Option<T> {
        self.pop_for(0)
    }

    /// Pop from `worker`'s home lane, stealing from the other lanes in
    /// cyclic order once it is empty. `None` only when every lane is
    /// drained.
    pub fn pop_for(&self, worker: usize) -> Option<T> {
        let n = self.lanes.len();
        let home = worker % n;
        for k in 0..n {
            if let Some(item) = self.lanes[(home + k) % n].lock().unwrap().pop_front() {
                self.count.fetch_sub(1, Ordering::SeqCst);
                return Some(item);
            }
        }
        None
    }

    /// Tasks currently queued — a single atomic load, no lane lock.
    pub fn len(&self) -> usize {
        self.count.load(Ordering::SeqCst)
    }

    /// `len() == 0` without touching a lane mutex.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn fifo_order_single_consumer() {
        let q = WorkQueue::new(0..5);
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn concurrent_drain_with_more_tasks_than_workers() {
        // 32 tasks, 4 workers: every task is consumed exactly once and
        // every worker that can make progress gets some share.
        let q = WorkQueue::new(0..32u32);
        let taken: Mutex<Vec<(usize, u32)>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let taken = &taken;
                s.spawn(move || {
                    while let Some(item) = q.pop() {
                        taken.lock().unwrap().push((w, item));
                        // Yield so the drain interleaves across workers.
                        std::thread::yield_now();
                    }
                });
            }
        });
        let taken = taken.into_inner().unwrap();
        assert_eq!(taken.len(), 32);
        let mut items: Vec<u32> = taken.iter().map(|&(_, i)| i).collect();
        items.sort_unstable();
        assert_eq!(items, (0..32).collect::<Vec<_>>(), "each task exactly once");
        assert!(q.is_empty());
    }

    #[test]
    fn striped_lanes_serve_home_worker_in_fifo_order() {
        // 8 items over 3 lanes: lane 0 = {0,3,6}, lane 1 = {1,4,7},
        // lane 2 = {2,5}. Each worker drains its home lane FIFO first.
        let q = WorkQueue::striped(0..8, 3);
        assert_eq!(q.len(), 8);
        assert_eq!(q.pop_for(1), Some(1));
        assert_eq!(q.pop_for(1), Some(4));
        assert_eq!(q.pop_for(2), Some(2));
        assert_eq!(q.pop_for(0), Some(0));
        assert_eq!(q.pop_for(3), Some(3), "worker ids wrap onto the lane count");
    }

    #[test]
    fn exhausted_worker_steals_from_the_next_lane() {
        let q = WorkQueue::striped(0..4, 2); // lane 0 = {0,2}, lane 1 = {1,3}
        assert_eq!(q.pop_for(0), Some(0));
        assert_eq!(q.pop_for(0), Some(2));
        // Home lane dry: steal lane 1's backlog, oldest first.
        assert_eq!(q.pop_for(0), Some(1));
        assert_eq!(q.pop_for(0), Some(3));
        assert_eq!(q.pop_for(0), None);
        assert!(q.is_empty());
    }

    #[test]
    fn striped_concurrent_drain_consumes_each_task_once() {
        // 64 tasks, 4 workers on their own lanes with stealing: the drain
        // must cover every task exactly once even when fast workers steal.
        let q = WorkQueue::striped(0..64u32, 4);
        let taken: Mutex<Vec<u32>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let taken = &taken;
                s.spawn(move || {
                    while let Some(item) = q.pop_for(w) {
                        taken.lock().unwrap().push(item);
                        std::thread::yield_now();
                    }
                });
            }
        });
        let mut items = taken.into_inner().unwrap();
        items.sort_unstable();
        assert_eq!(items, (0..64).collect::<Vec<_>>(), "each task exactly once");
        assert!(q.is_empty());
    }

    #[test]
    fn push_to_wraps_lanes_and_keeps_fifo_per_lane() {
        let q: WorkQueue<u32> = WorkQueue::striped(std::iter::empty(), 2);
        assert_eq!(q.stripes(), 2);
        q.push_to(0, 10);
        q.push_to(1, 11);
        q.push_to(2, 12); // wraps onto lane 0
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop_for(0), Some(10));
        assert_eq!(q.pop_for(0), Some(12));
        assert_eq!(q.pop_for(0), Some(11), "steal once home lane is dry");
        assert!(q.is_empty());
    }

    #[test]
    fn len_stays_exact_under_eight_thread_drain() {
        // Regression for the atomic occupancy counter: 800 tasks drained
        // by 8 stealing workers. Every observed `len()` must stay within
        // the number of tasks not yet recorded as taken (the counter may
        // lag a pop, never lead it), and the drained queue must report
        // exactly empty with every task consumed exactly once.
        const TASKS: usize = 800;
        let q = WorkQueue::striped(0..TASKS, 8);
        let taken: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..8 {
                let q = &q;
                let taken = &taken;
                s.spawn(move || {
                    while let Some(item) = q.pop_for(w) {
                        // The pop already decremented the counter, so at
                        // most TASKS - 1 tasks can still be queued.
                        assert!(q.len() < TASKS, "counter can never exceed the backlog");
                        taken.lock().unwrap().push(item);
                    }
                });
            }
        });
        assert_eq!(q.len(), 0, "drained queue must count zero");
        assert!(q.is_empty());
        let mut items = taken.into_inner().unwrap();
        items.sort_unstable();
        assert_eq!(items, (0..TASKS).collect::<Vec<_>>(), "each task exactly once");
    }

    #[test]
    fn concurrent_push_and_drain_count_stays_exact() {
        // One producer feeding lanes round-robin while 4 consumers drain:
        // the final ledger must balance — everything pushed was popped and
        // the counter returns to zero.
        use std::sync::atomic::AtomicBool;
        let q: WorkQueue<usize> = WorkQueue::striped(std::iter::empty(), 4);
        let popped: Mutex<Vec<usize>> = Mutex::new(Vec::new());
        let done = AtomicBool::new(false);
        std::thread::scope(|s| {
            let q = &q;
            let done = &done;
            s.spawn(move || {
                for i in 0..200 {
                    q.push_to(i, i);
                }
                done.store(true, Ordering::SeqCst);
            });
            for w in 0..4 {
                let popped = &popped;
                s.spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_for(w) {
                            Some(item) => got.push(item),
                            None if done.load(Ordering::SeqCst) && q.is_empty() => break,
                            None => std::thread::yield_now(),
                        }
                    }
                    popped.lock().unwrap().extend(got);
                });
            }
        });
        assert_eq!(q.len(), 0);
        let mut items = popped.into_inner().unwrap();
        items.sort_unstable();
        assert_eq!(items, (0..200).collect::<Vec<_>>());
    }
}
