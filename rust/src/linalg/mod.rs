//! Small dense linear algebra substrate for the GP and the LM fitter.
//!
//! Row-major `Mat` with Cholesky factorization/solves — the problem sizes
//! here are tiny (≤ a few dozen profiling points), so no blocking or SIMD is
//! needed; numerical robustness (jitter on near-singular systems) matters
//! more than speed.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        out
    }

    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec dims");
        (0..self.rows)
            .map(|i| (0..self.cols).map(|j| self[(i, j)] * v[j]).sum())
            .collect()
    }

    /// In-place scaled diagonal add: `A += lambda * I`.
    pub fn add_diag(&mut self, lambda: f64) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self[(i, i)] += lambda;
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix.
pub struct Cholesky {
    l: Mat,
}

#[derive(Debug, thiserror::Error)]
pub enum LinalgError {
    #[error("matrix not positive definite (pivot {0} = {1:.3e})")]
    NotPositiveDefinite(usize, f64),
    #[error("dimension mismatch: {0}")]
    Dims(String),
}

impl Cholesky {
    /// Factor `A = L Lᵀ`. Fails on non-SPD input.
    pub fn new(a: &Mat) -> Result<Self, LinalgError> {
        if a.rows != a.cols {
            return Err(LinalgError::Dims(format!("{}x{} not square", a.rows, a.cols)));
        }
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(LinalgError::NotPositiveDefinite(i, sum));
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Self { l })
    }

    /// Factor with escalating diagonal jitter until SPD (GP kernels).
    pub fn new_with_jitter(a: &Mat, mut jitter: f64) -> Result<(Self, f64), LinalgError> {
        let mut attempt = a.clone();
        for _ in 0..12 {
            match Self::new(&attempt) {
                Ok(ch) => return Ok((ch, jitter)),
                Err(_) => {
                    attempt = a.clone();
                    jitter = (jitter * 10.0).max(1e-12);
                    attempt.add_diag(jitter);
                }
            }
        }
        Err(LinalgError::NotPositiveDefinite(0, jitter))
    }

    /// Solve `A x = b` via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n, "cholesky solve dims");
        // L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    /// Solve only the forward half `L y = b` (for GP predictive variance).
    pub fn forward_solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        y
    }

    /// log |A| = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_solves_spd_system() {
        // A = M Mᵀ + I is SPD.
        let m = Mat::from_rows(&[&[1.0, 2.0, 0.5], &[0.0, 1.0, -1.0], &[2.0, 0.3, 1.0]]);
        let mut a = m.matmul(&m.transpose());
        a.add_diag(1.0);
        let x_true = vec![0.3, -1.2, 2.5];
        let b = a.matvec(&x_true);
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalues 3, -1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn jitter_recovers_semidefinite() {
        // Rank-1 PSD matrix: xxᵀ, singular -> jitter makes it SPD.
        let a = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let (ch, jitter) = Cholesky::new_with_jitter(&a, 1e-12).unwrap();
        assert!(jitter > 0.0);
        let x = ch.solve(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log_det_matches_direct() {
        let a = Mat::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]); // det = 8
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - 8.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn forward_solve_consistent() {
        let a = Mat::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let ch = Cholesky::new(&a).unwrap();
        let b = vec![1.0, 2.0];
        let y = ch.forward_solve(&b);
        // ||y||² = bᵀ A⁻¹ b
        let x = ch.solve(&b);
        let quad: f64 = b.iter().zip(&x).map(|(bi, xi)| bi * xi).sum();
        let ynorm: f64 = y.iter().map(|v| v * v).sum();
        assert!((quad - ynorm).abs() < 1e-12);
    }
}
