//! streamprof CLI — leader entrypoint for the profiling coordinator.
//!
//! Subcommands:
//!   nodes                      print Table I (the modeled testbed)
//!   acquire  [opts]            run the §III-A.a acquisition sweep -> CSV
//!   profile  [opts]            run one profiling session (sim or PJRT)
//!   adjust   [opts]            profile + adaptive resource adjustment plan
//!   fleet    [opts]            profile a fleet (batch or --daemon timeline)
//!   serve    [opts]            daemon scenario + telemetry HTTP endpoint
//!   telemetry query "<expr>"   evaluate a telemetry query offline
//!   repro    <id|all> [--full] regenerate paper tables/figures
//!   artifacts                  show AOT artifact/manifest status
//!
//! Run `streamprof` with no arguments for usage.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use streamprof::coordinator::{
    smape_vs_dataset, PjrtBackend, Profiler, ProfilerConfig, ProfilingBackend,
    ResourceAdjuster, SimulatedBackend,
};
use streamprof::earlystop::EarlyStopConfig;
use streamprof::fleet::telemetry::{Query, TelemetryServer, TelemetryStore};
use streamprof::fleet::{
    journal_json, sim_fleet, AdaptiveConfig, DriftConfig, DriftVerdict, FleetConfig,
    FleetDaemon, FleetJobSpec, FleetReport, FleetSession, MeasurementCache, MeshConfig,
    MeshFault, MeshTopology, RestoreOutcome, RuntimeShift,
};
use streamprof::repro;
use streamprof::runtime::{artifacts_available, default_artifacts_dir, Engine};
use streamprof::simulator::{node, Algo, SimulatedJob, NODES};
use streamprof::strategies;
use streamprof::stream::{ArrivalProcess, SensorStream};
use streamprof::util::{json, logging, Args, CsvWriter, Table};
use streamprof::workloads::PjrtJob;

fn main() {
    let args = Args::from_env();
    logging::set_level(logging::level_from_str(&args.opt_or("log", "info")));
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "nodes" => cmd_nodes(),
        "acquire" => cmd_acquire(&args),
        "profile" => cmd_profile(&args).map(|_| ()),
        "adjust" => cmd_adjust(&args),
        "fleet" => cmd_fleet(&args),
        "serve" => cmd_serve(&args),
        "telemetry" => cmd_telemetry(&args),
        "repro" => cmd_repro(&args),
        "artifacts" => cmd_artifacts(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "streamprof — efficient runtime profiling for black-box ML services\n\
         \n\
         USAGE: streamprof <command> [options]\n\
         \n\
         COMMANDS:\n\
         \u{20} nodes                         print the modeled testbed (Table I)\n\
         \u{20} acquire   --node pi4 --algo arima [--samples 10000] [--seed 1] [--out f.csv]\n\
         \u{20} profile   --node pi4 --algo arima --strategy nms [--p 0.05] [--n-initial 3]\n\
         \u{20}           [--samples 10000] [--steps 6] [--early-stop] [--lambda 0.1]\n\
         \u{20}           [--backend sim|pjrt] [--seed 1]\n\
         \u{20} adjust    <profile options> [--rate-lo 1] [--rate-hi 5] [--horizon 1000]\n\
         \u{20} fleet     [--jobs 12] [--workers 4] [--rounds 2] [--strategy nms]\n\
         \u{20}           [--samples 1000] [--steps 6] [--early-stop] [--seed 7]\n\
         \u{20}           [--horizon 1000] [--rebalance]\n\
         \u{20}           [--adaptive] [--epochs 3] [--epoch-ticks 500]\n\
         \u{20}           [--drift-threshold 0.25] [--rate-threshold 0.25]\n\
         \u{20}           [--shift-at 1500] [--shift-rate 8.0] [--shift-jobs 2]\n\
         \u{20}           [--stale-jobs 1] [--stale-scale 3.0]\n\
         \u{20}           [--daemon] [--probe-workers 0]   async pool size (0 = sync)\n\
         \u{20}           [--transfer]   prime fresh arrivals from the cross-job corpus\n\
         \u{20}           [--plan-quantile 0.95]   provision for tail runtimes, not means\n\
         \u{20}           [--events \"@0 submit 12, @600 retire job-01\"]\n\
         \u{20}           [--journal-out journal.json] (--daemon only)\n\
         \u{20}           [--mesh full:8|ring:8|line:8|star:8|grid:3x3[@<latency>]]\n\
         \u{20}           [--gossip-every 200] [--gossip-rounds 5]\n\
         \u{20}           [--partition \"@400 cut pi4.2-wally.0, @600 lose asok.1\"]\n\
         \u{20}           [--out report.json] [--cache-file cache.json]\n\
         \u{20} serve     [--port 7878] [fleet/daemon options]   serve telemetry over HTTP\n\
         \u{20}           endpoints: /healthz /series /snapshot /query?q=<expr>\n\
         \u{20} telemetry query \"<expr>\" [fleet/daemon options]\n\
         \u{20}           expr: select <series> [where label=L node=N] [| window N] [| agg p99]\n\
         \u{20} repro     <table1|fig2|fig3|fig4|fig5|fig6|fig7|all> [--full]\n\
         \u{20} artifacts                     AOT artifact status\n"
    );
}

fn cmd_nodes() -> Result<()> {
    println!("{}", repro::table1::run().rendered);
    Ok(())
}

fn cmd_acquire(args: &Args) -> Result<()> {
    let node_name = args.opt_or("node", "pi4");
    let algo = Algo::from_name(&args.opt_or("algo", "arima")).context("unknown algo")?;
    let spec = node(&node_name).with_context(|| format!("unknown node {node_name}"))?;
    let samples = args.opt_usize("samples", 10_000);
    let seed = args.opt_u64("seed", 1);
    let mut job = SimulatedJob::new(spec, algo, seed);
    let ds = job.acquire_dataset(samples);
    let out = args.opt_or("out", &format!("results/acquire_{node_name}_{}.csv", algo.name()));
    let mut csv = CsvWriter::create(&out, &["limit", "mean_runtime_s"])?;
    let mut table = Table::new(&["limit", "mean runtime (s)"]).with_title(&format!(
        "Acquisition sweep — {} / {} ({samples} samples)",
        node_name,
        algo.name()
    ));
    for p in &ds {
        csv.rowd(&[&p.limit, &p.runtime])?;
        if (p.limit * 10.0).round() as usize % 5 == 0 {
            table.rowd(&[&format!("{:.1}", p.limit), &format!("{:.4}", p.runtime)]);
        }
    }
    csv.flush()?;
    println!("{}", table.render());
    println!("wrote {out}");
    Ok(())
}

fn build_backend(args: &Args) -> Result<Box<dyn ProfilingBackend>> {
    let backend = args.opt_or("backend", "sim");
    match backend.as_str() {
        "sim" => {
            let node_name = args.opt_or("node", "pi4");
            let algo =
                Algo::from_name(&args.opt_or("algo", "arima")).context("unknown algo")?;
            let spec = node(&node_name).with_context(|| format!("unknown node {node_name}"))?;
            Ok(Box::new(SimulatedBackend::new(SimulatedJob::new(
                spec,
                algo,
                args.opt_u64("seed", 1),
            ))))
        }
        "pjrt" => {
            if !artifacts_available() {
                bail!("artifacts not built — run `make artifacts` first");
            }
            let algo =
                Algo::from_name(&args.opt_or("algo", "arima")).context("unknown algo")?;
            let engine = Engine::new(&default_artifacts_dir())?;
            let job = PjrtJob::load(&engine, algo)?;
            let cores = args.opt_f64("cores", 4.0);
            Ok(Box::new(PjrtBackend::new(
                job,
                SensorStream::new(args.opt_u64("seed", 1)),
                cores,
            )))
        }
        other => bail!("unknown backend '{other}' (sim|pjrt)"),
    }
}

fn cmd_profile(args: &Args) -> Result<streamprof::coordinator::SessionResult> {
    let cfg = ProfilerConfig {
        p: args.opt_f64("p", 0.05),
        n_initial: args.opt_usize("n-initial", 3),
        samples: args.opt_usize("samples", 10_000),
        early_stop: args.flag("early-stop").then(|| {
            EarlyStopConfig::new(
                args.opt_f64("confidence", 0.95),
                args.opt_f64("lambda", 0.1),
            )
        }),
        early_stop_cap: args.opt_usize("samples", 10_000),
        max_steps: args.opt_usize("steps", 6),
        ..Default::default()
    };
    let strategy_name = args.opt_or("strategy", "nms");
    let strategy = strategies::by_name(&strategy_name, args.opt_u64("seed", 1))
        .with_context(|| format!("unknown strategy {strategy_name}"))?;
    let mut backend = build_backend(args)?;
    let mut profiler = Profiler::new(cfg, strategy);
    let sess = profiler.run(backend.as_mut());

    let mut table =
        Table::new(&["step", "limit", "mean rt (s)", "samples", "cum time (s)", "model"])
            .with_title(&format!(
                "Profiling session — {} via {} (target rt {:.4}s)",
                sess.backend, sess.strategy, sess.target
            ));
    for s in &sess.steps {
        table.rowd(&[
            &s.index,
            &format!("{:.1}", s.limit),
            &format!("{:.4}", s.mean_runtime),
            &s.samples,
            &format!("{:.1}", s.cumulative_time),
            &s.model.kind.name(),
        ]);
    }
    println!("{}", table.render());
    let m = sess.final_model();
    println!(
        "final model: {} with a={:.4} b={:.3} c={:.5} d={:.3}",
        m.kind.name(),
        m.a,
        m.b,
        m.c,
        m.d
    );
    // SMAPE against a fresh acquisition (sim backend only).
    if args.opt_or("backend", "sim") == "sim" {
        let node_name = args.opt_or("node", "pi4");
        let algo = Algo::from_name(&args.opt_or("algo", "arima")).unwrap();
        let mut truth_job = SimulatedJob::new(
            node(&node_name).unwrap(),
            algo,
            args.opt_u64("seed", 1) + 10_000,
        );
        let truth = truth_job.acquire_dataset(10_000);
        println!("SMAPE vs 10k acquisition sweep: {:.3}", smape_vs_dataset(m, &truth));
    }
    Ok(sess)
}

fn cmd_adjust(args: &Args) -> Result<()> {
    let sess = cmd_profile(args)?;
    let l_max = node(&args.opt_or("node", "pi4")).map(|n| n.cores).unwrap_or(4.0);
    let adj = ResourceAdjuster::new(sess.final_model().clone(), 0.1, l_max, 0.1);
    let arrivals = ArrivalProcess::Varying {
        lo: args.opt_f64("rate-lo", 1.0),
        hi: args.opt_f64("rate-hi", 5.0),
        period: args.opt_f64("period", 400.0),
    };
    let horizon = args.opt_usize("horizon", 1000);
    let plan = adj.plan(&arrivals, horizon, args.opt_usize("window", 100));
    let mut table = Table::new(&["window", "budget (s)", "limit", "pred rt (s)", "feasible"])
        .with_title("Adaptive adjustment plan (Fig. 1 right-hand side)");
    for (i, a) in plan.iter().enumerate() {
        table.rowd(&[
            &i,
            &format!("{:.3}", a.budget),
            &format!("{:.1}", a.limit),
            &format!("{:.4}", a.predicted_runtime),
            &a.feasible,
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Build the [`FleetConfig`] shared by the `fleet`, `serve`, and
/// `telemetry` commands from their common CLI options.
fn fleet_config(args: &Args) -> FleetConfig {
    FleetConfig {
        workers: args.opt_usize("workers", 4),
        rounds: args.opt_usize("rounds", 2),
        strategy: args.opt_or("strategy", "nms"),
        profiler: ProfilerConfig {
            samples: args.opt_usize("samples", 1000),
            max_steps: args.opt_usize("steps", 6),
            early_stop: args.flag("early-stop").then(|| {
                EarlyStopConfig::new(
                    args.opt_f64("confidence", 0.95),
                    args.opt_f64("lambda", 0.1),
                )
            }),
            early_stop_cap: args.opt_usize("samples", 1000),
            ..Default::default()
        },
        horizon: args.opt_usize("horizon", 1000),
        probe_workers: args.opt_usize("probe-workers", 0),
        transfer: args.flag("transfer"),
        plan_quantile: args.opt("plan-quantile").and_then(|s| s.parse().ok()),
    }
}

/// One shared cache for the session, optionally restored from (and later
/// saved back to) `--cache-file`. Returns the cache, the save path, and
/// the restore outcome when a snapshot was actually read (so daemon call
/// sites can journal refusals).
fn open_cache(
    args: &Args,
) -> Result<(Arc<MeasurementCache>, Option<String>, Option<RestoreOutcome>)> {
    let cache = Arc::new(MeasurementCache::new());
    let cache_file = args.opt("cache-file").map(str::to_string);
    let mut restore_outcome = None;
    if let Some(path) = &cache_file {
        if std::path::Path::new(path).exists() {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading cache file {path}"))?;
            let snap = json::parse(&text)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("parsing cache file {path}"))?;
            let out = cache
                .restore(&snap)
                .with_context(|| format!("restoring cache file {path}"))?;
            let s = cache.stats();
            println!(
                "cache: restored {} measurements from {path} \
                 (lifetime: {} hits, {} misses, {:.2}s saved)",
                out.restored, s.hits, s.misses, s.saved_wallclock
            );
            if out.refused() > 0 {
                println!(
                    "cache: refused {} snapshot entries ({} newer than header, \
                     {} width conflicts) — corpus may be corrupted",
                    out.refused(),
                    out.refused_newer,
                    out.refused_width
                );
            }
            restore_outcome = Some(out);
        }
    }
    Ok((cache, cache_file, restore_outcome))
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let n_jobs = args.opt_usize("jobs", 12);
    let cfg = fleet_config(args);
    let workers = cfg.workers;
    let rounds = cfg.rounds;
    let mut specs = sim_fleet(n_jobs, args.opt_u64("seed", 7));
    let adaptive = args.flag("adaptive");
    if adaptive {
        inject_drift(args, &mut specs);
    }
    let (cache, cache_file, restored) = open_cache(args)?;

    if args.flag("daemon") {
        return cmd_fleet_daemon(args, cfg, cache, cache_file.as_deref(), restored);
    }

    let mut builder = FleetSession::builder()
        .config(cfg)
        .jobs(specs)
        .rebalance(args.flag("rebalance"))
        .cache(cache.clone());
    if let Some((topo, mcfg, faults)) = mesh_args(args)? {
        ensure!(!adaptive, "--mesh is sweep-mode only: drop --adaptive");
        builder = builder.mesh(topo, mcfg);
        for (at, fault) in faults {
            builder = builder.mesh_fault_at(at, fault);
        }
    }
    if adaptive {
        builder = builder.adaptive(AdaptiveConfig {
            epochs: args.opt_usize("epochs", 3),
            epoch_ticks: args.opt_usize("epoch-ticks", 500),
            drift: DriftConfig {
                smape_threshold: args.opt_f64("drift-threshold", 0.25),
                rate_threshold: args.opt_f64("rate-threshold", 0.25),
                ..Default::default()
            },
            ..Default::default()
        });
    }
    let report = builder.run()?;

    if let Some(summary) = &report.adaptive {
        print_fleet_adaptive(summary);
    } else {
        print_fleet_sweep(&report, n_jobs, workers, rounds);
    }
    if let Some(fleet_plan) = &report.plan {
        print_fleet_plan(fleet_plan);
    }
    if let Some(stats) = &report.mesh {
        print_mesh_stats(stats);
    }

    write_fleet_outputs(args, &report, &cache, cache_file.as_deref())
}

/// `streamprof fleet --daemon`: replay an `--events` timeline through the
/// long-lived [`FleetDaemon`] and print its journal plus the drained report.
///
/// The spec is a comma-separated list of clauses, each `@<tick> <verb> ...`:
///
/// ```text
/// @0 submit 4, @500 submit 2, @700 verdict job-00 model-stale, @900 retire job-01
/// ```
///
/// `submit <n>` extends the simulated roster by `n` jobs (rosters are
/// prefix-stable in the seed, so `@0 submit 4, @500 submit 2` profiles the
/// same six jobs as a batch `--jobs 6` run — just two arrivals late).
fn cmd_fleet_daemon(
    args: &Args,
    cfg: FleetConfig,
    cache: Arc<MeasurementCache>,
    cache_file: Option<&str>,
    restored: Option<RestoreOutcome>,
) -> Result<()> {
    if args.flag("adaptive") {
        bail!("--daemon replaces --adaptive: drive drift with `verdict` events instead");
    }
    let workers = cfg.workers;
    let rounds = cfg.rounds;
    let spec = args.opt_or("events", &format!("@0 submit {}", args.opt_usize("jobs", 12)));
    let mut builder = FleetDaemon::builder()
        .config(cfg)
        .rebalance(args.flag("rebalance"))
        .cache(cache.clone());
    if let Some((topo, mcfg, faults)) = mesh_args(args)? {
        builder = builder.mesh(topo, mcfg);
        for (at, fault) in faults {
            builder = builder.mesh_fault_at(at, fault);
        }
    }
    let mut daemon = builder.build();
    if let Some(out) = restored {
        daemon.note_cache_restore(out);
    }
    let last = schedule_events(&mut daemon, &spec, args.opt_u64("seed", 7))?;

    daemon.run_until(last)?;
    let journal = daemon.journal().to_vec();
    let metrics = daemon.metrics();
    if let Some(path) = args.opt("journal-out") {
        std::fs::write(path, json::to_string(&journal_json(&journal)))
            .with_context(|| format!("writing journal to {path}"))?;
        println!("wrote {path}");
    }
    let report = daemon.drain()?;

    let mut timeline = Table::new(&["tick", "event", "detail"]).with_title(&format!(
        "Fleet daemon timeline — {} events, {} replans",
        metrics.events_processed,
        metrics.replans
    ));
    for entry in &journal {
        timeline.rowd(&[&entry.at, &entry.kind, &entry.detail]);
    }
    println!("{}", timeline.render());

    let jobs = report.summary().outcomes.len();
    print_fleet_sweep(&report, jobs, workers, rounds);
    if let Some(fleet_plan) = &report.plan {
        print_fleet_plan(fleet_plan);
    }
    if let Some(stats) = &report.mesh {
        print_mesh_stats(stats);
    }
    write_fleet_outputs(args, &report, &cache, cache_file)
}

/// Parse the `--mesh` / `--gossip-*` / `--partition` option cluster into
/// the mesh topology, gossip cadence, and scheduled fault list shared by
/// the batch and `--daemon` fleet paths. `None` when `--mesh` is absent.
fn mesh_args(args: &Args) -> Result<Option<(MeshTopology, MeshConfig, Vec<(u64, MeshFault)>)>> {
    let Some(spec) = args.opt("mesh") else {
        ensure!(args.opt("partition").is_none(), "--partition needs --mesh");
        return Ok(None);
    };
    let topo = MeshTopology::parse(spec)?;
    let mcfg = MeshConfig {
        every: args.opt_u64("gossip-every", 200),
        rounds: args.opt_usize("gossip-rounds", 5),
    };
    let faults = match args.opt("partition") {
        Some(p) => parse_partition(p)?,
        None => Vec::new(),
    };
    Ok(Some((topo, mcfg, faults)))
}

/// Parse a `--partition` fault spec: comma-separated clauses, each
/// `@<tick> cut <a>-<b>`, `@<tick> heal <a>-<b>`, or `@<tick> lose <node>`
/// (node names are the mesh's `<base>.<idx>` names, e.g. `pi4.2`).
fn parse_partition(spec: &str) -> Result<Vec<(u64, MeshFault)>> {
    fn link(tok: &str) -> Result<(String, String)> {
        let (a, b) = tok
            .split_once('-')
            .with_context(|| format!("expected <a>-<b>, got '{tok}'"))?;
        Ok((a.to_string(), b.to_string()))
    }
    let mut faults = Vec::new();
    for clause in spec.split(',') {
        let toks: Vec<&str> = clause.split_whitespace().collect();
        if toks.is_empty() {
            continue;
        }
        let tick = toks[0]
            .strip_prefix('@')
            .with_context(|| format!("--partition clause '{}' lacks @<tick>", clause.trim()))?;
        let at: u64 = tick.parse().context("bad --partition tick")?;
        let fault = match (toks.get(1).copied(), toks.get(2).copied()) {
            (Some("cut"), Some(pair)) => {
                let (a, b) = link(pair)?;
                MeshFault::Cut(a, b)
            }
            (Some("heal"), Some(pair)) => {
                let (a, b) = link(pair)?;
                MeshFault::Heal(a, b)
            }
            (Some("lose"), Some(name)) => MeshFault::Lose(name.to_string()),
            _ => bail!("bad --partition clause '{}' (cut|heal|lose)", clause.trim()),
        };
        faults.push((at, fault));
    }
    Ok(faults)
}

/// One-line mesh-health summary printed after the plan tables.
fn print_mesh_stats(s: &streamprof::fleet::MeshStats) {
    println!(
        "mesh health: {} gossip rounds, {} summaries delivered ({} dropped on faulted links), \
         {} conflict rollback(s), {} move(s), {} staleness ticks observed",
        s.gossip_rounds,
        s.summaries_delivered,
        s.summaries_dropped,
        s.conflict_rollbacks,
        s.moves,
        s.staleness_ticks
    );
}

/// Parse an `--events` timeline spec and schedule every clause on the
/// daemon. Returns the last scheduled tick — the natural `run_until` bound.
fn schedule_events(daemon: &mut FleetDaemon, spec: &str, seed: u64) -> Result<u64> {
    let mut last = 0u64;
    let mut total = 0usize;
    for clause in spec.split(',') {
        let toks: Vec<&str> = clause.split_whitespace().collect();
        if toks.is_empty() {
            continue;
        }
        let tick = toks[0]
            .strip_prefix('@')
            .with_context(|| format!("--events clause '{}' lacks @<tick>", clause.trim()))?;
        let at: u64 = tick.parse().context("bad --events tick")?;
        last = last.max(at);
        match toks.get(1).copied() {
            Some("submit") => {
                let n: usize = toks
                    .get(2)
                    .context("submit needs a job count")?
                    .parse()
                    .context("submit needs a numeric job count")?;
                for job in sim_fleet(total + n, seed).into_iter().skip(total) {
                    daemon.submit_at(job, at);
                }
                total += n;
            }
            Some("retire") => {
                let name = toks.get(2).context("retire needs a job name")?;
                daemon.retire_at(name, at);
            }
            Some("verdict") => {
                let name = toks.get(2).context("verdict needs a job name")?;
                let kind = toks.get(3).context("verdict needs a kind")?;
                daemon.observe_verdict_at(name, parse_verdict(kind)?, at);
            }
            _ => bail!("bad --events clause '{}' (submit|retire|verdict)", clause.trim()),
        }
    }
    Ok(last)
}

/// Shared scenario runner for `serve` and `telemetry query`: replay the
/// `--events` timeline through a daemon with the given telemetry store
/// attached, honour `--out`/`--cache-file`, and return the drained report.
fn run_daemon_scenario(args: &Args, store: &Arc<TelemetryStore>) -> Result<FleetReport> {
    let (cache, cache_file, restored) = open_cache(args)?;
    let spec = args.opt_or("events", &format!("@0 submit {}", args.opt_usize("jobs", 12)));
    let mut daemon = FleetDaemon::builder()
        .config(fleet_config(args))
        .rebalance(args.flag("rebalance"))
        .cache(cache.clone())
        .telemetry(store.clone())
        .build();
    if let Some(out) = restored {
        daemon.note_cache_restore(out);
    }
    let last = schedule_events(&mut daemon, &spec, args.opt_u64("seed", 7))?;
    daemon.run_until(last)?;
    let report = daemon.drain()?;
    write_fleet_outputs(args, &report, &cache, cache_file.as_deref())?;
    Ok(report)
}

/// `streamprof serve`: replay an `--events` timeline through a daemon with
/// a telemetry recorder attached, then expose the store and the drained
/// report over std-only HTTP/JSON until killed.
fn cmd_serve(args: &Args) -> Result<()> {
    let store = Arc::new(TelemetryStore::new());
    let report = run_daemon_scenario(args, &store)?;
    let port = args.opt_u64("port", 7878);
    let server = TelemetryServer::bind(&format!("127.0.0.1:{port}"), store, &report.to_json())?;
    println!("serving telemetry on http://{}", server.local_addr());
    println!("  GET /healthz    store health and point counts");
    println!("  GET /series     every recorded series");
    println!("  GET /snapshot   the drained fleet report");
    println!("  GET /query?q=   e.g. /query?q=select+probes+%7C+agg+sum");
    server.serve_forever()
}

/// `streamprof telemetry query "<expr>"`: replay a daemon scenario offline
/// with telemetry attached and evaluate one query over the recorded store.
/// The result JSON is the last line on stdout, so scripts can `tail -n 1`.
fn cmd_telemetry(args: &Args) -> Result<()> {
    if args.positional.get(1).map(String::as_str) != Some("query") {
        bail!("usage: streamprof telemetry query \"<expr>\" [fleet options]");
    }
    let text = args.positional.get(2).context("telemetry query needs an expression")?;
    // Parse before the scenario runs: a bad expression should fail fast.
    let query = Query::parse(text).map_err(anyhow::Error::msg)?;
    let store = Arc::new(TelemetryStore::new());
    run_daemon_scenario(args, &store)?;
    println!("{}", json::to_string(&query.run(&store).to_json()));
    Ok(())
}

/// Map an `--events` verdict kind onto a representative [`DriftVerdict`].
fn parse_verdict(kind: &str) -> Result<DriftVerdict> {
    Ok(match kind {
        "model-stale" => DriftVerdict::ModelStale { rolling_smape: 1.0 },
        "rate-shift" => DriftVerdict::RateShift { provisioned_hz: 2.0, observed_hz: 8.0 },
        other => bail!("unknown verdict kind '{other}' (model-stale|rate-shift)"),
    })
}

/// Shared tail of the batch and daemon fleet paths: `--out` report dump
/// plus `--cache-file` snapshot save.
fn write_fleet_outputs(
    args: &Args,
    report: &FleetReport,
    cache: &MeasurementCache,
    cache_file: Option<&str>,
) -> Result<()> {
    if let Some(out) = args.opt("out") {
        std::fs::write(out, json::to_string(&report.to_json()))
            .with_context(|| format!("writing report to {out}"))?;
        println!("wrote {out}");
    }
    if let Some(path) = cache_file {
        std::fs::write(path, json::to_string(&cache.snapshot()))
            .with_context(|| format!("writing cache file {path}"))?;
        println!("cache: saved {} measurements to {path}", cache.len());
    }
    Ok(())
}

/// `--adaptive` scenario knobs: shift some streams' rates and some jobs'
/// runtime behaviour at a virtual tick.
fn inject_drift(args: &Args, specs: &mut [FleetJobSpec]) {
    let shift_at = args.opt_usize("shift-at", 1500);
    let shift_rate = args.opt_f64("shift-rate", 8.0);
    let shift_jobs = args.opt_usize("shift-jobs", 2).min(specs.len());
    let stale_jobs = args.opt_usize("stale-jobs", 1).min(specs.len() - shift_jobs);
    let stale_scale = args.opt_f64("stale-scale", 3.0);
    for s in specs.iter_mut().take(shift_jobs) {
        s.arrivals = s
            .arrivals
            .clone()
            .with_shift_at(shift_at, ArrivalProcess::Fixed(shift_rate));
    }
    for s in specs.iter_mut().skip(shift_jobs).take(stale_jobs) {
        s.runtime_shift = Some(RuntimeShift { at_tick: shift_at, scale: stale_scale });
    }
}

fn print_fleet_sweep(report: &FleetReport, n_jobs: usize, workers: usize, rounds: usize) {
    let summary = report.summary();
    let mut table = Table::new(&[
        "job",
        "device",
        "class",
        "worker",
        "probes",
        "refits",
        "model",
        "rate Hz",
        "limit",
        "guaranteed",
    ])
    .with_title(&format!(
        "Fleet profiling — {n_jobs} jobs, {workers} workers, {rounds} rounds"
    ));
    for o in &summary.outcomes {
        let (limit, guaranteed) = match summary.assignment(&o.name) {
            Some(a) => (format!("{:.1}", a.adjustment.limit), a.guaranteed.to_string()),
            None => ("-".into(), "-".into()),
        };
        table.rowd(&[
            &o.name,
            &o.node.name,
            &o.label,
            &o.worker,
            &o.points,
            &o.refits,
            &o.model.kind.name(),
            &format!("{:.1}", o.rate_hz),
            &limit,
            &guaranteed,
        ]);
    }
    println!("{}", table.render());

    let mut plans = Table::new(&["node", "capacity", "assigned", "guaranteed", "shed"])
        .with_title("Per-node capacity plans");
    for (node, plan) in &summary.plans {
        let guaranteed = plan.assignments.iter().filter(|a| a.guaranteed).count();
        plans.rowd(&[
            &node,
            &format!("{:.1}", plan.capacity),
            &format!("{:.1}", plan.total_assigned),
            &guaranteed,
            &(plan.assignments.len() - guaranteed),
        ]);
    }
    println!("{}", plans.render());

    let stats = report.cache;
    println!(
        "measurement cache: {} hits / {} misses ({:.0}% hit rate), \
         {:.0}s of profiling wallclock saved, {:.0}s executed",
        stats.hits,
        stats.misses,
        100.0 * report.hit_rate(),
        stats.saved_wallclock,
        summary.executed_wallclock()
    );
}

fn print_fleet_plan(fleet_plan: &streamprof::fleet::FleetPlan) {
    let mut moves =
        Table::new(&["job", "prio", "from", "to", "limit", "slack after", "reprofile"])
            .with_title("Shed-job migrations (cross-node placement via translated models)");
    for m in &fleet_plan.migrations {
        moves.rowd(&[
            &m.job,
            &m.priority,
            &m.from,
            &m.to,
            &format!("{:.1}", m.limit),
            &format!("{:.1}", m.slack_after),
            &m.needs_reprofile,
        ]);
    }
    if fleet_plan.migrations.is_empty() {
        println!("rebalance: no feasible migration (fleet already balanced)");
    } else {
        println!("{}", moves.render());
    }
    let fm = &fleet_plan.metrics;
    println!(
        "fleet plan: {}/{} jobs guaranteed (was {} before migration), \
         {:.1}/{:.1} CPUs assigned ({:.0}% utilization)",
        fm.guaranteed_after,
        fm.jobs,
        fm.guaranteed_before,
        fm.total_assigned,
        fm.total_capacity,
        100.0 * fm.utilization()
    );
}

/// `streamprof fleet --adaptive`: drift-aware continuous profiling with
/// injected rate and runtime shifts.
fn print_fleet_adaptive(summary: &streamprof::fleet::AdaptiveSummary) {
    for e in &summary.epochs {
        let mut table = Table::new(&["job", "verdict", "reprofiled", "SMAPE pre -> post"])
            .with_title(&format!("Adaptive epoch {}", e.epoch));
        for (name, verdict) in &e.verdicts {
            let re = e.reprofiled.iter().find(|r| &r.name == name);
            table.rowd(&[
                &name,
                &verdict.name(),
                &re.is_some(),
                &match re {
                    Some(r) => format!("{:.3} -> {:.3}", r.pre_smape, r.post_smape),
                    None => "-".into(),
                },
            ]);
        }
        println!("{}", table.render());
        if let Some(plan) = &e.plan {
            let fm = &plan.metrics;
            println!(
                "  rebalanced: {}/{} jobs guaranteed, {} migration(s)",
                fm.guaranteed_after,
                fm.jobs,
                plan.migrations.len()
            );
        }
    }

    let stats = summary.cache;
    println!(
        "measurement cache: {} hits / {} misses ({:.0}% hit rate), \
         {} stale hits refused, {} stale entries evicted, {} inserts",
        stats.hits,
        stats.misses,
        100.0 * stats.hit_rate(),
        stats.stale_hits_refused,
        stats.evictions,
        stats.inserts
    );
    println!(
        "probe executions during adaptation: {} (naive full re-profiling \
         would have executed {})",
        summary.adaptive_probe_executions,
        summary.naive_probe_executions()
    );
    let reprofiled = summary.reprofiled_names();
    println!(
        "re-profiled {} of {} jobs: {}",
        reprofiled.len(),
        summary.jobs.len(),
        if reprofiled.is_empty() { "-".to_string() } else { reprofiled.join(", ") }
    );
}

fn cmd_repro(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let quick = !args.flag("full");
    let reports = match which {
        "all" => repro::run_all(quick),
        "table1" => vec![repro::table1::run()],
        "fig2" => vec![repro::fig2::run()],
        "fig3" => vec![repro::fig3::run(quick)],
        "fig4" => vec![repro::fig4::run()],
        "fig5" => vec![repro::fig5::run(quick)],
        "fig6" => vec![repro::fig6::run()],
        "fig7" => vec![repro::fig7::run(quick)],
        other => bail!("unknown experiment '{other}'"),
    };
    for r in reports {
        println!("==== {} ====\n{}", r.id, r.rendered);
        for p in &r.csv_paths {
            println!("  wrote {}", p.display());
        }
        println!();
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    if !artifacts_available() {
        println!("artifacts: NOT built (run `make artifacts`)");
        return Ok(());
    }
    let engine = Engine::new(&default_artifacts_dir())?;
    println!("artifacts dir: {}", default_artifacts_dir().display());
    println!("pjrt platform: {}", engine.platform());
    let mut table = Table::new(&["artifact", "chunk", "inputs", "outputs"]);
    for a in &engine.manifest().artifacts {
        table.rowd(&[&a.name, &a.chunk, &a.inputs.len(), &a.outputs.len()]);
    }
    println!("{}", table.render());
    println!("nodes registry: {} machines", NODES.len());
    Ok(())
}
