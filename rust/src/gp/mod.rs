//! Gaussian-process regression substrate for the BO selection strategy.
//!
//! Paper §III-A.b: "We use BO with Matern5/2 as prior function, and Expected
//! Improvement (EI) as acquisition function." Inputs (CPU limitations) are
//! scaled to [0, 1]; observations are standardized to zero mean / unit
//! variance before conditioning, and EI is computed on the standardized
//! scale (maximization).

use crate::linalg::{Cholesky, Mat};
use crate::stats::{normal_cdf, normal_pdf, normal_quantile};

/// Matérn-5/2 kernel over scalar inputs.
#[derive(Clone, Copy, Debug)]
pub struct Matern52 {
    /// Signal variance σ².
    pub variance: f64,
    /// Length scale ℓ (in scaled-input units).
    pub length_scale: f64,
}

impl Default for Matern52 {
    fn default() -> Self {
        Self { variance: 1.0, length_scale: 0.25 }
    }
}

impl Matern52 {
    pub fn eval(&self, x1: f64, x2: f64) -> f64 {
        let r = (x1 - x2).abs() / self.length_scale;
        let s5 = 5.0f64.sqrt() * r;
        self.variance * (1.0 + s5 + 5.0 * r * r / 3.0) * (-s5).exp()
    }
}

/// GP posterior over scalar inputs with fixed hyperparameters + noise.
pub struct Gp {
    kernel: Matern52,
    noise: f64,
    xs: Vec<f64>,
    /// Standardized observations.
    ys_std: Vec<f64>,
    y_mean: f64,
    y_scale: f64,
    chol: Option<Cholesky>,
    alpha: Vec<f64>,
    /// Input scaling (lo, hi) -> [0,1].
    x_lo: f64,
    x_hi: f64,
}

impl Gp {
    pub fn new(kernel: Matern52, noise: f64, x_lo: f64, x_hi: f64) -> Self {
        assert!(x_hi > x_lo, "bad input range");
        Self {
            kernel,
            noise,
            xs: Vec::new(),
            ys_std: Vec::new(),
            y_mean: 0.0,
            y_scale: 1.0,
            chol: None,
            alpha: Vec::new(),
            x_lo,
            x_hi,
        }
    }

    fn scale_x(&self, x: f64) -> f64 {
        (x - self.x_lo) / (self.x_hi - self.x_lo)
    }

    /// Condition on observations `(x, y)`; replaces any previous data.
    pub fn fit(&mut self, points: &[(f64, f64)]) {
        self.xs = points.iter().map(|(x, _)| self.scale_x(*x)).collect();
        let raw: Vec<f64> = points.iter().map(|(_, y)| *y).collect();
        let n = raw.len();
        if n == 0 {
            self.chol = None;
            return;
        }
        self.y_mean = raw.iter().sum::<f64>() / n as f64;
        let var = raw.iter().map(|y| (y - self.y_mean).powi(2)).sum::<f64>() / n as f64;
        self.y_scale = var.sqrt().max(1e-9);
        self.ys_std = raw.iter().map(|y| (y - self.y_mean) / self.y_scale).collect();

        let mut k = Mat::from_fn(n, n, |i, j| self.kernel.eval(self.xs[i], self.xs[j]));
        for i in 0..n {
            k[(i, i)] += self.noise;
        }
        let (chol, _) = Cholesky::new_with_jitter(&k, 1e-10).expect("kernel matrix SPD");
        self.alpha = chol.solve(&self.ys_std);
        self.chol = Some(chol);
    }

    pub fn n_obs(&self) -> usize {
        self.xs.len()
    }

    /// Posterior mean/variance at `x` (original scale for mean; variance on
    /// the standardized scale).
    pub fn predict(&self, x: f64) -> (f64, f64) {
        let (mu_std, var_std) = self.predict_std(x);
        (self.y_mean + self.y_scale * mu_std, var_std)
    }

    /// Posterior standard deviation at `x` on the **original** observation
    /// scale — the spread a caller can compare directly against predicted
    /// means (the transfer-prior confidence gate does exactly that).
    pub fn predict_sd(&self, x: f64) -> f64 {
        let (_, var_std) = self.predict_std(x);
        self.y_scale * var_std.sqrt()
    }

    /// Posterior quantile at `x` on the original scale: the value `q`
    /// (in (0, 1)) of the Gaussian posterior — e.g. `q = 0.95` is the p95
    /// runtime prediction used by quantile-aware capacity planning, not
    /// just the mean.
    pub fn predict_quantile(&self, x: f64, q: f64) -> f64 {
        let (mu_std, var_std) = self.predict_std(x);
        let z = normal_quantile(q.clamp(1e-9, 1.0 - 1e-9));
        self.y_mean + self.y_scale * (mu_std + z * var_std.sqrt())
    }

    fn predict_std(&self, x: f64) -> (f64, f64) {
        let xs_scaled = self.scale_x(x);
        let Some(chol) = &self.chol else {
            return (0.0, self.kernel.variance);
        };
        let kstar: Vec<f64> =
            self.xs.iter().map(|&xi| self.kernel.eval(xs_scaled, xi)).collect();
        let mu: f64 = kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        let v = chol.forward_solve(&kstar);
        let var = self.kernel.eval(xs_scaled, xs_scaled) - v.iter().map(|x| x * x).sum::<f64>();
        (mu, var.max(1e-12))
    }

    /// Expected Improvement (maximization) at `x` given incumbent best
    /// observation `best_y` (original scale).
    pub fn expected_improvement(&self, x: f64, best_y: f64) -> f64 {
        let (mu_std, var_std) = self.predict_std(x);
        let best_std = (best_y - self.y_mean) / self.y_scale;
        let sigma = var_std.sqrt();
        if sigma < 1e-12 {
            return (mu_std - best_std).max(0.0);
        }
        let z = (mu_std - best_std) / sigma;
        (mu_std - best_std) * normal_cdf(z) + sigma * normal_pdf(z)
    }

    /// Argmax of EI over `candidates` (original-scale xs). Returns `None`
    /// when the candidate list is empty.
    pub fn argmax_ei(&self, candidates: &[f64], best_y: f64) -> Option<f64> {
        candidates
            .iter()
            .map(|&x| (x, self.expected_improvement(x, best_y)))
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(x, _)| x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_properties() {
        let k = Matern52::default();
        assert!((k.eval(0.3, 0.3) - k.variance).abs() < 1e-12);
        assert!(k.eval(0.0, 0.1) > k.eval(0.0, 0.5)); // decays with distance
        assert!((k.eval(0.1, 0.7) - k.eval(0.7, 0.1)).abs() < 1e-15); // symmetric
    }

    #[test]
    fn posterior_interpolates_observations() {
        let mut gp = Gp::new(Matern52::default(), 1e-8, 0.0, 4.0);
        let pts = [(0.5, 2.0), (1.5, 1.0), (3.0, 0.5)];
        gp.fit(&pts);
        for (x, y) in pts {
            let (mu, _) = gp.predict(x);
            assert!((mu - y).abs() < 1e-3, "at {x}: {mu} vs {y}");
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let mut gp = Gp::new(Matern52::default(), 1e-6, 0.0, 10.0);
        gp.fit(&[(2.0, 1.0), (3.0, 2.0)]);
        let (_, var_near) = gp.predict(2.5);
        let (_, var_far) = gp.predict(9.0);
        assert!(var_far > var_near * 5.0);
    }

    #[test]
    fn ei_positive_and_peaks_in_promising_region() {
        // Observations rising to the right: EI for maximization should
        // prefer the unexplored right side over the explored left.
        let mut gp = Gp::new(Matern52::default(), 1e-6, 0.0, 1.0);
        gp.fit(&[(0.1, 0.2), (0.3, 0.5), (0.5, 0.9)]);
        let best = 0.9;
        let ei_left = gp.expected_improvement(0.12, best);
        let ei_right = gp.expected_improvement(0.8, best);
        assert!(ei_right > ei_left, "{ei_right} vs {ei_left}");
    }

    #[test]
    fn argmax_ei_picks_from_candidates() {
        let mut gp = Gp::new(Matern52::default(), 1e-6, 0.0, 1.0);
        gp.fit(&[(0.2, 0.1), (0.8, 0.7)]);
        let got = gp.argmax_ei(&[0.1, 0.5, 0.9], 0.7).unwrap();
        assert!([0.1, 0.5, 0.9].contains(&got));
        assert!(gp.argmax_ei(&[], 0.7).is_none());
    }

    #[test]
    fn prior_prediction_without_data() {
        let gp = Gp::new(Matern52::default(), 1e-6, 0.0, 1.0);
        let (mu, var) = gp.predict(0.5);
        assert_eq!(mu, 0.0);
        assert!((var - 1.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_bracket_the_mean_and_track_sd() {
        let mut gp = Gp::new(Matern52::default(), 1e-6, 0.0, 4.0);
        gp.fit(&[(0.5, 2.0), (1.5, 1.0), (3.0, 0.5)]);
        for &x in &[0.7f64, 1.0, 2.0, 3.5] {
            let (mu, _) = gp.predict(x);
            let p05 = gp.predict_quantile(x, 0.05);
            let p50 = gp.predict_quantile(x, 0.5);
            let p95 = gp.predict_quantile(x, 0.95);
            assert!(p05 < p50 && p50 < p95, "at {x}: {p05} {p50} {p95}");
            assert!((p50 - mu).abs() < 1e-9, "median == mean for a Gaussian");
            // p95 - mean == z(0.95) * sd on the original scale.
            let sd = gp.predict_sd(x);
            assert!((p95 - mu - 1.6448536269514722 * sd).abs() < 1e-6, "at {x}");
        }
    }

    #[test]
    fn sd_is_original_scale() {
        // Observations with a large spread: the standardized variance is
        // O(1) but the original-scale sd must reflect the data magnitude.
        let mut gp = Gp::new(Matern52::default(), 1e-6, 0.0, 10.0);
        gp.fit(&[(2.0, 100.0), (3.0, 300.0)]);
        assert!(gp.predict_sd(9.0) > 50.0, "far from data, sd ~ full spread");
    }

    #[test]
    fn noisy_observations_smooth_not_interpolate() {
        let mut gp = Gp::new(Matern52::default(), 0.5, 0.0, 1.0);
        // Two contradictory observations at the same x.
        gp.fit(&[(0.5, 1.0), (0.5, -1.0)]);
        let (mu, _) = gp.predict(0.5);
        assert!(mu.abs() < 0.3, "should average, got {mu}");
    }
}
