//! streamprof — efficient runtime profiling for black-box ML services on
//! sensor streams (Becker et al., 2022).
//!
//! Three-layer reproduction: this crate is the L3 coordinator (profiling
//! strategies, early stopping, adaptive resource adjustment) plus every
//! substrate the paper depends on; the ML services themselves are JAX/Pallas
//! programs compiled AOT to HLO artifacts and executed via PJRT (see
//! `python/compile/` and DESIGN.md).
#![allow(clippy::needless_range_loop)]

pub mod fit;
pub mod coordinator;
pub mod earlystop;
pub mod fleet;
pub mod gp;
pub mod linalg;
pub mod repro;
pub mod runtime;
pub mod simulator;
pub mod stats;
pub mod strategies;
pub mod stream;
pub mod util;
pub mod workloads;
