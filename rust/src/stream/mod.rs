//! Sensor-stream substrate: multi-metric sample generation and arrival
//! processes.
//!
//! The paper's jobs consume "a dataset of 10,000 samples with 28 monitoring
//! metrics" (§III-A.a). The generator synthesizes plausible monitoring
//! series — per-metric trend + seasonality + noise + occasional anomaly
//! bursts — and the arrival processes model fixed and varying sample
//! frequencies (the varying case motivates adaptive resource adjustment).

use crate::util::Rng;

/// Number of monitoring metrics per sample (matches `python/compile/config.py`).
pub const METRICS: usize = 28;
/// Default dataset length (paper §III-A.a).
pub const DEFAULT_SAMPLES: usize = 10_000;

/// Per-metric signal parameters.
#[derive(Clone, Debug)]
struct MetricGen {
    base: f64,
    trend: f64,
    amp1: f64,
    freq1: f64,
    phase1: f64,
    amp2: f64,
    freq2: f64,
    phase2: f64,
    noise: f64,
}

/// Deterministic multi-metric sensor stream generator.
pub struct SensorStream {
    metrics: Vec<MetricGen>,
    rng: Rng,
    t: usize,
    /// Steps remaining in the current anomaly burst.
    burst_left: usize,
    burst_scale: f64,
    /// Probability of starting an anomaly burst at any step.
    pub anomaly_rate: f64,
    /// Regime change: from sample index `.0`, the varying part of every
    /// metric (seasonality + noise) is scaled by `.1`.
    regime_shift: Option<(usize, f64)>,
}

impl SensorStream {
    pub fn new(seed: u64) -> Self {
        Self::with_metrics(seed, METRICS)
    }

    pub fn with_metrics(seed: u64, n_metrics: usize) -> Self {
        let mut rng = Rng::new(seed);
        let metrics = (0..n_metrics)
            .map(|_| MetricGen {
                base: rng.uniform(-0.5, 0.5),
                trend: rng.uniform(-5e-5, 5e-5),
                amp1: rng.uniform(0.3, 1.0),
                freq1: rng.uniform(0.005, 0.05),
                phase1: rng.uniform(0.0, std::f64::consts::TAU),
                amp2: rng.uniform(0.05, 0.3),
                freq2: rng.uniform(0.05, 0.4),
                phase2: rng.uniform(0.0, std::f64::consts::TAU),
                noise: rng.uniform(0.01, 0.05),
            })
            .collect();
        Self {
            metrics,
            rng,
            t: 0,
            burst_left: 0,
            burst_scale: 0.0,
            anomaly_rate: 0.0,
            regime_shift: None,
        }
    }

    /// Enable random anomaly bursts (used by the e2e serving example).
    pub fn with_anomalies(mut self, rate: f64) -> Self {
        self.anomaly_rate = rate;
        self
    }

    /// Inject a regime change: from sample index `at`, seasonality and
    /// noise are scaled by `scale` (> 1 = heavier inputs). This is the
    /// stream-side drift knob — a black-box model consuming a heavier
    /// regime slows down, which is exactly what the fleet's
    /// [`crate::fleet::DriftMonitor`] must detect and re-profile.
    pub fn with_regime_shift_at(mut self, at: usize, scale: f64) -> Self {
        self.regime_shift = Some((at, scale));
        self
    }

    /// Whether the generator has passed its regime-change point.
    pub fn in_shifted_regime(&self) -> bool {
        matches!(self.regime_shift, Some((at, _)) if self.t >= at)
    }

    /// Whether the generator is currently inside an anomaly burst.
    pub fn in_anomaly(&self) -> bool {
        self.burst_left > 0
    }

    /// Produce the next sample (f32, ready for the PJRT artifacts).
    pub fn next_sample(&mut self) -> Vec<f32> {
        if self.burst_left == 0 && self.anomaly_rate > 0.0 {
            if self.rng.next_f64() < self.anomaly_rate {
                self.burst_left = 3 + self.rng.below(8);
                self.burst_scale = self.rng.uniform(4.0, 9.0);
            }
        } else if self.burst_left > 0 {
            self.burst_left -= 1;
        }
        let t = self.t as f64;
        let regime = match self.regime_shift {
            Some((at, scale)) if self.t >= at => scale,
            _ => 1.0,
        };
        self.t += 1;
        let anomaly = if self.burst_left > 0 { self.burst_scale } else { 0.0 };
        self.metrics
            .iter()
            .map(|m| {
                let varying = m.amp1 * (m.freq1 * t + m.phase1).sin()
                    + m.amp2 * (m.freq2 * t + m.phase2).sin()
                    + m.noise * self.rng.normal();
                let v = m.base + m.trend * t + regime * varying + anomaly * m.noise * 20.0;
                v as f32
            })
            .collect()
    }

    /// Generate a flat `[n * metrics]` buffer (row-major) — the shape the
    /// chunked artifacts consume.
    pub fn generate(&mut self, n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n * self.metrics.len());
        for _ in 0..n {
            out.extend(self.next_sample());
        }
        out
    }

    pub fn n_metrics(&self) -> usize {
        self.metrics.len()
    }
}

/// Sample arrival process: when does the next sample arrive?
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Fixed frequency (Hz).
    Fixed(f64),
    /// Sinusoidally varying frequency between `lo` and `hi` Hz with the
    /// given period (in samples) — the paper's "changing sample arrival
    /// rates" scenario.
    Varying { lo: f64, hi: f64, period: f64 },
    /// A regime change at sample index `at`: `before` governs indices
    /// `< at`, `after` governs the rest (indices stay absolute, so phases
    /// of a `Varying` tail remain aligned with the global clock). Built
    /// with [`ArrivalProcess::with_shift_at`]; shifts nest.
    Shifted {
        before: Box<ArrivalProcess>,
        at: usize,
        after: Box<ArrivalProcess>,
    },
}

impl ArrivalProcess {
    /// Inject a rate shift: from sample index `at` on, arrivals follow
    /// `after` instead of `self` — the drift-injection knob of the
    /// adaptive fleet loop and its scenario tests.
    pub fn with_shift_at(self, at: usize, after: ArrivalProcess) -> ArrivalProcess {
        ArrivalProcess::Shifted { before: Box::new(self), at, after: Box::new(after) }
    }

    /// Arrival rate (Hz) at sample index `i`.
    pub fn rate_at(&self, i: usize) -> f64 {
        match self {
            ArrivalProcess::Fixed(hz) => *hz,
            ArrivalProcess::Varying { lo, hi, period } => {
                let mid = 0.5 * (lo + hi);
                let amp = 0.5 * (hi - lo);
                mid + amp * (std::f64::consts::TAU * i as f64 / period).sin()
            }
            ArrivalProcess::Shifted { before, at, after } => {
                if i < *at {
                    before.rate_at(i)
                } else {
                    after.rate_at(i)
                }
            }
        }
    }

    /// Inter-arrival gap before sample `i` (seconds).
    pub fn gap_at(&self, i: usize) -> f64 {
        1.0 / self.rate_at(i)
    }

    /// Tightest per-sample runtime budget over the window `[start, end)` —
    /// what an adaptive epoch observes of the live stream.
    pub fn min_gap_in(&self, start: usize, end: usize) -> f64 {
        (start..end).map(|i| self.gap_at(i)).fold(f64::INFINITY, f64::min)
    }

    /// The tightest per-sample runtime budget over the whole horizon —
    /// the just-in-time constraint the adjuster must satisfy.
    pub fn min_gap(&self, horizon: usize) -> f64 {
        self.min_gap_in(0, horizon)
    }

    /// Peak arrival rate (Hz) over the window `[start, end)` (0 for an
    /// empty window) — the drift monitor's per-epoch rate observation.
    pub fn max_rate_in(&self, start: usize, end: usize) -> f64 {
        1.0 / self.min_gap_in(start, end)
    }

    /// Peak arrival rate (Hz) over the horizon — the rate a fleet job's
    /// guaranteed allocation must sustain (0 for an empty horizon).
    pub fn max_rate(&self, horizon: usize) -> f64 {
        self.max_rate_in(0, horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SensorStream::new(42);
        let mut b = SensorStream::new(42);
        assert_eq!(a.generate(10), b.generate(10));
    }

    #[test]
    fn sample_has_28_metrics() {
        let mut s = SensorStream::new(1);
        assert_eq!(s.next_sample().len(), METRICS);
        assert_eq!(s.n_metrics(), 28);
    }

    #[test]
    fn values_are_bounded_and_finite() {
        let mut s = SensorStream::new(2);
        for _ in 0..1000 {
            for v in s.next_sample() {
                assert!(v.is_finite());
                assert!(v.abs() < 10.0, "calm stream should stay small: {v}");
            }
        }
    }

    #[test]
    fn anomalies_create_outliers() {
        let mut s = SensorStream::new(3).with_anomalies(0.01);
        let mut max_abs: f32 = 0.0;
        let mut saw_anomaly = false;
        for _ in 0..2000 {
            let x = s.next_sample();
            if s.in_anomaly() {
                saw_anomaly = true;
            }
            for v in x {
                max_abs = max_abs.max(v.abs());
            }
        }
        assert!(saw_anomaly);
        assert!(max_abs > 2.0, "bursts should push values out: {max_abs}");
    }

    #[test]
    fn generate_is_row_major() {
        let mut a = SensorStream::new(7);
        let flat = a.generate(3);
        let mut b = SensorStream::new(7);
        let s0 = b.next_sample();
        let s1 = b.next_sample();
        assert_eq!(&flat[..METRICS], &s0[..]);
        assert_eq!(&flat[METRICS..2 * METRICS], &s1[..]);
    }

    #[test]
    fn varying_arrival_oscillates() {
        let p = ArrivalProcess::Varying { lo: 5.0, hi: 20.0, period: 100.0 };
        let rates: Vec<f64> = (0..100).map(|i| p.rate_at(i)).collect();
        let max = rates.iter().cloned().fold(f64::MIN, f64::max);
        let min = rates.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max > 19.0 && min < 6.0);
        // Budget = 1/max rate.
        assert!((p.min_gap(100) - 1.0 / max).abs() < 1e-9);
        assert!((p.max_rate(100) - max).abs() < 1e-9);
        assert_eq!(p.max_rate(0), 0.0, "empty horizon has no rate demand");
    }

    #[test]
    fn fixed_arrival_constant() {
        let p = ArrivalProcess::Fixed(10.0);
        assert_eq!(p.rate_at(0), 10.0);
        assert_eq!(p.gap_at(123), 0.1);
    }

    #[test]
    fn shifted_arrival_switches_regime_at_the_tick() {
        let p = ArrivalProcess::Fixed(2.0).with_shift_at(100, ArrivalProcess::Fixed(8.0));
        assert_eq!(p.rate_at(0), 2.0);
        assert_eq!(p.rate_at(99), 2.0);
        assert_eq!(p.rate_at(100), 8.0);
        assert_eq!(p.rate_at(5000), 8.0);
        // Windowed peaks see exactly the regime they cover.
        assert_eq!(p.max_rate_in(0, 100), 2.0);
        assert_eq!(p.max_rate_in(100, 200), 8.0);
        assert_eq!(p.max_rate_in(50, 150), 8.0);
        // Whole-horizon peak spans both regimes.
        assert_eq!(p.max_rate(200), 8.0);
        assert_eq!(p.max_rate(100), 2.0);
        assert_eq!(p.max_rate_in(10, 10), 0.0, "empty window has no rate demand");
    }

    #[test]
    fn shifted_varying_tail_keeps_absolute_phase() {
        // The post-shift Varying process must agree with an unshifted copy
        // at the same absolute index (phases stay on the global clock).
        let tail = ArrivalProcess::Varying { lo: 4.0, hi: 12.0, period: 128.0 };
        let p = ArrivalProcess::Fixed(1.0).with_shift_at(64, tail.clone());
        for i in [64usize, 100, 200, 333] {
            assert_eq!(p.rate_at(i), tail.rate_at(i), "index {i}");
        }
        // Shifts nest: a second shift overrides the first from its tick on.
        let q = p.clone().with_shift_at(256, ArrivalProcess::Fixed(20.0));
        assert_eq!(q.rate_at(0), 1.0);
        assert_eq!(q.rate_at(100), tail.rate_at(100));
        assert_eq!(q.rate_at(256), 20.0);
    }

    #[test]
    fn regime_shift_scales_stream_variability() {
        // Same seed, with and without the regime knob: identical before
        // the shift, visibly heavier after it.
        let mut calm = SensorStream::new(11);
        let mut shifted = SensorStream::new(11).with_regime_shift_at(500, 4.0);
        assert_eq!(calm.generate(500), shifted.generate(500), "pre-shift identical");
        assert!(shifted.in_shifted_regime(), "next sample starts the new regime");
        let spread = |xs: &[f32]| {
            let n = xs.len() as f64;
            let mean = xs.iter().map(|&v| v as f64).sum::<f64>() / n;
            (xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n).sqrt()
        };
        let calm_post = calm.generate(1000);
        let shifted_post = shifted.generate(1000);
        assert!(shifted.in_shifted_regime());
        assert!(
            spread(&shifted_post) > 2.0 * spread(&calm_post),
            "post-shift spread must grow: {} vs {}",
            spread(&shifted_post),
            spread(&calm_post)
        );
    }
}
