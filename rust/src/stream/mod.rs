//! Sensor-stream substrate: multi-metric sample generation and arrival
//! processes.
//!
//! The paper's jobs consume "a dataset of 10,000 samples with 28 monitoring
//! metrics" (§III-A.a). The generator synthesizes plausible monitoring
//! series — per-metric trend + seasonality + noise + occasional anomaly
//! bursts — and the arrival processes model fixed and varying sample
//! frequencies (the varying case motivates adaptive resource adjustment).

use crate::util::Rng;

/// Number of monitoring metrics per sample (matches `python/compile/config.py`).
pub const METRICS: usize = 28;
/// Default dataset length (paper §III-A.a).
pub const DEFAULT_SAMPLES: usize = 10_000;

/// Per-metric signal parameters.
#[derive(Clone, Debug)]
struct MetricGen {
    base: f64,
    trend: f64,
    amp1: f64,
    freq1: f64,
    phase1: f64,
    amp2: f64,
    freq2: f64,
    phase2: f64,
    noise: f64,
}

/// Deterministic multi-metric sensor stream generator.
pub struct SensorStream {
    metrics: Vec<MetricGen>,
    rng: Rng,
    t: usize,
    /// Steps remaining in the current anomaly burst.
    burst_left: usize,
    burst_scale: f64,
    /// Probability of starting an anomaly burst at any step.
    pub anomaly_rate: f64,
}

impl SensorStream {
    pub fn new(seed: u64) -> Self {
        Self::with_metrics(seed, METRICS)
    }

    pub fn with_metrics(seed: u64, n_metrics: usize) -> Self {
        let mut rng = Rng::new(seed);
        let metrics = (0..n_metrics)
            .map(|_| MetricGen {
                base: rng.uniform(-0.5, 0.5),
                trend: rng.uniform(-5e-5, 5e-5),
                amp1: rng.uniform(0.3, 1.0),
                freq1: rng.uniform(0.005, 0.05),
                phase1: rng.uniform(0.0, std::f64::consts::TAU),
                amp2: rng.uniform(0.05, 0.3),
                freq2: rng.uniform(0.05, 0.4),
                phase2: rng.uniform(0.0, std::f64::consts::TAU),
                noise: rng.uniform(0.01, 0.05),
            })
            .collect();
        Self {
            metrics,
            rng,
            t: 0,
            burst_left: 0,
            burst_scale: 0.0,
            anomaly_rate: 0.0,
        }
    }

    /// Enable random anomaly bursts (used by the e2e serving example).
    pub fn with_anomalies(mut self, rate: f64) -> Self {
        self.anomaly_rate = rate;
        self
    }

    /// Whether the generator is currently inside an anomaly burst.
    pub fn in_anomaly(&self) -> bool {
        self.burst_left > 0
    }

    /// Produce the next sample (f32, ready for the PJRT artifacts).
    pub fn next_sample(&mut self) -> Vec<f32> {
        if self.burst_left == 0 && self.anomaly_rate > 0.0 {
            if self.rng.next_f64() < self.anomaly_rate {
                self.burst_left = 3 + self.rng.below(8);
                self.burst_scale = self.rng.uniform(4.0, 9.0);
            }
        } else if self.burst_left > 0 {
            self.burst_left -= 1;
        }
        let t = self.t as f64;
        self.t += 1;
        let anomaly = if self.burst_left > 0 { self.burst_scale } else { 0.0 };
        self.metrics
            .iter()
            .map(|m| {
                let v = m.base
                    + m.trend * t
                    + m.amp1 * (m.freq1 * t + m.phase1).sin()
                    + m.amp2 * (m.freq2 * t + m.phase2).sin()
                    + m.noise * self.rng.normal()
                    + anomaly * m.noise * 20.0;
                v as f32
            })
            .collect()
    }

    /// Generate a flat `[n * metrics]` buffer (row-major) — the shape the
    /// chunked artifacts consume.
    pub fn generate(&mut self, n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n * self.metrics.len());
        for _ in 0..n {
            out.extend(self.next_sample());
        }
        out
    }

    pub fn n_metrics(&self) -> usize {
        self.metrics.len()
    }
}

/// Sample arrival process: when does the next sample arrive?
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// Fixed frequency (Hz).
    Fixed(f64),
    /// Sinusoidally varying frequency between `lo` and `hi` Hz with the
    /// given period (in samples) — the paper's "changing sample arrival
    /// rates" scenario.
    Varying { lo: f64, hi: f64, period: f64 },
}

impl ArrivalProcess {
    /// Arrival rate (Hz) at sample index `i`.
    pub fn rate_at(&self, i: usize) -> f64 {
        match self {
            ArrivalProcess::Fixed(hz) => *hz,
            ArrivalProcess::Varying { lo, hi, period } => {
                let mid = 0.5 * (lo + hi);
                let amp = 0.5 * (hi - lo);
                mid + amp * (std::f64::consts::TAU * i as f64 / period).sin()
            }
        }
    }

    /// Inter-arrival gap before sample `i` (seconds).
    pub fn gap_at(&self, i: usize) -> f64 {
        1.0 / self.rate_at(i)
    }

    /// The tightest per-sample runtime budget over the whole horizon —
    /// the just-in-time constraint the adjuster must satisfy.
    pub fn min_gap(&self, horizon: usize) -> f64 {
        (0..horizon).map(|i| self.gap_at(i)).fold(f64::INFINITY, f64::min)
    }

    /// Peak arrival rate (Hz) over the horizon — the rate a fleet job's
    /// guaranteed allocation must sustain (0 for an empty horizon).
    pub fn max_rate(&self, horizon: usize) -> f64 {
        1.0 / self.min_gap(horizon)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = SensorStream::new(42);
        let mut b = SensorStream::new(42);
        assert_eq!(a.generate(10), b.generate(10));
    }

    #[test]
    fn sample_has_28_metrics() {
        let mut s = SensorStream::new(1);
        assert_eq!(s.next_sample().len(), METRICS);
        assert_eq!(s.n_metrics(), 28);
    }

    #[test]
    fn values_are_bounded_and_finite() {
        let mut s = SensorStream::new(2);
        for _ in 0..1000 {
            for v in s.next_sample() {
                assert!(v.is_finite());
                assert!(v.abs() < 10.0, "calm stream should stay small: {v}");
            }
        }
    }

    #[test]
    fn anomalies_create_outliers() {
        let mut s = SensorStream::new(3).with_anomalies(0.01);
        let mut max_abs: f32 = 0.0;
        let mut saw_anomaly = false;
        for _ in 0..2000 {
            let x = s.next_sample();
            if s.in_anomaly() {
                saw_anomaly = true;
            }
            for v in x {
                max_abs = max_abs.max(v.abs());
            }
        }
        assert!(saw_anomaly);
        assert!(max_abs > 2.0, "bursts should push values out: {max_abs}");
    }

    #[test]
    fn generate_is_row_major() {
        let mut a = SensorStream::new(7);
        let flat = a.generate(3);
        let mut b = SensorStream::new(7);
        let s0 = b.next_sample();
        let s1 = b.next_sample();
        assert_eq!(&flat[..METRICS], &s0[..]);
        assert_eq!(&flat[METRICS..2 * METRICS], &s1[..]);
    }

    #[test]
    fn varying_arrival_oscillates() {
        let p = ArrivalProcess::Varying { lo: 5.0, hi: 20.0, period: 100.0 };
        let rates: Vec<f64> = (0..100).map(|i| p.rate_at(i)).collect();
        let max = rates.iter().cloned().fold(f64::MIN, f64::max);
        let min = rates.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max > 19.0 && min < 6.0);
        // Budget = 1/max rate.
        assert!((p.min_gap(100) - 1.0 / max).abs() < 1e-9);
        assert!((p.max_rate(100) - max).abs() < 1e-9);
        assert_eq!(p.max_rate(0), 0.0, "empty horizon has no rate demand");
    }

    #[test]
    fn fixed_arrival_constant() {
        let p = ArrivalProcess::Fixed(10.0);
        assert_eq!(p.rate_at(0), 10.0);
        assert_eq!(p.gap_at(123), 0.1);
    }
}
