//! IFTM workload drivers: the black-box jobs the profiler measures.
//!
//! Two interchangeable backends implement [`StreamJob`]:
//!   * [`PjrtJob`] — the real thing: executes the AOT-compiled artifacts
//!     via the PJRT runtime (optionally under a [`Throttle`]).
//!   * mirrors ([`mirror`]) — pure-Rust re-implementations used as a
//!     numeric cross-check oracle and an artifact-free backend.

pub mod mirror;

use std::time::Duration;

use anyhow::Result;

use crate::runtime::{Engine, LoadedJob, StepOutcome, Throttle};
use crate::simulator::Algo;

/// A black-box streaming job: consume one sample, emit the IFTM outcome.
pub trait StreamJob {
    /// Process one `[metrics]` sample.
    fn process(&mut self, x: &[f32]) -> Result<StepOutcome>;
    /// Job label for logs/metrics.
    fn label(&self) -> String;
}

/// Real PJRT-backed job (per-sample artifact) with optional CPU throttle.
pub struct PjrtJob {
    job: LoadedJob,
    throttle: Option<Throttle>,
    /// Effective per-sample runtimes (busy + stall) of every processed
    /// sample — what the profiler observes.
    pub latencies: Vec<Duration>,
}

impl PjrtJob {
    /// Load the per-sample artifact for `algo` from `engine`.
    pub fn load(engine: &Engine, algo: Algo) -> Result<Self> {
        let job = engine.load_job(algo.name())?;
        Ok(Self { job, throttle: None, latencies: Vec::new() })
    }

    /// Load any artifact by name (incl. chunked/batched variants).
    pub fn load_named(engine: &Engine, name: &str) -> Result<Self> {
        let job = engine.load_job(name)?;
        Ok(Self { job, throttle: None, latencies: Vec::new() })
    }

    /// Apply a CPU limitation (Docker-like duty cycle).
    pub fn with_throttle(mut self, throttle: Throttle) -> Self {
        self.throttle = Some(throttle);
        self
    }

    pub fn set_throttle(&mut self, throttle: Option<Throttle>) {
        self.throttle = throttle;
    }

    /// Reset job state (threshold model, windows, cells) to initial values.
    pub fn reset(&mut self) -> Result<()> {
        self.latencies.clear();
        self.job.reset()
    }

    /// Process a chunk via a chunked artifact (`xs` is `[T * metrics]`).
    pub fn process_chunk(&mut self, xs: &[f32]) -> Result<Vec<StepOutcome>> {
        let run = |job: &mut LoadedJob| job.step(xs);
        match self.throttle {
            Some(t) => {
                let (res, timing) = t.run(|| run(&mut self.job));
                let outs = res?;
                // Attribute the call's effective time across its samples.
                let per = timing.effective().div_f64(outs.len().max(1) as f64);
                self.latencies.extend(std::iter::repeat(per).take(outs.len()));
                Ok(outs)
            }
            None => {
                let t0 = std::time::Instant::now();
                let outs = run(&mut self.job)?;
                let per = t0.elapsed().div_f64(outs.len().max(1) as f64);
                self.latencies.extend(std::iter::repeat(per).take(outs.len()));
                Ok(outs)
            }
        }
    }

    pub fn samples_per_call(&self) -> usize {
        self.job.samples_per_call()
    }

    /// Mean observed per-sample latency (seconds).
    pub fn mean_latency(&self) -> f64 {
        if self.latencies.is_empty() {
            return 0.0;
        }
        self.latencies.iter().map(Duration::as_secs_f64).sum::<f64>()
            / self.latencies.len() as f64
    }

    /// Access the loaded artifact (diagnostics).
    pub fn inner(&self) -> &LoadedJob {
        &self.job
    }
}

impl StreamJob for PjrtJob {
    fn process(&mut self, x: &[f32]) -> Result<StepOutcome> {
        let outs = self.process_chunk(x)?;
        anyhow::ensure!(outs.len() == 1, "expected a per-sample artifact");
        Ok(outs[0])
    }

    fn label(&self) -> String {
        format!("pjrt:{}", self.job.name())
    }
}

/// Artifact-free mirror job implementing the same trait.
pub enum MirrorJob {
    Arima(mirror::ArimaMirror),
    Birch(mirror::BirchMirror),
    Lstm(mirror::LstmMirror),
}

impl MirrorJob {
    /// Build from the manifest + init blob so mirror and PJRT start from
    /// identical parameters.
    pub fn from_engine(engine: &Engine, algo: Algo) -> Result<Self> {
        let spec = engine
            .manifest()
            .artifact(algo.name())
            .ok_or_else(|| anyhow::anyhow!("artifact {} missing", algo.name()))?;
        let init = spec.load_init()?;
        let m = engine.manifest().metrics;
        Ok(match algo {
            Algo::Arima => {
                let p = spec.inputs[0].shape[0];
                MirrorJob::Arima(mirror::ArimaMirror::from_init(p, m, &init))
            }
            Algo::Birch => {
                let k = spec.inputs[0].shape[0];
                MirrorJob::Birch(mirror::BirchMirror::from_init(k, m, &init))
            }
            Algo::Lstm => {
                let h = spec.inputs[1].shape[0]; // wh1 is [H, 4H]
                MirrorJob::Lstm(mirror::LstmMirror::from_init(m, h, &init))
            }
        })
    }
}

impl StreamJob for MirrorJob {
    fn process(&mut self, x: &[f32]) -> Result<StepOutcome> {
        Ok(match self {
            MirrorJob::Arima(j) => j.step(x),
            MirrorJob::Birch(j) => j.step(x),
            MirrorJob::Lstm(j) => j.step(x),
        })
    }

    fn label(&self) -> String {
        match self {
            MirrorJob::Arima(_) => "mirror:arima".into(),
            MirrorJob::Birch(_) => "mirror:birch".into(),
            MirrorJob::Lstm(_) => "mirror:lstm".into(),
        }
    }
}
