//! Pure-Rust mirrors of the three IFTM step functions.
//!
//! These reproduce `python/compile/model.py` exactly (same constants, same
//! f32 arithmetic order where it matters) and serve two purposes:
//!   1. cross-check oracle for the PJRT artifacts (integration tests assert
//!      PJRT ≈ mirror over long streams), and
//!   2. an artifact-free job backend for tests and quick experiments.

use crate::runtime::StepOutcome;

/// EWMA smoothing factor (== config.EWMA_ALPHA).
pub const EWMA_ALPHA: f32 = 0.05;
/// Sigma multiplier of the threshold model (== config.SIGMA_K).
pub const SIGMA_K: f32 = 3.0;
/// NLMS step size (== config.AR_MU).
pub const AR_MU: f32 = 0.05;

/// IFTM threshold model state (ewma mean, ewma var).
#[derive(Clone, Copy, Debug, Default)]
pub struct ThresholdModel {
    pub mean: f32,
    pub var: f32,
}

impl ThresholdModel {
    /// One update; returns (threshold-in-effect, flag).
    pub fn step(&mut self, err: f32) -> (f32, f32) {
        let thr = self.mean + SIGMA_K * self.var.max(1e-12).sqrt();
        let flag = if err > thr { 1.0 } else { 0.0 };
        let new_mean = (1.0 - EWMA_ALPHA) * self.mean + EWMA_ALPHA * err;
        let diff = err - new_mean;
        let new_var = (1.0 - EWMA_ALPHA) * self.var + EWMA_ALPHA * diff * diff;
        self.mean = new_mean;
        self.var = new_var;
        (thr, flag)
    }
}

/// Online AR(p) with NLMS updates — mirrors `model.arima_step`.
pub struct ArimaMirror {
    p: usize,
    m: usize,
    /// [P, M] row-major.
    coeffs: Vec<f32>,
    /// [P, M] row-major, row 0 oldest.
    window: Vec<f32>,
    tm: ThresholdModel,
}

impl ArimaMirror {
    pub fn new(p: usize, m: usize) -> Self {
        let mut coeffs = vec![0.0f32; p * m];
        // Persistence init: last row = 1.
        for j in 0..m {
            coeffs[(p - 1) * m + j] = 1.0;
        }
        Self { p, m, coeffs, window: vec![0.0; p * m], tm: ThresholdModel::default() }
    }

    /// Construct from artifact init tensors (coeffs, window, tm).
    pub fn from_init(p: usize, m: usize, init: &[Vec<f32>]) -> Self {
        Self {
            p,
            m,
            coeffs: init[0].clone(),
            window: init[1].clone(),
            tm: ThresholdModel { mean: init[2][0], var: init[2][1] },
        }
    }

    pub fn step(&mut self, x: &[f32]) -> StepOutcome {
        assert_eq!(x.len(), self.m);
        let (p, m) = (self.p, self.m);
        // pred[j] = Σ_i coeffs[i,j] * window[i,j]
        let mut pred = vec![0.0f32; m];
        for i in 0..p {
            for j in 0..m {
                pred[j] += self.coeffs[i * m + j] * self.window[i * m + j];
            }
        }
        let mut abs_sum = 0.0f32;
        let mut resid = vec![0.0f32; m];
        for j in 0..m {
            resid[j] = x[j] - pred[j];
            abs_sum += resid[j].abs();
        }
        let err = abs_sum / m as f32;
        // NLMS per-metric normalized update.
        let mut norm = vec![1e-6f32; m];
        for i in 0..p {
            for j in 0..m {
                let w = self.window[i * m + j];
                norm[j] += w * w;
            }
        }
        for i in 0..p {
            for j in 0..m {
                self.coeffs[i * m + j] +=
                    AR_MU * self.window[i * m + j] * (resid[j] / norm[j]);
            }
        }
        // Slide window.
        self.window.copy_within(m.., 0);
        let off = (p - 1) * m;
        self.window[off..off + m].copy_from_slice(x);
        let (thr, flag) = self.tm.step(err);
        StepOutcome { err, thr, flag }
    }
}

/// Nearest-centroid Birch mirror — mirrors `model.birch_step`.
pub struct BirchMirror {
    k: usize,
    m: usize,
    /// [K, M] row-major.
    centroids: Vec<f32>,
    counts: Vec<f32>,
    tm: ThresholdModel,
}

impl BirchMirror {
    pub fn from_init(k: usize, m: usize, init: &[Vec<f32>]) -> Self {
        Self {
            k,
            m,
            centroids: init[0].clone(),
            counts: init[1].clone(),
            tm: ThresholdModel { mean: init[2][0], var: init[2][1] },
        }
    }

    pub fn step(&mut self, x: &[f32]) -> StepOutcome {
        assert_eq!(x.len(), self.m);
        let (k, m) = (self.k, self.m);
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for i in 0..k {
            let mut d = 0.0f32;
            for j in 0..m {
                let diff = x[j] - self.centroids[i * m + j];
                d += diff * diff;
            }
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        let err = best_d.max(0.0).sqrt();
        let lr = 1.0 / (self.counts[best] + 1.0);
        for j in 0..m {
            let c = self.centroids[best * m + j];
            self.centroids[best * m + j] = c + lr * (x[j] - c);
        }
        self.counts[best] += 1.0;
        let (thr, flag) = self.tm.step(err);
        StepOutcome { err, thr, flag }
    }
}

/// Two stacked LSTM cells + linear readout — mirrors `model.lstm_step`.
pub struct LstmMirror {
    m: usize,
    h: usize,
    // Params, row-major as written by aot.py.
    wx1: Vec<f32>, // [M, 4H]
    wh1: Vec<f32>, // [H, 4H]
    b1: Vec<f32>,  // [4H]
    wx2: Vec<f32>, // [H, 4H]
    wh2: Vec<f32>, // [H, 4H]
    b2: Vec<f32>,  // [4H]
    wo: Vec<f32>,  // [H, M]
    bo: Vec<f32>,  // [M]
    // State.
    h1: Vec<f32>,
    c1: Vec<f32>,
    h2: Vec<f32>,
    c2: Vec<f32>,
    tm: ThresholdModel,
    // Scratch (avoid per-step allocation on the hot path).
    gates: Vec<f32>,
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

impl LstmMirror {
    pub fn from_init(m: usize, h: usize, init: &[Vec<f32>]) -> Self {
        Self {
            m,
            h,
            wx1: init[0].clone(),
            wh1: init[1].clone(),
            b1: init[2].clone(),
            wx2: init[3].clone(),
            wh2: init[4].clone(),
            b2: init[5].clone(),
            wo: init[6].clone(),
            bo: init[7].clone(),
            h1: init[8].clone(),
            c1: init[9].clone(),
            h2: init[10].clone(),
            c2: init[11].clone(),
            tm: ThresholdModel { mean: init[12][0], var: init[12][1] },
            gates: vec![0.0; 4 * h],
        }
    }

    /// `gates = x @ Wx + h @ Wh + b`; then the cell update.
    fn cell(
        gates: &mut [f32],
        x: &[f32],
        wx: &[f32],
        hs: &mut Vec<f32>,
        cs: &mut [f32],
        wh: &[f32],
        b: &[f32],
        hidden: usize,
    ) {
        let g4 = 4 * hidden;
        gates.copy_from_slice(b);
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &wx[i * g4..(i + 1) * g4];
            for (g, &w) in gates.iter_mut().zip(row) {
                *g += xi * w;
            }
        }
        for (i, &hi) in hs.iter().enumerate() {
            if hi == 0.0 {
                continue;
            }
            let row = &wh[i * g4..(i + 1) * g4];
            for (g, &w) in gates.iter_mut().zip(row) {
                *g += hi * w;
            }
        }
        for j in 0..hidden {
            let i_g = sigmoid(gates[j]);
            let f_g = sigmoid(gates[hidden + j]);
            let g_g = gates[2 * hidden + j].tanh();
            let o_g = sigmoid(gates[3 * hidden + j]);
            let c_new = f_g * cs[j] + i_g * g_g;
            cs[j] = c_new;
            hs[j] = o_g * c_new.tanh();
        }
    }

    pub fn step(&mut self, x: &[f32]) -> StepOutcome {
        assert_eq!(x.len(), self.m);
        let (m, h) = (self.m, self.h);
        // Forecast from the previous layer-2 state.
        let mut abs_sum = 0.0f32;
        for j in 0..m {
            let mut pred = self.bo[j];
            for i in 0..h {
                pred += self.h2[i] * self.wo[i * m + j];
            }
            abs_sum += (pred - x[j]).abs();
        }
        let err = abs_sum / m as f32;
        // Advance the stacked cells.
        let mut gates = std::mem::take(&mut self.gates);
        Self::cell(&mut gates, x, &self.wx1, &mut self.h1, &mut self.c1, &self.wh1, &self.b1, h);
        let h1_snapshot = self.h1.clone();
        Self::cell(
            &mut gates,
            &h1_snapshot,
            &self.wx2,
            &mut self.h2,
            &mut self.c2,
            &self.wh2,
            &self.b2,
            h,
        );
        self.gates = gates;
        let (thr, flag) = self.tm.step(err);
        StepOutcome { err, thr, flag }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::SensorStream;

    #[test]
    fn threshold_model_flags_spikes() {
        let mut tm = ThresholdModel::default();
        for _ in 0..100 {
            tm.step(0.1);
        }
        let (_, flag) = tm.step(5.0);
        assert_eq!(flag, 1.0);
        // Quiet sample right after should not flag (mean barely moved).
        let (_, flag2) = tm.step(0.1);
        assert_eq!(flag2, 0.0);
    }

    #[test]
    fn arima_error_vanishes_on_constant_signal() {
        let m = 28;
        let mut job = ArimaMirror::new(8, m);
        let x = vec![1.5f32; m];
        let mut last = f32::MAX;
        for _ in 0..20 {
            last = job.step(&x).err;
        }
        assert!(last < 1e-3, "err {last}");
    }

    #[test]
    fn arima_learns_sinusoid() {
        let m = 28;
        let mut job = ArimaMirror::new(8, m);
        let mut stream = SensorStream::new(11);
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..300 {
            let e = job.step(&stream.next_sample()).err;
            if (20..60).contains(&i) {
                early += e;
            }
            if i >= 260 {
                late += e;
            }
        }
        assert!(late / 40.0 < early / 40.0, "late {late} early {early}");
    }

    #[test]
    fn birch_winning_centroid_converges() {
        let k = 4;
        let m = 3;
        let init = vec![
            vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0, -1.0, -1.0, -1.0, 2.0, 2.0, 2.0],
            vec![1.0; k],
            vec![0.0, 0.0],
        ];
        let mut job = BirchMirror::from_init(k, m, &init);
        let x = vec![0.9f32, 0.9, 0.9];
        let mut err = f32::MAX;
        for _ in 0..50 {
            err = job.step(&x).err;
        }
        assert!(err < 0.05, "err {err}");
    }

    #[test]
    fn lstm_mirror_runs_and_bounds_hidden() {
        let (m, h) = (4, 3);
        // Tiny random-ish params.
        let mk = |n: usize, s: f32| {
            (0..n)
                .map(|i| ((i * 37 % 11) as f32 / 11.0 - 0.5) * s)
                .collect::<Vec<f32>>()
        };
        let init = vec![
            mk(m * 4 * h, 0.6),
            mk(h * 4 * h, 0.6),
            vec![0.0; 4 * h],
            mk(h * 4 * h, 0.6),
            mk(h * 4 * h, 0.6),
            vec![0.0; 4 * h],
            mk(h * m, 0.6),
            vec![0.0; m],
            vec![0.0; h],
            vec![0.0; h],
            vec![0.0; h],
            vec![0.0; h],
            vec![0.0, 0.0],
        ];
        let mut job = LstmMirror::from_init(m, h, &init);
        for t in 0..50 {
            let x: Vec<f32> = (0..m).map(|j| ((t + j) as f32 * 0.3).sin()).collect();
            let out = job.step(&x);
            assert!(out.err.is_finite());
        }
        for v in &job.h1 {
            assert!(v.abs() <= 1.0 + 1e-5);
        }
        for v in &job.h2 {
            assert!(v.abs() <= 1.0 + 1e-5);
        }
    }
}
