//! Early stopping for single-limitation profiling runs (paper §II-C).
//!
//! While a limitation is profiled, per-sample runtimes stream in; the
//! monitor maintains Welford statistics and stops as soon as the two-sided
//! Student-t confidence interval `[a, b]` at the configured confidence
//! level satisfies `|b − a| < λ · mean` — "the size of the interval is used
//! as stopping criteria", which guarantees termination in finite time for
//! any concrete CPU limitation.

use crate::stats::{t_quantile, RunningStats};

/// Early-stopping configuration.
#[derive(Clone, Copy, Debug)]
pub struct EarlyStopConfig {
    /// Confidence level of the t-interval (paper: 0.95 or 0.995).
    pub confidence: f64,
    /// CI width threshold as a fraction λ of the empirical mean
    /// (paper example: 0.02 needs far more samples than 0.10).
    pub lambda: f64,
    /// Never stop before this many samples (CI needs ≥ 2; warmup noise).
    pub min_samples: u64,
}

impl Default for EarlyStopConfig {
    fn default() -> Self {
        Self { confidence: 0.95, lambda: 0.10, min_samples: 10 }
    }
}

impl EarlyStopConfig {
    pub fn new(confidence: f64, lambda: f64) -> Self {
        assert!((0.0..1.0).contains(&confidence) && confidence > 0.5);
        assert!(lambda > 0.0 && lambda < 1.0);
        Self { confidence, lambda, ..Default::default() }
    }
}

/// Streaming monitor for one profiling run.
#[derive(Clone, Debug)]
pub struct EarlyStopMonitor {
    cfg: EarlyStopConfig,
    stats: RunningStats,
    /// CI half-width history (diagnostics/Fig. 2).
    trace: Vec<(u64, f64, f64)>, // (n, mean, ci_width)
    keep_trace: bool,
    /// Cached t-quantile: `(df_at_cache, value)`. Recomputing the quantile
    /// (Newton on the incomplete beta) per pushed sample dominated the
    /// per-sample cost (~3.4µs); the quantile changes by < 1e-4 per unit
    /// df beyond ~30, so it is refreshed only when df grows by 2% (exact
    /// below df=30). See EXPERIMENTS.md §Perf.
    cached_t: Option<(f64, f64)>,
}

impl EarlyStopMonitor {
    pub fn new(cfg: EarlyStopConfig) -> Self {
        Self {
            cfg,
            stats: RunningStats::new(),
            trace: Vec::new(),
            keep_trace: false,
            cached_t: None,
        }
    }

    /// Two-sided t-quantile for the current df, cached per §Perf note.
    fn t_value(&mut self, df: f64) -> f64 {
        let p = 1.0 - (1.0 - self.cfg.confidence) / 2.0;
        match self.cached_t {
            Some((cached_df, v)) if df < 30.0 && cached_df == df => v,
            Some((cached_df, v)) if df >= 30.0 && df < cached_df * 1.02 => v,
            _ => {
                let v = t_quantile(p, df);
                self.cached_t = Some((df, v));
                v
            }
        }
    }

    /// Record the CI trajectory for Fig. 2 style plots.
    pub fn with_trace(mut self) -> Self {
        self.keep_trace = true;
        self
    }

    /// Feed one per-sample runtime; returns `true` when profiling of this
    /// limitation can stop.
    pub fn push(&mut self, runtime: f64) -> bool {
        self.stats.push(runtime);
        let n = self.stats.count();
        if n < 2 {
            return false;
        }
        let t = self.t_value((n - 1) as f64);
        let width = 2.0 * t * self.stats.std_dev() / (n as f64).sqrt();
        if self.keep_trace {
            self.trace.push((n, self.stats.mean(), width));
        }
        n >= self.cfg.min_samples && width < self.cfg.lambda * self.stats.mean()
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    pub fn samples(&self) -> u64 {
        self.stats.count()
    }

    pub fn stats(&self) -> &RunningStats {
        &self.stats
    }

    /// `(n, mean, ci_width)` per pushed sample (when tracing).
    pub fn trace(&self) -> &[(u64, f64, f64)] {
        &self.trace
    }

    pub fn config(&self) -> &EarlyStopConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn run_until_stop(cov: f64, cfg: EarlyStopConfig, seed: u64, cap: usize) -> (u64, f64) {
        let mut rng = Rng::new(seed);
        let mut mon = EarlyStopMonitor::new(cfg);
        for _ in 0..cap {
            let x = rng.lognormal_mean_cov(0.2, cov);
            if mon.push(x) {
                break;
            }
        }
        (mon.samples(), mon.mean())
    }

    #[test]
    fn stops_in_finite_time() {
        let (n, mean) = run_until_stop(0.15, EarlyStopConfig::default(), 1, 100_000);
        assert!(n < 100_000, "did not stop");
        assert!((mean - 0.2).abs() / 0.2 < 0.1, "mean {mean}");
    }

    #[test]
    fn tighter_lambda_needs_more_samples() {
        // Paper §II-C: λ=2% requires more samples than λ=10%.
        let n10 = run_until_stop(0.2, EarlyStopConfig::new(0.95, 0.10), 7, 1_000_000).0;
        let n02 = run_until_stop(0.2, EarlyStopConfig::new(0.95, 0.02), 7, 1_000_000).0;
        assert!(
            n02 > n10 * 5,
            "λ=2% should need far more samples: {n02} vs {n10}"
        );
    }

    #[test]
    fn higher_confidence_needs_more_samples() {
        let n95 = run_until_stop(0.2, EarlyStopConfig::new(0.95, 0.05), 3, 1_000_000).0;
        let n995 = run_until_stop(0.2, EarlyStopConfig::new(0.995, 0.05), 3, 1_000_000).0;
        assert!(n995 > n95, "{n995} vs {n95}");
    }

    #[test]
    fn noisier_signal_needs_more_samples() {
        let lo = run_until_stop(0.05, EarlyStopConfig::default(), 5, 1_000_000).0;
        let hi = run_until_stop(0.40, EarlyStopConfig::default(), 5, 1_000_000).0;
        assert!(hi > lo * 3, "{hi} vs {lo}");
    }

    #[test]
    fn constant_signal_stops_at_min_samples() {
        let mut mon = EarlyStopMonitor::new(EarlyStopConfig::default());
        let mut stopped_at = 0;
        for i in 1..100 {
            if mon.push(0.5) {
                stopped_at = i;
                break;
            }
        }
        assert_eq!(stopped_at as u64, EarlyStopConfig::default().min_samples);
    }

    #[test]
    fn trace_records_shrinking_ci() {
        let mut rng = Rng::new(9);
        let mut mon = EarlyStopMonitor::new(EarlyStopConfig::new(0.95, 0.02)).with_trace();
        for _ in 0..5000 {
            if mon.push(rng.lognormal_mean_cov(1.0, 0.2)) {
                break;
            }
        }
        let trace = mon.trace();
        assert!(trace.len() > 10);
        let early_w = trace[3].2;
        let late_w = trace[trace.len() - 1].2;
        assert!(late_w < early_w, "CI must shrink: {early_w} -> {late_w}");
    }
}
