//! Nested Modeling Strategy — the paper's contribution (§III-A.b).
//!
//! "Our proposed runtime model is directly used for — given a (synthetic)
//! target runtime — predicting the next CPU limitation to investigate. In
//! the NMS, learned model weights are reused for a warm-start of the model
//! training in the next iteration."
//!
//! The inversion `f⁻¹(target)` of the currently fitted nested model gives
//! the raw next limitation, which is snapped to the nearest unprofiled grid
//! point; `warm_start()` tells the profiler to seed each refit from the
//! previous step's parameters.

use super::{ProfilingContext, SelectionStrategy};

pub struct NestedModeling;

impl NestedModeling {
    pub fn new() -> Self {
        NestedModeling
    }
}

impl Default for NestedModeling {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionStrategy for NestedModeling {
    fn name(&self) -> &'static str {
        "NMS"
    }

    fn warm_start(&self) -> bool {
        true
    }

    fn next_limit(&mut self, ctx: &ProfilingContext) -> Option<f64> {
        if ctx.candidates().is_empty() {
            return None;
        }
        if let Some(raw) = ctx.model.invert(ctx.target) {
            if raw.is_finite() && raw > 0.0 {
                return ctx.nearest_candidate(raw);
            }
        }
        // Target unreachable under the current fit (e.g. asymptote above
        // the target): refine the exponential knee instead — probe just
        // above the smallest profiled limit.
        let knee = ctx
            .points
            .iter()
            .map(|p| p.limit)
            .fold(f64::INFINITY, f64::min);
        let fallback = if knee.is_finite() { knee + ctx.delta } else { ctx.l_min };
        ctx.nearest_candidate(fallback)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{ProfilePoint, RuntimeModel};

    fn rt(r: f64) -> f64 {
        0.05 * r.powf(-0.9) + 0.005
    }

    #[test]
    fn picks_model_inversion_of_target() {
        let mut c = ProfilingContext::new(0.1, 4.0, 0.1);
        // Fit on three points; target = runtime at 0.2.
        for r in [0.2, 2.0, 1.8] {
            c.points.push(ProfilePoint::new(r, rt(r)));
        }
        c.model = RuntimeModel::fit(&c.points);
        c.target = rt(0.2);
        let mut nms = NestedModeling::new();
        let q = nms.next_limit(&c).unwrap();
        // 0.2 itself is profiled; the inversion lands near it -> 0.1 or 0.3.
        assert!(q <= 0.4, "expected a knee probe, got {q}");
    }

    #[test]
    fn successive_points_cluster_near_target_like_fig4() {
        // Fig. 4: NMS's next points sit close to the synthetic target
        // around 0.2 CPU.
        let mut c = ProfilingContext::new(0.1, 4.0, 0.1);
        for r in [0.2, 1.0, 2.8] {
            c.points.push(ProfilePoint::new(r, rt(r)));
        }
        c.target = rt(0.2);
        let mut nms = NestedModeling::new();
        let mut picks = Vec::new();
        for _ in 0..3 {
            c.model = RuntimeModel::fit_warm(&c.points, Some(&c.model));
            let q = nms.next_limit(&c).unwrap();
            picks.push(q);
            c.points.push(ProfilePoint::new(q, rt(q)));
        }
        assert!(
            picks.iter().all(|&q| q <= 0.6),
            "NMS picks should cluster near the knee: {picks:?}"
        );
    }

    #[test]
    fn warm_start_enabled() {
        assert!(NestedModeling::new().warm_start());
    }

    #[test]
    fn fallback_when_target_unreachable() {
        let mut c = ProfilingContext::new(0.1, 4.0, 0.1);
        c.points.push(ProfilePoint::new(0.5, 1.0));
        c.points.push(ProfilePoint::new(1.0, 0.6));
        c.model = RuntimeModel { c: 0.5, ..RuntimeModel::identity() };
        c.target = 0.1; // below asymptote c=0.5 -> invert() is None
        let mut nms = NestedModeling::new();
        let q = nms.next_limit(&c).unwrap();
        assert!(q <= 0.7, "knee fallback expected, got {q}");
    }

    #[test]
    fn none_when_grid_exhausted() {
        let mut c = ProfilingContext::new(0.1, 0.2, 0.1);
        c.points.push(ProfilePoint::new(0.1, 1.0));
        c.points.push(ProfilePoint::new(0.2, 0.5));
        c.model = RuntimeModel::fit(&c.points);
        c.target = 0.7;
        assert!(NestedModeling::new().next_limit(&c).is_none());
    }
}
