//! Synthetic targets + initial parallel profiling placement (Algorithm 1).
//!
//! The limitation `l_p = max(0.2, l_max · p)` is profiled first; its
//! observed runtime becomes the *synthetic target* that steers all later
//! selections. The initial `n ∈ {2,3,4}` runs execute in parallel, so their
//! limitations must sum to at most `l_max` (Eq. 2).

/// Algorithm 1: the initial CPU limitations to profile in parallel.
///
/// Returns limits snapped to the `delta` grid, deduplicated, each ≥
/// `l_min`, and with `Σ ≤ l_max`. On machines too small for the requested
/// parallelism (the paper's 1-core n1 case) fewer than `n` limits are
/// returned.
pub fn initial_limits(p: f64, n: usize, l_min: f64, l_max: f64, delta: f64) -> Vec<f64> {
    assert!((2..=4).contains(&n), "paper evaluates n in {{2,3,4}}");
    let lp = (l_max * p).max(0.2);
    let lm = (l_min + l_max) / 2.0;
    let lq = (lp + l_max) / 4.0;
    let raw: Vec<f64> = match n {
        2 => vec![lp, l_max - lp],
        3 if l_max > 1.0 => vec![lp, lm, l_max - lm - lp],
        3 => vec![lp, lq, l_max / 2.0], // "comfort small CPUs"
        _ => {
            let lqm = (lp + lq) / 2.0;
            vec![lp, lq, lqm, l_max - lqm - lq - lp]
        }
    };
    sanitize(raw, l_min, l_max, delta)
}

/// Snap to grid, drop non-positive/duplicate entries, and enforce the
/// parallel-capacity constraint `Σ ≤ l_max` by dropping the largest
/// entries first (the small ones carry the synthetic-target information).
fn sanitize(raw: Vec<f64>, l_min: f64, l_max: f64, delta: f64) -> Vec<f64> {
    let snap = |r: f64| ((r / delta).round() * delta * 1e9).round() / 1e9;
    let mut out: Vec<f64> = Vec::new();
    for r in raw {
        let s = snap(r).clamp(0.0, l_max);
        if s >= l_min - 1e-9 && !out.iter().any(|&x| (x - s).abs() < delta / 2.0) {
            out.push(s);
        }
    }
    // Capacity: drop largest while the sum exceeds l_max.
    out.sort_by(|a, b| a.partial_cmp(b).unwrap());
    while out.len() > 1 && out.iter().sum::<f64>() > l_max + 1e-9 {
        out.pop();
    }
    out
}

/// The synthetic-target percentage sweep of the evaluation (§III-A.c):
/// p ∈ {2.5%, 5%, …, 15%}.
pub const TARGET_PERCENTAGES: [f64; 6] = [0.025, 0.05, 0.075, 0.10, 0.125, 0.15];

/// Initial-parallel-run counts of the evaluation.
pub const PARALLEL_RUNS: [usize; 3] = [2, 3, 4];

#[cfg(test)]
mod tests {
    use super::*;

    fn sum(v: &[f64]) -> f64 {
        v.iter().sum()
    }

    #[test]
    fn n2_on_pi4_matches_algorithm1() {
        // l_max=4, p=5% -> lp = max(0.2, 0.2) = 0.2; {0.2, 3.8}.
        let l = initial_limits(0.05, 2, 0.1, 4.0, 0.1);
        assert_eq!(l, vec![0.2, 3.8]);
        assert!(sum(&l) <= 4.0 + 1e-9);
    }

    #[test]
    fn n3_large_machine_uses_middle_value() {
        // l_max=8, p=2.5% -> lp=0.2, lm=4.05->4.0(snap), third=8-4.05-0.2=3.75->3.8
        let l = initial_limits(0.025, 3, 0.1, 8.0, 0.1);
        assert_eq!(l.len(), 3);
        assert!((l[0] - 0.2).abs() < 1e-9);
        assert!(sum(&l) <= 8.0 + 1e-9);
    }

    #[test]
    fn n3_small_machine_comforts_small_cpus() {
        // n1: l_max=1 -> {lp=0.2, lq=0.3, 0.5}, sum=1.0.
        let l = initial_limits(0.05, 3, 0.1, 1.0, 0.1);
        assert_eq!(l, vec![0.2, 0.3, 0.5]);
        assert!((sum(&l) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn n4_on_one_core_degrades_gracefully() {
        // Paper: four parallel runs are not possible on n1; we return fewer.
        let l = initial_limits(0.05, 4, 0.1, 1.0, 0.1);
        assert!(l.len() < 4, "{l:?}");
        assert!(sum(&l) <= 1.0 + 1e-9);
        assert!(l.contains(&0.2), "synthetic target survives: {l:?}");
    }

    #[test]
    fn n4_on_big_machine_has_four_unique() {
        let l = initial_limits(0.05, 4, 0.1, 16.0, 0.1);
        assert_eq!(l.len(), 4);
        assert!(sum(&l) <= 16.0 + 1e-9);
        for w in l.windows(2) {
            assert!(w[1] > w[0], "sorted unique: {l:?}");
        }
    }

    #[test]
    fn synthetic_target_floor_is_point_two() {
        // Paper §III-A.c: 0.1 is excluded to avoid prolonging profiling;
        // limits 2.5%..10% of 2 cores all floor at 0.2.
        for p in [0.025, 0.05, 0.075, 0.10] {
            let l = initial_limits(p, 2, 0.1, 2.0, 0.1);
            assert!((l[0] - 0.2).abs() < 1e-9, "p={p}: {l:?}");
        }
        // 12.5% and 15% of 2 cores -> 0.25/0.3 -> snap 0.3 (paper: "0.3 CPU
        // for two available cores").
        for p in [0.125, 0.15] {
            let l = initial_limits(p, 2, 0.1, 2.0, 0.1);
            assert!((l[0] - 0.3).abs() < 1e-9, "p={p}: {l:?}");
        }
    }

    #[test]
    fn e216_lowest_target_is_04() {
        // Paper: e216 best fitted with target at 2.5% of 16 cores = 0.4.
        let l = initial_limits(0.025, 3, 0.1, 16.0, 0.1);
        assert!((l[0] - 0.4).abs() < 1e-9, "{l:?}");
    }

    #[test]
    fn all_sweep_configs_satisfy_eq2() {
        use crate::simulator::NODES;
        for node in NODES {
            for &p in &TARGET_PERCENTAGES {
                for &n in &PARALLEL_RUNS {
                    let l = initial_limits(p, n, 0.1, node.cores, 0.1);
                    assert!(!l.is_empty(), "{} p={p} n={n}", node.name);
                    assert!(
                        sum(&l) <= node.cores + 1e-9,
                        "{} p={p} n={n}: {l:?}",
                        node.name
                    );
                    for &x in &l {
                        assert!(x >= 0.1 - 1e-9);
                    }
                }
            }
        }
    }
}
