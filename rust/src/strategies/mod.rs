//! Profiling-point selection strategies (paper §II-B, §III-A.b).
//!
//! All strategies operate on the limitation grid
//! `L = {l_min, l_min+δ, …, l_max}` and are driven by a **synthetic
//! target** runtime (the observed runtime of a deliberately small CPU
//! limitation), so the exponential knee of the curve is explored without a
//! user-specified runtime target.

mod bo;
mod bs;
mod nms;
mod random;
pub mod synthetic;

pub use bo::BayesianOpt;
pub use bs::BinarySearch;
pub use nms::NestedModeling;
pub use random::RandomSelect;
pub use synthetic::initial_limits;

use crate::fit::{ProfilePoint, RuntimeModel};

/// Index of the limitation-grid cell containing `r` (nearest multiple of
/// `delta`). This is the canonical quantization shared by grid snapping and
/// the fleet engine's measurement-cache keys, so a probe at 0.30000000004
/// and a cached measurement at 0.3 always land in the same bucket.
pub fn grid_bucket(r: f64, delta: f64) -> i64 {
    debug_assert!(delta > 0.0);
    (r / delta).round() as i64
}

/// Everything a strategy may look at when choosing the next limitation.
pub struct ProfilingContext {
    pub l_min: f64,
    pub l_max: f64,
    pub delta: f64,
    /// Synthetic target runtime (seconds per sample).
    pub target: f64,
    /// Points profiled so far, in profiling order.
    pub points: Vec<ProfilePoint>,
    /// Model fitted to `points` (nested family).
    pub model: RuntimeModel,
}

impl ProfilingContext {
    pub fn new(l_min: f64, l_max: f64, delta: f64) -> Self {
        Self {
            l_min,
            l_max,
            delta,
            target: f64::NAN,
            points: Vec::new(),
            model: RuntimeModel::identity(),
        }
    }

    /// Snap a raw limitation onto the grid, clamped to `[l_min, l_max]`.
    pub fn snap(&self, r: f64) -> f64 {
        let q = grid_bucket(r, self.delta) as f64 * self.delta;
        q.clamp(self.l_min, self.l_max)
    }

    /// Whether a grid point was already profiled (within grid tolerance).
    pub fn profiled(&self, r: f64) -> bool {
        self.points.iter().any(|p| (p.limit - r).abs() < self.delta / 2.0)
    }

    /// All unprofiled grid points, ascending.
    pub fn candidates(&self) -> Vec<f64> {
        let n = ((self.l_max - self.l_min) / self.delta).round() as usize;
        (0..=n)
            .map(|i| self.snap(self.l_min + i as f64 * self.delta))
            .filter(|&r| !self.profiled(r))
            .collect()
    }

    /// Nearest unprofiled grid point to `r` (ties -> smaller limit).
    pub fn nearest_candidate(&self, r: f64) -> Option<f64> {
        self.candidates()
            .into_iter()
            .min_by(|a, b| {
                let da = (a - r).abs();
                let db = (b - r).abs();
                da.partial_cmp(&db).unwrap().then(a.partial_cmp(b).unwrap())
            })
    }
}

/// A profiling-point selection strategy.
pub trait SelectionStrategy {
    /// Display name used in figures/CSV.
    fn name(&self) -> &'static str;
    /// Choose the next CPU limitation to profile; `None` when exhausted.
    fn next_limit(&mut self, ctx: &ProfilingContext) -> Option<f64>;
    /// Whether the profiler should warm-start model fits from the previous
    /// step's parameters (the NMS reuse, §III-B.3).
    fn warm_start(&self) -> bool {
        false
    }
}

/// Construct a strategy by name (CLI/bench plumbing).
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn SelectionStrategy>> {
    match name.to_ascii_lowercase().as_str() {
        "bs" | "binary" | "binarysearch" => Some(Box::new(BinarySearch::new())),
        "bo" | "bayesian" => Some(Box::new(BayesianOpt::new())),
        "nms" | "nested" => Some(Box::new(NestedModeling::new())),
        "random" => Some(Box::new(RandomSelect::new(seed))),
        _ => None,
    }
}

/// The four strategies of the final evaluation (Fig. 7).
pub const STRATEGY_NAMES: [&str; 4] = ["NMS", "BS", "BO", "Random"];

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ProfilingContext {
        ProfilingContext::new(0.1, 4.0, 0.1)
    }

    #[test]
    fn grid_bucket_absorbs_float_drift() {
        // 0.1 * 3 accumulates drift; the bucket index must not.
        let drifted = 0.1 + 0.1 + 0.1;
        assert_eq!(grid_bucket(drifted, 0.1), 3);
        assert_eq!(grid_bucket(0.3, 0.1), 3);
        assert_eq!(grid_bucket(0.24, 0.1), 2);
        assert_eq!(grid_bucket(16.0, 0.1), 160);
    }

    #[test]
    fn snap_quantizes_to_grid() {
        let c = ctx();
        assert!((c.snap(0.234) - 0.2).abs() < 1e-9);
        assert!((c.snap(3.99) - 4.0).abs() < 1e-9);
        assert!((c.snap(0.0) - 0.1).abs() < 1e-9); // clamped to l_min
        assert!((c.snap(9.0) - 4.0).abs() < 1e-9); // clamped to l_max
    }

    #[test]
    fn candidates_exclude_profiled() {
        let mut c = ctx();
        assert_eq!(c.candidates().len(), 40);
        c.points.push(ProfilePoint::new(0.2, 1.0));
        c.points.push(ProfilePoint::new(2.0, 0.1));
        let cands = c.candidates();
        assert_eq!(cands.len(), 38);
        assert!(!cands.iter().any(|&r| (r - 0.2).abs() < 1e-9));
        assert!(!cands.iter().any(|&r| (r - 2.0).abs() < 1e-9));
    }

    #[test]
    fn nearest_candidate_skips_profiled() {
        let mut c = ctx();
        c.points.push(ProfilePoint::new(0.5, 1.0));
        let got = c.nearest_candidate(0.5).unwrap();
        assert!((got - 0.4).abs() < 1e-9, "tie -> smaller, got {got}");
    }

    #[test]
    fn by_name_builds_all() {
        for n in ["bs", "bo", "nms", "random"] {
            assert!(by_name(n, 1).is_some(), "{n}");
        }
        assert!(by_name("hillclimb", 1).is_none());
    }
}
