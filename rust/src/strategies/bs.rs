//! Binary Search selection (paper §III-A.b).
//!
//! "It recursively compares a target value to the middle element of a
//! sorted value list, and continues searching in either its first or second
//! half." The sorted list is the limitation grid; the compared value is the
//! runtime observed at the probed limitation vs. the synthetic target.
//! Runtime decreases with the limit, so: observed runtime above the target
//! → the limit was too small → search the upper half, and vice versa.

use super::{ProfilingContext, SelectionStrategy};

pub struct BinarySearch {
    /// Current bracket over the grid (inclusive indices), established on
    /// the first call from the full grid.
    bracket: Option<(usize, usize)>,
    /// The limit we asked for last, to locate its observation.
    last_query: Option<f64>,
}

impl BinarySearch {
    pub fn new() -> Self {
        Self { bracket: None, last_query: None }
    }

    fn grid(ctx: &ProfilingContext) -> Vec<f64> {
        let n = ((ctx.l_max - ctx.l_min) / ctx.delta).round() as usize;
        (0..=n).map(|i| ctx.snap(ctx.l_min + i as f64 * ctx.delta)).collect()
    }
}

impl Default for BinarySearch {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionStrategy for BinarySearch {
    fn name(&self) -> &'static str {
        "BS"
    }

    fn next_limit(&mut self, ctx: &ProfilingContext) -> Option<f64> {
        let grid = Self::grid(ctx);
        let (mut lo, mut hi) = self.bracket.unwrap_or((0, grid.len() - 1));
        // Consume the observation of our previous query.
        if let Some(q) = self.last_query.take() {
            if let Some(obs) = ctx
                .points
                .iter()
                .rev()
                .find(|p| (p.limit - q).abs() < ctx.delta / 2.0)
            {
                let mid = grid.iter().position(|&g| (g - q).abs() < ctx.delta / 2.0);
                if let Some(mid) = mid {
                    if obs.runtime > ctx.target {
                        // Too slow -> need more CPU -> upper half.
                        lo = (mid + 1).min(hi);
                    } else {
                        // Fast enough -> tighten -> lower half.
                        hi = mid.saturating_sub(1).max(lo);
                    }
                }
            }
        }
        // Probe the middle of the bracket. The paper's BS is deliberately
        // "comparably naive": when the exact midpoint was already profiled
        // (e.g. by the initial parallel runs) it probes the *nearest*
        // unprofiled grid point inside the bracket — it does not skip ahead.
        if lo > hi {
            return ctx.candidates().into_iter().next();
        }
        let mid = (lo + hi) / 2;
        let cand = grid[mid];
        let probe = if ctx.profiled(cand) {
            let in_bracket: Vec<f64> = grid[lo..=hi]
                .iter()
                .copied()
                .filter(|&g| !ctx.profiled(g))
                .collect();
            in_bracket
                .into_iter()
                .min_by(|a, b| {
                    let da = (a - cand).abs();
                    let db = (b - cand).abs();
                    da.partial_cmp(&db).unwrap().then(a.partial_cmp(b).unwrap())
                })
                .or_else(|| ctx.nearest_candidate(cand))
        } else {
            Some(cand)
        };
        self.bracket = Some((lo, hi));
        self.last_query = probe;
        probe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{ProfilePoint, RuntimeModel};

    fn ctx_with_target(target: f64) -> ProfilingContext {
        let mut c = ProfilingContext::new(0.1, 4.0, 0.1);
        c.target = target;
        c.model = RuntimeModel::identity();
        c
    }

    /// Ground truth runtime used in the tests: t(R) = 0.04/R.
    fn rt(r: f64) -> f64 {
        0.04 / r
    }

    #[test]
    fn converges_to_target_neighbourhood() {
        // Target = runtime at 0.2 CPU -> BS should walk toward ~0.2.
        let target = rt(0.2);
        let mut c = ctx_with_target(target);
        let mut bs = BinarySearch::new();
        let mut queried = Vec::new();
        for _ in 0..6 {
            let q = bs.next_limit(&c).unwrap();
            queried.push(q);
            c.points.push(ProfilePoint::new(q, rt(q)));
        }
        let last = *queried.last().unwrap();
        assert!(last <= 0.5, "should approach the small-limit region: {queried:?}");
        // Strictly halving: first query is the grid middle (~2.0).
        assert!((queried[0] - 2.0).abs() < 0.11, "{queried:?}");
    }

    #[test]
    fn never_repeats_a_point() {
        let mut c = ctx_with_target(rt(1.0));
        let mut bs = BinarySearch::new();
        let mut seen = Vec::new();
        for _ in 0..12 {
            if let Some(q) = bs.next_limit(&c) {
                assert!(
                    !seen.iter().any(|&s: &f64| (s - q).abs() < 0.05),
                    "repeat {q} in {seen:?}"
                );
                seen.push(q);
                c.points.push(ProfilePoint::new(q, rt(q)));
            }
        }
    }

    #[test]
    fn moves_up_when_too_slow() {
        let mut c = ctx_with_target(rt(3.0)); // generous target
        let mut bs = BinarySearch::new();
        let q1 = bs.next_limit(&c).unwrap();
        // Observe something much slower than the target.
        c.points.push(ProfilePoint::new(q1, rt(q1)));
        let q2 = bs.next_limit(&c).unwrap();
        // rt(q1 ~2.0) = 0.02 > target(=0.0133)? rt(2.0)=0.02, target=0.0133:
        // too slow -> move up.
        assert!(q2 > q1, "{q1} -> {q2}");
    }
}
