//! Random selection — the control strategy of the final evaluation
//! (§III-B.5): "randomly chooses profiling points after the initial
//! parallel ones".

use super::{ProfilingContext, SelectionStrategy};
use crate::util::Rng;

pub struct RandomSelect {
    rng: Rng,
}

impl RandomSelect {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }
}

impl SelectionStrategy for RandomSelect {
    fn name(&self) -> &'static str {
        "Random"
    }

    fn next_limit(&mut self, ctx: &ProfilingContext) -> Option<f64> {
        let cands = ctx.candidates();
        if cands.is_empty() {
            None
        } else {
            Some(*self.rng.choose(&cands))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::ProfilePoint;

    #[test]
    fn picks_unprofiled_grid_points() {
        let mut c = ProfilingContext::new(0.1, 1.0, 0.1);
        c.points.push(ProfilePoint::new(0.5, 1.0));
        let mut r = RandomSelect::new(42);
        for _ in 0..50 {
            let q = r.next_limit(&c).unwrap();
            assert!((q - 0.5).abs() > 0.05, "picked profiled point");
            assert!((0.1..=1.0).contains(&q));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let c = ProfilingContext::new(0.1, 4.0, 0.1);
        let mut a = RandomSelect::new(7);
        let mut b = RandomSelect::new(7);
        for _ in 0..10 {
            assert_eq!(a.next_limit(&c), b.next_limit(&c));
        }
    }

    #[test]
    fn exhausts_to_none() {
        let mut c = ProfilingContext::new(0.1, 0.1, 0.1);
        c.points.push(ProfilePoint::new(0.1, 1.0));
        assert!(RandomSelect::new(1).next_limit(&c).is_none());
    }
}
