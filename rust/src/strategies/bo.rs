//! Bayesian Optimization selection (paper §III-A.b).
//!
//! GP prior with a Matérn-5/2 kernel, Expected Improvement acquisition.
//! Observations are transformed as the paper describes: runtimes are
//! normalized by the synthetic target, and *negated on target violation*
//! (runtime above target), "so BO better understands pre-defined
//! constraints". The resulting reward
//!
//! ```text
//! g(R) = rt(R)/target        if rt(R) ≤ target   (feasible: higher = tighter fit)
//!       −rt(R)/target        otherwise            (violation: strongly repelled)
//! ```
//!
//! is maximized; its optimum sits at the tightest limitation that still
//! meets the target — exactly the knee the profiler wants to map.

use super::{ProfilingContext, SelectionStrategy};
use crate::gp::{Gp, Matern52};

pub struct BayesianOpt {
    kernel: Matern52,
    noise: f64,
}

impl BayesianOpt {
    pub fn new() -> Self {
        // Observation noise reflects that rewards derive from noisy
        // empirical runtime means (the paper's normalized observations).
        Self { kernel: Matern52 { variance: 1.0, length_scale: 0.2 }, noise: 1e-2 }
    }

    fn reward(runtime: f64, target: f64) -> f64 {
        let norm = runtime / target;
        if runtime <= target {
            norm
        } else {
            -norm
        }
    }
}

impl Default for BayesianOpt {
    fn default() -> Self {
        Self::new()
    }
}

impl SelectionStrategy for BayesianOpt {
    fn name(&self) -> &'static str {
        "BO"
    }

    fn next_limit(&mut self, ctx: &ProfilingContext) -> Option<f64> {
        let cands = ctx.candidates();
        if cands.is_empty() {
            return None;
        }
        if ctx.points.is_empty() || !ctx.target.is_finite() {
            // No prior belief yet: probe the grid middle.
            return ctx.nearest_candidate((ctx.l_min + ctx.l_max) / 2.0);
        }
        let obs: Vec<(f64, f64)> = ctx
            .points
            .iter()
            .map(|p| (p.limit, Self::reward(p.runtime, ctx.target)))
            .collect();
        let best = obs.iter().map(|(_, y)| *y).fold(f64::NEG_INFINITY, f64::max);
        let mut gp = Gp::new(self.kernel, self.noise, ctx.l_min, ctx.l_max);
        gp.fit(&obs);
        gp.argmax_ei(&cands, best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{ProfilePoint, RuntimeModel};

    fn rt(r: f64) -> f64 {
        0.05 * r.powf(-0.9) + 0.005
    }

    fn ctx(target_limit: f64) -> ProfilingContext {
        let mut c = ProfilingContext::new(0.1, 4.0, 0.1);
        c.target = rt(target_limit);
        c.model = RuntimeModel::identity();
        c
    }

    #[test]
    fn reward_shape_matches_paper_transform() {
        let t = 1.0;
        assert!(BayesianOpt::reward(0.9, t) > BayesianOpt::reward(0.5, t));
        assert!(BayesianOpt::reward(1.1, t) < 0.0);
        assert!(BayesianOpt::reward(0.99, t) > BayesianOpt::reward(1.01, t));
    }

    #[test]
    fn first_probe_without_data_is_midpoint() {
        let c = ctx(0.2);
        let mut bo = BayesianOpt::new();
        let q = bo.next_limit(&c).unwrap();
        assert!((q - 2.0).abs() < 0.11, "got {q}");
    }

    #[test]
    fn homes_in_on_feasible_knee() {
        // Target at 0.3 CPU; seed with the Alg-1-style initial points.
        let mut c = ctx(0.3);
        for r in [0.2, 2.0, 1.8] {
            c.points.push(ProfilePoint::new(r, rt(r)));
        }
        let mut bo = BayesianOpt::new();
        let mut last = f64::NAN;
        for _ in 0..6 {
            if let Some(q) = bo.next_limit(&c) {
                c.points.push(ProfilePoint::new(q, rt(q)));
                last = q;
            }
        }
        // Should concentrate probes near/below 1.0, not at the flat top.
        assert!(last <= 1.6, "last probe {last}, points {:?}", c.points);
    }

    #[test]
    fn exhausts_gracefully() {
        let mut c = ProfilingContext::new(0.1, 0.3, 0.1);
        c.target = 1.0;
        for r in [0.1, 0.2, 0.3] {
            c.points.push(ProfilePoint::new(r, 1.0 / r));
        }
        let mut bo = BayesianOpt::new();
        assert!(bo.next_limit(&c).is_none());
    }
}
